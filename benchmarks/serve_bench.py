"""Overload-safe serving load harness (ISSUE 6 tentpole).

Two-phase load generator against the robustness-wrapped ``QueryServer``:

* **closed loop** — keeps the lane pools saturated (queue topped up to
  2x lanes, unbounded) to measure service capacity: queries/s and the
  per-tick completion rate that calibrates the open-loop arrival rates;
* **open loop** — Poisson arrivals at 1x / 2x / 4x the measured
  capacity against a bounded queue under the 'reject' and 'shed'
  overload policies, with a mixed BFS/SSSP/PPR workload over a zipfian
  root distribution (cache-friendly repeats), per-request deadlines on a
  slice of the traffic, round budgets on another, and two weighted
  tenants.  Reports p50/p99 latency, queries/s, shed rate, deadline /
  timeout / budget counts, cache hit rate, and the maximum queue depth
  (bounded by construction — the acceptance criterion).
* **faults** — a fault-injected leg (induced lane failure + delayed
  tick) proving failure paths resolve as typed statuses mid-load.

Every leg asserts the zero-uncaught-exception criterion: each submitted
request resolves to exactly one typed terminal status, i.e.
``counters['submitted'] == sum(terminal counters)`` — the consistency
check the CI smoke leg pins at 2x overload.

Usage:  PYTHONPATH=src python benchmarks/serve_bench.py [--out PATH]
        [--smoke]      # CI: tiny graph, pinned seed, 2x overload only
"""
from __future__ import annotations

import argparse
import json
import time

import common  # pins JAX_PLATFORMS=cpu before jax loads; --seed helper
import numpy as np

from repro.apps.pagerank import _pr_graph
from repro.core import engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators
from repro.query import FaultPlan, QueryServer, QueryStatus, ServeConfig

TERMINAL = sorted(QueryStatus.TERMINAL)


def build_part(log2_nodes: int, seed: int):
    g = generators.rmat(log2_nodes, edge_factor=6,
                        seed=seed).with_random_weights(seed=seed)
    num_shards = 4 if log2_nodes <= 10 else 8
    part = build_partition(_pr_graph(g),
                           PartitionConfig(num_shards=num_shards,
                                           rpvo_max=4))
    return g, part


class Workload:
    """Deterministic mixed-kind request stream: zipfian roots over the
    high-degree vertices (repeats -> cache hits), 60/20/20
    bfs/sssp/ppr, deadlines on a quarter of the traffic, round budgets
    on a tenth, two tenants at 2:1 weight."""

    def __init__(self, g, seed: int, deadline_s: float, n_roots: int = 64):
        self.rng = np.random.default_rng(seed)
        deg = np.argsort(-g.out_degrees())
        self.roots = deg[:n_roots].astype(int)
        self.deadline_s = deadline_s

    def next(self):
        r = self.rng
        root = int(self.roots[min(r.geometric(0.25) - 1,
                                  len(self.roots) - 1)])
        u = r.random()
        kind = "bfs" if u < 0.6 else ("sssp" if u < 0.8 else "ppr")
        kw = dict(tenant="gold" if r.random() < 0.33 else "free",
                  priority=2 if r.random() < 0.15 else 0)
        if r.random() < 0.25:
            kw["deadline_s"] = self.deadline_s
        if r.random() < 0.10:
            kw["max_rounds"] = 4
        return kind, root, kw


def submit_safe(srv, kind, root, kw, errors):
    """The zero-uncaught-exception harness: any exception escaping a
    policed submit is an acceptance failure, recorded not raised."""
    try:
        srv.submit(kind, root, **kw)
    except Exception as e:          # noqa: BLE001 — the bench's whole point
        errors.append(f"{kind}@{root}: {type(e).__name__}: {e}")


def consistency(srv) -> dict:
    """Each submitted request resolved to exactly one terminal status."""
    terminal_total = sum(srv.counters[s] for s in TERMINAL)
    return {
        "submitted": srv.counters["submitted"],
        "terminal_total": terminal_total,
        "results": len(srv.results),
        "consistent": (srv.counters["submitted"] == terminal_total
                       == len(srv.results)),
    }


def summarize(srv, wall_s: float, max_qlen: int) -> dict:
    res = srv.results.values()
    lat_ok = np.array([r.latency_s for r in res
                       if r.status == QueryStatus.OK]) * 1e3
    c = srv.counters
    submitted = c["submitted"]
    dropped = c[QueryStatus.REJECTED] + c[QueryStatus.SHED]
    cache_lookups = c["cache_hits"] + c["cache_misses"]
    out = {
        "submitted": submitted,
        "completed_ok": int(c[QueryStatus.OK]),
        "qps": c[QueryStatus.OK] / max(wall_s, 1e-9),
        "p50_ms": float(np.percentile(lat_ok, 50)) if len(lat_ok) else None,
        "p99_ms": float(np.percentile(lat_ok, 99)) if len(lat_ok) else None,
        "shed_rate": dropped / max(submitted, 1),
        "cache_hit_rate": (c["cache_hits"] / cache_lookups
                           if cache_lookups else 0.0),
        "max_queue_len": max_qlen,
        "ticks": srv.tick,
        "preemptions": int(c["preemptions"]),
        "statuses": {s: int(c[s]) for s in TERMINAL if c[s]},
        "consistency": consistency(srv),
    }
    return out


def closed_loop(part, wl: Workload, lanes: int, n_queries: int) -> dict:
    """Saturation throughput: the queue is topped up to 2x lanes every
    tick, so the pools never starve — service capacity, not latency."""
    srv = QueryServer(part, n_lanes=lanes, ppr_lanes=max(lanes // 2, 1))
    errors: list[str] = []
    submitted = 0
    t0 = time.perf_counter()
    while srv.counters[QueryStatus.OK] < n_queries:
        while submitted < n_queries and len(srv.queue) < 2 * lanes:
            kind, root, kw = wl.next()
            kw.pop("deadline_s", None)    # capacity probe: no drops
            kw.pop("max_rounds", None)
            submit_safe(srv, kind, root, kw, errors)
            submitted += 1
        srv.step()
    wall = time.perf_counter() - t0
    assert not errors, errors
    return {
        "completed": int(srv.counters[QueryStatus.OK]),
        "ticks": srv.tick,
        "qps": srv.counters[QueryStatus.OK] / wall,
        "service_per_tick": srv.counters[QueryStatus.OK] / max(srv.tick, 1),
        "occupancy": srv.occupancy(),
        "consistency": consistency(srv),
    }


def open_loop(part, wl: Workload, lanes: int, policy: str, overload: float,
              service_per_tick: float, n_ticks: int,
              faults: FaultPlan | None = None) -> dict:
    """Poisson arrivals at ``overload`` x measured capacity against a
    bounded queue; after the arrival window the server drains."""
    serve = ServeConfig(max_queue=2 * lanes, overload_policy=policy,
                        cache_size=64, cache_ttl_s=None, faults=faults)
    srv = QueryServer(part, n_lanes=lanes, ppr_lanes=max(lanes // 2, 1),
                      serve=serve)
    lam = overload * service_per_tick
    errors: list[str] = []
    max_qlen = 0
    t0 = time.perf_counter()
    for _ in range(n_ticks):
        for _ in range(int(wl.rng.poisson(lam))):
            kind, root, kw = wl.next()
            submit_safe(srv, kind, root, kw, errors)
        max_qlen = max(max_qlen, len(srv.queue))
        srv.step()
    srv.run()                                  # drain the tail
    wall = time.perf_counter() - t0
    out = summarize(srv, wall, max_qlen)
    out["errors"] = errors
    out["bounded"] = max_qlen <= serve.max_queue
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--nodes", type=int, default=10,
                    help="log2 graph vertices (default 10)")
    ap.add_argument("--lanes", type=int, default=6)
    ap.add_argument("--closed-queries", type=int, default=48)
    ap.add_argument("--ticks", type=int, default=160,
                    help="open-loop arrival window, in server ticks")
    ap.add_argument("--deadline-ms", type=float, default=400.0)
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI leg: tiny graph, 2x overload only, hard "
                         "consistency assertions")
    common.add_seed_arg(ap)
    common.add_obs_out_arg(ap)
    args = ap.parse_args()
    if args.smoke:
        args.nodes, args.lanes = min(args.nodes, 9), 4
        args.closed_queries, args.ticks = 24, 60

    g, part = build_part(args.nodes, args.seed)
    report = {
        "bench": "serve", "seed": args.seed, "lanes": args.lanes,
        "graph": {"n": int(g.n), "m": int(len(g.src))},
        "partition": {"S": part.S, "R_max": part.R_max},
        "smoke": bool(args.smoke),
    }

    wl = Workload(g, args.seed, args.deadline_ms / 1e3)
    print(f"closed loop: {args.closed_queries} queries, "
          f"{args.lanes} lanes ...")
    closed = closed_loop(part, wl, args.lanes, args.closed_queries)
    assert closed["consistency"]["consistent"], closed["consistency"]
    report["closed_loop"] = closed
    spt = closed["service_per_tick"]
    print(f"  capacity {closed['qps']:.1f} q/s, "
          f"{spt:.3f} completions/tick")

    overloads = [2.0] if args.smoke else [1.0, 2.0, 4.0]
    report["open_loop"] = {}
    for policy in ("reject", "shed"):
        report["open_loop"][policy] = {}
        for ov in overloads:
            leg = open_loop(part, wl, args.lanes, policy, ov, spt,
                            args.ticks)
            key = f"{ov:g}x"
            report["open_loop"][policy][key] = leg
            assert not leg["errors"], leg["errors"]
            assert leg["consistency"]["consistent"], leg["consistency"]
            assert leg["bounded"], "queue exceeded its bound"
            print(f"  {policy:>6} {key}: p50={leg['p50_ms']:.0f}ms "
                  f"p99={leg['p99_ms']:.0f}ms shed={leg['shed_rate']:.2f} "
                  f"cache={leg['cache_hit_rate']:.2f} "
                  f"qlen<={leg['max_queue_len']}")

    # overload must actually shed under a bounded queue (acceptance:
    # nonzero shed rate at 4x; the smoke leg pins consistency at 2x)
    if not args.smoke:
        top = f"{overloads[-1]:g}x"
        for policy in ("reject", "shed"):
            assert report["open_loop"][policy][top]["shed_rate"] > 0, \
                f"no shedding at {top} under {policy!r}"

    # fault-injection leg: induced lane failure + delayed tick mid-load
    plan = FaultPlan(lane_failures=((3, "min", 0), (5, "ppr", 0)),
                     tick_delays=((4, args.deadline_ms / 1e3),))
    fault_leg = open_loop(part, wl, args.lanes, "reject", 2.0, spt,
                          max(args.ticks // 2, 30), faults=plan)
    assert not fault_leg["errors"], fault_leg["errors"]
    assert fault_leg["consistency"]["consistent"], fault_leg["consistency"]
    report["faults"] = fault_leg
    print(f"  faults: statuses={fault_leg['statuses']}")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    common.finish_report(report, obs_out=args.obs_out)


if __name__ == "__main__":
    main()
