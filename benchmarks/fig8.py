"""Paper Fig 8: BFS speedup vs rpvo_max (1..16) on skewed graphs at two
chip sizes — speedup measured as cost-model cycles relative to rpvo_max=1."""
import numpy as np

from benchmarks.common import emit, timed
from repro.core.costmodel import CostModel
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference


def main():
    g = generators.ba_skewed(1 << 14, m_per=8, seed=3)  # WK-like in-skew
    # PageRank-style rounds: every vertex diffuses each round, so the
    # 15k-in-degree hub's inbox is under real load (paper Fig 8 uses BFS on
    # WK/R22 whose hubs are high in BOTH degrees; BA at this scale needs PR)
    trace = [np.arange(g.n, dtype=np.int64)] * 5
    for shards in (4096, 16384):
        base = None
        for rmax in (1, 2, 4, 8, 16):
            part = build_partition(g, PartitionConfig(
                num_shards=shards, rpvo_max=rmax,
                local_edge_list_size=16, seed=6))
            res, us = timed(CostModel(part, torus=True).replay, trace)
            if base is None:
                base = res.cycles
            emit(f"fig8/cc{shards}/rpvo{rmax}", us,
                 f"cycles={res.cycles:.0f};speedup={base / res.cycles:.2f}")


if __name__ == "__main__":
    main()
