"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun/*.json.  ``python -m benchmarks.report [--tag default]``
prints markdown.
"""
import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

ARCH_ORDER = ["paligemma-3b", "whisper-medium", "granite-moe-1b-a400m",
              "deepseek-moe-16b", "command-r-35b", "minitron-4b",
              "qwen3-32b", "phi3-medium-14b", "xlstm-125m", "jamba-v0.1-52b",
              "graph-bfs-rhizome", "graph-bfs-rpvo", "graph-bfs-simple"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k", "rmat22"]


def load(tag: str):
    recs = {}
    for path in glob.glob(os.path.join(RESULTS, f"*__{tag}.json")):
        with open(path) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["multi_pod"])] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b/2**30:.2f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="default")
    args = ap.parse_args()
    recs = load(args.tag)

    print("### Dry-run (per-device memory & compile status)\n")
    print("| arch | shape | mesh | status | args GiB/dev | temps GiB/dev |"
          " compile s |")
    print("|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            for mp in (False, True):
                r = recs.get((arch, shape, mp))
                if r is None:
                    continue
                mesh = "2x16x16" if mp else "16x16"
                if "skipped" in r:
                    print(f"| {arch} | {shape} | {mesh} | SKIP² | - | - | - |")
                    continue
                if not r.get("ok"):
                    print(f"| {arch} | {shape} | {mesh} | FAIL | - | - | - |")
                    continue
                m = r["memory"]
                print(f"| {arch} | {shape} | {mesh} | ok "
                      f"| {fmt_bytes(m['argument_size_bytes'])} "
                      f"| {fmt_bytes(m['temp_size_bytes'])} "
                      f"| {r.get('compile_s', 0):.0f} |")

    print("\n### Roofline (single-pod 16x16, per-device program)\n")
    print("| arch | shape | compute s | memory s | collective s | dominant |"
          " useful ratio¹ | compute fraction |")
    print("|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, False))
            if r is None or "skipped" in r or not r.get("ok"):
                continue
            t = r["roofline"]
            tot = t["compute_s"] + t["memory_s"] + t["collective_s"]
            frac = t["compute_s"] / max(tot, 1e-30)
            u = r.get("useful_compute_ratio")
            dyn = (" (per-round)" if r["per_device"].get("has_dynamic_loops")
                   else "")
            print(f"| {arch} | {shape}{dyn} | {t['compute_s']:.3e} "
                  f"| {t['memory_s']:.3e} | {t['collective_s']:.3e} "
                  f"| {t['dominant'].replace('_s','')} "
                  f"| {f'{u:.2f}' if u else '-'} | {frac:.3f} |")


if __name__ == "__main__":
    main()
