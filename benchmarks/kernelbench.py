"""Pallas kernel micro-benchmark: rhizome_segment_reduce vs the jnp oracle
(interpret mode on CPU — correctness + relative cost only; Mosaic timings
need a real TPU)."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels.ref import segment_combine_ref
from repro.kernels.rhizome_segment_reduce import segment_combine_pallas


def main():
    rng = np.random.default_rng(0)
    for e, nseg in ((4096, 1024), (16384, 4096)):
        data = jnp.asarray(rng.uniform(-1, 1, e).astype(np.float32))
        ids = jnp.asarray(np.sort(rng.integers(0, nseg, e)).astype(np.int32))
        for kind in ("min", "sum"):
            ref = jax.jit(lambda d, i: segment_combine_ref(d, i, nseg, kind))
            _ = ref(data, ids).block_until_ready()
            _, us_ref = timed(lambda: ref(data, ids).block_until_ready(),
                              repeats=5)
            out = segment_combine_pallas(data, ids, nseg, kind,
                                         interpret=True)
            np.testing.assert_allclose(np.asarray(out),
                                       np.asarray(ref(data, ids)),
                                       rtol=5e-5, atol=1e-6)
            emit(f"kernel/{kind}/E{e}", us_ref,
                 f"oracle_us={us_ref:.0f};pallas=validated-interpret")


if __name__ == "__main__":
    main()
