"""Shared benchmark fixtures: laptop-scale analogs of the paper's datasets.

Scaled so every figure reproduces its paper counterpart's *shape* in
seconds, not hours: RMAT keeps (a=0.45,b=0.25,c=0.15); BA supplies the
WK/LJ-style heavy in-degree tail; ER is the low-skew control.

Importing this module (before jax) pins JAX_PLATFORMS=cpu when no
accelerator chips are visible: with libtpu installed but no TPU attached,
backend autodetect stalls ~5 min on unreachable TPU metadata (the PR 1
subprocess-test fix, applied to the benchmark entrypoints).  A visible
TPU (/dev/accel*) or GPU (/dev/nvidia*) leaves the choice to jax.
"""
from __future__ import annotations

import glob
import os
import time

if "JAX_PLATFORMS" not in os.environ \
        and not glob.glob("/dev/accel*") and not glob.glob("/dev/nvidia*"):
    os.environ["JAX_PLATFORMS"] = "cpu"

from repro.graph import generators

DATASETS = {
    "E14": lambda: generators.erdos_renyi(1 << 14, avg_degree=9.0, seed=1),
    "R14": lambda: generators.rmat(14, edge_factor=16, seed=2),
    "BA14": lambda: generators.ba_skewed(1 << 14, m_per=8, seed=3),
    "AM-like": lambda: generators.rmat(14, edge_factor=5, a=0.30, b=0.25,
                                       c=0.25, seed=4),
}


def add_seed_arg(ap, default: int = 7):
    """Grow a bench arg parser a ``--seed`` flag: the base RNG seed for
    graph generation (and anything else stochastic), threaded through the
    engine/query benches so BENCH_*.json runs are reproducible
    run-to-run and recorded in the emitted report."""
    ap.add_argument("--seed", type=int, default=default,
                    help="base RNG seed for graph generation "
                         f"(default {default}; recorded in the report)")
    return ap


def add_grid_mode_arg(ap, default: str = "worklist"):
    """Grow a bench arg parser a ``--grid-mode`` flag: the fused kernel's
    launch shape for the worklist-capable bench variants (ISSUE 5) —
    'dense' (the classic early-exit grid), 'worklist' (host-planned
    live-cell launches), 'auto', or 'device_worklist' (on-device frontier
    compaction, ISSUE 8).  Recorded in the emitted BENCH json so the perf
    trajectory distinguishes dense from worklist runs.

    The default can be overridden without touching the command line via
    the ``REPRO_GRID_MODE`` env var — the CI device-worklist leg sets
    ``REPRO_GRID_MODE=device_worklist`` and reruns the tier-1 suite and
    bench smokes unchanged."""
    env = os.environ.get("REPRO_GRID_MODE")
    if env:
        default = env
    ap.add_argument("--grid-mode", default=default,
                    choices=("dense", "worklist", "auto",
                             "device_worklist"),
                    help="fused-kernel grid mode for worklist-capable "
                         f"variants (default {default}; env "
                         "REPRO_GRID_MODE overrides; recorded in the "
                         "report)")
    return ap


def disp_snap():
    """Snapshot the obs registry's engine dispatch / host-sync counters
    (summed over run labels) — the benches' ``dispatches_total`` and
    ``host_syncs_per_round`` columns are registry deltas across each
    variant's run, the same counters the shipped runners feed."""
    from repro import obs
    reg = obs.registry()
    d = sum(reg.counter("engine_dispatches_total").snapshot_values()
            .values())
    s = sum(reg.counter("engine_host_syncs_total").snapshot_values()
            .values())
    return d, s


def disp_delta(before):
    after = disp_snap()
    return after[0] - before[0], after[1] - before[1]


def reversed_graph(g):
    from repro.graph.graph import COOGraph
    return COOGraph(g.n, g.dst, g.src, g.weight)


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # us


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def add_obs_out_arg(ap):
    """Grow a bench arg parser an ``--obs-out`` flag: also write the
    BENCH columns as a Prometheus text exposition rendered from the obs
    metrics registry (the same registry the engine/serving layers feed)."""
    ap.add_argument("--obs-out", default=None,
                    help="also write the report's numeric columns as a "
                         "Prometheus text exposition (obs registry)")
    return ap


def emit_report_metrics(report: dict, registry=None):
    """Re-emit every numeric column of a BENCH_*.json report through the
    obs metrics registry as ``bench_value{bench=...,key=...}`` gauges, so
    benchmark output and engine/serving telemetry share one exposition
    path.  Returns the registry used."""
    from repro import obs
    reg = registry if registry is not None else obs.registry()
    bench = str(report.get("bench", "bench"))
    g = reg.gauge("bench_value",
                  "numeric BENCH report columns (key = /-joined path)")

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                if k in ("per_round", "notes"):  # summary columns only
                    continue
                walk(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        elif isinstance(node, (bool, int, float)):
            g.labels(bench=bench, key=prefix).set(float(node))

    walk("", report)
    return reg


def finish_report(report: dict, obs_out=None):
    """Common bench epilogue: re-emit the report through the obs registry
    and, with ``--obs-out``, write the Prometheus exposition next to the
    BENCH json."""
    reg = emit_report_metrics(report)
    if obs_out:
        with open(obs_out, "w") as fh:
            fh.write(reg.render_prometheus())
        print(f"wrote {obs_out}")
    return reg
