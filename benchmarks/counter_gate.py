"""Counter-regression gate: exact engine counters on a tiny CI graph.

Runs BFS, SSSP, and delta-PageRank on a fixed-seed RMAT partition with
the flight recorder installed and compares the *exact* per-run totals —
rounds, messages, pruned deliveries, live grid cells, DMA bytes — against
the committed baselines in ``benchmarks/baselines/counter_gate.json``.
Any drift in message counts or planner-mirror grid accounting (the
numbers PRs 4–7 assert equal to the kernels' ``with_debug`` counters)
fails CI with a field-level diff, so a perf "optimization" that silently
changes how much work the engine does cannot land unnoticed.

Wall-clock never participates: the gate compares only deterministic
counters, so it is stable across machines.

Usage::

    python benchmarks/counter_gate.py            # compare (CI)
    python benchmarks/counter_gate.py --update   # rewrite baselines
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import common  # noqa: F401  (pins JAX_PLATFORMS=cpu before jax loads)
import numpy as np

from repro import obs
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators

BASELINE = pathlib.Path(__file__).parent / "baselines" / "counter_gate.json"

# deliberately tiny: the gate must run in CI seconds, and the counters
# are exact at any scale
SCALE, EDGE_FACTOR, SEED = 8, 8, 7
SHARDS, RPVO_MAX = 4, 4
PR_ITERS, PR_TOL = 8, 3e-5


def _totals(rounds, run):
    rs = [r for r in rounds if r.run == run]
    return {
        "rounds": len(rs),
        "frontier_first": rs[0].frontier if rs else 0,
        "messages": sum(r.messages for r in rs),
        "pruned": sum(r.pruned for r in rs),
        "cells": sum(r.cells for r in rs),
        "launched": sum(r.launched for r in rs),
        "tile_dmas": sum(r.tile_dmas for r in rs),
        "dma_bytes": sum(r.dma_bytes for r in rs),
        "shard_messages": [sum(col) for col in zip(
            *(r.shard_messages for r in rs))] if rs else [],
    }


def run_gate() -> dict:
    g = generators.rmat(SCALE, edge_factor=EDGE_FACTOR, seed=SEED)
    gw = g.with_random_weights(seed=SEED)
    root = int(np.argmax(g.out_degrees()))
    pcfg = PartitionConfig(num_shards=SHARDS, rpvo_max=RPVO_MAX)
    part = build_partition(gw, pcfg)

    from repro.apps.pagerank import _pr_graph
    part_pr = build_partition(_pr_graph(g), pcfg)

    out = {"graph": {"scale": SCALE, "edge_factor": EDGE_FACTOR,
                     "seed": SEED, "n": g.n, "num_edges": g.num_edges,
                     "root": root},
           "runs": {}}
    with obs.recording() as rec:
        for name, sem in (("bfs", actions.BFS), ("sssp", actions.SSSP)):
            # device_worklist records per-WINDOW rows (rounds = window
            # count); its additive counters must stay exactly equal to
            # the host-driven runs' totals, so the gate pins all three
            for grid in ("dense", "worklist", "device_worklist"):
                cfg = engine.EngineConfig(use_pallas=True, grid_mode=grid)
                init = engine.init_values(part, sem, {root: 0.0})
                engine.run_stacked(sem, part, init, cfg)
                key = f"{name}_{grid}"
                out["runs"][key] = _totals(rec.rounds, sem.name)
                rec.rounds.clear()
        engine.run_pagerank_delta(
            part_pr, tol=PR_TOL, max_rounds=PR_ITERS,
            cfg=engine.EngineConfig(use_pallas=True, grid_mode="auto"))
        out["runs"]["pagerank_delta"] = _totals(rec.rounds,
                                                "pagerank_delta")
        rec.rounds.clear()
        engine.run_pagerank_delta(
            part_pr, tol=PR_TOL, max_rounds=PR_ITERS,
            cfg=engine.EngineConfig(use_pallas=True,
                                    grid_mode="device_worklist"))
        out["runs"]["pagerank_delta_device"] = _totals(
            rec.rounds, "pagerank_delta")

    out["runs"].update(_stream_leg(gw))
    out["runs"].update(_resilient_leg(gw, part, root))
    return out


def _resilient_leg(gw, part, root) -> dict:
    """Kill-and-restore leg (ISSUE 10): a shard killed at round 3, the
    resilient driver restores from the round-2 checkpoint, and the
    POST-RECOVERY totals — rounds, messages, work — are pinned EQUAL to
    the uninterrupted run's (counters ride in the checkpoint tree, so
    recovery is invisible in the accounting)."""
    import tempfile

    from repro.core.resilient import StackedTask, run_resilient
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.chaos import ChaosEvent, ChaosPlan

    cfg = engine.EngineConfig(checkpoint_every=2)
    init = engine.init_values(part, actions.SSSP, {root: 0.0})
    base_val, base_stats = engine.run_stacked(
        actions.SSSP, part, init, engine.EngineConfig())
    chaos = ChaosPlan(events=(
        ChaosEvent(round=3, kind="kill_shard", shard=1),))
    with tempfile.TemporaryDirectory() as d:
        got, stats, report = run_resilient(
            StackedTask(actions.SSSP, part, init, cfg), chaos=chaos,
            manager=CheckpointManager(d))
    return {"resilient_kill_restore": {
        "status": report.status,
        "faults": len(report.faults),
        "restores": report.restores,
        "rounds_lost": report.rounds_lost,
        "checkpoints_written": report.checkpoints_written,
        "rounds": int(stats.iterations),
        "messages": int(stats.messages),
        "work": int(stats.work_actions),
        "equal_uninterrupted": bool(
            int(stats.iterations) == int(base_stats.iterations)
            and int(stats.messages) == int(base_stats.messages)
            and int(stats.work_actions) == int(base_stats.work_actions)
            and np.array_equal(np.asarray(got), np.asarray(base_val))),
    }}


def _stream_leg(gw) -> dict:
    """Streaming leg: a FIXED mutation schedule on the same scale-8
    RMAT; pins the incremental-maintenance message/cell counters (the
    warm-start fixpoints the ISSUE 9 splice path drives) so a change
    that silently re-lifts more than the affected region fails CI."""
    from repro.core.streaming import StreamingGraph

    root = int(np.argmax(gw.out_degrees()))
    pcfg = PartitionConfig(num_shards=SHARDS, rpvo_max=RPVO_MAX)
    cfg = engine.EngineConfig(use_pallas=True, grid_mode="dense")
    sg = StreamingGraph(gw, pcfg, cfg=cfg)
    sg.track("bfs", root)
    sg.track("sssp", root)
    rng = np.random.default_rng(SEED)
    out = {}
    with obs.recording() as rec:
        for batch in range(2):
            s = rng.integers(0, gw.n, 16).astype(np.int32)
            d = rng.integers(0, gw.n, 16).astype(np.int32)
            w = rng.integers(1, 10, 16).astype(np.float32)
            sg.insert_edges(s, d, w)
            if batch == 1:
                idx = rng.choice(sg.g.num_edges, 8, replace=False)
                sg.delete_edges(sg.g.src[idx], sg.g.dst[idx])
            info = sg.commit()
            for name in ("bfs", "sssp"):
                key = f"stream_{name}_batch{batch}"
                out[key] = _totals(rec.rounds, name)
                ms = info.maint[(name, root)]
                out[key]["maint_messages"] = ms.messages
                out[key]["seeds"] = ms.seeds
                out[key]["invalidated"] = ms.invalidated
            sp = info.splices["base"]
            out[f"stream_splice_batch{batch}"] = {
                "shards_rebuilt": sp.shards_rebuilt,
                "replicas_added": sp.replicas_added,
                "replicas_moved": sp.replicas_moved,
                "affected_edges": sp.affected_edges,
            }
            rec.rounds.clear()
    return out


def diff(base: dict, got: dict, path="") -> list[str]:
    errs = []
    if isinstance(base, dict) and isinstance(got, dict):
        for k in sorted(set(base) | set(got)):
            if k not in base or k not in got:
                errs.append(f"{path}/{k}: only in "
                            f"{'baseline' if k in base else 'run'}")
            else:
                errs.extend(diff(base[k], got[k], f"{path}/{k}"))
    elif base != got:
        errs.append(f"{path}: baseline {base!r} != run {got!r}")
    return errs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update", action="store_true",
                    help="rewrite the committed baselines from this run")
    args = ap.parse_args(argv)

    got = run_gate()
    if args.update:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        with open(BASELINE, "w") as fh:
            json.dump(got, fh, indent=1, sort_keys=True)
        print(f"wrote {BASELINE}")
        return 0

    if not BASELINE.exists():
        print(f"missing baseline {BASELINE}; run with --update", flush=True)
        return 2
    with open(BASELINE) as fh:
        base = json.load(fh)
    errs = diff(base, got)
    if errs:
        print("counter gate FAILED — exact-counter drift:")
        for e in errs:
            print("  " + e)
        return 1
    n = len(base["runs"])
    msgs = sum(r.get("messages", 0) for r in base["runs"].values())
    print(f"counter gate OK: {n} runs, {msgs} messages, all counters exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
