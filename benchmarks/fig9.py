"""Paper Fig 9: per-channel contention histogram with/without rhizomes.

The paper shows rhizomes flatten the contention distribution on RMAT-22
at 128x128 cells; we replay BFS on the skewed BA graph and report the
link-load histogram (bins=25) plus max/mean link load.
"""
import numpy as np

from benchmarks.common import emit, timed
from repro.core.costmodel import CostModel
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference


def main():
    g = generators.ba_skewed(1 << 14, m_per=8, seed=3)
    trace = [np.arange(g.n, dtype=np.int64)] * 5  # PR-style all-active rounds
    for rmax, label in ((1, "no-rhizome"), (16, "rhizome")):
        part = build_partition(g, PartitionConfig(
            num_shards=16384, rpvo_max=rmax, local_edge_list_size=16,
            seed=7))
        res, us = timed(CostModel(part, torus=True).replay, trace)
        loads = res.link_loads[res.link_loads > 0]
        hist, _ = np.histogram(loads, bins=25)
        emit(f"fig9/{label}", us,
             f"max_link={res.max_link_load};mean_link={loads.mean():.1f};"
             f"p99_link={np.percentile(loads, 99):.0f};"
             f"hist_head={list(hist[:5])}")


if __name__ == "__main__":
    main()
