"""Paper Fig 10: Torus-Mesh vs Mesh — % time reduction and % energy
increase (cycle-level AM-CCA simulator, BFS)."""
import numpy as np

from benchmarks.common import emit, timed
from repro.core.amcca_sim import AmccaSim
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators


def main():
    for n, shards in ((600, 64), (1200, 256)):
        g = generators.ba_skewed(n, m_per=5, seed=9)
        root = int(np.argmax(g.out_degrees()))
        out = {}
        for torus in (False, True):
            part = build_partition(g, PartitionConfig(
                num_shards=shards, rpvo_max=4, local_edge_list_size=8,
                torus=torus, seed=8))
            res, us = timed(AmccaSim(part, torus=torus).run_min_app,
                            {root: 0.0}, False)
            out[torus] = (res.cycles, res.energy_pj, us)
        dt = 100 * (out[False][0] - out[True][0]) / out[False][0]
        de = 100 * (out[True][1] - out[False][1]) / out[False][1]
        emit(f"fig10/cc{shards}", out[True][2],
             f"time_reduction_pct={dt:.1f};energy_increase_pct={de:.1f}")


if __name__ == "__main__":
    main()
