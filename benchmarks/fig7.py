"""Paper Fig 7: strong scaling of BFS/SSSP/PageRank, 256 -> 16384 cells,
with and without rhizomes (cost-model cycles over reference traces)."""
import numpy as np

from benchmarks.common import emit, timed
from repro.core.costmodel import CostModel
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference


def main():
    g = generators.rmat(14, edge_factor=16, seed=2)  # R14 (skewed)
    root = int(np.argmax(g.out_degrees()))
    traces = {
        "bfs": reference.bfs_frontier_trace(g, root),
        "sssp": reference.sssp_relax_trace(g.with_random_weights(seed=2), root),
    }
    pr_trace = [np.arange(g.n, dtype=np.int64)] * 10  # PR: all active x iters
    traces["pagerank"] = pr_trace
    for app, trace in traces.items():
        for shards in (256, 1024, 4096):
            for rmax, label in ((1, "rpvo"), (16, "rhizome")):
                part = build_partition(g, PartitionConfig(
                    num_shards=shards, rpvo_max=rmax,
                    local_edge_list_size=16, seed=5))
                res, us = timed(CostModel(part, torus=True).replay, trace)
                emit(f"fig7/{app}/{label}/cc{shards}", us,
                     f"cycles={res.cycles:.0f};msgs={res.messages};"
                     f"max_link={res.max_link_load}")


if __name__ == "__main__":
    main()
