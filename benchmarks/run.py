"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Individual benches:
  python -m benchmarks.table1   (dataset statistics, Table 1)
  python -m benchmarks.fig6     (action/diffusion pruning, Fig 6)
  python -m benchmarks.fig7     (strong scaling, Fig 7)
  python -m benchmarks.fig8     (rpvo_max sweep, Fig 8)
  python -m benchmarks.fig9     (contention histogram, Fig 9)
  python -m benchmarks.fig10    (mesh vs torus, Fig 10)
  python -m benchmarks.kernelbench (Pallas kernel vs jnp oracle timing)
  python -m benchmarks.roofline (LM+graph roofline table from the dry-run)
"""
import importlib
import sys
import time


MODULES = ["table1", "fig6", "fig7", "fig8", "fig9", "fig10", "kernelbench",
           "roofline"]


def main() -> None:
    print("name,us_per_call,derived")
    for name in MODULES:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.main()
            print(f"bench/{name},{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # noqa: BLE001
            print(f"bench/{name},{(time.time()-t0)*1e6:.0f},"
                  f"ERROR:{type(e).__name__}:{e}", file=sys.stderr)
            print(f"bench/{name},{(time.time()-t0)*1e6:.0f},error")


if __name__ == '__main__':
    main()
