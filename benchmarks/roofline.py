"""Roofline table from the dry-run artifacts (results/dryrun/*.json).

Per (arch × shape × mesh): the three terms in seconds, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful-compute ratio, and the roofline
fraction bound_term / sum_terms (how close the dominant term is to being
the whole step — the optimizable headroom indicator).
"""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(pattern="*.json"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, pattern))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def main():
    recs = load_records()
    print("name,us_per_call,derived")
    for r in recs:
        tag = f"roofline/{r['arch']}/{r['shape']}/" \
              f"{'pod2' if r.get('multi_pod') else 'pod1'}"
        if "skipped" in r:
            print(f"{tag},0.0,SKIP:{r['skipped'][:60]}")
            continue
        if not r.get("ok"):
            print(f"{tag},0.0,FAIL:{r.get('error', '')[:80]}")
            continue
        if "roofline" not in r:   # e.g. the pipeline proof cell
            print(f"{tag},0.0,ok-no-roofline")
            continue
        t = r["roofline"]
        total = t["compute_s"] + t["memory_s"] + t["collective_s"]
        frac = t["compute_s"] / max(total, 1e-30)
        ucr = r.get("useful_compute_ratio")
        print(f"{tag},{r.get('compile_s', 0) * 1e6:.0f},"
              f"comp={t['compute_s']:.3e};mem={t['memory_s']:.3e};"
              f"coll={t['collective_s']:.3e};dom={t['dominant']};"
              f"mfu_bound={frac:.3f}"
              + (f";useful={ucr:.2f}" if ucr else ""))


if __name__ == "__main__":
    main()
