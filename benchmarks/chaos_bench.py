"""Chaos benchmark (ISSUE 10): recovery cost under injected faults.

Four legs against the crash-safe fixpoint stack:

* **fault matrix** — a seeded random ``ChaosPlan`` (kills, corruptions,
  dropped/duplicated inboxes, delays at randomized rounds) against the
  resilient SSSP driver.  HARD assertion: every injected fault resolves
  to a typed terminal status ('ok' for benign/straggler faults,
  'recovered', or 'degraded') with min-semiring values BIT-equal to a
  fault-free oracle whenever the run was not degraded.  Columns:
  recovery wall time, retries/restores, rounds lost.
* **checkpoint cadence** — kill at a fixed round under
  ``checkpoint_every ∈ {off, 1, 4, 16}``: rounds lost to replay vs
  checkpoint write overhead per round (the paper-standard
  recovery-cost/steady-state-cost trade).
* **serving kill-and-restore** — a ``QueryServer`` snapshot at a
  commit (tick) boundary, killed mid-flight and warm-booted from the
  checkpoint: restore wall time and a hard equality check of every
  query's values/rounds/messages against an uninterrupted server.
* **streaming WAL replay** — a mutation batch checkpointed in the
  write-ahead log, crashed before ``commit()``, restored and replayed:
  tracked min values must be bit-equal to an uninterrupted commit.

Usage:  PYTHONPATH=src python benchmarks/chaos_bench.py [--out PATH]
        [--smoke]   # CI: tiny graph, fewer events, same assertions
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import common  # noqa: F401  (pins JAX_PLATFORMS=cpu before jax loads)
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.core.resilient import StackedTask, run_resilient
from repro.core.streaming import StreamingGraph
from repro.graph import generators
from repro.runtime.chaos import ChaosEvent, ChaosPlan, RecoveryPolicy


def _case(scale: int, seed: int, shards: int, grid_mode: str):
    g = generators.rmat(scale, edge_factor=6,
                        seed=seed).with_random_weights(seed=seed)
    part = build_partition(g, PartitionConfig(num_shards=shards,
                                              rpvo_max=2))
    root = int(np.argmax(g.out_degrees()))
    cfg = engine.EngineConfig(use_pallas=(grid_mode != "dense"),
                              grid_mode=grid_mode)
    init = engine.init_values(part, actions.SSSP, {root: 0.0})
    return g, part, root, cfg, init


# --------------------------------------------------------------------------
# leg 1: the randomized fault matrix
# --------------------------------------------------------------------------

def fault_matrix(scale: int, seed: int, shards: int, grid_mode: str,
                 n_plans: int, events_per_plan: int) -> dict:
    g, part, root, cfg, init = _case(scale, seed, shards, grid_mode)
    oracle, ostats = engine.run_stacked(actions.SSSP, part, init, cfg)
    oracle_h = np.asarray(oracle)
    max_round = max(int(ostats.iterations) - 1, 2)

    rows = []
    by_status = {"ok": 0, "recovered": 0, "degraded": 0}
    for p in range(n_plans):
        chaos = ChaosPlan.random(seed=seed + 100 + p,
                                 n_events=events_per_plan,
                                 max_round=max_round, num_shards=shards)
        policy = RecoveryPolicy(max_retries=2,
                                max_restores=2 * events_per_plan)
        t0 = time.perf_counter()
        got, stats, report = run_resilient(
            StackedTask(actions.SSSP, part, init, cfg), chaos=chaos,
            policy=policy)
        wall = time.perf_counter() - t0
        # HARD assertions: typed terminal status; oracle-equal values
        # and accounting totals for every non-degraded run
        assert report.status in ("ok", "recovered", "degraded"), \
            report.status
        if report.status != "degraded":
            np.testing.assert_array_equal(np.asarray(got), oracle_h)
            assert int(stats.messages) == int(ostats.messages)
            assert int(stats.iterations) == int(ostats.iterations)
        by_status[report.status] += 1
        rows.append({
            "plan_seed": seed + 100 + p,
            "events": [[e.round, e.kind, e.shard] for e in chaos.events],
            "status": report.status,
            "faults_detected": len(report.faults),
            "retries": report.retries,
            "restores": report.restores,
            "rounds_lost": report.rounds_lost,
            "recovery_s": report.recovery_s,
            "wall_s": wall,
        })
    return {
        "oracle_rounds": int(ostats.iterations),
        "oracle_messages": int(ostats.messages),
        "plans": rows,
        "by_status": by_status,
        "recovery_s_mean": float(np.mean([r["recovery_s"]
                                          for r in rows])),
        "rounds_lost_mean": float(np.mean([r["rounds_lost"]
                                           for r in rows])),
    }


# --------------------------------------------------------------------------
# leg 2: rounds lost / write overhead vs checkpoint cadence
# --------------------------------------------------------------------------

def checkpoint_cadence(scale: int, seed: int, shards: int,
                       grid_mode: str, ckptdir: str) -> dict:
    g, part, root, cfg0, init = _case(scale, seed, shards, grid_mode)
    oracle, ostats = engine.run_stacked(actions.SSSP, part, init, cfg0)
    oracle_h = np.asarray(oracle)
    kill_round = max(int(ostats.iterations) - 2, 3)

    out = {"kill_round": kill_round,
           "oracle_rounds": int(ostats.iterations)}
    for K in (None, 1, 4, 16):
        import dataclasses
        cfg = dataclasses.replace(cfg0, checkpoint_every=K)
        mgr = (CheckpointManager(f"{ckptdir}/K{K}")
               if K is not None else None)
        chaos = ChaosPlan(events=(
            ChaosEvent(round=kill_round, kind="kill_shard", shard=1),))
        t0 = time.perf_counter()
        got, stats, report = run_resilient(
            StackedTask(actions.SSSP, part, init, cfg), chaos=chaos,
            manager=mgr)
        wall = time.perf_counter() - t0
        assert report.status == "recovered"
        np.testing.assert_array_equal(np.asarray(got), oracle_h)
        assert int(stats.messages) == int(ostats.messages)
        rounds = max(int(stats.iterations), 1)
        out[f"checkpoint_every_{'off' if K is None else K}"] = {
            "rounds_lost": report.rounds_lost,
            "checkpoints_written": report.checkpoints_written,
            "checkpoint_write_s": report.checkpoint_write_s,
            "checkpoint_write_s_per_round":
                report.checkpoint_write_s / rounds,
            "recovery_s": report.recovery_s,
            "wall_s": wall,
        }
    return out


# --------------------------------------------------------------------------
# leg 3: serving kill-and-restore at a commit boundary
# --------------------------------------------------------------------------

def serving_kill_restore(scale: int, seed: int, shards: int,
                         ckptdir: str) -> dict:
    from repro.query import QueryServer
    from repro.serve.admission import QueryStatus, ServeConfig

    g = generators.rmat(scale, edge_factor=5,
                        seed=seed).with_random_weights(seed=seed)
    part = build_partition(g, PartitionConfig(num_shards=shards,
                                              rpvo_max=2))
    roots = [int(r) for r in np.argsort(-g.out_degrees())[:6]]

    def submit_all(srv):
        qs = []
        for i, r in enumerate(roots):
            qs.append(srv.submit("bfs" if i % 2 else "sssp", r))
        return qs

    oracle = QueryServer(part, n_lanes=3)
    oq = submit_all(oracle)
    ores = oracle.run()

    serve = ServeConfig(checkpoint_every=2)
    srv = QueryServer(part, n_lanes=3, serve=serve)
    qs = submit_all(srv)
    srv.attach_checkpoints(CheckpointManager(f"{ckptdir}/serve"))
    kill_tick = 4
    for _ in range(kill_tick):
        srv.step()
    in_flight = sum(1 for q in qs if q not in srv.results)
    del srv                                  # crash

    t0 = time.perf_counter()
    srv2 = QueryServer.restore(part, CheckpointManager(f"{ckptdir}/serve"),
                               serve=serve)
    restore_s = time.perf_counter() - t0
    res = srv2.run()

    recovered = 0
    for q, oq_ in zip(qs, oq):
        o, r = ores[oq_], res[q]
        np.testing.assert_array_equal(np.asarray(r.values),
                                      np.asarray(o.values))
        assert r.rounds == o.rounds and r.messages == o.messages
        recovered += r.status == QueryStatus.RECOVERED
    return {
        "queries": len(qs),
        "kill_tick": kill_tick,
        "in_flight_at_kill": in_flight,
        "recovered_statuses": recovered,
        "restore_s": restore_s,
        "all_values_equal_oracle": True,     # asserted above
    }


# --------------------------------------------------------------------------
# leg 4: streaming WAL replay across a crash-mid-commit
# --------------------------------------------------------------------------

def streaming_wal_replay(scale: int, seed: int, shards: int,
                         ckptdir: str) -> dict:
    g = generators.rmat(scale, edge_factor=5, seed=seed)
    pcfg = PartitionConfig(num_shards=shards, rpvo_max=2)
    rng = np.random.default_rng(seed)
    k = max(8, g.num_edges // 50)
    ins = (rng.integers(0, g.n, k).astype(np.int32),
           rng.integers(0, g.n, k).astype(np.int32),
           (rng.random(k) + 0.1).astype(np.float32))

    def make():
        sg = StreamingGraph(g, pcfg)
        sg.track("bfs", 0)
        sg.track("sssp", 1)
        return sg

    oracle = make()
    oracle.insert_edges(*ins)
    oracle.commit()

    sg = make()
    sg.insert_edges(*ins)
    mgr = CheckpointManager(f"{ckptdir}/wal")
    t0 = time.perf_counter()
    sg.save_checkpoint(mgr, blocking=True)
    ckpt_s = time.perf_counter() - t0
    del sg                                   # crash mid-commit

    t0 = time.perf_counter()
    sg2 = StreamingGraph.restore(mgr)
    restore_s = time.perf_counter() - t0
    assert sg2._pending_ins, "WAL lost the uncommitted batch"
    sg2.commit()                             # replay
    for key in oracle.tracked:
        np.testing.assert_array_equal(
            np.asarray(oracle.tracked[key]["vals"]),
            np.asarray(sg2.tracked[key]["vals"]))
    return {
        "wal_edges": int(k),
        "checkpoint_s": ckpt_s,
        "restore_s": restore_s,
        "replay_exact": True,                # asserted above
    }


# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: tiny graphs, fewer plans, same assertions")
    common.add_seed_arg(ap)
    common.add_grid_mode_arg(ap, default="dense")
    common.add_obs_out_arg(ap)
    args = ap.parse_args(argv)

    scale = 7 if args.smoke else 9
    shards = 4 if args.smoke else 8
    n_plans = 3 if args.smoke else 8
    events = 2 if args.smoke else 4
    report = {"bench": "chaos", "seed": args.seed, "smoke": args.smoke,
              "grid_mode": args.grid_mode}

    with tempfile.TemporaryDirectory() as ckptdir:
        print(f"fault matrix ({n_plans} random plans x {events} events, "
              f"scale {scale}, grid {args.grid_mode}) ...")
        leg1 = fault_matrix(scale, args.seed, shards, args.grid_mode,
                            n_plans, events)
        report["fault_matrix"] = leg1
        print(f"  statuses {leg1['by_status']}, mean recovery "
              f"{leg1['recovery_s_mean'] * 1e3:.1f} ms, mean rounds lost "
              f"{leg1['rounds_lost_mean']:.1f}")

        print("checkpoint cadence (kill at fixed round) ...")
        leg2 = checkpoint_cadence(scale, args.seed, shards,
                                  args.grid_mode, ckptdir)
        report["checkpoint_cadence"] = leg2
        for key, row in leg2.items():
            if not isinstance(row, dict):
                continue
            print(f"  {key}: {row['rounds_lost']} rounds lost, "
                  f"{row['checkpoints_written']} ckpts "
                  f"({row['checkpoint_write_s'] * 1e3:.1f} ms written)")

        print("serving kill-and-restore ...")
        leg3 = serving_kill_restore(scale, args.seed, shards, ckptdir)
        report["serving_kill_restore"] = leg3
        print(f"  {leg3['queries']} queries, {leg3['in_flight_at_kill']} "
              f"in flight at kill, {leg3['recovered_statuses']} RECOVERED,"
              f" restore {leg3['restore_s'] * 1e3:.1f} ms")

        print("streaming WAL replay ...")
        leg4 = streaming_wal_replay(scale, args.seed, shards, ckptdir)
        report["streaming_wal_replay"] = leg4
        print(f"  {leg4['wal_edges']} WAL edges, replay exact, restore "
              f"{leg4['restore_s'] * 1e3:.1f} ms")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    common.finish_report(report, obs_out=args.obs_out)


if __name__ == "__main__":
    main()


