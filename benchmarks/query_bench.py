"""Multi-query serving benchmark: lane batching vs a serial query loop
(ISSUE 2).

Three measurements on one shared rhizome-partitioned RMAT graph:

* **serial**  — a 16-query mixed BFS/SSSP workload run one query at a
  time through the laned runner with Q=1 (compiled once, reused), the
  per-query baseline a naive serving loop would pay;
* **batched** — the same 16 queries as 16 lanes of ONE laned fixpoint
  (one compiled round advances every live query; converged lanes ride
  along inert).  The acceptance bar: aggregate queries/s must beat the
  serial loop;
* **server**  — ``QueryServer`` continuous batching over a deeper queue
  (3x lanes): requests join lanes freed mid-flight, giving per-query
  latency percentiles and lane-occupancy, the serving analog of the
  paper's always-busy compute cells.

Also emits the per-round OR-frontier grid-cell counts for the fused
laned kernel (a grid cell executes iff its edge chunk is live in at
least one lane), and the **compact-vs-dense laned exchange volume**
(ISSUE 3): the same lane batch run on the §Perf compact targeted
exchange ships strictly fewer entries per live lane than the dense
(S, R_max, Q) inbox — ``LaneStats.exchanged`` records the per-lane
totals, and the values are asserted bit-identical.

Usage:  PYTHONPATH=src python benchmarks/query_bench.py [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import common  # pins JAX_PLATFORMS=cpu before jax loads; --seed helper
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators
from repro.kernels.fused_relax_reduce import fused_grid_cells
from repro.query import QueryServer
from repro.query.lanes import (
    _lane_round_stacked, init_lane_values, make_stacked_lanes_fn,
)


def _mixed_queries(g, n_queries, seed=0):
    rng = np.random.default_rng(seed)
    deg = np.argsort(-g.out_degrees())
    pool = deg[: max(4 * n_queries, 64)]
    roots = rng.choice(pool, size=n_queries, replace=False)
    return [("bfs" if i % 2 == 0 else "sssp", int(r))
            for i, r in enumerate(roots)]


def _timed_run(fn, init, unitw, chg, repeats):
    best = np.inf
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(init, unitw, chg)
        jax.tree.map(lambda x: x.block_until_ready(), out)
        best = min(best, time.perf_counter() - t0)
    return out, best


def bench_batch_vs_serial(part, queries, cfg, repeats=3):
    if cfg.wants_worklist:
        # host-driven laned runner: per-round worklist launches planned
        # from the OR-across-lanes frontier (ISSUE 5) — same values and
        # LaneStats as the traced fixpoint; feeds the dispatch counters
        # itself (one dispatch + host sync per round)
        from repro.query.lanes import run_stacked_lanes

        def fn(init, unitw, chg):
            return run_stacked_lanes(part, init, unitw, cfg=cfg,
                                     init_changed=chg)
    else:
        # traced whole-fixpoint runner (dense grid, or device-compacted
        # worklist under grid_mode='device_worklist'): one dispatch with
        # one result sync per call — counted through the same registry
        raw = make_stacked_lanes_fn(part, cfg)

        def fn(init, unitw, chg):
            out = raw(init, unitw, chg)
            engine._count_dispatches("bench_lanes", 1, 1)
            return out
    slot_valid = jnp.asarray(part.slot_vertex >= 0)

    def prep(qs):
        init, unitw = init_lane_values(part, qs)
        init = jnp.asarray(init)
        chg = actions.SSSP.improved(init, jnp.full_like(init, jnp.inf)) \
            & slot_valid[..., None]
        return init, jnp.asarray(unitw), chg

    # batched: all queries as lanes of one fixpoint
    init, unitw, chg = prep(queries)
    fn(init, unitw, chg)[0].block_until_ready()      # compile Q=K
    snap = common.disp_snap()
    (val_b, stats_b), wall_batch = _timed_run(fn, init, unitw, chg, 1)
    dd_b, ds_b = common.disp_delta(snap)
    if repeats > 1:
        (val_b, stats_b), wall_batch = _timed_run(fn, init, unitw, chg,
                                                  repeats)

    # serial: one compiled Q=1 runner reused across the workload
    solo = [prep([qr]) for qr in queries]
    fn(*solo[0])[0].block_until_ready()              # compile Q=1
    wall_serial = np.inf
    serial_rounds = 0
    snap = common.disp_snap()
    for rep in range(repeats):
        if rep == 1:
            dd_s, ds_s = common.disp_delta(snap)
        t0 = time.perf_counter()
        serial_rounds = 0
        for args in solo:
            _, st = fn(*args)
            serial_rounds += int(st.rounds[0])
        wall_serial = min(wall_serial, time.perf_counter() - t0)
    if repeats == 1:
        dd_s, ds_s = common.disp_delta(snap)

    k = len(queries)
    rounds_q = np.asarray(stats_b.rounds)
    rounds_b = int(rounds_q.max())
    return {
        "queries": k,
        "serial": {"wall_s": wall_serial,
                   "queries_per_s": k / wall_serial,
                   "rounds_total": serial_rounds,
                   "dispatches_total": int(dd_s),
                   "host_syncs_per_round":
                       ds_s / max(serial_rounds, 1)},
        "batched": {"wall_s": wall_batch,
                    "queries_per_s": k / wall_batch,
                    "rounds_total": rounds_b,
                    "rounds_per_query": rounds_q.tolist(),
                    "messages_per_query":
                        np.asarray(stats_b.messages).tolist(),
                    "dispatches_total": int(dd_b),
                    "host_syncs_per_round": ds_b / max(rounds_b, 1)},
        "batched_speedup": wall_serial / wall_batch,
        "batched_beats_serial": wall_batch < wall_serial,
    }


def bench_grid_cells(part, queries, cfg, max_rounds=64):
    """Round-by-round OR-frontier grid-cell counts for the laned fused
    kernel: cells live in >=1 lane vs the sum of per-lane counts a
    serial fused loop would execute."""
    arrays = engine.DeviceArrays.from_partition(part)
    init, unitw = init_lane_values(part, queries)
    val = jnp.asarray(init)
    slot_valid = jnp.asarray(part.slot_vertex >= 0)
    chg = actions.SSSP.improved(val, jnp.full_like(val, jnp.inf)) \
        & slot_valid[..., None]
    unitw = jnp.asarray(unitw)
    total = part.S * part.R_max
    rounds = []
    for _ in range(max_rounds):
        chg_h = np.asarray(chg)
        if not chg_h.any():
            break
        or_frontier = chg_h.reshape(-1, chg_h.shape[-1]).any(axis=1)
        cells_or = fused_grid_cells(
            part.edge_dst_flat, part.edge_mask, part.edge_src_root_flat,
            or_frontier, total)["fused_live"]
        cells_serial = sum(
            fused_grid_cells(
                part.edge_dst_flat, part.edge_mask,
                part.edge_src_root_flat,
                chg_h.reshape(-1, chg_h.shape[-1])[:, q], total)
            ["fused_live"]
            for q in range(chg_h.shape[-1])
            if chg_h[..., q].any())
        rounds.append({"grid_cells_or_batched": cells_or,
                       "grid_cells_serial_sum": cells_serial,
                       "live_lanes":
                           int(chg_h.reshape(-1, chg_h.shape[-1])
                               .any(axis=0).sum())})
        val, chg, _ = _lane_round_stacked(
            actions.SSSP, arrays, cfg, part.S, part.R_max, unitw, val, chg)
    return {
        "per_round": rounds,
        "grid_cells_or_total": sum(r["grid_cells_or_batched"]
                                   for r in rounds),
        "grid_cells_serial_total": sum(r["grid_cells_serial_sum"]
                                       for r in rounds),
    }


def bench_exchange_volume(part, queries, use_pallas=False):
    """Compact targeted vs dense laned exchange on one lane batch: per-
    lane exchanged-entry totals (entries shipped through the inter-shard
    exchange while the lane was live), bit-identity of the results, and
    the volume-reduction ratio — the paper's §Perf message reduction
    measured on the lane axis."""
    slot_valid = jnp.asarray(part.slot_vertex >= 0)
    init_np, unitw_np = init_lane_values(part, queries)
    init = jnp.asarray(init_np)
    chg = actions.SSSP.improved(init, jnp.full_like(init, jnp.inf)) \
        & slot_valid[..., None]
    unitw = jnp.asarray(unitw_np)
    out, vals = {}, {}
    for label, cfg in (
            ("dense", engine.EngineConfig(use_pallas=use_pallas)),
            ("compact", engine.EngineConfig(use_pallas=use_pallas,
                                            exchange="compact"))):
        fn = make_stacked_lanes_fn(part, cfg)
        val, stats = fn(init, unitw, chg)
        val.block_until_ready()
        t0 = time.perf_counter()
        val, stats = fn(init, unitw, chg)
        val.block_until_ready()
        wall = time.perf_counter() - t0
        vals[label] = np.asarray(val)
        ex = np.asarray(stats.exchanged)
        out[label] = {
            "wall_s": wall,
            "exchanged_total": int(ex.sum()),
            "exchanged_per_lane": ex.tolist(),
            "messages_total": int(np.asarray(stats.messages).sum()),
        }
    identical = bool(np.array_equal(vals["dense"], vals["compact"]))
    assert identical, "compact laned exchange diverged from dense"
    out["values_bit_identical"] = identical
    out["volume_ratio_dense_over_compact"] = (
        out["dense"]["exchanged_total"]
        / max(out["compact"]["exchanged_total"], 1))
    out["partition"] = {"R_max": part.R_max, "P_t": part.P_t,
                        "shards": part.S}
    return out


def bench_server(part, queries, n_lanes, cfg, tick_rounds=1):
    srv = QueryServer(part, n_lanes=n_lanes, ppr_lanes=0, cfg=cfg,
                      tick_rounds=tick_rounds)
    snap = common.disp_snap()
    t0 = time.perf_counter()
    for kind, root in queries:
        srv.submit(kind, root)
    results = srv.run()
    wall = time.perf_counter() - t0
    dd, ds = common.disp_delta(snap)
    lat = np.array([r.latency_s for r in results.values()])
    rounds = np.array([r.rounds for r in results.values()])
    return {
        "queries": len(queries),
        "lanes": n_lanes,
        "tick_rounds": tick_rounds,
        "wall_s": wall,
        "queries_per_s": len(queries) / wall,
        "ticks": srv.tick,
        "rounds_driven": srv.rounds_driven,
        "dispatches_total": int(dd),
        "host_syncs_per_round": ds / max(srv.rounds_driven, 1),
        "lane_occupancy": srv.occupancy(),
        "latency_s": {
            "p50": float(np.percentile(lat, 50)),
            "p90": float(np.percentile(lat, 90)),
            "p99": float(np.percentile(lat, 99)),
            "max": float(lat.max()),
        },
        "rounds_per_query": {
            "p50": float(np.percentile(rounds, 50)),
            "max": int(rounds.max()),
        },
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_query.json")
    ap.add_argument("--scale", type=int, default=10,
                    help="RMAT scale (n = 2**scale)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--rpvo-max", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--server-queue", type=int, default=48)
    ap.add_argument("--tick-rounds", type=int, default=4,
                    help="K-round window for the windowed-server row "
                         "(one dispatch advances every live lane K "
                         "rounds)")
    common.add_seed_arg(ap)
    common.add_obs_out_arg(ap)
    common.add_grid_mode_arg(ap)
    args = ap.parse_args()

    g = generators.rmat(args.scale, edge_factor=args.edge_factor,
                        seed=args.seed).with_random_weights(seed=args.seed)
    part = build_partition(
        g, PartitionConfig(num_shards=args.shards, rpvo_max=args.rpvo_max))
    workload = _mixed_queries(g, args.lanes, seed=args.seed + 1)
    deep_queue = _mixed_queries(g, args.server_queue, seed=args.seed + 2)

    report = {
        "bench": "query_serving",
        "graph": {"kind": "rmat", "scale": args.scale,
                  "edge_factor": args.edge_factor, "n": g.n,
                  "num_edges": g.num_edges, "seed": args.seed},
        "config": {"shards": args.shards, "rpvo_max": args.rpvo_max,
                   "lanes": args.lanes, "grid_mode": args.grid_mode,
                   "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu"},
        "notes": (
            "serial = one query at a time through the same compiled Q=1 "
            "laned runner; batched = the workload as lanes of one "
            "fixpoint. Grid-cell counts mirror the laned fused kernel's "
            "OR-frontier chunk skip vs the sum a serial fused loop "
            "executes. The fused variant is reported under CPU interpret "
            "mode, where kernel Python overhead dominates; the batching "
            "ratio is the portable signal. dispatches_total / "
            "host_syncs_per_round are obs-registry deltas: fused_dev "
            "(grid_mode='device_worklist') runs the whole laned fixpoint "
            "as ONE traced dispatch; server_windowed ticks in "
            "tick_rounds-round windows — one dispatch per window instead "
            "of one per round."),
        "variants": {},
    }

    variants = [("jnp", engine.EngineConfig()),
                ("fused", engine.EngineConfig(use_pallas=True))]
    if args.grid_mode != "dense":
        host_mode = args.grid_mode \
            if args.grid_mode in ("worklist", "auto") else "worklist"
        variants += [
            ("fused_wl", engine.EngineConfig(use_pallas=True,
                                             grid_mode=host_mode)),
            # on-device frontier compaction: the whole laned fixpoint is
            # ONE traced dispatch (ISSUE 8) — compare dispatches_total
            # against fused_wl's one-per-round
            ("fused_dev",
             engine.EngineConfig(use_pallas=True,
                                 grid_mode="device_worklist")),
        ]
    for label, cfg in variants:
        entry = bench_batch_vs_serial(part, workload, cfg,
                                      repeats=3 if label == "jnp" else 1)
        print(f"{label:6s} serial={entry['serial']['wall_s']:.3f}s "
              f"batched={entry['batched']['wall_s']:.3f}s "
              f"speedup={entry['batched_speedup']:.2f}x "
              f"({entry['batched']['queries_per_s']:.1f} q/s)")
        report["variants"][label] = entry

    report["grid_cells"] = bench_grid_cells(
        part, workload, engine.EngineConfig(use_pallas=True))
    gc = report["grid_cells"]
    print(f"grid cells: batched-OR={gc['grid_cells_or_total']} "
          f"serial-sum={gc['grid_cells_serial_total']}")

    report["exchange_volume"] = bench_exchange_volume(part, workload)
    ev = report["exchange_volume"]
    print(f"laned exchange volume: dense={ev['dense']['exchanged_total']} "
          f"compact={ev['compact']['exchanged_total']} "
          f"({ev['volume_ratio_dense_over_compact']:.2f}x reduction, "
          f"bit-identical={ev['values_bit_identical']})")

    report["server"] = bench_server(part, deep_queue, args.lanes,
                                    engine.EngineConfig())
    sv = report["server"]
    print(f"server {sv['queries']} queries / {sv['lanes']} lanes: "
          f"{sv['queries_per_s']:.1f} q/s occupancy={sv['lane_occupancy']:.2f} "
          f"p50={sv['latency_s']['p50']*1e3:.1f}ms "
          f"p99={sv['latency_s']['p99']*1e3:.1f}ms")

    # K-round window ticks (ISSUE 8): one dispatch advances every live
    # lane tick_rounds rounds — same results, ~1/K the host syncs
    report["server_windowed"] = bench_server(
        part, deep_queue, args.lanes, engine.EngineConfig(),
        tick_rounds=args.tick_rounds)
    sw = report["server_windowed"]
    print(f"server tick_rounds={sw['tick_rounds']}: "
          f"{sw['queries_per_s']:.1f} q/s ticks={sw['ticks']} "
          f"(vs {sv['ticks']}) dispatches={sw['dispatches_total']} "
          f"syncs/round={sw['host_syncs_per_round']:.2f}")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    common.finish_report(report, obs_out=args.obs_out)


if __name__ == "__main__":
    main()
