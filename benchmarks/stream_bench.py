"""Streaming-graph benchmark (ISSUE 9 tentpole).

Three legs against ``StreamingGraph`` (in-place partition splicing +
incremental result maintenance + adaptive rhizome growth):

* **incremental vs cold** — a 1%-of-edges insert batch on a fixed-seed
  RMAT: incremental BFS/SSSP/delta-PageRank maintenance (warm-started
  at the affected region) vs a cold fixpoint on the final graph, in
  exact engine counters — rounds, messages, live grid cells (the
  planner mirror).  The acceptance column: incremental does measurably
  fewer messages AND cells than cold on every app.
* **mutate-while-serving** — a ``QueryServer`` bound to the stream:
  interleaved query waves and mutation commits, reporting sustained
  mutations/s, queries/s, splice sizes, and cache invalidations.
* **staleness vs recompute cost** — the same mutation schedule applied
  with ``refresh_every ∈ {1, 4, 16}`` batches per maintenance commit:
  deferring maintenance amortizes warm-start cost (messages/commit)
  against result staleness (max |stale − fresh| PageRank error sampled
  between commits).

Usage:  PYTHONPATH=src python benchmarks/stream_bench.py [--out PATH]
        [--smoke]   # CI: tiny graph + assert incremental < cold
"""
from __future__ import annotations

import argparse
import json
import time

import common  # noqa: F401  (pins JAX_PLATFORMS=cpu before jax loads)
import numpy as np

from repro import obs
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.core.streaming import StreamingGraph, _pr_weights
from repro.graph import generators

PR_TOL = 1e-6


def _totals(rounds, run):
    rs = [r for r in rounds if r.run == run]
    return {"rounds": len(rs),
            "messages": sum(r.messages for r in rs),
            "cells": sum(r.cells for r in rs)}


def _build(scale: int, seed: int, shards: int):
    gw = generators.rmat(scale, edge_factor=6,
                         seed=seed).with_random_weights(seed=seed)
    pcfg = PartitionConfig(num_shards=shards, rpvo_max=4)
    cfg = engine.EngineConfig(use_pallas=True, grid_mode="dense")
    return gw, pcfg, cfg


# --------------------------------------------------------------------------
# leg 1: incremental vs cold after a 1%-edge insert batch
# --------------------------------------------------------------------------

def incremental_vs_cold(scale: int, seed: int, shards: int) -> dict:
    gw, pcfg, cfg = _build(scale, seed, shards)
    root = int(np.argmax(gw.out_degrees()))
    sg = StreamingGraph(gw, pcfg, cfg=cfg)
    sg.track("bfs", root)
    sg.track("sssp", root)
    sg.track("pagerank", tol=PR_TOL)

    rng = np.random.default_rng(seed)
    k = max(1, gw.num_edges // 100)          # the 1% batch
    s = rng.integers(0, gw.n, k).astype(np.int32)
    d = rng.integers(0, gw.n, k).astype(np.int32)
    w = rng.integers(1, 10, k).astype(np.float32)
    sg.insert_edges(s, d, w)
    with obs.recording() as rec:
        info = sg.commit()
    inc = {"bfs": _totals(rec.rounds, "bfs"),
           "sssp": _totals(rec.rounds, "sssp"),
           "pagerank": _totals(rec.rounds, "pagerank_delta")}

    part = sg.view("base").part
    part_pr = sg.view("pr").part
    with obs.recording() as rec:
        for name, sem in (("bfs", actions.BFS), ("sssp", actions.SSSP)):
            init = engine.init_values(part, sem, {root: 0.0})
            engine.run_stacked(sem, part, init, cfg)
        engine.run_pagerank_delta(part_pr, tol=PR_TOL, cfg=cfg)
    cold = {"bfs": _totals(rec.rounds, "bfs"),
            "sssp": _totals(rec.rounds, "sssp"),
            "pagerank": _totals(rec.rounds, "pagerank_delta")}

    sp = info.splices["base"]
    return {
        "graph": {"scale": scale, "n": gw.n,
                  "edges_before": gw.num_edges - 0,
                  "insert_batch": k, "root": root},
        "splice": {"shards_rebuilt": sp.shards_rebuilt,
                   "shards_total": sp.shards_total,
                   "replicas_added": sp.replicas_added,
                   "affected_edges": sp.affected_edges},
        "incremental": inc,
        "cold": cold,
        "ratio_messages": {
            app: (inc[app]["messages"] / max(cold[app]["messages"], 1))
            for app in inc},
        "ratio_cells": {
            app: (inc[app]["cells"] / max(cold[app]["cells"], 1))
            for app in inc},
    }


# --------------------------------------------------------------------------
# leg 2: sustained mutations interleaved with live queries
# --------------------------------------------------------------------------

def mutate_while_serving(scale: int, seed: int, shards: int,
                         batches: int, queries_per_batch: int) -> dict:
    from repro.query.server import QueryServer
    from repro.serve.admission import ServeConfig

    gw, pcfg, cfg = _build(scale, seed, shards)
    sg = StreamingGraph(gw, pcfg, cfg=cfg)
    srv = QueryServer(sg.view("base").part, n_lanes=4,
                      serve=ServeConfig(cache_size=32))
    sg.bind_server(srv)
    rng = np.random.default_rng(seed + 1)
    hubs = np.argsort(gw.out_degrees())[-16:]

    t0 = time.monotonic()
    mutated_edges = 0
    for b in range(batches):
        for _ in range(queries_per_batch):
            kind = ("bfs", "sssp")[int(rng.integers(0, 2))]
            srv.submit(kind, [int(rng.choice(hubs))])
        srv.run()
        k = 16
        s = rng.integers(0, gw.n, k).astype(np.int32)
        d = rng.integers(0, gw.n, k).astype(np.int32)
        sg.insert_edges(s, d, rng.integers(1, 10, k).astype(np.float32))
        if b % 2 == 1:
            idx = rng.choice(sg.g.num_edges, 8, replace=False)
            sg.delete_edges(sg.g.src[idx], sg.g.dst[idx])
            mutated_edges += 8
        sg.commit()
        mutated_edges += k
    wall = time.monotonic() - t0
    done = sum(1 for r in srv.results.values() if r.status == "ok")
    return {
        "batches": batches, "wall_s": wall,
        "mutated_edges": mutated_edges,
        "mutations_per_s": mutated_edges / max(wall, 1e-9),
        "queries_completed": done,
        "queries_per_s": done / max(wall, 1e-9),
        "cache_invalidations": int(srv.counters["cache_invalidations"]),
        "server_mutations": int(srv.counters["mutations"]),
    }


# --------------------------------------------------------------------------
# leg 3: staleness vs recompute cost
# --------------------------------------------------------------------------

def staleness_vs_cost(scale: int, seed: int, shards: int,
                      batches: int) -> dict:
    out = {}
    for refresh_every in (1, 4, 16):
        gw, pcfg, cfg = _build(scale, seed, shards)
        sg = StreamingGraph(gw, pcfg, cfg=cfg)
        sg.track("pagerank", tol=PR_TOL)
        rng = np.random.default_rng(seed + 2)
        cost_msgs = 0
        commits = 0
        stale_errs = []
        from repro.graph.graph import COOGraph
        true_g = gw
        for b in range(batches):
            k = 8
            s = rng.integers(0, gw.n, k).astype(np.int32)
            d = rng.integers(0, gw.n, k).astype(np.int32)
            w = rng.integers(1, 10, k).astype(np.float32)
            sg.insert_edges(s, d, w)
            true_g = COOGraph(true_g.n,
                              np.concatenate([true_g.src, s]),
                              np.concatenate([true_g.dst, d]),
                              np.concatenate([true_g.weight, w]))
            if (b + 1) % refresh_every == 0:
                info = sg.commit()
                commits += 1
                cost_msgs += info.maint[("pagerank", None)].messages
            else:
                # stale window: measure the served (old) ranks against a
                # fresh fixpoint on the would-be graph
                part_pr = build_partition(_pr_weights(true_g), sg.pcfg)
                rank_t, _ = engine.run_pagerank_delta(
                    part_pr, tol=PR_TOL, cfg=engine.EngineConfig())
                fresh = engine.vertex_values(part_pr, rank_t)
                stale_errs.append(float(np.abs(
                    sg.values("pagerank") - fresh).max()))
        out[f"refresh_every_{refresh_every}"] = {
            "commits": commits,
            "maintenance_messages": cost_msgs,
            "messages_per_commit": cost_msgs / max(commits, 1),
            "stale_batches": len(stale_errs),
            "staleness_max": max(stale_errs, default=0.0),
            "staleness_mean": (float(np.mean(stale_errs))
                               if stale_errs else 0.0),
        }

    # --- staleness-SLO row: instead of a fixed cadence, the stream
    # auto-commits whenever the pending-mutation staleness bound
    # crosses the SLO (deferred commits with a bounded stale window)
    slo = 2.5 * 8                          # ~2.5 batches of 8 edges
    gw, pcfg, cfg = _build(scale, seed, shards)
    sg = StreamingGraph(gw, pcfg, cfg=cfg, staleness_slo=slo)
    sg.track("pagerank", tol=PR_TOL)
    rng = np.random.default_rng(seed + 2)
    cost_msgs = 0
    with obs.recording() as rec:
        for b in range(batches):
            k = 8
            s = rng.integers(0, gw.n, k).astype(np.int32)
            d = rng.integers(0, gw.n, k).astype(np.int32)
            w = rng.integers(1, 10, k).astype(np.float32)
            sg.insert_edges(s, d, w)
    cost_msgs = sum(r.messages for r in rec.rounds
                    if r.run == "pagerank_delta")
    out["auto_slo"] = {
        "staleness_slo": slo,
        "commits": sg.auto_refreshes,
        "auto_refreshes": sg.auto_refreshes,
        "maintenance_messages": cost_msgs,
        "messages_per_commit": cost_msgs / max(sg.auto_refreshes, 1),
        "residual_staleness": sg.staleness(),
    }
    return out


# --------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_stream.json")
    ap.add_argument("--smoke", action="store_true",
                    help="CI: tiny graph; assert incremental < cold")
    common.add_seed_arg(ap)
    common.add_obs_out_arg(ap)
    args = ap.parse_args(argv)

    scale = 7 if args.smoke else 9
    shards = 4 if args.smoke else 8
    report = {"bench": "stream", "seed": args.seed, "smoke": args.smoke}

    print(f"incremental vs cold (scale {scale}, 1% insert batch) ...")
    leg1 = incremental_vs_cold(scale, args.seed, shards)
    report["incremental_vs_cold"] = leg1
    for app in ("bfs", "sssp", "pagerank"):
        inc = leg1["incremental"][app]
        cold = leg1["cold"][app]
        print(f"  {app:>8}: messages {inc['messages']} vs {cold['messages']}"
              f" ({leg1['ratio_messages'][app]:.3f}x), cells"
              f" {inc['cells']} vs {cold['cells']}"
              f" ({leg1['ratio_cells'][app]:.3f}x)")
        # the acceptance criterion: strictly fewer messages AND cells
        # on the insert schedule (hard-asserted in the CI smoke leg)
        if args.smoke:
            assert inc["messages"] < cold["messages"], app
            assert inc["cells"] < cold["cells"], app

    print("mutate while serving ...")
    batches = 4 if args.smoke else 12
    leg2 = mutate_while_serving(scale, args.seed, shards, batches, 4)
    report["mutate_while_serving"] = leg2
    print(f"  {leg2['mutations_per_s']:.0f} edge-mutations/s, "
          f"{leg2['queries_per_s']:.1f} queries/s, "
          f"{leg2['queries_completed']} queries over {batches} batches")

    print("staleness vs recompute cost ...")
    leg3 = staleness_vs_cost(6 if args.smoke else 7, args.seed, 4,
                             8 if args.smoke else 16)
    report["staleness_vs_cost"] = leg3
    for key, row in leg3.items():
        if key == "auto_slo":
            print(f"  {key}: {row['maintenance_messages']} msgs over "
                  f"{row['auto_refreshes']} auto-refreshes "
                  f"(slo {row['staleness_slo']}, residual "
                  f"{row['residual_staleness']})")
        else:
            print(f"  {key}: {row['maintenance_messages']} msgs over "
                  f"{row['commits']} commits, staleness max "
                  f"{row['staleness_max']:.2e}")

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    common.finish_report(report, obs_out=args.obs_out)


if __name__ == "__main__":
    main()
