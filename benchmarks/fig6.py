"""Paper Fig 6: lazy-diffuse opportunities — % of delivered actions that
perform work (predicate true) and % of staged diffusions pruned.

Engine path gives the bulk-synchronous analog (messages vs work); the
cycle-level AM-CCA simulator gives the event-level numbers incl. pruning
at injection time.
"""
import numpy as np

from benchmarks.common import DATASETS, emit, reversed_graph, timed
from repro.apps import bfs
from repro.core.amcca_sim import AmccaSim
from repro.core.partition import PartitionConfig, build_partition


def main():
    for name, make in DATASETS.items():
        g = make()
        if name.startswith("BA"):   # reverse for traversal reach
            g = reversed_graph(g)
        root = int(np.argmax(g.out_degrees()))
        (levels, stats, part), us = timed(
            bfs, g, root, num_shards=16, rpvo_max=1)
        msgs = max(int(stats.messages), 1)
        work = int(stats.work_actions)
        emit(f"fig6/engine/{name}", us,
             f"actions={msgs};work_pct={100*work/msgs:.1f}")
    # event-level (simulator) on a small skewed graph
    from repro.graph import generators
    g = generators.rmat(10, edge_factor=8, seed=7).with_random_weights(seed=7)
    root = int(np.argmax(g.out_degrees()))
    part = build_partition(g, PartitionConfig(
        num_shards=256, rpvo_max=8, local_edge_list_size=8,
        ghost_alloc="vicinity", seed=1))
    sim = AmccaSim(part, torus=True)
    res, us = timed(sim.run_min_app, {root: 0.0}, True)  # SSSP: subsumption
    emit("fig6/amcca/R10-sssp", us,
         f"acts={res.actions_executed};"
         f"work_pct={100*res.work_actions/max(res.actions_executed,1):.1f};"
         f"pruned={res.diffusions_pruned}")


if __name__ == "__main__":
    main()
