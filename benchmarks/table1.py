"""Paper Table 1: input-graph statistics (degree mean/std/max/percentile)."""
from benchmarks.common import DATASETS, emit, timed
from repro.graph.graph import degree_stats


def main():
    for name, make in DATASETS.items():
        g, us = timed(make)
        s = degree_stats(g)
        derived = (f"V={s['vertices']};E={s['edges']};"
                   f"kin_mu={s['in']['mean']:.1f};kin_sd={s['in']['std']:.1f};"
                   f"kin_max={s['in']['max']};"
                   f"kout_max={s['out']['max']};in_skew={s['in_skew']:.1f}")
        emit(f"table1/{name}", us, derived)


if __name__ == "__main__":
    main()
