"""Engine hot-path benchmark: fused vs unfused relax phase (ISSUE 1),
the VMEM-tiled fused path (ISSUE 4), and the sparsity-proportional
worklist launches + delta-PageRank (ISSUE 5).

Runs BFS / SSSP / PageRank / delta-PageRank on a skewed RMAT graph
through the stacked engine:

* ``fused``    — the frontier-aware relax+reduce Pallas kernel, dense
  (num_sblk, num_chunks) grid with per-cell early exit, value table
  pinned in VMEM;
* ``tiled``    — the same kernel with the VMEM budget forced below the
  slot table (HBM-tiled double-buffered DMA, per-CHUNK tile lists);
* ``worklist`` — the 1-D live-cell worklist launch (host-planned each
  round from the frontier): late sparse rounds launch a handful of
  padded cells instead of the full grid;
* ``wl_tiled`` — worklist × tiled: per-CELL dst-range-filtered tile
  lists with j-major 2-slot reuse — the DMA bytes drop below the
  per-chunk baseline (``dst_filter_dma_reduction``);
* ``unfused``  — the pre-fusion composition (``pallas_mode='reduce'``);
* ``jnp``      — no Pallas at all, the oracle.

Every worklist round ALSO launches the kernel once with ``with_debug``
and asserts the kernel-side [executed cells, issued DMAs] counters equal
the host planner mirror EXACTLY — the provably-exact accounting bar.

``pagerank_delta`` runs the push-based residual rounds at the same
round count as dense PageRank: the frontier shrinks as residuals decay,
so messages, grid cells, and DMA bytes all drop round over round — the
first time the frontier machinery fires for the sum semiring.

Emits ``BENCH_engine.json`` (rounds, wall/round, messages/s, exact grid
cells, tiled-vs-pinned and worklist-vs-dense columns) for the perf
trajectory.

Usage:  PYTHONPATH=src python benchmarks/engine_bench.py [--out PATH]
        [--seed N] [--grid-mode dense|worklist|auto]
"""
from __future__ import annotations

import argparse
import json
import time

import common  # pins JAX_PLATFORMS=cpu before jax loads; --seed helper
import jax
import jax.numpy as jnp
import numpy as np

from common import disp_delta, disp_snap
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators
from repro.kernels.fused_relax_reduce import (
    _wl_pad_len, fused_grid_cells, fused_relax_reduce_pallas,
    select_kernel_path,
)


def _debug_check(part, sem, gval, gchg, total, worklist, vblk, cells):
    """Launch the fused kernel once more with ``with_debug`` on the exact
    per-round inputs and assert the kernel-side executed-cell / DMA
    counters equal the host mirror — exercised by the CI smoke leg."""
    args = (gval, jnp.asarray(gchg),
            jnp.asarray(part.edge_src_root_flat.reshape(-1)),
            jnp.asarray(part.edge_w.reshape(-1), jnp.float32),
            jnp.asarray(part.edge_mask.reshape(-1)),
            jnp.asarray(part.edge_dst_flat.reshape(-1)))
    if worklist is not None:
        _, dbg = fused_relax_reduce_pallas(
            *args, total, sem.relax_kind, sem.segment, worklist=worklist,
            with_debug=True)
        assert int(dbg[0]) == cells["wl_cells"], (int(dbg[0]), cells)
        want_dmas = cells["wl_tile_dmas"] if worklist.path == "tiled" else 0
        assert int(dbg[1]) == want_dmas, (int(dbg[1]), cells)
    else:
        _, dbg = fused_relax_reduce_pallas(
            *args, total, sem.relax_kind, sem.segment,
            path="tiled" if vblk else "pinned", vblk=vblk, with_debug=True)
        assert int(dbg[0]) == cells["fused_live"], (int(dbg[0]), cells)
        if vblk:
            assert int(dbg[1]) == cells["fused_tile_dmas"]


def bench_rounds(sem, part, sources, cfg, max_rounds, fixed_rounds=None,
                 repeats=5, damping=0.85, vblk=None, delta_tol=None,
                 check_debug=False):
    """Drive the stacked engine round-by-round (jitted round fn — the
    exact round the shipped runners execute), timing each round
    (best-of-``repeats``, the round fn is pure), mirroring the grid-cell
    / DMA counts from the frontier, and — for worklist variants —
    planning each round's live-cell launch exactly as the host-driven
    runners do."""
    arrays = engine.DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    total = S * R_max
    planner = engine.launch_planner(part, cfg) if cfg.wants_worklist \
        else None
    # the mirror must follow the planner's ACTUAL residency — the
    # REPRO_VMEM_BUDGET env var can tip a nominally-pinned variant onto
    # the tiled path (that composition is exactly what the CI smoke leg
    # exercises)
    if planner is not None and planner.path == "tiled" and vblk is None:
        vblk = planner.vblk

    if delta_tol is not None:      # delta-PageRank residual rounds
        tol_j = jnp.asarray(delta_tol, jnp.float32)
        base = (1.0 - damping) / part.n

        @jax.jit
        def round_fn(state, wl):
            rank, delta = state
            nr, nd, _, mc = engine.exchange.delta_pagerank_round_stacked(
                sem, arrays, cfg, S, R_max, damping, tol_j, rank, delta,
                worklist=wl)
            return (nr, nd), mc

        init = jnp.where(arrays.slot_valid, base, 0.0)
        state = (init, init)

        def frontier(state):
            return np.asarray((state[1] > tol_j) & arrays.slot_valid)

        def relax_inputs(state):
            return state[1].reshape(-1), frontier(state).reshape(-1)

    elif sem.segment == "sum":     # PageRank: the counted dense round
        base = (1.0 - damping) / part.n
        chg = arrays.slot_valid    # PR predicate is #t — always diffuse

        @jax.jit
        def round_fn(state, wl):
            nv, mc = engine._pagerank_round_stacked(
                sem, arrays, cfg, S, R_max, base, damping, state[0], chg,
                worklist=wl)
            return (nv,), mc

        state = (jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0),)

        def frontier(_):
            return np.asarray(arrays.slot_valid)

        def relax_inputs(state):
            return state[0].reshape(-1), \
                np.asarray(arrays.slot_valid).reshape(-1)

    else:                          # BFS/SSSP: the fixpoint round

        @jax.jit
        def round_fn(state, wl):
            nv, nc, mc = engine._fixpoint_round_stacked(
                sem, arrays, cfg, S, R_max, state[0], state[1],
                worklist=wl)
            return (nv, nc), mc

        init = engine.init_values(part, sem, sources)
        val = jnp.asarray(init)
        chg = sem.improved(val, jnp.full_like(val, sem.identity)) \
            & arrays.slot_valid
        state = (val, chg)

        def frontier(state):
            return np.asarray(state[1])

        def relax_inputs(state):
            return state[0].reshape(-1), np.asarray(state[1]).reshape(-1)

    # compile outside timing (the worklist retraces per pow2 bucket; the
    # best-of-repeats timing below absorbs those)
    wl0 = (engine.plan_round_worklist(planner, cfg,
                                      frontier(state).reshape(-1))
           if planner else None)
    jax.tree.map(lambda x: x.block_until_ready(),
                 round_fn(state, wl0)[0])

    rounds = []
    n = fixed_rounds if fixed_rounds is not None else max_rounds
    for _ in range(n):
        chg_h = frontier(state)
        if fixed_rounds is None and not chg_h.any():
            break
        gchg = chg_h.reshape(-1)
        cells = fused_grid_cells(
            part.edge_dst_flat, part.edge_mask, part.edge_src_root_flat,
            gchg, total, vblk=vblk,
            grid_mode="worklist" if planner else "dense")
        wl = (engine.plan_round_worklist(planner, cfg, gchg)
              if planner else None)
        if check_debug and cfg.use_pallas and cfg.pallas_mode == "fused" \
                and cfg.exchange == "dense":
            gval_f, gchg_f = relax_inputs(state)
            _debug_check(part, sem, gval_f, gchg_f, total, wl, vblk, cells)
        dt = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            nstate, msg_count = round_fn(state, wl)
            nstate[0].block_until_ready()
            dt = min(dt, time.perf_counter() - t0)
        state = nstate
        # one *logical* dispatch + host sync per host-driven round (the
        # timing repeats re-execute the same round and are not counted)
        engine._count_dispatches("bench", 1, 1)
        row = {
            "wall_s": dt,
            "messages": int(msg_count),
            "grid_fused_live": cells["fused_live"],
            "grid_range_live": cells["range_live"],
            "grid_total_fused": cells["total_fused"],
            "grid_total_unfused": cells["total_unfused"],
        }
        if vblk is not None:
            row["grid_tile_dmas"] = cells["fused_tile_dmas"]
            row["dma_bytes"] = cells["dma_bytes"]
        if planner is not None:
            row["grid_wl_cells"] = cells["wl_cells"]
            row["grid_wl_launched"] = cells["wl_launched"]
            if vblk is not None:
                row["wl_tile_dmas"] = cells["wl_tile_dmas"]
                row["wl_dma_bytes"] = cells["wl_dma_bytes"]
        rounds.append(row)
    return rounds


def summarize(rounds, cell_key):
    total_msgs = sum(r["messages"] for r in rounds)
    total_wall = sum(r["wall_s"] for r in rounds)
    executed = (sum(r[cell_key] for r in rounds)
                if cell_key is not None else 0)
    out = {
        "rounds": len(rounds),
        "wall_s_total": total_wall,
        "wall_s_per_round": total_wall / max(len(rounds), 1),
        "messages_total": total_msgs,
        "messages_per_s": total_msgs / max(total_wall, 1e-12),
        "grid_cells_executed": executed,
        "per_round": rounds,
    }
    if rounds and "dma_bytes" in rounds[0]:
        out["tile_dmas_total"] = sum(r["grid_tile_dmas"] for r in rounds)
        out["dma_bytes_total"] = sum(r["dma_bytes"] for r in rounds)
    if rounds and "wl_dma_bytes" in rounds[0]:
        out["wl_tile_dmas_total"] = sum(r["wl_tile_dmas"] for r in rounds)
        out["wl_dma_bytes_total"] = sum(r["wl_dma_bytes"] for r in rounds)
    if rounds and "grid_wl_cells" in rounds[0]:
        out["wl_cells_total"] = sum(r["grid_wl_cells"] for r in rounds)
        out["wl_launched_total"] = sum(r["grid_wl_launched"]
                                      for r in rounds)
    return out


def _device_debug_check(part, sem, gval, gchg, total):
    """Launch the fused kernel once in ``grid_mode='device_worklist'``
    with ``with_debug`` and assert the kernel-side executed-cell / DMA
    counters equal the host mirror for the device-compacted launch —
    the CI device-worklist smoke leg's assertion."""
    cells = fused_grid_cells(
        part.edge_dst_flat, part.edge_mask, part.edge_src_root_flat,
        gchg, total, grid_mode="device_worklist")
    _, dbg = fused_relax_reduce_pallas(
        jnp.asarray(gval), jnp.asarray(gchg),
        jnp.asarray(part.edge_src_root_flat.reshape(-1)),
        jnp.asarray(part.edge_w.reshape(-1), jnp.float32),
        jnp.asarray(part.edge_mask.reshape(-1)),
        jnp.asarray(part.edge_dst_flat.reshape(-1)),
        total, sem.relax_kind, sem.segment,
        grid_mode="device_worklist", with_debug=True)
    assert int(dbg[0]) == cells["wl_cells"], (int(dbg[0]), cells)
    assert int(dbg[1]) == cells["wl_tile_dmas"], (int(dbg[1]), cells)


def bench_device_fixpoint(name, sem, part, sources, max_rounds,
                          damping=0.85, delta_tol=None):
    """Run the WHOLE fixpoint through the shipped device-resident runner
    (``grid_mode='device_worklist'``, no recorder → one traced
    ``lax.while_loop``) and report wall time plus the obs-registry
    dispatch counters — the ISSUE-8 acceptance row: ``dispatches_total``
    must be exactly 1 for the full fixpoint."""
    cfg = engine.EngineConfig(use_pallas=True,
                              grid_mode="device_worklist")
    if delta_tol is not None:
        def run():
            return engine.run_pagerank_delta(
                part, damping=damping, tol=delta_tol, cfg=cfg,
                max_rounds=max_rounds)
    else:
        init = engine.init_values(part, sem, sources)

        def run():
            return engine.run_stacked(sem, part, init, cfg)

    run()                               # compile outside timing
    snap = disp_snap()
    t0 = time.perf_counter()
    val, stats = run()
    jax.block_until_ready(val)
    wall = time.perf_counter() - t0
    dd, ds = disp_delta(snap)
    assert dd == 1, f"{name}: device fixpoint took {dd} dispatches"
    rounds = int(stats.iterations)
    planner = engine.launch_planner(
        part, engine.EngineConfig(use_pallas=True, grid_mode="worklist"))
    l_pad = _wl_pad_len(planner.total_cells)
    return {
        "rounds": rounds,
        "wall_s_total": wall,
        "wall_s_per_round": wall / max(rounds, 1),
        "messages_total": int(stats.messages),
        "messages_per_s": int(stats.messages) / max(wall, 1e-12),
        "grid_cells_executed": 0,   # on device; exactness asserted in
                                    # tests/test_worklist.py vs planner
        "wl_launched_total": l_pad * rounds,
        "dispatches_total": int(dd),
        "host_syncs_per_round": ds / max(rounds, 1),
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--scale", type=int, default=10,
                    help="RMAT scale (n = 2**scale)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--rpvo-max", type=int, default=4)
    ap.add_argument("--pr-iters", type=int, default=10)
    ap.add_argument("--pr-tol", type=float, default=3e-5,
                    help="delta-PageRank residual tolerance (default "
                         "chosen so the BENCH RMAT frontier decays "
                         "through it within --pr-iters rounds)")
    ap.add_argument("--max-rounds", type=int, default=64)
    ap.add_argument("--repeats", type=int, default=5)
    common.add_seed_arg(ap)
    common.add_obs_out_arg(ap)
    common.add_grid_mode_arg(ap)
    args = ap.parse_args()

    g = generators.rmat(args.scale, edge_factor=args.edge_factor,
                        seed=args.seed)
    gw = g.with_random_weights(seed=args.seed)
    root = int(np.argmax(g.out_degrees()))
    pcfg = PartitionConfig(num_shards=args.shards, rpvo_max=args.rpvo_max)

    report = {
        "bench": "engine_round",
        "graph": {"kind": "rmat", "scale": args.scale,
                  "edge_factor": args.edge_factor, "n": g.n,
                  "num_edges": g.num_edges, "root": root,
                  "seed": args.seed},
        "config": {"shards": args.shards, "rpvo_max": args.rpvo_max,
                   "grid_mode": args.grid_mode, "pr_tol": args.pr_tol,
                   "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu"},
        "notes": (
            "Grid-cell counts are exact mirrors of each variant's launch "
            "shape (fused: dense flattened launch with frontier chunk "
            "skip; worklist: host-planned 1-D live-cell launch, kernel "
            "with_debug counters asserted equal to the mirror every "
            "round; unfused: S per-shard reduce launches, range skip "
            "only). Dense PageRank diffuses every round (predicate #t), "
            "so only the delta-PageRank rounds shrink the sum-semiring "
            "frontier — compare the pagerank_delta rows' messages/cells "
            "against pagerank at the same round count. wl_tiled's "
            "per-cell dst-filtered tile lists + j-major reuse cut "
            "dma_bytes below tiled's per-chunk baseline "
            "(dst_filter_dma_reduction). dispatches_total / "
            "host_syncs_per_round are obs-registry deltas: host-driven "
            "variants pay one dispatch+sync per round; the "
            "device_worklist row runs the WHOLE fixpoint as one traced "
            "lax.while_loop dispatch (asserted == 1) with on-device "
            "frontier compaction."),
        "apps": {},
    }

    from repro.apps.pagerank import _pr_graph
    part = build_partition(gw, pcfg)
    part_pr = build_partition(_pr_graph(g), pcfg)

    # (name, semiring, partition, sources, fixed_rounds, delta_tol)
    jobs = [
        ("bfs", actions.BFS, part, {root: 0.0}, None, None),
        ("sssp", actions.SSSP, part, {root: 0.0}, None, None),
        ("pagerank", actions.PAGERANK, part_pr, {}, args.pr_iters, None),
        # same round count as dense pagerank -> apples-to-apples pruning
        ("pagerank_delta", actions.PAGERANK, part_pr, {}, args.pr_iters,
         args.pr_tol),
    ]
    for name, sem, p, sources, fixed, dtol in jobs:
        entry = {}
        # budget a quarter of the padded slot table's bytes — always below
        # the table, so the fused launch takes the tiled path at any
        # --scale (an absolute floor would fall back to pinned on small
        # partitions and silently bench the wrong kernel)
        slots = p.S * p.R_max
        v_pad = -(-slots // 128) * 128
        budget = v_pad * 4 // 4
        path, vblk = select_kernel_path(slots, 1, budget)
        assert path == "tiled", (slots, budget)
        entry["kernel_budget"] = {"vmem_budget_bytes": budget,
                                  "vblk": vblk, "slots": slots}
        variants = [
            ("fused", engine.EngineConfig(use_pallas=True),
             "grid_fused_live", None),
            ("unfused",
             engine.EngineConfig(use_pallas=True, pallas_mode="reduce"),
             "grid_range_live", None),
            ("jnp", engine.EngineConfig(use_pallas=False), None, None),
            ("tiled",
             engine.EngineConfig(use_pallas=True,
                                 vmem_budget_bytes=budget),
             "grid_fused_live", vblk),
        ]
        if args.grid_mode != "dense":
            # the per-round host-planned variants need a host planner —
            # under --grid-mode device_worklist they keep planning with
            # 'worklist' and the device_worklist row below covers the
            # device-compacted whole-fixpoint dispatch
            host_mode = args.grid_mode \
                if args.grid_mode in ("worklist", "auto") else "worklist"
            variants += [
                ("worklist",
                 engine.EngineConfig(use_pallas=True,
                                     grid_mode=host_mode),
                 "grid_wl_cells", None),
                ("wl_tiled",
                 engine.EngineConfig(use_pallas=True,
                                     grid_mode=host_mode,
                                     vmem_budget_bytes=budget),
                 "grid_wl_cells", vblk),
            ]
        for label, cfg, cell_key, use_vblk in variants:
            snap = disp_snap()
            rounds = bench_rounds(
                sem, p, sources, cfg, args.max_rounds, fixed_rounds=fixed,
                repeats=args.repeats, vblk=use_vblk, delta_tol=dtol,
                check_debug=label.startswith(("worklist", "wl_", "fused",
                                              "tiled")))
            dd, ds = disp_delta(snap)
            entry[label] = summarize(rounds, cell_key)
            entry[label]["dispatches_total"] = int(dd)
            entry[label]["host_syncs_per_round"] = \
                ds / max(len(rounds), 1)
            print(f"{name:15s} {label:8s} "
                  f"rounds={entry[label]['rounds']:3d} "
                  f"wall/round={entry[label]['wall_s_per_round']*1e3:8.2f}ms "
                  f"msgs/s={entry[label]['messages_per_s']:.3e} "
                  f"cells={entry[label]['grid_cells_executed']}")
        if args.grid_mode != "dense" and name != "pagerank":
            # ISSUE-8 acceptance row: the whole fixpoint as ONE traced
            # dispatch, plus the device-compaction mirror assertion on
            # the first frontier (kernel with_debug == host mirror)
            if dtol is None:
                init = engine.init_values(p, sem, sources)
                arrays0 = engine.DeviceArrays.from_partition(p)
                val0 = jnp.asarray(init)
                chg0 = sem.improved(
                    val0, jnp.full_like(val0, sem.identity)) \
                    & arrays0.slot_valid
                _device_debug_check(p, sem, np.asarray(val0).reshape(-1),
                                    np.asarray(chg0).reshape(-1), slots)
            entry["device_worklist"] = bench_device_fixpoint(
                name, sem, p, sources, args.max_rounds
                if fixed is None else fixed, delta_tol=dtol)
            dw = entry["device_worklist"]
            print(f"{name:15s} {'device':8s} "
                  f"rounds={dw['rounds']:3d} "
                  f"wall/round={dw['wall_s_per_round']*1e3:8.2f}ms "
                  f"msgs/s={dw['messages_per_s']:.3e} "
                  f"dispatches={dw['dispatches_total']}")
        f, u, t = entry["fused"], entry["unfused"], entry["tiled"]
        entry["tiled_vs_pinned"] = {
            "wall_s_per_round_tiled": t["wall_s_per_round"],
            "wall_s_per_round_pinned": f["wall_s_per_round"],
            "wall_ratio": t["wall_s_per_round"]
            / max(f["wall_s_per_round"], 1e-12),
            "grid_cells_tiled": t["grid_cells_executed"],
            "grid_cells_pinned": f["grid_cells_executed"],
            "tile_dmas_total": t.get("tile_dmas_total", 0),
            "dma_bytes_total": t.get("dma_bytes_total", 0),
        }
        if "wl_tiled" in entry:
            wt = entry["wl_tiled"]
            # ISSUE-5 acceptance: per-cell dst-range filtering + reuse
            # strictly <= (and on multi-SBLK partitions <) the per-chunk
            # tile lists' DMA bytes, at identical round structure
            entry["dst_filter_dma_reduction"] = {
                "dma_bytes_per_chunk_lists": t.get("dma_bytes_total", 0),
                "dma_bytes_per_cell_filtered":
                    wt.get("wl_dma_bytes_total", 0),
                "reduction": 1.0 - wt.get("wl_dma_bytes_total", 0)
                / max(t.get("dma_bytes_total", 1), 1),
            }
            assert wt.get("wl_dma_bytes_total", 0) \
                <= t.get("dma_bytes_total", 0)
            wl = entry["worklist"]
            entry["worklist_vs_dense"] = {
                "cells_launched_worklist": wl["wl_launched_total"],
                "cells_live_worklist": wl["wl_cells_total"],
                "cells_executed_dense": f["grid_cells_executed"],
                "grid_total_dense":
                    sum(r["grid_total_fused"] for r in f["per_round"]),
                "wall_s_per_round_worklist": wl["wall_s_per_round"],
                "wall_s_per_round_dense": f["wall_s_per_round"],
            }
            if fixed is None and wl["per_round"]:
                late = wl["per_round"][-1]
                entry["late_round_worklist"] = {
                    "wl_cells": late["grid_wl_cells"],
                    "wl_launched": late["grid_wl_launched"],
                    "dense_grid": late["grid_total_fused"],
                    "dense_live": late["grid_fused_live"],
                }
        # the frontier skip must fire: strictly fewer grid cells on the
        # late sparse rounds of the frontier apps (incl. delta-PR)
        if fixed is None and f["per_round"]:
            late = f["per_round"][-1]
            entry["late_round_skip"] = {
                "fused_live": late["grid_fused_live"],
                "range_live": late["grid_range_live"],
                "skip_firing": late["grid_fused_live"]
                < late["grid_range_live"],
            }
        entry["grid_cell_reduction"] = (
            1.0 - f["grid_cells_executed"] / max(u["grid_cells_executed"], 1))
        report["apps"][name] = entry

    pr, prd = report["apps"]["pagerank"], report["apps"]["pagerank_delta"]
    report["delta_vs_dense_pagerank"] = {
        "rounds": (pr["fused"]["rounds"], prd["fused"]["rounds"]),
        "messages": (pr["fused"]["messages_total"],
                     prd["fused"]["messages_total"]),
        "grid_cells": (pr["fused"]["grid_cells_executed"],
                       prd["fused"]["grid_cells_executed"]),
        "delta_prunes": prd["fused"]["messages_total"]
        < pr["fused"]["messages_total"]
        and prd["fused"]["grid_cells_executed"]
        < pr["fused"]["grid_cells_executed"],
    }
    # the ISSUE-5 acceptance bar (strictly fewer messages AND cells)
    # holds whenever the residual frontier actually thinned a chunk
    # within the round budget — guaranteed at the committed BENCH
    # parameters (scale 10, 10 iters, pr-tol 3e-5); short/small runs may
    # prune messages before any whole edge chunk goes dead, so gate the
    # strict cell assert on the observed last-round frontier
    assert prd["fused"]["messages_total"] \
        <= pr["fused"]["messages_total"]
    last_delta = prd["fused"]["per_round"][-1]["grid_fused_live"]
    last_dense = pr["fused"]["per_round"][-1]["grid_fused_live"]
    if last_delta < last_dense:
        assert report["delta_vs_dense_pagerank"]["delta_prunes"], \
            report["delta_vs_dense_pagerank"]

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")
    common.finish_report(report, obs_out=args.obs_out)


if __name__ == "__main__":
    main()
