"""Engine hot-path benchmark: fused vs unfused relax phase (ISSUE 1),
plus the VMEM-tiled fused path (ISSUE 4).

Runs BFS / SSSP / PageRank on a skewed RMAT graph through the stacked
engine four ways — ``fused`` (the frontier-aware relax+reduce Pallas
kernel, value table pinned in VMEM), ``tiled`` (the same kernel with the
VMEM budget forced below the slot table so every launch runs the
HBM-tiled double-buffered-DMA path), ``unfused`` (the pre-fusion
composition: XLA gather/relax/mask ops + the standalone Pallas
segment-reduce kernel, ``pallas_mode='reduce'``), and ``jnp`` (no Pallas
at all, the oracle) — measuring per-round wall time, delivered messages,
and the exact number of Pallas grid cells each variant executes per
round (``fused_grid_cells`` mirrors the kernel's skip predicates; for
the tiled variant it additionally mirrors the per-cell value-tile DMA
issues and bytes).

Emits ``BENCH_engine.json`` so future PRs have a perf trajectory:

    rounds, wall-time/round, messages/s per app x variant, per-round
    grid-cell counts demonstrating the frontier skip firing on late
    sparse BFS/SSSP rounds, and tiled-vs-pinned wall/round + DMA-byte
    columns (``tiled_vs_pinned``) for the out-of-core path.

Usage:  PYTHONPATH=src python benchmarks/engine_bench.py [--out PATH]
        [--seed N]
"""
from __future__ import annotations

import argparse
import json
import time

import common  # pins JAX_PLATFORMS=cpu before jax loads; --seed helper
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators
from repro.kernels.fused_relax_reduce import (
    fused_grid_cells, select_kernel_path,
)


def bench_rounds(sem, part, sources, cfg, max_rounds, fixed_rounds=None,
                 repeats=5, damping=0.85, vblk=None):
    """Drive the stacked engine round-by-round (jitted round fn — the
    exact round the shipped runners execute), timing each round
    (best-of-``repeats``, the round fn is pure) and mirroring the
    grid-cell skip counts from the frontier."""
    arrays = engine.DeviceArrays.from_partition(part)
    total = part.S * part.R_max

    if sem.segment == "sum":   # PageRank: the run_pagerank_stacked round
        base = (1.0 - damping) / part.n

        @jax.jit
        def round_fn(v, c):
            nv, mc = engine._pagerank_round_stacked(
                sem, arrays, cfg, part.S, part.R_max, base, damping, v, c)
            return nv, c, mc

        val = jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0)
        chg = arrays.slot_valid
    else:                      # BFS/SSSP: the run_stacked fixpoint round

        @jax.jit
        def round_fn(v, c):
            return engine._fixpoint_round_stacked(
                sem, arrays, cfg, part.S, part.R_max, v, c)

        init = engine.init_values(part, sem, sources)
        val = jnp.asarray(init)
        chg = sem.improved(val, jnp.full_like(val, sem.identity)) \
            & arrays.slot_valid

    round_fn(val, chg)[0].block_until_ready()        # compile outside timing

    rounds = []
    n = fixed_rounds if fixed_rounds is not None else max_rounds
    for _ in range(n):
        if fixed_rounds is None and not bool(jnp.any(chg)):
            break
        cells = fused_grid_cells(
            part.edge_dst_flat, part.edge_mask, part.edge_src_root_flat,
            np.asarray(chg).reshape(-1), total, vblk=vblk)
        dt = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            nval, nchg, msg_count = round_fn(val, chg)
            nval.block_until_ready()
            dt = min(dt, time.perf_counter() - t0)
        val, chg = nval, nchg
        row = {
            "wall_s": dt,
            "messages": int(msg_count),
            "grid_fused_live": cells["fused_live"],
            "grid_range_live": cells["range_live"],
            "grid_total_fused": cells["total_fused"],
            "grid_total_unfused": cells["total_unfused"],
        }
        if vblk is not None:
            row["grid_tile_dmas"] = cells["fused_tile_dmas"]
            row["dma_bytes"] = cells["dma_bytes"]
        rounds.append(row)
    return rounds


def summarize(rounds, cell_key):
    total_msgs = sum(r["messages"] for r in rounds)
    total_wall = sum(r["wall_s"] for r in rounds)
    executed = (sum(r[cell_key] for r in rounds)
                if cell_key is not None else 0)
    out = {
        "rounds": len(rounds),
        "wall_s_total": total_wall,
        "wall_s_per_round": total_wall / max(len(rounds), 1),
        "messages_total": total_msgs,
        "messages_per_s": total_msgs / max(total_wall, 1e-12),
        "grid_cells_executed": executed,
        "per_round": rounds,
    }
    if rounds and "dma_bytes" in rounds[0]:
        out["tile_dmas_total"] = sum(r["grid_tile_dmas"] for r in rounds)
        out["dma_bytes_total"] = sum(r["dma_bytes"] for r in rounds)
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--scale", type=int, default=10,
                    help="RMAT scale (n = 2**scale)")
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--shards", type=int, default=8)
    ap.add_argument("--rpvo-max", type=int, default=4)
    ap.add_argument("--pr-iters", type=int, default=10)
    ap.add_argument("--max-rounds", type=int, default=64)
    common.add_seed_arg(ap)
    args = ap.parse_args()

    g = generators.rmat(args.scale, edge_factor=args.edge_factor,
                        seed=args.seed)
    gw = g.with_random_weights(seed=args.seed)
    root = int(np.argmax(g.out_degrees()))
    pcfg = PartitionConfig(num_shards=args.shards, rpvo_max=args.rpvo_max)

    report = {
        "bench": "engine_round",
        "graph": {"kind": "rmat", "scale": args.scale,
                  "edge_factor": args.edge_factor, "n": g.n,
                  "num_edges": g.num_edges, "root": root,
                  "seed": args.seed},
        "config": {"shards": args.shards, "rpvo_max": args.rpvo_max,
                   "backend": jax.default_backend(),
                   "interpret_mode": jax.default_backend() != "tpu"},
        "notes": (
            "Grid-cell counts are exact mirrors of each variant's launch "
            "shape (fused: one flattened launch with frontier chunk skip; "
            "unfused: S per-shard reduce launches, range skip only). "
            "PageRank diffuses every round (predicate #t), so the frontier "
            "skip cannot fire there and the fused kernel's in-cell gather "
            "is pure overhead under CPU interpret mode; the skip's win "
            "shows on the sparse late rounds of the fixpoint apps."),
        "apps": {},
    }

    from repro.apps.pagerank import _pr_graph
    part = build_partition(gw, pcfg)
    part_pr = build_partition(_pr_graph(g), pcfg)

    jobs = [
        ("bfs", actions.BFS, part, {root: 0.0}, None),
        ("sssp", actions.SSSP, part, {root: 0.0}, None),
        ("pagerank", actions.PAGERANK, part_pr, {}, args.pr_iters),
    ]
    variants = [
        ("fused", engine.EngineConfig(use_pallas=True), "grid_fused_live"),
        ("unfused",
         engine.EngineConfig(use_pallas=True, pallas_mode="reduce"),
         "grid_range_live"),
        ("jnp", engine.EngineConfig(use_pallas=False), None),
    ]
    for name, sem, p, sources, fixed in jobs:
        entry = {}
        # budget a quarter of the padded slot table's bytes — always below
        # the table, so the fused launch takes the tiled path at any
        # --scale (an absolute floor would fall back to pinned on small
        # partitions and silently bench the wrong kernel)
        slots = p.S * p.R_max
        v_pad = -(-slots // 128) * 128
        budget = v_pad * 4 // 4
        path, vblk = select_kernel_path(slots, 1, budget)
        assert path == "tiled", (slots, budget)
        entry["kernel_budget"] = {"vmem_budget_bytes": budget,
                                  "vblk": vblk, "slots": slots}
        tiled_cfg = engine.EngineConfig(use_pallas=True,
                                        vmem_budget_bytes=budget)
        for label, cfg, cell_key in variants + [
                ("tiled", tiled_cfg, "grid_fused_live")]:
            rounds = bench_rounds(
                sem, p, sources, cfg, args.max_rounds, fixed_rounds=fixed,
                vblk=vblk if label == "tiled" else None)
            entry[label] = summarize(rounds, cell_key)
            print(f"{name:9s} {label:8s} rounds={entry[label]['rounds']:3d} "
                  f"wall/round={entry[label]['wall_s_per_round']*1e3:8.2f}ms "
                  f"msgs/s={entry[label]['messages_per_s']:.3e} "
                  f"cells={entry[label]['grid_cells_executed']}")
        f, u, t = entry["fused"], entry["unfused"], entry["tiled"]
        entry["tiled_vs_pinned"] = {
            "wall_s_per_round_tiled": t["wall_s_per_round"],
            "wall_s_per_round_pinned": f["wall_s_per_round"],
            "wall_ratio": t["wall_s_per_round"]
            / max(f["wall_s_per_round"], 1e-12),
            "grid_cells_tiled": t["grid_cells_executed"],
            "grid_cells_pinned": f["grid_cells_executed"],
            "tile_dmas_total": t.get("tile_dmas_total", 0),
            "dma_bytes_total": t.get("dma_bytes_total", 0),
        }
        # the frontier skip must fire: strictly fewer grid cells on the
        # late sparse rounds of the fixpoint apps
        if fixed is None and f["per_round"]:
            late = f["per_round"][-1]
            entry["late_round_skip"] = {
                "fused_live": late["grid_fused_live"],
                "range_live": late["grid_range_live"],
                "skip_firing": late["grid_fused_live"]
                < late["grid_range_live"],
            }
        entry["grid_cell_reduction"] = (
            1.0 - f["grid_cells_executed"] / max(u["grid_cells_executed"], 1))
        report["apps"][name] = entry

    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
