"""Batched serving example: prefill + incremental decode with KV caches /
recurrent states, across three architecture families (dense KV cache,
MoE, and an O(1)-state xLSTM — the long_500k-capable family).

  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs import get_config
from repro.lm.models.model import Model

for arch in ("phi3-medium-14b", "granite-moe-1b-a400m", "xlstm-125m"):
    cfg = get_config(arch).reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init(key)
    B, S, GEN = 4, 12, 6
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    caches = model.init_cache(B, S + GEN)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    logits, caches = prefill(params, {"tokens": toks}, caches)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok)[:, 0]]
    t0 = time.time()
    for i in range(GEN - 1):
        logits, caches = decode(params, tok, caches,
                                jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok)[:, 0])
    dt = time.time() - t0
    gen = np.stack(out, 1)
    state_kind = ("recurrent state" if cfg.family == "ssm" else "KV cache")
    print(f"{arch:22s} [{cfg.family:6s}] generated {gen.shape} via "
          f"{state_kind}; {B * (GEN - 1) / max(dt, 1e-9):7.1f} tok/s "
          f"sample={gen[0].tolist()}")
