"""Dynamic graph processing (paper §7 future work): mutate the graph with
edge-insertion actions, then recompute BFS incrementally from the
affected region — without starting from scratch.

  PYTHONPATH=src python examples/dynamic_graphs.py
"""
import numpy as np

from repro.core.dynamic import DynamicGraph
from repro.core.partition import PartitionConfig
from repro.graph import generators, reference

g = generators.rmat(12, edge_factor=8, seed=3)
root = int(np.argmax(g.out_degrees()))
dg = DynamicGraph.build(g, PartitionConfig(num_shards=16, rpvo_max=8))

lv0, full_stats = dg.bfs_full(root)
print(f"initial BFS: {int(full_stats.iterations)} rounds, "
      f"{int(full_stats.messages)} messages")

# an action inserts shortcut edges (hub -> far vertices)
UNREACHED = np.iinfo(np.int32).max
reached = np.nonzero(lv0 != UNREACHED)[0]
far = reached[np.argsort(lv0[reached])[-8:]]
seeds = dg.insert_edges(np.full(far.shape, root, np.int32), far.astype(np.int32))
print(f"inserted {far.size} shortcut edges from the root")

lv1, inc_stats = dg.bfs_incremental_insert(seeds)
assert (lv1 == reference.bfs_levels(dg.g, root)).all()
improved = int((lv1[reached] < lv0[reached]).sum())
print(f"incremental BFS: {int(inc_stats.iterations)} rounds, "
      f"{int(inc_stats.messages)} messages "
      f"({100 * int(inc_stats.messages) / max(int(full_stats.messages), 1):.1f}% "
      f"of from-scratch), {improved} vertices improved — verified exact")

# deletions invalidate monotone state -> full recompute path
dg.delete_edges([int(g.src[0])], [int(g.dst[0])])
lv2, _ = dg.bfs_full(root)
assert (lv2 == reference.bfs_levels(dg.g, root)).all()
print("post-delete full recompute verified exact")
