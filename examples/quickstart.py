"""Quickstart: Rhizomatic-RPVO graph processing in five minutes.

Builds a skewed synthetic graph, partitions it three ways ('simple
vertex', RPVO, Rhizomatic-RPVO), runs diffusive BFS / SSSP / PageRank on
the JAX engine, and prints the data-structure cost metrics that the
paper's technique improves.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import bfs, pagerank, sssp
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.graph.graph import degree_stats

# 1. a highly skewed graph (RMAT, same generator family as the paper's R18)
g = generators.rmat(12, edge_factor=16, seed=0).with_random_weights(seed=0)
stats = degree_stats(g)
print(f"graph: V={stats['vertices']} E={stats['edges']} "
      f"max_in={stats['in']['max']} in_skew={stats['in_skew']:.1f}")

# 2. three layouts for the same graph
layouts = {
    "simple-vertex": PartitionConfig(num_shards=64, rpvo_max=1,
                                     ghost_alloc="home"),
    "rpvo": PartitionConfig(num_shards=64, rpvo_max=1,
                            ghost_alloc="balanced", local_edge_list_size=32),
    "rhizomatic-rpvo": PartitionConfig(num_shards=64, rpvo_max=16,
                                       ghost_alloc="balanced",
                                       local_edge_list_size=32),
}
parts = {}
for name, pc in layouts.items():
    part = build_partition(g, pc)
    parts[name] = part
    m = part.metrics
    print(f"{name:18s} E_max={m['E_max']:7d} (balance {m['edge_balance']:.2f}) "
          f"hot-inbox={m['max_inbox_per_slot']:6d} replicas=+{m['replicas_total']-g.n}")

# 3. run the three diffusive apps on the rhizomatic layout
root = int(np.argmax(g.out_degrees()))
part = parts["rhizomatic-rpvo"]

levels, st, _ = bfs(g, root, part=part)
assert (levels == reference.bfs_levels(g, root)).all()
print(f"BFS ok: {int(st.iterations)} rounds, "
      f"{int(st.messages)} actions, "
      f"{100 * int(st.work_actions) / max(int(st.messages), 1):.1f}% did work "
      f"(the rest pruned by their predicate)")

dist, _, _ = sssp(g, root, part=part)
ref = reference.sssp_dijkstra(g, root)
finite = np.isfinite(ref)
assert np.allclose(dist[finite], ref[finite], rtol=1e-5)
print("SSSP ok: matches Dijkstra oracle")

pr, _ = pagerank(g, iters=20, num_shards=64, rpvo_max=16)
assert np.allclose(pr, reference.pagerank(g, iters=20), rtol=1e-4, atol=1e-7)
print("PageRank ok: matches power-iteration oracle "
      "(rhizome-collapse = AND-gate all-reduce)")

# 4. query serving: many concurrent queries on ONE shared partition.
# A batch of mixed BFS/SSSP queries runs as lanes of a single fixpoint
# (the value table grows a query axis), and QueryServer continuously
# batches a request stream into lanes freed mid-flight — a short query
# never waits behind a long one.
from repro.apps import batched_queries
from repro.query import QueryServer

deg = np.argsort(-g.out_degrees())
queries = [("bfs", int(deg[0])), ("sssp", int(deg[1])),
           ("bfs", int(deg[2])), ("sssp", int(deg[3]))]
results, lane_stats, _ = batched_queries(g, queries, part=part)
assert (results[0] == reference.bfs_levels(g, int(deg[0]))).all()
print(f"lane batch ok: {len(queries)} queries, per-lane rounds="
      f"{np.asarray(lane_stats.rounds).tolist()}")

# the same batch on the compact *targeted* exchange (§Perf): only
# (target, distinct-slot) contributions travel — bit-identical results,
# strictly fewer exchanged entries per lane
from repro.core.engine import EngineConfig

res_c, stats_c, _ = batched_queries(
    g, queries, part=part, cfg=EngineConfig(exchange="compact"))
assert all((a == b).all() for a, b in zip(results, res_c))
dense_vol = int(np.asarray(lane_stats.exchanged).sum())
compact_vol = int(np.asarray(stats_c.exchanged).sum())
assert compact_vol < dense_vol
print(f"compact targeted exchange ok: bit-identical, "
      f"{dense_vol / compact_vol:.1f}x less exchange volume")

srv = QueryServer(part, n_lanes=2)   # 2 lanes << 5 queries: continuous batching
qids = [srv.submit(kind, root) for kind, root in queries]
qids.append(srv.submit("reachability", int(deg[4])))
served = srv.run()
assert (served[qids[0]].values == reference.bfs_levels(g, int(deg[0]))).all()
print(f"QueryServer ok: {len(served)} queries on 2 lanes in {srv.tick} "
      f"round ticks, occupancy {srv.occupancy():.2f}")

# 5. overload-safe serving (ISSUE 6): the same server behind a bounded
# admission queue with typed overload outcomes — a full queue rejects or
# sheds (never an exception), a priority-5 request preempts the
# lowest-priority running lane, an expired deadline evicts mid-flight
# with partial values, a zero round budget returns the initial values
# immediately, and repeat roots are served from the root-keyed cache.
from repro.query import QueryStatus, ServeConfig

srv = QueryServer(part, n_lanes=1, serve=ServeConfig(
    max_queue=2, overload_policy="reject", cache_size=8, cache_ttl_s=60.0))
q_slow = srv.submit("bfs", int(deg[0]))
q_wait = srv.submit("sssp", int(deg[1]))           # fills the queue...
q_over = srv.submit("bfs", int(deg[2]))            # ...typed rejection
srv.step()                                         # q_slow takes the lane
q_hot = srv.submit("bfs", int(deg[3]), priority=5)  # preempts q_slow
q_zero = srv.submit("sssp", int(deg[1]), max_rounds=0)  # initial values
served = srv.run()
assert served[q_over].status == QueryStatus.REJECTED
assert served[q_zero].status == QueryStatus.BUDGET_EXHAUSTED
assert served[q_zero].partial and served[q_hot].status == QueryStatus.OK
assert served[q_slow].preemptions == 1             # restarted, still right
assert (served[q_slow].values == reference.bfs_levels(g, int(deg[0]))).all()
q_again = srv.submit("bfs", int(deg[0]))           # repeat root: cache hit
assert srv.results[q_again].cached                 # resolved at submit
print(f"overload-safe serving ok: statuses "
      f"{sorted({r.status for r in served.values()})}, "
      f"{srv.counters['cache_hits']} cache hit, "
      f"{srv.counters['preemptions']} preemption — no exceptions")

# 6. sparsity-proportional execution (ISSUE 5): the worklist grid mode
# launches only the frontier-live kernel cells (grid_mode='auto' plans a
# sparse launch whenever the live fraction is thin), and delta-PageRank
# diffuses only residuals above a tolerance — the engine's diffusion
# pruning finally firing for the sum semiring.  smem_budget_bytes guards
# the scalar-prefetch tables on real-TPU-scale chunk counts.
from repro.apps import pagerank_delta

wl_cfg = EngineConfig(use_pallas=True, grid_mode="auto",
                      smem_budget_bytes=64 * 1024)
levels_wl, st_wl, _ = bfs(g, root, part=part, cfg=wl_cfg)
assert (levels_wl == levels).all() and int(st_wl.messages) == int(st.messages)
pr_delta, st_delta, _ = pagerank_delta(g, tol=1e-8, num_shards=64,
                                       rpvo_max=16, cfg=wl_cfg,
                                       max_rounds=200)
# dropped sub-tol residuals bound the rank error by O(tol/(1-d)) a round
assert np.allclose(pr_delta, reference.pagerank(g, iters=200),
                   rtol=1e-3, atol=1e-6)
print(f"worklist + delta-PageRank ok: BFS bit-identical under sparse "
      f"launches; delta-PR converged in {int(st_delta.iterations)} rounds, "
      f"{int(st_delta.pruned_actions)} diffusions pruned below tol")

# 7. the flight recorder (ISSUE 7): install a recorder, re-run the BFS
# fixpoint and a small QueryServer burst under it, and render the run
# summary.  Recording is off by default and costs nothing when off; on,
# every round's grid-cell / DMA columns are the same planner mirror the
# differential tests assert against the kernel's debug counters.
from repro import obs
from repro.obs import report

with obs.recording(meta={"demo": "quickstart"}) as recorder:
    levels_rec, st_rec, _ = bfs(g, root, part=part, cfg=wl_cfg)
    srv = QueryServer(part, n_lanes=2,
                      serve=ServeConfig(max_queue=8, cache_size=8))
    for r in (int(deg[0]), int(deg[1]), int(deg[2])):
        srv.submit("bfs", r)
    srv.run()
    srv.submit("bfs", int(deg[0]))                 # repeat root: cache hit
    srv.run()
assert (levels_rec == levels).all()                # recording changes nothing
assert sum(r.messages for r in recorder.rounds
           if r.run == "bfs") == int(st_rec.messages)
recorder.save("quickstart_obs_session.json")       # metrics + trace + rounds
print("-- flight recorder (python -m repro.obs.report) " + "-" * 22)
print(report.render(recorder.to_session()), end="")
print("obs ok: session saved to quickstart_obs_session.json "
      "(trace loads in Perfetto)")

# 8. device-resident fixpoints (ISSUE 8): grid_mode='device_worklist'
# compacts the frontier into the live-cell worklist ON DEVICE
# (cumsum-scatter over the frontier chunk bitmap), so the whole BFS
# fixpoint — sparse launches, convergence test and all — runs as ONE
# lax.while_loop dispatch with zero per-round host syncs instead of one
# dispatch + sync per round.  Same answer, bit for bit.
dev_cfg = EngineConfig(use_pallas=True, grid_mode="device_worklist")
reg = obs.registry()
before = sum(reg.counter("engine_dispatches_total")
             .snapshot_values().values())
levels_dev, st_dev, _ = bfs(g, root, part=part, cfg=dev_cfg)
dispatches = sum(reg.counter("engine_dispatches_total")
                 .snapshot_values().values()) - before
assert (levels_dev == levels).all() and dispatches == 1
print(f"device-resident fixpoint ok: {int(st_dev.iterations)} BFS rounds "
      f"in {dispatches} dispatch (host-driven pays "
      f"{int(st_dev.iterations)} dispatches + syncs)")

# the serving tick gets the same lever: tick_rounds=K advances every
# live lane K rounds per dispatch (lanes carrying round budgets or
# deadlines drop back to K=1 so their policing stays per-round exact)
srv = QueryServer(part, n_lanes=2, cfg=dev_cfg, tick_rounds=4)
for kind, r in queries:
    srv.submit(kind, r)
served_dev = srv.run()
assert (served_dev[0].values == reference.bfs_levels(g, int(deg[0]))).all()
print(f"windowed serving ok: {len(served_dev)} queries, "
      f"{srv.rounds_driven} pool rounds in {srv.tick} ticks "
      f"(~{srv.rounds_driven / max(srv.tick, 1):.1f} rounds/dispatch)")

# 9. streaming graphs (ISSUE 9): mutate the graph WHILE it serves.
# StreamingGraph buffers edge insert/delete batches; commit() splices
# only the affected shard rows of every live partition (counter-hashed
# placement makes the splice field-identical to a from-scratch build),
# warm-starts tracked fixpoints at just the affected region, splits a
# vertex into a new rhizome replica online when streamed in-degree
# crosses the pinned Eq. 1 cutoff — and swaps a bound QueryServer onto
# the new partition between ticks, firing its cache-invalidation hooks.
from repro.core.streaming import StreamingGraph
from repro.query import QueryServer, ServeConfig

gs = generators.rmat(8, edge_factor=8, seed=0).with_random_weights(seed=0)
stream = StreamingGraph(gs, PartitionConfig(num_shards=8, rpvo_max=4))
sroot = int(np.argmax(gs.out_degrees()))
stream.track("bfs", sroot)                  # maintained incrementally
srv = QueryServer(stream.view("base").part, n_lanes=2,
                  serve=ServeConfig(cache_size=16))
stream.bind_server(srv)                     # mutations apply between ticks

qid = srv.submit("bfs", sroot)
srv.run()                                   # cold serve, result cached
rng = np.random.default_rng(0)
stream.insert_edges(rng.integers(0, gs.n, 32).astype(np.int32),
                    rng.integers(0, gs.n, 32).astype(np.int32))
stream.delete_edges(stream.g.src[:4], stream.g.dst[:4])
info = stream.commit()                      # splice + maintain + notify
ms = info.maint[("bfs", sroot)]
sp = info.splices["base"]
assert srv.counters["cache_invalidations"] >= 1   # stale entry dropped
qid2 = srv.submit("bfs", sroot)
srv.run()                                   # recomputed on the new graph
assert (srv.results[qid2].values
        == reference.bfs_levels(stream.g, sroot)).all()
slv = stream.values("bfs", sroot)
lvl = np.full(gs.n, np.iinfo(np.int32).max, np.int64)
lvl[np.isfinite(slv)] = slv[np.isfinite(slv)].astype(np.int64)
assert (lvl == reference.bfs_levels(stream.g, sroot)).all()
print(f"streaming ok: {info.inserted}+{info.deleted} edge mutations, "
      f"{sp.shards_rebuilt}/{sp.shards_total} shard rows respliced, "
      f"+{info.replicas_added} rhizome replicas, incremental BFS "
      f"re-lifted {ms.invalidated} vertices in {ms.messages} messages "
      f"({ms.rounds} rounds) — server cache invalidated, fresh answer "
      f"served")

# 10. crash-safe fixpoints (ISSUE 10): kill a shard mid-run, restore
# from the last checkpoint, land on the exact same answer.  The
# resilient driver checkpoints {value tables, frontier, counters} at
# round boundaries, detects the death through the heartbeat window (a
# crc scrub and a message-count mirror catch corruptions and lost
# inboxes the same way), re-dispatches from the checkpoint, and — since
# the accounting rides inside the checkpoint tree — finishes with
# totals EQUAL to a run that never crashed.
import tempfile

from repro.checkpoint.manager import CheckpointManager
from repro.core import actions, engine
from repro.core.resilient import StackedTask, run_resilient
from repro.runtime.chaos import ChaosEvent, ChaosPlan

kcfg = engine.EngineConfig(checkpoint_every=2)
kinit = engine.init_values(part, actions.SSSP, {root: 0.0})
clean, clean_st = engine.run_stacked(actions.SSSP, part, kinit,
                                     engine.EngineConfig())
chaos = ChaosPlan(events=(
    ChaosEvent(round=3, kind="kill_shard", shard=1),))
with tempfile.TemporaryDirectory() as ckdir:
    rval, rst, rep = run_resilient(
        StackedTask(actions.SSSP, part, kinit, kcfg), chaos=chaos,
        manager=CheckpointManager(ckdir))
assert rep.status == "recovered" and len(rep.faults) == 1
assert (np.asarray(rval) == np.asarray(clean)).all()       # bit-equal
assert int(rst.messages) == int(clean_st.messages)         # exact totals
assert int(rst.iterations) == int(clean_st.iterations)
print(f"crash-safe fixpoint ok: shard killed at round 3, detected by "
      f"the heartbeat window, restored from the last checkpoint "
      f"({rep.checkpoints_written} written, {rep.rounds_lost} rounds "
      f"replayed) — values bit-equal, {int(rst.messages)} messages "
      f"exactly equal the uninterrupted run")
