"""Quickstart: Rhizomatic-RPVO graph processing in five minutes.

Builds a skewed synthetic graph, partitions it three ways ('simple
vertex', RPVO, Rhizomatic-RPVO), runs diffusive BFS / SSSP / PageRank on
the JAX engine, and prints the data-structure cost metrics that the
paper's technique improves.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps import bfs, pagerank, sssp
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.graph.graph import degree_stats

# 1. a highly skewed graph (RMAT, same generator family as the paper's R18)
g = generators.rmat(12, edge_factor=16, seed=0).with_random_weights(seed=0)
stats = degree_stats(g)
print(f"graph: V={stats['vertices']} E={stats['edges']} "
      f"max_in={stats['in']['max']} in_skew={stats['in_skew']:.1f}")

# 2. three layouts for the same graph
layouts = {
    "simple-vertex": PartitionConfig(num_shards=64, rpvo_max=1,
                                     ghost_alloc="home"),
    "rpvo": PartitionConfig(num_shards=64, rpvo_max=1,
                            ghost_alloc="balanced", local_edge_list_size=32),
    "rhizomatic-rpvo": PartitionConfig(num_shards=64, rpvo_max=16,
                                       ghost_alloc="balanced",
                                       local_edge_list_size=32),
}
parts = {}
for name, pc in layouts.items():
    part = build_partition(g, pc)
    parts[name] = part
    m = part.metrics
    print(f"{name:18s} E_max={m['E_max']:7d} (balance {m['edge_balance']:.2f}) "
          f"hot-inbox={m['max_inbox_per_slot']:6d} replicas=+{m['replicas_total']-g.n}")

# 3. run the three diffusive apps on the rhizomatic layout
root = int(np.argmax(g.out_degrees()))
part = parts["rhizomatic-rpvo"]

levels, st, _ = bfs(g, root, part=part)
assert (levels == reference.bfs_levels(g, root)).all()
print(f"BFS ok: {int(st.iterations)} rounds, "
      f"{int(st.messages)} actions, "
      f"{100 * int(st.work_actions) / max(int(st.messages), 1):.1f}% did work "
      f"(the rest pruned by their predicate)")

dist, _, _ = sssp(g, root, part=part)
ref = reference.sssp_dijkstra(g, root)
finite = np.isfinite(ref)
assert np.allclose(dist[finite], ref[finite], rtol=1e-5)
print("SSSP ok: matches Dijkstra oracle")

pr, _ = pagerank(g, iters=20, num_shards=64, rpvo_max=16)
assert np.allclose(pr, reference.pagerank(g, iters=20), rtol=1e-4, atol=1e-7)
print("PageRank ok: matches power-iteration oracle "
      "(rhizome-collapse = AND-gate all-reduce)")
