"""End-to-end LM training example: train a ~small dense model for a few
hundred steps on local devices with checkpointing, then show restart.

Defaults are CPU-sized; on a real slice pass --arch/--steps and a mesh
via repro.lm.launch.train instead.

  PYTHONPATH=src python examples/train_lm.py --steps 120
"""
import argparse
import dataclasses

from repro.lm.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.lm.models.model import Model
from repro.lm.train.optimizer import AdamW, cosine_schedule
from repro.lm.train.trainer import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--ckpt-dir", default="/tmp/repro_example_lm")
args = ap.parse_args()

cfg = dataclasses.replace(
    get_config("phi3-medium-14b").reduced(),
    n_layers=4, d_model=128, d_ff=256, vocab=512)
model = Model(cfg)
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, global_batch=16, seed=0)
opt = AdamW(lr=cosine_schedule(1e-3, warmup=20, total=args.steps),
            weight_decay=0.01)
tcfg = TrainerConfig(steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt_dir,
                     log_every=20, async_ckpt=True)
trainer = Trainer(model, opt, pipe, tcfg)
state = trainer.run()
print("history:")
for row in trainer.history:
    print(f"  step {row['step']:4d}  ce={row['ce']:.4f}  "
          f"gnorm={row['grad_norm']:.3f}")
assert trainer.history[-1]["ce"] < trainer.history[0]["ce"]
print(f"checkpoints at: {trainer.ckpt.all_steps()} (resumable — rerun me)")
