"""End-to-end driver: large(ish) skewed-graph analytics with the paper's
full pipeline — partition -> diffusive engine -> AM-CCA cost model —
comparing RPVO vs Rhizomatic-RPVO the way the paper's Figs 8/9 do.

  PYTHONPATH=src python examples/graph_analytics.py [--scale 14]
"""
import argparse
import time

import numpy as np

from repro.apps import bfs
from repro.core.costmodel import CostModel
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=int, default=13)
ap.add_argument("--shards", type=int, default=4096)
args = ap.parse_args()

g = generators.rmat(args.scale, edge_factor=16, seed=1)
root = int(np.argmax(g.out_degrees()))
print(f"RMAT-{args.scale}: V={g.n} E={g.num_edges}")

# real computation on the JAX engine (64-shard layout)
t0 = time.time()
levels, st, part = bfs(g, root, num_shards=64, rpvo_max=8)
print(f"engine BFS: {time.time()-t0:.1f}s, {int(st.iterations)} rounds, "
      f"levels verified={bool((levels == reference.bfs_levels(g, root)).all())}")

# paper-style chip-scale what-if: replay the frontier trace through the
# AM-CCA cost model at 64x64 cells, with and without rhizomes
trace = reference.bfs_frontier_trace(g, root)
for rmax, label in ((1, "rpvo"), (16, "rhizomatic")):
    p = build_partition(g, PartitionConfig(
        num_shards=args.shards, rpvo_max=rmax, local_edge_list_size=16))
    res = CostModel(p, torus=True).replay(trace)
    print(f"{label:12s} cells={args.shards}: est_cycles={res.cycles:9.0f} "
          f"max_link={res.max_link_load:6d} "
          f"energy={res.energy_pj/1e6:.1f} uJ")
