"""Engine-level chaos: deterministic fault injection below the serving
layer (ISSUE 10 tentpole part 2).

PR 6's ``FaultPlan`` kills serving lanes at tick boundaries; this module
extends the same deterministic-schedule idea down into the fixpoint
round machinery.  A ``ChaosPlan`` is a seedable schedule of engine-level
fault events keyed on the *round* number:

* ``kill_shard`` — shard ``s`` stops heartbeating and its value/frontier
  rows are lost (detected by the heartbeat window, or by the crc scrub
  when the dead shard's rows were zeroed in place);
* ``drop_inbox`` — shard ``s``'s outgoing frontier rows are masked for
  one round, so downstream shards silently miss messages (detected by
  the host counter mirror: reported messages < expected);
* ``dup_inbox`` — shard ``s``'s messages are double-counted for one
  round (reported messages > the mirror's expectation);
* ``corrupt_tile`` — bytes in shard ``s``'s value table are flipped
  (detected by the crc scrub over the round-boundary value snapshot, or
  by the kernels' ``with_debug`` counter mismatch on the next launch);
* ``delay_shard`` — shard ``s`` misses ``rounds`` heartbeats but comes
  back (a straggler, not a death — must NOT trigger recovery as long as
  the delay stays inside the heartbeat window).

Every detected fault surfaces as a typed ``FaultDetected``; the
``RecoveryPolicy`` bounds how the resilient driver responds — transient
retry, re-dispatch from the last checkpoint, then graceful degradation
to typed partial results.
"""
from __future__ import annotations

import dataclasses

import numpy as np

KINDS = ("kill_shard", "drop_inbox", "dup_inbox", "corrupt_tile",
         "delay_shard")

# which fault classes lose device state (recovery must re-dispatch from
# a checkpoint) vs transient per-round perturbations (retrying the same
# round from the intact pre-round state suffices)
STATE_LOSS = frozenset(("kill_shard", "corrupt_tile"))
TRANSIENT = frozenset(("drop_inbox", "dup_inbox", "delay_shard"))


class FaultDetected(RuntimeError):
    """A chaos-injected (or real) fault caught by a detector: crc scrub,
    counter-mirror mismatch, or heartbeat expiry.  Typed so the resilient
    driver can route it to the right recovery path and tests can assert
    the detector that fired."""

    def __init__(self, kind: str, shard: int | None = None,
                 round_: int | None = None, detail: str = ""):
        self.kind = kind
        self.shard = shard
        self.round = round_
        msg = f"fault detected: {kind}"
        if shard is not None:
            msg += f" shard={shard}"
        if round_ is not None:
            msg += f" round={round_}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    round: int          # fixpoint round the event fires before
    kind: str           # one of KINDS
    shard: int          # target shard
    rounds: int = 1     # delay_shard: heartbeats missed

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"expected one of {KINDS}")


@dataclasses.dataclass
class ChaosPlan:
    """Deterministic engine-level fault schedule (the round-keyed analog
    of the serving layer's tick-keyed ``FaultPlan``).

    The plan is pure data: the resilient driver consumes events by round
    and marks them fired, so a re-dispatch of the same round after
    recovery does not re-fire them (each event injects exactly once —
    the differential suite depends on runs terminating)."""

    events: tuple = ()

    def __post_init__(self):
        self.events = tuple(
            e if isinstance(e, ChaosEvent) else ChaosEvent(*e)
            for e in self.events)
        self._fired: set = set()

    def events_at(self, round_: int):
        """Unfired events scheduled for ``round_`` (does not mark them)."""
        return [e for i, e in enumerate(self.events)
                if e.round == round_ and i not in self._fired]

    def mark_fired(self, event: ChaosEvent):
        for i, e in enumerate(self.events):
            if e is event or (e == event and i not in self._fired):
                self._fired.add(i)
                return
        raise ValueError(f"event not in plan: {event}")

    def reset(self):
        """Forget fired state (reuse the plan for a fresh run)."""
        self._fired.clear()

    @classmethod
    def random(cls, seed: int, n_events: int, max_round: int,
               num_shards: int, kinds=KINDS) -> "ChaosPlan":
        """A seedable random schedule: ``n_events`` events uniformly over
        rounds ``[1, max_round]`` × shards × ``kinds``.  Same seed, same
        plan — the chaos bench's randomized-round injection stays
        reproducible run-to-run."""
        rng = np.random.default_rng(seed)
        kinds = tuple(kinds)
        evs = []
        for _ in range(int(n_events)):
            evs.append(ChaosEvent(
                round=int(rng.integers(1, max(max_round, 1) + 1)),
                kind=kinds[int(rng.integers(0, len(kinds)))],
                shard=int(rng.integers(0, num_shards))))
        # stable order: by round, then construction order
        evs.sort(key=lambda e: e.round)
        return cls(events=tuple(evs))


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """Bounds on the resilient driver's response ladder:

    1. transient faults (dropped/duplicated inbox, short delays) —
       retry the same round from the intact pre-round state, at most
       ``max_retries`` times per round;
    2. state-loss faults (killed shard, corrupted tile) — re-dispatch
       from the last checkpoint (round 0's initial state counts as the
       implicit checkpoint), at most ``max_restores`` times per run;
    3. budgets exhausted — graceful degradation: return the current
       values with a typed ``'degraded'`` status instead of raising.

    ``heartbeat_window``: rounds a shard may miss heartbeats before it
    is declared dead (mirrors ``ElasticCoordinator``'s window).
    ``on_dead``: ``'restore'`` re-dispatches the same layout from the
    checkpoint; ``'shrink'`` rebuilds the partition on the surviving
    shards (the ``ShardPool`` path)."""

    max_retries: int = 2
    max_restores: int = 2
    heartbeat_window: int = 3
    on_dead: str = "restore"
    degrade: bool = True

    def __post_init__(self):
        if self.on_dead not in ("restore", "shrink"):
            raise ValueError("on_dead must be 'restore' or 'shrink'")


@dataclasses.dataclass
class FaultEventRecord:
    """One detected fault + how it was resolved (for reports/benches)."""

    kind: str
    shard: int | None
    round: int
    action: str          # 'retry' | 'restore' | 'shrink' | 'degrade'
    rounds_lost: int = 0


@dataclasses.dataclass
class FixpointReport:
    """Resilient-run epilogue: terminal status + recovery accounting.

    status: 'ok' (no faults), 'recovered' (faults occurred, full result),
    or 'degraded' (recovery budget exhausted; values are partial)."""

    status: str = "ok"
    faults: list = dataclasses.field(default_factory=list)
    retries: int = 0
    restores: int = 0
    rounds_lost: int = 0
    checkpoints_written: int = 0
    checkpoint_write_s: float = 0.0
    recovery_s: float = 0.0
