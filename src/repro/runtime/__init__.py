from repro.runtime.elastic import ElasticCoordinator, StragglerMonitor

__all__ = ["ElasticCoordinator", "StragglerMonitor"]
