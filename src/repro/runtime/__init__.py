from repro.runtime.chaos import (
    ChaosEvent, ChaosPlan, FaultDetected, FixpointReport, RecoveryPolicy)
from repro.runtime.elastic import (
    ElasticCoordinator, ShardPool, StragglerMonitor)

__all__ = ["ChaosEvent", "ChaosPlan", "ElasticCoordinator",
           "FaultDetected", "FixpointReport", "RecoveryPolicy",
           "ShardPool", "StragglerMonitor"]
