"""Elastic scaling + straggler mitigation for 1000+ node fleets.

``ElasticCoordinator`` owns the fleet view: hosts heartbeat every step;
on a missed-heartbeat window the coordinator declares the host dead,
re-factorizes the largest viable mesh from surviving hosts (keeping the
model axis intact — TP is latency-critical; DP shrinks), and the trainer
restores from the latest checkpoint and continues. Because the sharding
rules are mesh-shape agnostic (sharding/specs.py), re-lowering for the
new mesh is mechanical — tests re-lower the same config at 3 fleet sizes.

``StragglerMonitor`` tracks per-host step durations with an EWMA; hosts
slower than ``threshold ×`` the fleet median are flagged for (1) input
bypass (data pipeline substitutes the fallback batch rather than stall),
then (2) eviction after ``patience`` consecutive flags — the two-stage
response of production fleets (bounded staleness first, re-mesh second).

Failures here are *simulated* (no real TPU fleet in this container); the
state machine and mesh math are the deliverable.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HostState:
    alive: bool = True
    last_heartbeat: int = 0
    ewma_step_s: float = 0.0
    slow_flags: int = 0


def viable_mesh_shapes(n_hosts: int, devices_per_host: int,
                       model_axis: int) -> list[tuple[int, int, int]]:
    """(pod, data, model) factorizations keeping the model axis intact and
    data a multiple of 2 where possible, largest first."""
    total = n_hosts * devices_per_host
    out = []
    if total % model_axis:
        return out
    rest = total // model_axis
    for pod in (2, 1):
        if rest % pod == 0:
            out.append((pod, rest // pod, model_axis))
    return sorted(set(out), key=lambda s: -s[0] * s[1] * s[2])


class ElasticCoordinator:
    def __init__(self, n_hosts: int, devices_per_host: int,
                 model_axis: int = 16, heartbeat_window: int = 3):
        self.devices_per_host = devices_per_host
        self.model_axis = model_axis
        self.window = heartbeat_window
        self.hosts = {h: HostState() for h in range(n_hosts)}
        self.step = 0
        self.remesh_events: list[dict] = []

    # --- heartbeats ---------------------------------------------------------
    def heartbeat(self, host_id: int, step: int):
        hs = self.hosts[host_id]
        hs.last_heartbeat = step

    def tick(self, step: int) -> bool:
        """Advance coordinator; returns True if a re-mesh is required."""
        self.step = step
        died = []
        for h, hs in self.hosts.items():
            if hs.alive and step - hs.last_heartbeat > self.window:
                hs.alive = False
                died.append(h)
        if died:
            self.remesh_events.append(
                {"step": step, "died": died, "mesh": self.current_mesh_shape()})
            return True
        return False

    def revive(self, host_id: int, step: int):
        """A restarted host rejoins the fleet (recovery after a restore
        re-dispatch): alive again, heartbeat clock reset to ``step``."""
        hs = self.hosts[host_id]
        hs.alive = True
        hs.last_heartbeat = step

    def kill_host(self, host_id: int):
        """Test hook: simulate an abrupt host failure."""
        self.hosts[host_id].alive = False
        self.remesh_events.append(
            {"step": self.step, "died": [host_id],
             "mesh": self.current_mesh_shape()})

    def alive_hosts(self) -> list[int]:
        return [h for h, hs in self.hosts.items() if hs.alive]

    def current_mesh_shape(self) -> tuple[int, int, int] | None:
        """Largest viable (pod, data, model) mesh. Prefers idling surplus
        hosts over shrinking the model axis (TP is latency-critical);
        degrades the model axis only when >10% of the fleet would idle."""
        total = len(self.alive_hosts()) * self.devices_per_host
        best = None
        for m in (self.model_axis, self.model_axis // 2,
                  self.model_axis // 4, 2, 1):
            if m < 1:
                continue
            usable = (total // m) * m
            if usable == 0:
                continue
            shapes = viable_mesh_shapes(
                usable // self.devices_per_host if usable % self.devices_per_host == 0
                else usable, 1 if usable % self.devices_per_host else self.devices_per_host,
                m)
            if not shapes:
                continue
            cand = shapes[0]
            if usable >= 0.9 * total:
                return cand          # keep (or nearly keep) the fleet busy
            if best is None:
                best = cand
        return best


class ShardPool:
    """Graph-shard liveness tracker for the resilient fixpoint driver
    (ISSUE 10 tentpole part 3): the multi-host heartbeat/declare-dead
    state machine above, reused one-"host"-per-shard.

    Shards heartbeat every fixpoint round; a shard that misses
    ``window`` consecutive rounds is declared dead at the next
    ``tick()``.  The driver then either restores the same layout from
    the last checkpoint (the dead shard's process restarts — ``revive``)
    or shrinks the shard pool: rebuild the partition on the survivors
    (``core.resilient.shrink_partition``) and migrate per-vertex values.
    A *delayed* shard — missed heartbeats but fewer than the window —
    never trips the machine (stragglers are not failures)."""

    def __init__(self, num_shards: int, window: int = 3):
        self.num_shards = num_shards
        self.coord = ElasticCoordinator(
            n_hosts=num_shards, devices_per_host=1, model_axis=1,
            heartbeat_window=window)

    def heartbeat(self, shard: int, round_: int):
        self.coord.heartbeat(shard, round_)

    def heartbeat_all(self, round_: int, except_shards=()):
        for s in range(self.num_shards):
            if s not in except_shards:
                self.coord.heartbeat(s, round_)

    def tick(self, round_: int) -> list[int]:
        """Advance the round clock; returns shards NEWLY declared dead."""
        before = set(self.alive())
        self.coord.tick(round_)
        return sorted(before - set(self.alive()))

    def alive(self) -> list[int]:
        return self.coord.alive_hosts()

    def dead(self) -> list[int]:
        return [s for s in range(self.num_shards)
                if s not in set(self.alive())]

    def revive(self, shard: int, round_: int):
        self.coord.revive(shard, round_)

    def revive_all(self, round_: int):
        for s in self.dead():
            self.coord.revive(s, round_)


class StragglerMonitor:
    def __init__(self, threshold: float = 1.5, patience: int = 3,
                 alpha: float = 0.3):
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.hosts: dict[int, HostState] = {}

    def record(self, host_id: int, step_s: float):
        hs = self.hosts.setdefault(host_id, HostState())
        hs.ewma_step_s = (step_s if hs.ewma_step_s == 0.0
                          else self.alpha * step_s
                          + (1 - self.alpha) * hs.ewma_step_s)

    def classify(self) -> dict:
        """{'bypass': [...], 'evict': [...]} — stage-1 input bypass,
        stage-2 eviction recommendation."""
        if not self.hosts:
            return {"bypass": [], "evict": []}
        med = float(np.median([h.ewma_step_s for h in self.hosts.values()]))
        bypass, evict = [], []
        for hid, hs in self.hosts.items():
            if med > 0 and hs.ewma_step_s > self.threshold * med:
                hs.slow_flags += 1
                if hs.slow_flags >= self.patience:
                    evict.append(hid)
                else:
                    bypass.append(hid)
            else:
                hs.slow_flags = 0
        return {"bypass": sorted(bypass), "evict": sorted(evict)}
