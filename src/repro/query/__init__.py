from repro.query.lanes import (
    LaneStats, init_lane_values, make_ppr_delta_round, make_ppr_round,
    make_sharded_lanes_fn, make_sharded_min_round, make_sharded_ppr_round,
    make_sharded_ppr_delta_round, make_stacked_lanes_fn, ppr_base_table,
    run_ppr_delta_lanes, run_ppr_lanes, run_sharded_lanes,
    run_stacked_lanes,
)
from repro.query.server import QueryRequest, QueryResult, QueryServer
from repro.serve.admission import (
    AdmissionError, AdmissionQueue, FaultPlan, QueryStatus,
    QueryValidationError, ResultCache, ServeConfig,
)

__all__ = [
    "AdmissionError", "AdmissionQueue", "FaultPlan", "LaneStats",
    "QueryRequest", "QueryResult", "QueryServer", "QueryStatus",
    "QueryValidationError", "ResultCache", "ServeConfig",
    "init_lane_values", "make_ppr_delta_round", "make_ppr_round",
    "make_sharded_lanes_fn", "make_sharded_min_round",
    "make_sharded_ppr_round", "make_sharded_ppr_delta_round",
    "make_stacked_lanes_fn", "ppr_base_table",
    "run_ppr_delta_lanes", "run_ppr_lanes", "run_sharded_lanes",
    "run_stacked_lanes",
]
