"""Continuous-batching graph query server (ISSUE 2 tentpole; sharded
serving loop — ISSUE 3; overload-safe serving — ISSUE 6).

The graph-query analog of ``serve.scheduler.ContinuousBatcher``: a pool
of ``Q`` query lanes shares one compiled round step per semiring class
(a min-pool for BFS / SSSP / reachability, a sum-pool for personalized
PageRank).  Requests join free lanes mid-flight via masked state
injection — the new lane's (S, R_max) column of values and frontier is
written into the batched tables between rounds — and are evicted the
round they converge, so a nearby-source BFS never waits on a
diameter-spanning SSSP (no head-of-line blocking: the serving analog of
the paper's always-busy compute cells).

A freed lane is inert by construction: its ``changed`` column is
all-False, so it reads as the absorbing identity inside the shared relax
and contributes nothing until the next injection overwrites it.

``QueryServer(mesh=...)`` drives the lanes × ``shard_map`` round instead
of the stacked one: the same continuous-batching loop, but each tick is
one real-collective round over the mesh (value/changed ``all_gather``,
inbox ``all_to_all`` — dense or §Perf compact targeted per
``EngineConfig.exchange``), so one serving loop batches queries across
devices.  Lane state lives sharded on the mesh; injection writes a
column of the distributed table between rounds.

The PPR pool runs **delta rounds** (``make_ppr_delta_round`` stacked,
``make_sharded_ppr_delta_round`` on a mesh): each lane diffuses only
residual deltas above its tolerance, so a serving tick's sum-semiring
work shrinks with the frontier instead of touching every slot of every
live lane.

**Overload safety (ISSUE 6).**  ``ServeConfig`` wraps the batcher in the
production-robustness layer — the serving-side analog of the
CCA-Simulator's ``THROTTLE`` / ``ACTIONQUEUESIZE`` congestion knobs:

* bounded admission queue with a backpressure policy (``'block'`` /
  ``'reject'`` / ``'shed'`` — see ``serve.admission.AdmissionQueue``);
* priority- and deadline-aware lane assignment: an urgent request can
  preempt the lowest-priority running lane (strictly greater priority
  only); an expired deadline evicts mid-flight with a partial-result
  flag; queued requests whose deadline passes never occupy a lane;
* per-request round budgets (``max_rounds``; zero returns immediately
  with the initial values and a partial status) and wall-clock execution
  timeouts (``timeout_s``) so a pathological query cannot pin a lane;
* weighted per-tenant fairness (deficit-ordered admission, see
  ``AdmissionQueue``) so a heavy tenant cannot starve a light one;
* a root-keyed LRU result cache with a staleness bound for the highly
  repetitive PPR/BFS recommendation traffic;
* deterministic fault injection (``FaultPlan``): an induced lane failure
  or delayed tick resolves the affected request with a typed
  ``QueryResult.status`` — never an exception out of the serving loop.

Every overload outcome is a ``QueryStatus`` string on the result.  With
the default ``ServeConfig`` (unbounded queue, uniform priorities, no
cache, no faults) the serving loop is trace-identical to the unpoliced
server — the 8-device parity test in ``tests/test_exchange_unified.py``
pins this down.

The ``EngineConfig`` handed to the server also governs the fused
kernel's value-table residency (``vmem_budget_bytes``): a served
partition whose lane table exceeds the VMEM budget runs every pool
round through the HBM-tiled DMA kernel with identical serving
semantics — the continuous-batching loop never needs to know.
"""
from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import exchange, obs
from repro.core import actions, engine
from repro.core.engine import EngineConfig
from repro.core.partition import Partition
from repro.query import lanes as L
from repro.serve import admission as _adm
from repro.serve.admission import (
    AdmissionError, AdmissionQueue, FaultPlan, QueryStatus,
    QueryValidationError, ResultCache, ServeConfig,
)

MIN_KINDS = ("bfs", "sssp", "reachability")


@dataclasses.dataclass
class QueryRequest:
    """One source-rooted query over the served graph.

    kind: 'bfs' | 'sssp' | 'reachability' (min-pool) or 'ppr' (sum-pool).
    sources: vertex id, list of vertices (multi-source), or {vertex:
    initial value} dict; for 'ppr' a single personalization seed vertex.

    Robustness fields (ISSUE 6): ``priority`` (higher = more urgent; may
    preempt a strictly-lower-priority lane), ``tenant`` (fair-share
    admission id), ``deadline_s`` (SLO from submit, queue wait included —
    expiry evicts with partial values), ``timeout_s`` (wall-clock
    execution cap from admission), ``max_rounds`` (round budget; 0
    returns the initial values immediately).  All malformed inputs raise
    ``QueryValidationError`` at construction — nothing reaches a lane.
    """

    qid: int
    kind: str
    sources: object
    damping: float = 0.85        # ppr only
    tol: float = 1e-6            # ppr only
    priority: int = 0
    tenant: str = "default"
    deadline_s: float | None = None
    timeout_s: float | None = None
    max_rounds: int | None = None

    def __post_init__(self):
        if self.kind not in MIN_KINDS + ("ppr",):
            raise QueryValidationError(
                f"unknown query kind {self.kind!r}")
        if isinstance(self.sources, dict):
            n_src = len(self.sources)
            for v, x in self.sources.items():
                if not np.isfinite(float(x)):
                    raise QueryValidationError(
                        f"non-finite initial value {x!r} for source "
                        f"vertex {v!r}")
        elif isinstance(self.sources, (list, tuple, np.ndarray)):
            n_src = int(np.asarray(self.sources).reshape(-1).size)
        else:
            n_src = 1
        if n_src == 0:
            raise QueryValidationError(
                "empty sources: a query needs at least one source vertex")
        if self.kind == "ppr":
            if n_src != 1:
                raise QueryValidationError(
                    "ppr takes a single personalization seed vertex; "
                    "multi-seed personalization is not supported")
            d = float(self.damping)
            if not np.isfinite(d) or not (0.0 < d < 1.0):
                raise QueryValidationError(
                    f"ppr damping must be finite and in (0, 1); got "
                    f"{self.damping!r}")
            t = float(self.tol)
            if not np.isfinite(t) or t < 0.0:
                raise QueryValidationError(
                    f"ppr tol must be finite and >= 0; got {self.tol!r}")
        if self.max_rounds is not None and int(self.max_rounds) < 0:
            raise QueryValidationError(
                f"max_rounds must be >= 0; got {self.max_rounds!r}")
        for name in ("deadline_s", "timeout_s"):
            v = getattr(self, name)
            if v is not None and (not np.isfinite(float(v)) or v < 0):
                raise QueryValidationError(
                    f"{name} must be finite and >= 0; got {v!r}")


@dataclasses.dataclass
class QueryResult:
    qid: int
    kind: str
    values: np.ndarray | None    # (n,) levels/distances/bool/scores; None
    #                              when the outcome carries no values
    rounds: int                  # rounds the lane was live
    messages: int                # actions delivered for this query
    lane: int                    # lane the query ran in (-1: never ran)
    admitted_tick: int
    completed_tick: int
    latency_s: float             # submit -> completion (includes queue wait)
    exchanged: int = 0           # exchange entries shipped while live
    status: str = QueryStatus.OK  # typed outcome (see QueryStatus)
    partial: bool = False        # values are a mid-flight snapshot
    cached: bool = False         # served from the result cache
    tenant: str = "default"
    priority: int = 0
    preemptions: int = 0         # times this request was preempted
    submitted_tick: int = 0


def _cache_key(req: QueryRequest):
    """Canonical root key: list order and dict insertion order never
    split cache entries for the same logical query."""
    if isinstance(req.sources, dict):
        src = tuple(sorted((int(v), float(x))
                           for v, x in req.sources.items()))
    elif isinstance(req.sources, (list, tuple, np.ndarray)):
        src = tuple(sorted(int(v) for v in
                           np.asarray(req.sources).reshape(-1)))
    else:
        src = (int(req.sources),)
    key = (req.kind, src)
    if req.kind == "ppr":
        key += (float(req.damping), float(req.tol))
    return key


def _req_to_dict(req: QueryRequest) -> dict:
    """JSON-able form of a request (checkpoint manifest payload)."""
    if isinstance(req.sources, dict):
        src = {"kind": "map", "v": [[int(v), float(x)]
                                    for v, x in req.sources.items()]}
    elif isinstance(req.sources, (list, tuple, np.ndarray)):
        src = {"kind": "list",
               "v": [int(v) for v in np.asarray(req.sources).reshape(-1)]}
    else:
        src = {"kind": "one", "v": int(req.sources)}
    return {"qid": req.qid, "kind": req.kind, "sources": src,
            "damping": float(req.damping), "tol": float(req.tol),
            "priority": req.priority, "tenant": req.tenant,
            "deadline_s": req.deadline_s, "timeout_s": req.timeout_s,
            "max_rounds": req.max_rounds}


def _req_from_dict(d: dict) -> QueryRequest:
    src = d["sources"]
    if src["kind"] == "map":
        sources = {int(v): float(x) for v, x in src["v"]}
    elif src["kind"] == "list":
        sources = [int(v) for v in src["v"]]
    else:
        sources = int(src["v"])
    return QueryRequest(qid=d["qid"], kind=d["kind"], sources=sources,
                        damping=d["damping"], tol=d["tol"],
                        priority=d["priority"], tenant=d["tenant"],
                        deadline_s=d["deadline_s"],
                        timeout_s=d["timeout_s"],
                        max_rounds=d["max_rounds"])


_RESULT_META_FIELDS = (
    "qid", "kind", "rounds", "messages", "lane", "admitted_tick",
    "completed_tick", "latency_s", "exchanged", "status", "partial",
    "cached", "tenant", "priority", "preemptions", "submitted_tick")


def _result_to_dict(r: QueryResult) -> dict:
    d = {f: getattr(r, f) for f in _RESULT_META_FIELDS}
    d["latency_s"] = float(d["latency_s"])
    d["has_values"] = r.values is not None
    return d


def _result_from_dict(d: dict, values) -> QueryResult:
    return QueryResult(values=values,
                       **{f: d[f] for f in _RESULT_META_FIELDS})


class _LanePool:
    """Shared pool plumbing: lane state lives on device — stacked, or
    sharded over the server's mesh (``_sharding`` set, ``_arrays``
    holding the mesh-placed graph tables), in which case every state
    update is re-placed so the per-tick round never re-shards.

    ``step_window(k)`` is the K-round tick (ISSUE 8): a ``lax.scan``
    over the pool's round compiled as ONE dispatch, returning per-lane
    message counts and live-round counts summed over the window.  A
    lane that converges mid-window reads as the absorbing identity for
    the remaining rounds, so the summed accounting equals K
    single-round ticks exactly."""

    _sharding = None

    def _put(self, x):
        return x if self._sharding is None else jax.device_put(
            x, self._sharding)

    def _window_fn(self, k: int):
        if k not in self._windows:
            self._windows[k] = jax.jit(self._build_window(k))
        return self._windows[k]


class _MinPool(_LanePool):
    """Min-semiring lane pool: one compiled laned fixpoint round —
    stacked, or lanes × shard_map when the server holds a mesh."""

    def __init__(self, part: Partition, n_lanes: int, cfg: EngineConfig,
                 arrays: engine.DeviceArrays, mesh=None,
                 axis_names=("data", "model")):
        self.part, self.n = part, n_lanes
        self._cfg, self._mesh, self._axis_names = cfg, mesh, axis_names
        S, R_max = part.S, part.R_max
        self.exchange_volume = L._volume(part, cfg)
        self.unitw = np.zeros(n_lanes, np.int32)
        self.reqs: list[QueryRequest | None] = [None] * n_lanes
        self._windows: dict = {}
        self._bind_rounds(arrays)
        self.val = self._put(jnp.full((S, R_max, n_lanes), jnp.inf,
                                      jnp.float32))
        self.chg = self._put(jnp.zeros((S, R_max, n_lanes), bool))

    def _bind_rounds(self, arrays):
        part, cfg = self.part, self._cfg
        S, R_max = part.S, part.R_max
        if self._mesh is None:
            def round_fn(val, chg, unitw):
                return exchange.fixpoint_round_stacked(
                    actions.SSSP, arrays, cfg, S, R_max, val, chg,
                    lane_unitw=unitw)

            self._round_raw = round_fn
            self._round = jax.jit(round_fn)
        else:
            self._round, self._sharding = L.make_sharded_min_round(
                S, R_max, self._mesh, self._axis_names, cfg)
            self._arrays = arrays          # already device_put by the server

    def rebind(self, part: Partition, arrays: engine.DeviceArrays,
               insert_seeds=None, has_deletes: bool = False) -> None:
        """Swap the pool onto a mutated partition (streaming commit).

        Rounds/windows recompile over the new arrays (shapes may change
        when splicing grows ``R_max``).  Live lanes migrate: insert-only
        batches warm-continue — per-vertex values are still valid upper
        bounds, so they re-scatter onto the new replica layout with the
        lane frontier OR'd with the insert seeds; a batch with deletes
        can RAISE min values, so affected lanes restart cold from their
        original request (same lane, rounds keep accumulating)."""
        old_part = self.part
        old_val = np.asarray(self.val)
        old_chg = np.asarray(self.chg)
        self.part = part
        self.exchange_volume = L._volume(part, self._cfg)
        self._windows = {}
        self._bind_rounds(arrays)
        S, R_max = part.S, part.R_max
        val = np.full((S, R_max, self.n), np.inf, np.float32)
        chg = np.zeros((S, R_max, self.n), bool)
        sv_old = np.asarray(old_part.slot_vertex)
        ok_old = sv_old >= 0
        sv_new = np.asarray(part.slot_vertex)
        ok_new = sv_new >= 0
        restart = []
        for lane, req in enumerate(self.reqs):
            if req is None:
                continue
            if has_deletes:
                restart.append(lane)
                continue
            vv = engine.vertex_values(old_part, old_val[:, :, lane])
            fl = np.zeros(part.n, bool)
            np.logical_or.at(fl, sv_old[ok_old],
                             old_chg[:, :, lane][ok_old])
            if insert_seeds is not None and len(insert_seeds):
                seeds = np.asarray(insert_seeds, np.int64)
                fl[seeds[np.isfinite(vv[seeds])]] = True
            val[:, :, lane][ok_new] = vv[sv_new[ok_new]]
            chg[:, :, lane][ok_new] = fl[sv_new[ok_new]]
        self.val = self._put(jnp.asarray(val))
        self.chg = self._put(jnp.asarray(chg))
        for lane in restart:
            self.inject(lane, self.reqs[lane])

    def inject(self, lane: int, req: QueryRequest):
        init, unitw = L.init_lane_values(
            self.part, [("bfs" if req.kind == "reachability" else req.kind,
                         req.sources)])
        col = jnp.asarray(init[..., 0])
        chg_col = (actions.SSSP.improved(col, jnp.full_like(col, jnp.inf))
                   & jnp.asarray(self.part.slot_vertex >= 0))
        self.val = self._put(self.val.at[:, :, lane].set(col))
        self.chg = self._put(self.chg.at[:, :, lane].set(chg_col))
        self.unitw[lane] = int(unitw[0])
        self.reqs[lane] = req

    def live(self) -> np.ndarray:
        # reduce to (Q,) on device; never ship the whole changed table
        return np.asarray(jnp.any(self.chg, axis=(0, 1)))

    def step(self) -> np.ndarray:
        """One shared round; returns (Q,) per-lane message counts."""
        if self._sharding is None:
            self.val, self.chg, counts = self._round(
                self.val, self.chg, jnp.asarray(self.unitw))
            return np.asarray(counts)
        arrays = self._arrays
        self.val, self.chg, counts = self._round(
            arrays, self.val, self.chg, jnp.asarray(self.unitw))
        return np.asarray(counts)[0]     # psum'd — identical per shard row

    def _build_window(self, k: int):
        sharded = self._sharding is not None

        def win(val, chg, unitw, arrays=None):
            def stepf(carry, _):
                val, chg = carry
                live = jnp.any(chg, axis=(0, 1))
                if sharded:
                    nval, nchg, counts = self._round(arrays, val, chg,
                                                     unitw)
                    counts = counts[0]
                else:
                    nval, nchg, counts = self._round_raw(val, chg, unitw)
                return (nval, nchg), (counts, live.astype(jnp.int32))

            (val, chg), (counts, lives) = lax.scan(
                stepf, (val, chg), None, length=k)
            return val, chg, counts.sum(axis=0), lives.sum(axis=0)

        return win

    def step_window(self, k: int):
        """K shared rounds as ONE dispatch; returns ((Q,) summed message
        counts, (Q,) live-round counts) — exact K-tick accounting."""
        unitw = jnp.asarray(self.unitw)
        if self._sharding is None:
            self.val, self.chg, counts, lives = self._window_fn(k)(
                self.val, self.chg, unitw)
        else:
            self.val, self.chg, counts, lives = self._window_fn(k)(
                self.val, self.chg, unitw, self._arrays)
        return np.asarray(counts), np.asarray(lives)

    def extract(self, lane: int) -> np.ndarray:
        vv = engine.vertex_values(self.part, self.val[:, :, lane])
        return L.decode_min_values(vv, self.reqs[lane].kind)

    def silence(self, lane: int):
        """Kill a lane's in-flight frontier (eviction before
        convergence): the lane reads as the absorbing identity until the
        next injection overwrites it."""
        self.chg = self._put(self.chg.at[:, :, lane].set(False))


class _PprPool(_LanePool):
    """Sum-semiring lane pool on **delta rounds**: per-lane seed/damping
    residual diffusion with per-lane tolerance frontiers — stacked
    (``make_ppr_delta_round``) or sharded (``make_sharded_ppr_delta_round``)
    — so converged and late-stage lanes stop costing relax work instead
    of diffusing every slot every round (the ROADMAP full-frontier
    leftover, closed)."""

    def __init__(self, part: Partition, n_lanes: int, cfg: EngineConfig,
                 arrays: engine.DeviceArrays, mesh=None,
                 axis_names=("data", "model")):
        self.part, self.n = part, n_lanes
        self._cfg, self._mesh, self._axis_names = cfg, mesh, axis_names
        S, R_max = part.S, part.R_max
        self.exchange_volume = L._volume(part, cfg)
        self.damping = np.zeros(n_lanes, np.float32)
        self.tol = np.full(n_lanes, 1e-6, np.float32)
        self.reqs: list[QueryRequest | None] = [None] * n_lanes
        self._windows: dict = {}
        self._bind_rounds(arrays)
        self.rank = self._put(jnp.zeros((S, R_max, n_lanes), jnp.float32))
        self.delta = self._put(jnp.zeros((S, R_max, n_lanes), jnp.float32))
        self.chg = self._put(jnp.zeros((S, R_max, n_lanes), bool))

    def _bind_rounds(self, arrays):
        part, cfg = self.part, self._cfg
        if self._mesh is None:
            self._round = L.make_ppr_delta_round(part, cfg, arrays=arrays)
        else:
            self._round, self._sharding = L.make_sharded_ppr_delta_round(
                part.S, part.R_max, self._mesh, self._axis_names, cfg)
            self._arrays = arrays          # already device_put by the server

    def rebind(self, part: Partition, arrays: engine.DeviceArrays,
               insert_seeds=None, has_deletes: bool = False) -> None:
        """Swap the pool onto a mutated partition (streaming commit).
        Sum-semiring residual state is exact only for the graph it was
        seeded on, so every live lane restarts from its request."""
        self.part = part
        self.exchange_volume = L._volume(part, self._cfg)
        self._windows = {}
        self._bind_rounds(arrays)
        S, R_max = part.S, part.R_max
        self.rank = self._put(jnp.zeros((S, R_max, self.n), jnp.float32))
        self.delta = self._put(jnp.zeros((S, R_max, self.n), jnp.float32))
        self.chg = self._put(jnp.zeros((S, R_max, self.n), bool))
        for lane, req in enumerate(self.reqs):
            if req is not None:
                self.inject(lane, req)

    def inject(self, lane: int, req: QueryRequest):
        srcs = np.asarray(req.sources).reshape(-1)
        if srcs.size != 1:
            raise QueryValidationError(
                f"ppr takes a single personalization seed; got "
                f"{srcs.size} sources")
        seed = int(srcs[0])
        base = jnp.asarray(
            L.ppr_base_table(self.part, [seed], [req.damping])[..., 0])
        chg_col = (base > np.float32(req.tol)) \
            & jnp.asarray(self.part.slot_vertex >= 0)
        self.rank = self._put(self.rank.at[:, :, lane].set(base))
        self.delta = self._put(self.delta.at[:, :, lane].set(base))
        self.chg = self._put(self.chg.at[:, :, lane].set(chg_col))
        self.damping[lane] = req.damping
        self.tol[lane] = req.tol
        self.reqs[lane] = req

    def live(self) -> np.ndarray:
        return np.asarray(jnp.any(self.chg, axis=(0, 1)))

    def step(self) -> np.ndarray:
        if self._sharding is None:
            self.rank, self.delta, self.chg, counts = self._round(
                self.rank, self.delta, jnp.asarray(self.damping),
                jnp.asarray(self.tol))
            return np.asarray(counts)
        self.rank, self.delta, self.chg, counts = self._round(
            self._arrays, self.rank, self.delta,
            jnp.asarray(self.damping), jnp.asarray(self.tol))
        return np.asarray(counts)[0]     # psum'd — identical per shard row

    def _build_window(self, k: int):
        sharded = self._sharding is not None

        def win(rank, delta, chg, damping, tol, arrays=None):
            def stepf(carry, _):
                rank, delta, chg = carry
                live = jnp.any(chg, axis=(0, 1))
                if sharded:
                    nrank, ndelta, nchg, counts = self._round(
                        arrays, rank, delta, damping, tol)
                    counts = counts[0]
                else:
                    nrank, ndelta, nchg, counts = self._round(
                        rank, delta, damping, tol)
                return (nrank, ndelta, nchg), (counts,
                                               live.astype(jnp.int32))

            (rank, delta, chg), (counts, lives) = lax.scan(
                stepf, (rank, delta, chg), None, length=k)
            return rank, delta, chg, counts.sum(axis=0), lives.sum(axis=0)

        return win

    def step_window(self, k: int):
        """K delta rounds as ONE dispatch; returns ((Q,) summed message
        counts, (Q,) live-round counts) — exact K-tick accounting."""
        damping, tol = jnp.asarray(self.damping), jnp.asarray(self.tol)
        if self._sharding is None:
            self.rank, self.delta, self.chg, counts, lives = \
                self._window_fn(k)(self.rank, self.delta, self.chg,
                                   damping, tol)
        else:
            self.rank, self.delta, self.chg, counts, lives = \
                self._window_fn(k)(self.rank, self.delta, self.chg,
                                   damping, tol, self._arrays)
        return np.asarray(counts), np.asarray(lives)

    def extract(self, lane: int) -> np.ndarray:
        return engine.vertex_values(
            self.part, self.rank[:, :, lane]).astype(np.float64)

    def silence(self, lane: int):
        self.delta = self._put(self.delta.at[:, :, lane].set(0.0))
        self.chg = self._put(self.chg.at[:, :, lane].set(False))


class QueryServer:
    """Continuous batcher over query lanes sharing one compiled round.

    ``step()`` is one global round tick: apply any injected faults,
    expire queued deadlines, admit queued requests into free lanes
    (priority / fairness / preemption aware), advance each pool one
    laned round, retire converged lanes — and evict lanes whose
    deadline, timeout, or round budget ran out, with typed statuses and
    partial values.  ``run()`` drains the queue.  Occupancy / round /
    message counters are kept per lane for the serving metrics in
    ``benchmarks/query_bench.py`` and ``benchmarks/serve_bench.py``.

    With ``mesh=`` the per-tick round is the lanes × shard_map round with
    real collectives (see the module docstring); the batching semantics —
    masked mid-flight injection, eviction on convergence, no head-of-line
    blocking — are identical to the stacked server's.

    ``serve=ServeConfig(...)`` enables the overload-safety layer; the
    default config reproduces the unpoliced server trace-identically.
    ``clock`` injects a virtual wall clock (tests); ``server.counters``
    tallies every typed outcome for the load harness's consistency
    check.

    ``tick_rounds=K`` (ISSUE 8) makes each tick a K-round window: one
    ``lax.scan`` dispatch advances every pool up to K rounds, so a
    16-lane query tick costs one dispatch instead of ~K host round
    trips.  Converged lanes are inert mid-window and per-lane
    rounds/messages come from the window's returned live-round counts,
    so results and accounting are exactly the single-round tick's;
    ticks serving a lane with a max_rounds / deadline / timeout
    constraint fall back to single-round stepping automatically.
    """

    def __init__(self, part: Partition, n_lanes: int = 8,
                 cfg: EngineConfig = EngineConfig(),
                 ppr_lanes: int | None = None, mesh=None,
                 axis_names=("data", "model"),
                 serve: ServeConfig | None = None, clock=None,
                 tick_rounds: int = 1):
        self.part = part
        self.mesh = mesh
        self.serve = serve if serve is not None else ServeConfig()
        if int(tick_rounds) < 1:
            raise ValueError(f"tick_rounds={tick_rounds!r}")
        # K-round window tick (ISSUE 8): each tick advances every pool
        # up to K rounds in ONE dispatch (lax.scan) instead of K host
        # round trips.  Ticks with a lane under a max_rounds / deadline
        # / timeout constraint fall back to single-round stepping so
        # eviction points stay exact; tick_rounds=1 is the classic
        # per-round tick, bit-for-bit.
        self.tick_rounds = int(tick_rounds)
        self._clock = clock if clock is not None else time.monotonic
        self._clock_offset = 0.0         # advanced by FaultPlan tick delays
        # one device copy of the static graph tables, shared by both pools
        arrays = engine.DeviceArrays.from_partition(part)
        if mesh is not None:
            sharding = NamedSharding(mesh, P(exchange.axis_tuple(axis_names)))
            arrays = jax.tree.map(
                lambda x: jax.device_put(x, sharding), arrays)
        self.min_pool = _MinPool(part, n_lanes, cfg, arrays, mesh,
                                 axis_names)
        self.ppr_pool = _PprPool(
            part, n_lanes if ppr_lanes is None else ppr_lanes, cfg, arrays,
            mesh, axis_names)
        self.queue = AdmissionQueue(
            self.serve.max_queue, self.serve.overload_policy,
            self.serve.tenant_weights)
        self.cache = ResultCache(self.serve.cache_size,
                                 self.serve.cache_ttl_s)
        self.results: dict[int, QueryResult] = {}
        self.counters = collections.Counter()
        self.tick = 0
        self.rounds_driven = 0   # pool rounds advanced (windows included)
        self._next_qid = 0
        self._lane_rounds = {}       # (pool, lane) -> rounds live
        self._lane_msgs = {}
        self._lane_exchanged = {}
        self._submit_time = {}       # qid -> clock time at submit
        self._submit_tick = {}       # qid -> tick at submit
        self._deadline_at = {}       # qid -> absolute clock deadline
        self._admit_tick = {}
        self._admit_time = {}        # (pool, lane) -> clock time at admit
        self._seq_of_qid = {}        # qid -> FIFO seq (preemption put-back)
        self._preempt_count = {}     # qid -> times preempted
        self._pools_used: set[int] = set()
        self.occupancy_trace: list[int] = []   # live lanes per tick
        self._obs_submit_t = {}      # qid -> tracer time at submit
        self._obs_admit_t = {}       # qid -> tracer time at admission
        self._ckpt_manager = None    # attach_checkpoints() wires saving
        self._resumed_qids: set[int] = set()   # lanes that crossed a restore

    def now(self) -> float:
        """Server wall clock (injected faults advance it)."""
        return self._clock() + self._clock_offset

    # ------------------------------------------------------------- submit
    def submit(self, kind: str, sources, damping: float = 0.85,
               tol: float = 1e-6, qid: int | None = None,
               priority: int = 0, tenant: str = "default",
               deadline_s: float | None = None,
               timeout_s: float | None = None,
               max_rounds: int | None = None) -> int:
        if qid is None:
            qid = self._next_qid
        self._next_qid = max(self._next_qid, qid) + 1
        req = QueryRequest(qid=qid, kind=kind, sources=sources,
                           damping=damping, tol=tol, priority=priority,
                           tenant=tenant, deadline_s=deadline_s,
                           timeout_s=timeout_s, max_rounds=max_rounds)
        pool = self.ppr_pool if kind == "ppr" else self.min_pool
        if pool.n == 0:
            raise ValueError(
                f"no lanes for kind {kind!r}: the request could never be "
                "admitted (server built with 0 lanes in its pool)")
        self._check_sources_in_range(req)
        now = self.now()
        self._submit_time[qid] = now
        self._submit_tick[qid] = self.tick
        self.counters["submitted"] += 1
        rec = obs.get_recorder()
        if rec is not None:
            self._obs_submit_t[qid] = rec.tracer.now()
            rec.registry.counter(
                "serve_submitted_total",
                "requests submitted").labels(kind=kind).inc()
        if deadline_s is not None:
            self._deadline_at[qid] = now + deadline_s

        # root-keyed result cache: a fresh hit never touches a lane
        if self.serve.cache_size:
            hit = self.cache.get(_cache_key(req), now)
            if rec is not None:
                rec.registry.counter(
                    "serve_cache_total", "result-cache events").labels(
                        event="hit" if hit is not None else "miss").inc()
            if hit is not None:
                self.counters["cache_hits"] += 1
                self._finish(req, values=np.array(hit, copy=True),
                             status=QueryStatus.OK, partial=False,
                             cached=True, rounds=0)
                return qid
            self.counters["cache_misses"] += 1

        # zero round budget: resolve immediately with the initial values
        if max_rounds is not None and int(max_rounds) == 0:
            self._finish(req, values=self._initial_values(req),
                         status=QueryStatus.BUDGET_EXHAUSTED, partial=True,
                         rounds=0)
            return qid

        if self.serve.overload_policy == "block" and self.queue.full:
            spins = 0
            while self.queue.full:
                if spins >= self.serve.block_max_ticks:
                    raise AdmissionError(
                        f"blocked submit exceeded block_max_ticks="
                        f"{self.serve.block_max_ticks}")
                progressed = self.step()
                spins += 1
                if not progressed and self.queue.full:
                    raise AdmissionError(
                        "blocked submit cannot make progress: queue full "
                        "and the serving loop is drained")
        seq = self.queue.next_seq
        decision, victim = self.queue.offer(req, priority, tenant)
        if victim is not None:
            self._finish(victim, values=None, status=QueryStatus.SHED)
        if decision == "admitted":
            self._seq_of_qid[qid] = seq
        elif decision == "rejected":
            self._finish(req, values=None, status=QueryStatus.REJECTED)
        elif decision == "shed_incoming":
            self._finish(req, values=None, status=QueryStatus.SHED)
        return qid

    def _check_sources_in_range(self, req: QueryRequest):
        if isinstance(req.sources, dict):
            ids = list(req.sources.keys())
        elif isinstance(req.sources, (list, tuple, np.ndarray)):
            ids = np.asarray(req.sources).reshape(-1).tolist()
        else:
            ids = [req.sources]
        n = self.part.n
        for v in ids:
            if not (0 <= int(v) < n):
                raise QueryValidationError(
                    f"source vertex {int(v)} out of range for a graph "
                    f"with {n} vertices")

    def _initial_values(self, req: QueryRequest) -> np.ndarray:
        """The 0-round snapshot: what a lane would hold right after
        injection (zero-round-budget requests return this)."""
        if req.kind == "ppr":
            seed = int(np.asarray(req.sources).reshape(-1)[0])
            col = L.ppr_base_table(self.part, [seed], [req.damping])[..., 0]
            return engine.vertex_values(self.part, col).astype(np.float64)
        kind = "bfs" if req.kind == "reachability" else req.kind
        init, _ = L.init_lane_values(self.part, [(kind, req.sources)])
        vv = engine.vertex_values(self.part, init[..., 0])
        return L.decode_min_values(vv, req.kind)

    def _finish(self, req: QueryRequest, values, status: str,
                partial: bool = False, cached: bool = False,
                rounds: int = 0):
        """Resolve a request that never ran (or ran 0 rounds) with a
        typed status."""
        self.results[req.qid] = QueryResult(
            qid=req.qid, kind=req.kind, values=values, rounds=rounds,
            messages=0, lane=-1,
            admitted_tick=-1 if status in (QueryStatus.REJECTED,
                                           QueryStatus.SHED) else self.tick,
            completed_tick=self.tick,
            latency_s=self.now() - self._submit_time[req.qid],
            status=status, partial=partial, cached=cached,
            tenant=req.tenant, priority=req.priority,
            preemptions=self._preempt_count.get(req.qid, 0),
            submitted_tick=self._submit_tick[req.qid])
        self.counters[status] += 1
        self._obs_request_end(req, status, cached=cached)

    def _obs_request_end(self, req: QueryRequest, status: str,
                         cached: bool = False):
        """Terminal-status metrics + the request's lifecycle spans
        (queued→admitted→terminal) — no-op without an installed
        recorder."""
        rec = obs.get_recorder()
        if rec is None:
            return
        rec.registry.counter(
            "serve_requests_total", "terminal request statuses").labels(
                status=status, kind=req.kind).inc()
        rec.registry.histogram(
            "serve_latency_seconds",
            "submit -> terminal latency (queue wait included)").labels(
                kind=req.kind).observe(
                    self.now() - self._submit_time[req.qid])
        end = rec.tracer.now()
        t0 = self._obs_submit_t.pop(req.qid, None)
        ta = self._obs_admit_t.pop(req.qid, None)
        if t0 is not None:
            rec.tracer.complete(
                "queued", track="requests", start=t0,
                end=ta if ta is not None else end,
                qid=req.qid, kind=req.kind)
        if ta is not None or cached:
            # cache hits never touch a lane: a zero-duration run at the
            # terminal instant keeps every lifecycle ending in a 'run'
            rec.tracer.complete(
                "run", track="requests",
                start=ta if ta is not None else end, end=end,
                qid=req.qid, kind=req.kind, status=status,
                cached=cached)

    # ---------------------------------------------------------- cache ops
    def invalidate_cache(self, root: int | None = None) -> int:
        """Invalidate cached results — rooted at ``root``, or the whole
        cache with None (the streaming-graph mutation hook).  Returns
        entries dropped; tallied in ``counters['cache_invalidations']``
        and the obs ``serve_cache_total{event="invalidation"}`` counter."""
        n = (self.cache.invalidate_all() if root is None
             else self.cache.invalidate(root))
        self.counters["cache_invalidations"] += n
        rec = obs.get_recorder()
        if rec is not None and n:
            rec.registry.counter(
                "serve_cache_total", "result-cache events").labels(
                    event="invalidation").inc(n)
        return n

    # ------------------------------------------------------- streaming ops
    def apply_mutation(self, new_part: Partition, insert_seeds=None,
                       has_deletes: bool = False,
                       affected_roots=None) -> None:
        """Swap the server onto a mutated partition between ticks (the
        ``StreamingGraph.commit`` hook).

        One fresh device copy of the new graph tables feeds both pools'
        ``rebind``: compiled rounds/windows recompile, live min lanes
        warm-continue across insert-only batches (frontier OR'd with
        ``insert_seeds``) and restart when ``has_deletes``, ppr lanes
        always restart.  The result cache is then invalidated — whole
        cache when ``affected_roots`` is None (exact: a mutation can
        move any root's result), else per affected root (the root-affine
        heuristic ``invalidate_cache(root)`` documents)."""
        arrays = engine.DeviceArrays.from_partition(new_part)
        if self.mesh is not None:
            sharding = NamedSharding(
                self.mesh, P(exchange.axis_tuple(
                    self.min_pool._axis_names)))
            arrays = jax.tree.map(
                lambda x: jax.device_put(x, sharding), arrays)
        self.part = new_part
        self.min_pool.rebind(new_part, arrays, insert_seeds=insert_seeds,
                             has_deletes=has_deletes)
        self.ppr_pool.rebind(new_part, arrays)
        if affected_roots is None:
            self.invalidate_cache(None)
        else:
            for root in np.asarray(affected_roots).reshape(-1):
                self.invalidate_cache(int(root))
        self.counters["mutations"] += 1
        rec = obs.get_recorder()
        if rec is not None:
            rec.registry.counter(
                "serve_mutations_total",
                "partition swaps applied between ticks").inc()

    # -------------------------------------------------------------- admit
    def _tenant_in_flight(self) -> dict:
        c: dict = {}
        for pool in (self.min_pool, self.ppr_pool):
            for r in pool.reqs:
                if r is not None:
                    c[r.tenant] = c.get(r.tenant, 0) + 1
        return c

    def _place(self, pool, lane: int, req: QueryRequest):
        pool.inject(lane, req)
        self._pools_used.add(id(pool))
        key = (id(pool), lane)
        self._lane_rounds[key] = 0
        self._lane_msgs[key] = 0
        self._lane_exchanged[key] = 0
        self._admit_tick[key] = self.tick
        self._admit_time[key] = self.now()
        self.counters["admitted"] += 1
        rec = obs.get_recorder()
        if rec is not None:
            self._obs_admit_t[req.qid] = rec.tracer.now()
            rec.registry.counter(
                "serve_admitted_total",
                "requests admitted into a lane").labels(
                    kind=req.kind).inc()

    def _preempt(self, pool, lane: int):
        """Evict a running lane for a more urgent request: the victim is
        re-queued at its original FIFO position and restarts."""
        req = pool.reqs[lane]
        pool.silence(lane)
        pool.reqs[lane] = None
        self._preempt_count[req.qid] = \
            self._preempt_count.get(req.qid, 0) + 1
        self.counters["preemptions"] += 1
        rec = obs.get_recorder()
        if rec is not None:
            rec.registry.counter(
                "serve_preemptions_total", "running lanes preempted").inc()
            ta = self._obs_admit_t.pop(req.qid, None)
            if ta is not None:     # close the preempted stint's run span
                rec.tracer.complete("run", track="requests", start=ta,
                                    qid=req.qid, kind=req.kind,
                                    status="preempted")
            rec.tracer.instant("preempt", track="requests", qid=req.qid)
        back = self.queue.put_back(
            req, req.priority, req.tenant,
            self._seq_of_qid.get(req.qid, self.queue.next_seq))
        if back is False:
            self._finish(req, values=None, status=QueryStatus.SHED)
        elif back is not True:       # a lower-priority queued item displaced
            self._finish(back, values=None, status=QueryStatus.SHED)

    def _admit(self) -> list[int]:
        admitted = []
        for pool, kinds in ((self.min_pool, MIN_KINDS),
                            (self.ppr_pool, ("ppr",))):
            def pool_pred(r, kinds=kinds):
                return r.kind in kinds

            for lane in range(pool.n):
                if pool.reqs[lane] is not None or not len(self.queue):
                    continue
                entry = self.queue.take(pool_pred, self._tenant_in_flight())
                if entry is None:
                    break
                self._seq_of_qid[entry.item.qid] = entry.seq
                self._place(pool, lane, entry.item)
                admitted.append(entry.item.qid)
            # preemption: the best still-queued candidate may outrank the
            # lowest-priority running lane (strictly greater only, so
            # uniform-priority traffic never preempts)
            while self.serve.preempt and len(self.queue):
                entry = self.queue.peek(pool_pred, self._tenant_in_flight())
                if entry is None:
                    break
                occ = [(pool.reqs[l].priority,
                        -self._admit_tick[(id(pool), l)], l)
                       for l in range(pool.n) if pool.reqs[l] is not None]
                if not occ:
                    break
                victim_pri, _, victim_lane = min(occ)
                if entry.priority <= victim_pri:
                    break
                self.queue.remove(entry)
                self._preempt(pool, victim_lane)
                self._seq_of_qid[entry.item.qid] = entry.seq
                self._place(pool, victim_lane, entry.item)
                admitted.append(entry.item.qid)
        return admitted

    # --------------------------------------------------------------- step
    def _retire(self, pool, lane: int, status: str, partial: bool):
        req = pool.reqs[lane]
        key = (id(pool), lane)
        if status == QueryStatus.OK and req.qid in self._resumed_qids:
            # the lane crossed a restore: the values are complete (and
            # bit-identical for min lanes) but the path was not clean
            status = QueryStatus.RECOVERED
        keep_values = (status == QueryStatus.OK
                       or status == QueryStatus.RECOVERED
                       or status in QueryStatus.PARTIAL_VALUED)
        values = pool.extract(lane) if keep_values else None
        self.results[req.qid] = QueryResult(
            qid=req.qid, kind=req.kind, values=values,
            rounds=self._lane_rounds[key],
            messages=self._lane_msgs[key], lane=lane,
            admitted_tick=self._admit_tick[key],
            completed_tick=self.tick,
            latency_s=self.now() - self._submit_time[req.qid],
            exchanged=self._lane_exchanged[key],
            status=status, partial=partial, tenant=req.tenant,
            priority=req.priority,
            preemptions=self._preempt_count.get(req.qid, 0),
            submitted_tick=self._submit_tick[req.qid])
        self.counters[status] += 1
        self._obs_request_end(req, status)
        if status == QueryStatus.OK and self.serve.cache_size:
            self.cache.put(_cache_key(req), np.array(values, copy=True),
                           self.now())
        pool.reqs[lane] = None             # lane freed immediately
        if status != QueryStatus.OK:
            pool.silence(lane)             # kill the in-flight frontier

    def _evict_overdue(self, pool, occupied, live_before):
        """Budget / deadline / timeout checks on still-live lanes.  A
        lane that already converged is retired OK by the normal path —
        convergence wins the race against a same-tick deadline expiry."""
        now = self.now()
        for lane in list(occupied):
            if not live_before[lane]:
                continue
            req = pool.reqs[lane]
            key = (id(pool), lane)
            status = None
            if req.max_rounds is not None \
                    and self._lane_rounds[key] >= req.max_rounds:
                status = QueryStatus.BUDGET_EXHAUSTED
            elif req.deadline_s is not None \
                    and now >= self._deadline_at[req.qid]:
                status = QueryStatus.DEADLINE_EXPIRED
            elif req.timeout_s is not None \
                    and now >= self._admit_time[key] + req.timeout_s:
                status = QueryStatus.TIMEOUT
            if status is not None:
                self._retire(pool, lane, status, partial=True)
                occupied.remove(lane)
                live_before[lane] = False

    def _tick_window(self, pool, occupied) -> int:
        """Rounds this tick may advance in one dispatch: ``tick_rounds``
        unless some occupied lane carries a per-round constraint
        (max_rounds / deadline_s / timeout_s), whose eviction point
        must stay exact at round granularity."""
        if self.tick_rounds == 1:
            return 1
        for lane in occupied:
            r = pool.reqs[lane]
            if r.max_rounds is not None or r.deadline_s is not None \
                    or r.timeout_s is not None:
                return 1
        return self.tick_rounds

    def _step_pool(self, pool):
        occupied = [lane for lane in range(pool.n)
                    if pool.reqs[lane] is not None]
        if not occupied:
            return 0
        live_before = np.array(pool.live())   # writable copy: evictions
        self._evict_overdue(pool, occupied, live_before)  # flip lanes off
        lives = None           # per-lane live-round counts (window tick)
        if not any(live_before[lane] for lane in occupied):
            # occupied-but-converged lanes (e.g. empty-frontier queries)
            # still retire below; nothing to relax
            counts = np.zeros(pool.n, np.int64)
        else:
            k = self._tick_window(pool, occupied)
            if k == 1:
                counts = pool.step()
            else:
                counts, lives = pool.step_window(k)
            self.rounds_driven += k
            engine._count_dispatches(
                "server_min" if pool is self.min_pool else "server_ppr",
                1, 1)
        live_after = pool.live()
        n_live = 0
        for lane in occupied:
            key = (id(pool), lane)
            if live_before[lane]:
                rl = 1 if lives is None else int(lives[lane])
                self._lane_rounds[key] += rl
                self._lane_msgs[key] += int(counts[lane])
                self._lane_exchanged[key] += pool.exchange_volume * rl
                n_live += 1
            if not live_after[lane]:           # converged -> evict now
                self._retire(pool, lane, QueryStatus.OK, partial=False)
        return n_live

    def _apply_faults(self):
        plan = self.serve.faults
        if plan is None:
            return
        delay = plan.delay_at(self.tick)
        if delay:
            self._clock_offset += delay    # a stalled tick, without sleeping
            self.counters["injected_delays"] += 1
        for pool_name, lane in plan.failures_at(self.tick):
            pool = self.min_pool if pool_name == "min" else self.ppr_pool
            if 0 <= lane < pool.n and pool.reqs[lane] is not None:
                self.counters["injected_lane_failures"] += 1
                self._retire(pool, lane, QueryStatus.FAILED, partial=True)

    def _expire_queued(self):
        if not len(self.queue):
            return
        now = self.now()
        expired = self.queue.drain_if(
            lambda r: r.deadline_s is not None
            and now >= self._deadline_at[r.qid])
        for req in expired:
            self._finish(req, values=None,
                         status=QueryStatus.DEADLINE_EXPIRED)

    def step(self) -> bool:
        """One global round tick. Returns False when fully drained."""
        rec = obs.get_recorder()
        span = (rec.tracer.span("tick", track="server", tick=self.tick)
                if rec is not None else None)
        self._apply_faults()
        self._expire_queued()
        self._admit()
        n_live = self._step_pool(self.min_pool) \
            + self._step_pool(self.ppr_pool)
        self.occupancy_trace.append(n_live)
        self.tick += 1
        K = self.serve.checkpoint_every
        if self._ckpt_manager is not None and K and self.tick % K == 0:
            self.save_checkpoint()
        if rec is not None:
            depth = len(self.queue)
            span.end(live=n_live, queue=depth)
            rec.registry.counter("serve_ticks_total",
                                 "server round ticks").inc()
            rec.registry.gauge("serve_queue_depth",
                               "queued requests after the tick").set(depth)
            rec.registry.gauge("serve_live_lanes",
                               "live lanes this tick").set(n_live)
            rec.tracer.counter("server",
                               {"queue_depth": depth, "live_lanes": n_live})
        return bool(n_live or len(self.queue)
                    or any(r is not None for r in self.min_pool.reqs)
                    or any(r is not None for r in self.ppr_pool.reqs))

    def run(self, max_ticks: int = 10000) -> dict[int, QueryResult]:
        for _ in range(max_ticks):
            if not self.step():
                break
        return self.results

    # ------------------------------------------------- checkpoint/restore
    def attach_checkpoints(self, manager) -> None:
        """Wire a ``CheckpointManager``: with ``ServeConfig.
        checkpoint_every=K`` set, ``step()`` snapshots the whole serving
        state every K ticks (async, atomic, crc-verified)."""
        self._ckpt_manager = manager

    def snapshot(self) -> tuple[dict, dict]:
        """(array tree, JSON meta) capturing the server at a tick
        boundary: both pools' lane tables + per-lane unit-weight /
        damping / tolerance vectors, every queued and in-flight request,
        the per-lane accounting, completed results, and the admission
        queue — everything ``restore`` needs to warm-boot a server whose
        min lanes resume bit-identically."""
        tree = {
            "min": {"val": np.asarray(self.min_pool.val),
                    "chg": np.asarray(self.min_pool.chg),
                    "unitw": np.array(self.min_pool.unitw, copy=True)},
            "ppr": {"rank": np.asarray(self.ppr_pool.rank),
                    "delta": np.asarray(self.ppr_pool.delta),
                    "chg": np.asarray(self.ppr_pool.chg),
                    "damping": np.array(self.ppr_pool.damping, copy=True),
                    "tol": np.array(self.ppr_pool.tol, copy=True)},
            "results": {str(qid): np.asarray(r.values)
                        for qid, r in self.results.items()
                        if r.values is not None},
        }
        pools = {"min": self.min_pool, "ppr": self.ppr_pool}
        lanes = {}
        for name, pool in pools.items():
            rows = []
            for lane, req in enumerate(pool.reqs):
                if req is None:
                    rows.append(None)
                    continue
                key = (id(pool), lane)
                rows.append({
                    "req": _req_to_dict(req),
                    "rounds": int(self._lane_rounds[key]),
                    "msgs": int(self._lane_msgs[key]),
                    "exchanged": int(self._lane_exchanged[key]),
                    "admit_tick": int(self._admit_tick[key]),
                    "admit_time": float(self._admit_time[key]),
                })
            lanes[name] = rows
        meta = {
            "n_lanes": self.min_pool.n, "ppr_lanes": self.ppr_pool.n,
            "tick_rounds": self.tick_rounds,
            "tick": self.tick, "rounds_driven": self.rounds_driven,
            "next_qid": self._next_qid, "now": float(self.now()),
            "counters": {k: int(v) for k, v in self.counters.items()},
            "occupancy_trace": [int(x) for x in self.occupancy_trace],
            "pools_used": [n for n, p in pools.items()
                           if id(p) in self._pools_used],
            "lanes": lanes,
            "queue": {
                "seq": self.queue.next_seq,
                "entries": [[int(e.seq), int(e.priority), e.tenant,
                             _req_to_dict(e.item)]
                            for e in self.queue._entries]},
            "submit_time": {str(k): float(v)
                            for k, v in self._submit_time.items()},
            "submit_tick": {str(k): int(v)
                            for k, v in self._submit_tick.items()},
            "deadline_at": {str(k): float(v)
                            for k, v in self._deadline_at.items()},
            "seq_of_qid": {str(k): int(v)
                           for k, v in self._seq_of_qid.items()},
            "preempt_count": {str(k): int(v)
                              for k, v in self._preempt_count.items()},
            "resumed_qids": sorted(self._resumed_qids),
            "results": [_result_to_dict(r) for r in self.results.values()],
        }
        return tree, meta

    def save_checkpoint(self, blocking: bool = False) -> int:
        """Snapshot the serving state to the attached manager at the
        current tick (async by default).  Returns the checkpoint step."""
        if self._ckpt_manager is None:
            raise RuntimeError("no CheckpointManager attached "
                               "(call attach_checkpoints first)")
        tree, meta = self.snapshot()
        self._ckpt_manager.save(self.tick, tree, blocking=blocking,
                                meta=meta)
        rec = obs.get_recorder()
        if rec is not None:
            rec.registry.counter(
                "serve_checkpoints_total",
                "serving-state checkpoints written").inc()
        return self.tick

    @classmethod
    def restore(cls, part: Partition, manager, *, step: int | None = None,
                cfg: EngineConfig = EngineConfig(), mesh=None,
                axis_names=("data", "model"),
                serve: ServeConfig | None = None, clock=None):
        """Warm-boot a server from a checkpoint: lane tables, queued and
        in-flight requests, accounting, and results all resume at the
        checkpointed tick — min-semiring lanes bit-identically (same
        tables, same compiled round).  In-flight lanes complete with
        ``QueryStatus.RECOVERED``.  ``part``/``cfg``/``mesh`` must
        describe the same served graph the checkpoint was taken on."""
        if step is None:
            step = manager.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint to restore from")
        meta = manager.restore_meta(step)
        srv = cls(part, n_lanes=meta["n_lanes"], cfg=cfg,
                  ppr_lanes=meta["ppr_lanes"], mesh=mesh,
                  axis_names=axis_names, serve=serve, clock=clock,
                  tick_rounds=meta["tick_rounds"])
        like = {
            "min": {"val": 0, "chg": 0, "unitw": 0},
            "ppr": {"rank": 0, "delta": 0, "chg": 0, "damping": 0,
                    "tol": 0},
            "results": {str(r["qid"]): 0 for r in meta["results"]
                        if r["has_values"]},
        }
        tree = manager.restore(step, like)
        srv._load_snapshot(tree, meta)
        return srv

    def _load_snapshot(self, tree: dict, meta: dict):
        mp, pp = self.min_pool, self.ppr_pool
        mp.val = mp._put(jnp.asarray(tree["min"]["val"]))
        mp.chg = mp._put(jnp.asarray(tree["min"]["chg"]))
        mp.unitw = np.asarray(tree["min"]["unitw"], np.int32)
        pp.rank = pp._put(jnp.asarray(tree["ppr"]["rank"]))
        pp.delta = pp._put(jnp.asarray(tree["ppr"]["delta"]))
        pp.chg = pp._put(jnp.asarray(tree["ppr"]["chg"]))
        pp.damping = np.asarray(tree["ppr"]["damping"], np.float32)
        pp.tol = np.asarray(tree["ppr"]["tol"], np.float32)
        self.tick = int(meta["tick"])
        self.rounds_driven = int(meta["rounds_driven"])
        self._next_qid = int(meta["next_qid"])
        self.counters = collections.Counter(meta["counters"])
        self.occupancy_trace = list(meta["occupancy_trace"])
        pools = {"min": mp, "ppr": pp}
        self._pools_used = {id(pools[n]) for n in meta["pools_used"]}
        for name, pool in pools.items():
            for lane, row in enumerate(meta["lanes"][name]):
                if row is None:
                    continue
                req = _req_from_dict(row["req"])
                pool.reqs[lane] = req
                key = (id(pool), lane)
                self._lane_rounds[key] = row["rounds"]
                self._lane_msgs[key] = row["msgs"]
                self._lane_exchanged[key] = row["exchanged"]
                self._admit_tick[key] = row["admit_tick"]
                self._admit_time[key] = row["admit_time"]
                self._resumed_qids.add(req.qid)
                if name == "min":
                    _, unitw = L.init_lane_values(
                        self.part,
                        [("bfs" if req.kind == "reachability"
                          else req.kind, req.sources)])
                    pool.unitw[lane] = int(unitw[0])
        self.queue._entries = [
            _adm._Entry(seq, pri, tenant, _req_from_dict(d))
            for seq, pri, tenant, d in meta["queue"]["entries"]]
        self.queue._seq = int(meta["queue"]["seq"])
        self._submit_time = {int(k): v
                             for k, v in meta["submit_time"].items()}
        self._submit_tick = {int(k): v
                             for k, v in meta["submit_tick"].items()}
        self._deadline_at = {int(k): v
                             for k, v in meta["deadline_at"].items()}
        self._seq_of_qid = {int(k): v
                            for k, v in meta["seq_of_qid"].items()}
        self._preempt_count = {int(k): v
                               for k, v in meta["preempt_count"].items()}
        self._resumed_qids.update(meta["resumed_qids"])
        for rd in meta["results"]:
            vals = (tree["results"][str(rd["qid"])]
                    if rd["has_values"] else None)
            self.results[rd["qid"]] = _result_from_dict(rd, vals)
        # resume the snapshot's wall clock so restored deadlines /
        # timeouts / latencies stay coherent under any injected clock
        self._clock_offset = meta["now"] - self._clock()

    def degrade_in_flight(self) -> list[int]:
        """Graceful degradation when recovery is impossible (no usable
        checkpoint, restore budget exhausted): every in-flight lane
        retires with ``QueryStatus.DEGRADED`` partial values, every
        queued request resolves ``DEGRADED`` with no values.  The server
        stays serviceable for new traffic.  Returns the affected qids."""
        out = []
        for pool in (self.min_pool, self.ppr_pool):
            for lane in range(pool.n):
                if pool.reqs[lane] is not None:
                    out.append(pool.reqs[lane].qid)
                    self._retire(pool, lane, QueryStatus.DEGRADED,
                                 partial=True)
        for req in self.queue.drain_if(lambda r: True):
            out.append(req.qid)
            self._finish(req, values=None, status=QueryStatus.DEGRADED)
        return out

    # ------------------------------------------------------------ metrics
    def occupancy(self) -> float:
        """Mean live lanes per tick over the capacity of the pools that
        actually served requests (serving utilization)."""
        if not self.occupancy_trace:
            return 0.0
        cap = sum(pool.n for pool in (self.min_pool, self.ppr_pool)
                  if id(pool) in self._pools_used)
        return float(np.mean(self.occupancy_trace)) / max(cap, 1)

    def in_flight(self) -> int:
        return sum(r is not None for pool in (self.min_pool, self.ppr_pool)
                   for r in pool.reqs)
