"""Continuous-batching graph query server (ISSUE 2 tentpole; sharded
serving loop — ISSUE 3).

The graph-query analog of ``serve.scheduler.ContinuousBatcher``: a pool
of ``Q`` query lanes shares one compiled round step per semiring class
(a min-pool for BFS / SSSP / reachability, a sum-pool for personalized
PageRank).  Requests join free lanes mid-flight via masked state
injection — the new lane's (S, R_max) column of values and frontier is
written into the batched tables between rounds — and are evicted the
round they converge, so a nearby-source BFS never waits on a
diameter-spanning SSSP (no head-of-line blocking: the serving analog of
the paper's always-busy compute cells).

A freed lane is inert by construction: its ``changed`` column is
all-False, so it reads as the absorbing identity inside the shared relax
and contributes nothing until the next injection overwrites it.

``QueryServer(mesh=...)`` drives the lanes × ``shard_map`` round instead
of the stacked one: the same continuous-batching loop, but each tick is
one real-collective round over the mesh (value/changed ``all_gather``,
inbox ``all_to_all`` — dense or §Perf compact targeted per
``EngineConfig.exchange``), so one serving loop batches queries across
devices.  Lane state lives sharded on the mesh; injection writes a
column of the distributed table between rounds.

The ``EngineConfig`` handed to the server also governs the fused
kernel's value-table residency (``vmem_budget_bytes``): a served
partition whose lane table exceeds the VMEM budget runs every pool
round through the HBM-tiled DMA kernel with identical serving
semantics — the continuous-batching loop never needs to know.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import exchange
from repro.core import actions, engine
from repro.core.engine import EngineConfig
from repro.core.partition import Partition
from repro.query import lanes as L

MIN_KINDS = ("bfs", "sssp", "reachability")


@dataclasses.dataclass
class QueryRequest:
    """One source-rooted query over the served graph.

    kind: 'bfs' | 'sssp' | 'reachability' (min-pool) or 'ppr' (sum-pool).
    sources: vertex id, list of vertices (multi-source), or {vertex:
    initial value} dict; for 'ppr' a single personalization seed vertex.
    """

    qid: int
    kind: str
    sources: object
    damping: float = 0.85        # ppr only
    tol: float = 1e-6            # ppr only

    def __post_init__(self):
        if self.kind not in MIN_KINDS + ("ppr",):
            raise ValueError(f"unknown query kind {self.kind!r}")
        if self.kind == "ppr" \
                and np.asarray(self.sources).reshape(-1).size != 1:
            raise ValueError(
                "ppr takes a single personalization seed vertex; "
                "multi-seed personalization is not supported")


@dataclasses.dataclass
class QueryResult:
    qid: int
    kind: str
    values: np.ndarray           # (n,) levels / distances / bool / scores
    rounds: int                  # rounds the lane was live
    messages: int                # actions delivered for this query
    lane: int                    # lane the query ran in
    admitted_tick: int
    completed_tick: int
    latency_s: float             # submit -> completion (includes queue wait)
    exchanged: int = 0           # exchange entries shipped while live


class _LanePool:
    """Shared pool plumbing: lane state lives on device — stacked, or
    sharded over the server's mesh (``_sharding`` set, ``_arrays``
    holding the mesh-placed graph tables), in which case every state
    update is re-placed so the per-tick round never re-shards."""

    _sharding = None

    def _put(self, x):
        return x if self._sharding is None else jax.device_put(
            x, self._sharding)


class _MinPool(_LanePool):
    """Min-semiring lane pool: one compiled laned fixpoint round —
    stacked, or lanes × shard_map when the server holds a mesh."""

    def __init__(self, part: Partition, n_lanes: int, cfg: EngineConfig,
                 arrays: engine.DeviceArrays, mesh=None,
                 axis_names=("data", "model")):
        self.part, self.n = part, n_lanes
        S, R_max = part.S, part.R_max
        self.exchange_volume = L._volume(part, cfg)
        self.unitw = np.zeros(n_lanes, np.int32)
        self.reqs: list[QueryRequest | None] = [None] * n_lanes
        if mesh is None:
            def round_fn(val, chg, unitw):
                return exchange.fixpoint_round_stacked(
                    actions.SSSP, arrays, cfg, S, R_max, val, chg,
                    lane_unitw=unitw)

            self._round = jax.jit(round_fn)
        else:
            self._round, self._sharding = L.make_sharded_min_round(
                S, R_max, mesh, axis_names, cfg)
            self._arrays = arrays          # already device_put by the server
        self.val = self._put(jnp.full((S, R_max, n_lanes), jnp.inf,
                                      jnp.float32))
        self.chg = self._put(jnp.zeros((S, R_max, n_lanes), bool))

    def inject(self, lane: int, req: QueryRequest):
        init, unitw = L.init_lane_values(
            self.part, [("bfs" if req.kind == "reachability" else req.kind,
                         req.sources)])
        col = jnp.asarray(init[..., 0])
        chg_col = (actions.SSSP.improved(col, jnp.full_like(col, jnp.inf))
                   & jnp.asarray(self.part.slot_vertex >= 0))
        self.val = self._put(self.val.at[:, :, lane].set(col))
        self.chg = self._put(self.chg.at[:, :, lane].set(chg_col))
        self.unitw[lane] = int(unitw[0])
        self.reqs[lane] = req

    def live(self) -> np.ndarray:
        # reduce to (Q,) on device; never ship the whole changed table
        return np.asarray(jnp.any(self.chg, axis=(0, 1)))

    def step(self) -> np.ndarray:
        """One shared round; returns (Q,) per-lane message counts."""
        if self._sharding is None:
            self.val, self.chg, counts = self._round(
                self.val, self.chg, jnp.asarray(self.unitw))
            return np.asarray(counts)
        arrays = self._arrays
        self.val, self.chg, counts = self._round(
            arrays, self.val, self.chg, jnp.asarray(self.unitw))
        return np.asarray(counts)[0]     # psum'd — identical per shard row

    def extract(self, lane: int) -> np.ndarray:
        vv = engine.vertex_values(self.part, self.val[:, :, lane])
        return L.decode_min_values(vv, self.reqs[lane].kind)


class _PprPool(_LanePool):
    """Sum-semiring lane pool: per-lane seed/damping counted rounds with
    tolerance-based convergence — stacked, or sharded under a mesh."""

    def __init__(self, part: Partition, n_lanes: int, cfg: EngineConfig,
                 arrays: engine.DeviceArrays, mesh=None,
                 axis_names=("data", "model")):
        self.part, self.n = part, n_lanes
        S, R_max = part.S, part.R_max
        self.exchange_volume = L._volume(part, cfg)
        self.damping = np.zeros(n_lanes, np.float32)
        self.tol = np.full(n_lanes, 1e-6, np.float32)
        self.live_mask = np.zeros(n_lanes, bool)
        self.reqs: list[QueryRequest | None] = [None] * n_lanes
        if mesh is None:
            self._round = L.make_ppr_round(part, cfg, arrays=arrays)
        else:
            self._round, self._sharding = L.make_sharded_ppr_round(
                S, R_max, mesh, axis_names, cfg)
            self._arrays = arrays          # already device_put by the server
        self.val = self._put(jnp.zeros((S, R_max, n_lanes), jnp.float32))
        # device-resident like `val`: only an injection touches it, so the
        # per-tick round must not re-upload a table-sized host array
        self.base = self._put(jnp.zeros((S, R_max, n_lanes), jnp.float32))

    def inject(self, lane: int, req: QueryRequest):
        srcs = np.asarray(req.sources).reshape(-1)
        if srcs.size != 1:
            raise ValueError(
                f"ppr takes a single personalization seed; got "
                f"{srcs.size} sources")
        seed = int(srcs[0])
        self.base = self._put(self.base.at[:, :, lane].set(jnp.asarray(
            L.ppr_base_table(self.part, [seed], [req.damping])[..., 0])))
        col = engine.init_values(self.part, actions.PAGERANK, {seed: 1.0})
        self.val = self._put(self.val.at[:, :, lane].set(jnp.asarray(col)))
        self.damping[lane] = req.damping
        self.tol[lane] = req.tol
        self.live_mask[lane] = True
        self.reqs[lane] = req

    def live(self) -> np.ndarray:
        return self.live_mask.copy()

    def step(self) -> np.ndarray:
        if self._sharding is None:
            self.val, delta, counts = self._round(
                self.val, self.base, jnp.asarray(self.damping),
                jnp.asarray(self.live_mask))
            delta, counts = np.asarray(delta), np.asarray(counts)
        else:
            self.val, delta, counts = self._round(
                self._arrays, self.val, self.base,
                jnp.asarray(self.damping), jnp.asarray(self.live_mask))
            # pmax'd / psum'd — identical per shard row
            delta, counts = np.asarray(delta)[0], np.asarray(counts)[0]
        self.live_mask &= delta > self.tol
        return counts

    def extract(self, lane: int) -> np.ndarray:
        return engine.vertex_values(
            self.part, self.val[:, :, lane]).astype(np.float64)


class QueryServer:
    """Continuous batcher over query lanes sharing one compiled round.

    ``step()`` is one global round tick: admit queued requests into free
    lanes, advance each pool one laned round, retire converged lanes.
    ``run()`` drains the queue.  Occupancy / round / message counters are
    kept per lane for the serving metrics in ``benchmarks/query_bench.py``.

    With ``mesh=`` the per-tick round is the lanes × shard_map round with
    real collectives (see the module docstring); the batching semantics —
    masked mid-flight injection, eviction on convergence, no head-of-line
    blocking — are identical to the stacked server's.
    """

    def __init__(self, part: Partition, n_lanes: int = 8,
                 cfg: EngineConfig = EngineConfig(),
                 ppr_lanes: int | None = None, mesh=None,
                 axis_names=("data", "model")):
        self.part = part
        self.mesh = mesh
        # one device copy of the static graph tables, shared by both pools
        arrays = engine.DeviceArrays.from_partition(part)
        if mesh is not None:
            sharding = NamedSharding(mesh, P(exchange.axis_tuple(axis_names)))
            arrays = jax.tree.map(
                lambda x: jax.device_put(x, sharding), arrays)
        self.min_pool = _MinPool(part, n_lanes, cfg, arrays, mesh,
                                 axis_names)
        self.ppr_pool = _PprPool(
            part, n_lanes if ppr_lanes is None else ppr_lanes, cfg, arrays,
            mesh, axis_names)
        self.queue: list[QueryRequest] = []
        self.results: dict[int, QueryResult] = {}
        self.tick = 0
        self._next_qid = 0
        self._lane_rounds = {}       # (pool, lane) -> rounds live
        self._lane_msgs = {}
        self._lane_exchanged = {}
        self._submit_time = {}       # qid -> wall time at submit
        self._admit_tick = {}
        self._pools_used: set[int] = set()
        self.occupancy_trace: list[int] = []   # live lanes per tick

    # ------------------------------------------------------------- submit
    def submit(self, kind: str, sources, damping: float = 0.85,
               tol: float = 1e-6, qid: int | None = None) -> int:
        pool = self.ppr_pool if kind == "ppr" else self.min_pool
        if kind in MIN_KINDS + ("ppr",) and pool.n == 0:
            raise ValueError(
                f"no lanes for kind {kind!r}: the request could never be "
                "admitted (server built with 0 lanes in its pool)")
        if qid is None:
            qid = self._next_qid
        self._next_qid = max(self._next_qid, qid) + 1
        self.queue.append(QueryRequest(qid=qid, kind=kind, sources=sources,
                                       damping=damping, tol=tol))
        self._submit_time[qid] = time.perf_counter()
        return qid

    # -------------------------------------------------------------- admit
    def _admit(self) -> list[int]:
        admitted = []
        for pool, kinds in ((self.min_pool, MIN_KINDS),
                            (self.ppr_pool, ("ppr",))):
            for lane in range(pool.n):
                if pool.reqs[lane] is not None or not self.queue:
                    continue
                nxt = next((i for i, r in enumerate(self.queue)
                            if r.kind in kinds), None)
                if nxt is None:
                    break
                req = self.queue.pop(nxt)
                pool.inject(lane, req)
                self._pools_used.add(id(pool))
                key = (id(pool), lane)
                self._lane_rounds[key] = 0
                self._lane_msgs[key] = 0
                self._lane_exchanged[key] = 0
                self._admit_tick[key] = self.tick
                admitted.append(req.qid)
        return admitted

    # --------------------------------------------------------------- step
    def _step_pool(self, pool):
        occupied = [lane for lane in range(pool.n)
                    if pool.reqs[lane] is not None]
        if not occupied:
            return 0
        live_before = pool.live()
        if not any(live_before[lane] for lane in occupied):
            # occupied-but-converged lanes (e.g. empty-frontier queries)
            # still retire below; nothing to relax
            counts = np.zeros(pool.n, np.int64)
        else:
            counts = pool.step()
        live_after = pool.live()
        n_live = 0
        for lane in occupied:
            key = (id(pool), lane)
            if live_before[lane]:
                self._lane_rounds[key] += 1
                self._lane_msgs[key] += int(counts[lane])
                self._lane_exchanged[key] += pool.exchange_volume
                n_live += 1
            if not live_after[lane]:           # converged -> evict now
                req = pool.reqs[lane]
                self.results[req.qid] = QueryResult(
                    qid=req.qid, kind=req.kind, values=pool.extract(lane),
                    rounds=self._lane_rounds[key],
                    messages=self._lane_msgs[key], lane=lane,
                    admitted_tick=self._admit_tick[key],
                    completed_tick=self.tick,
                    latency_s=time.perf_counter()
                    - self._submit_time[req.qid],
                    exchanged=self._lane_exchanged[key],
                )
                pool.reqs[lane] = None         # lane freed immediately
        return n_live

    def step(self) -> bool:
        """One global round tick. Returns False when fully drained."""
        self._admit()
        n_live = self._step_pool(self.min_pool) \
            + self._step_pool(self.ppr_pool)
        self.occupancy_trace.append(n_live)
        self.tick += 1
        return bool(n_live or self.queue
                    or any(r is not None for r in self.min_pool.reqs)
                    or any(r is not None for r in self.ppr_pool.reqs))

    def run(self, max_ticks: int = 10000) -> dict[int, QueryResult]:
        for _ in range(max_ticks):
            if not self.step():
                break
        return self.results

    # ------------------------------------------------------------ metrics
    def occupancy(self) -> float:
        """Mean live lanes per tick over the capacity of the pools that
        actually served requests (serving utilization)."""
        if not self.occupancy_trace:
            return 0.0
        cap = sum(pool.n for pool in (self.min_pool, self.ppr_pool)
                  if id(pool) in self._pools_used)
        return float(np.mean(self.occupancy_trace)) / max(cap, 1)
