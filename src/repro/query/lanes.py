"""Lane-batched multi-query fixpoint execution (ISSUE 2 tentpole).

The paper's runtime keeps every compute cell busy by letting actions spawn
fine-grain work; serving heavy traffic means the unit of load is *many
concurrent source-rooted queries* over one shared rhizome-partitioned
graph.  Here the engine's value table grows a trailing **query-lane axis
Q**: values are ``(S, R_max, Q)``, the ``changed`` frontier is per-lane,
and one relax round advances every live query at once — the batching
answer to per-query underutilization in vertex-centric systems (iPregel;
Yan et al.), amortizing message/synchronization cost across queries.

Per-lane convergence is free: a lane whose frontier column is all-False
reads as the absorbing identity inside the relax, so it stops relaxing
while the round keeps running for live lanes; the fused kernel's frontier
chunk-skip bitmap becomes the OR across lanes (a grid cell is skipped
only when its edge chunk is dead in *every* lane — see
``kernels.fused_relax_reduce.fused_relax_reduce_lanes_pallas``).

One compiled round serves a **mixed BFS/SSSP batch**: all min-semiring
queries relax with 'add_w', and the per-lane ``lane_unitw`` flag swaps
the edge weight for the constant 1.0 (BFS levels are SSSP distances over
unit weights — the same float op, so a batched lane is bit-identical to
its solo ``engine.run_stacked`` run).  Sum-semiring lanes (personalized
PageRank, per-lane seed/damping) run as counted ``make_ppr_round`` rounds
with a per-lane tolerance-based convergence mask.

Laned execution is dense-exchange / eager-collapse only (the compact
targeted exchange stays single-query; ROADMAP open item).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import actions, engine
from repro.core.actions import Semiring
from repro.core.engine import DeviceArrays, EngineConfig
from repro.core.partition import Partition


UNREACHED = np.iinfo(np.int32).max


def decode_min_values(vv: np.ndarray, kind: str) -> np.ndarray:
    """Decode a min-lane's per-vertex values for its query kind: 'bfs' ->
    int64 levels with the UNREACHED sentinel, 'reachability' -> bool,
    'sssp' -> float64 distances (inf where unreachable).  The single
    decoding point for batched apps and the QueryServer."""
    if kind == "bfs":
        out = np.where(np.isfinite(vv), vv, 0).astype(np.int64)
        out[~np.isfinite(vv)] = UNREACHED
        return out
    if kind == "reachability":
        return np.isfinite(vv)
    if kind == "sssp":
        return vv.astype(np.float64)
    raise ValueError(f"unknown min-lane query kind {kind!r}")


class LaneStats(typing.NamedTuple):
    """Per-lane (Q,) counters — the Fig-6 statistics, one per query."""

    rounds: jax.Array        # rounds in which the lane was live
    messages: jax.Array      # actions delivered (active edges) per lane
    work_actions: jax.Array  # predicate-true slot updates per lane


def _check_cfg(cfg: EngineConfig):
    if cfg.exchange != "dense":
        raise ValueError(
            "lane-batched runners support exchange='dense' only (the "
            "compact targeted exchange is single-query; ROADMAP)")
    if cfg.collapse != "eager":
        raise ValueError("lane-batched runners support collapse='eager' only")
    if cfg.use_pallas and cfg.pallas_mode != "fused":
        raise ValueError(
            "lane-batched Pallas execution is fused-only (the pre-fusion "
            "'reduce' composition has no laned form)")


def _check_min(sem: Semiring):
    # the laned round relaxes with 'add_w' + the per-lane unitw flag, so a
    # semiring whose own relax differs (e.g. BFS 'add_one') must not be
    # accepted and silently re-relaxed with edge weights — BFS lanes are
    # expressed as lane_unitw=1 under the SSSP semiring instead
    if sem.segment != "min" or sem.relax_kind != "add_w":
        raise ValueError(
            "laned runners drive min-semiring 'add_w' fixpoints (express "
            "BFS lanes with lane_unitw=1, not the 'add_one' semiring); "
            "sum semirings run as make_ppr_round counted rounds")


# --------------------------------------------------------------------------
# shared laned per-round math (dense exchange)
# --------------------------------------------------------------------------

def _lane_relax_dense(cfg: EngineConfig, edge_src, edge_w, edge_mask,
                      edge_dst, gval, gchg, lane_unitw, num_segments,
                      relax_kind, kind):
    """Laned relax phase over one edge set: gather per-lane sources, relax
    all lanes, partial-reduce per lane.  ``gval``/``gchg``: (V, Q).
    Returns ((num_segments, Q) partial, (Q,) per-lane message counts)."""
    src = edge_src.reshape(-1)
    ids = edge_dst.reshape(-1)
    w = edge_w.reshape(-1)
    mask = edge_mask.reshape(-1)
    q = gval.shape[-1]
    identity = jnp.inf if kind == "min" else 0.0
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        partial, counts = kops.fused_relax_reduce_lanes(
            gval, gchg, lane_unitw, src, w, mask, ids, num_segments,
            relax_kind=relax_kind, kind=kind)
        if not cfg.track_stats:
            counts = jnp.zeros((q,), jnp.int32)
        return partial, counts
    src_val = jnp.take(gval, src, axis=0)                  # (E, Q)
    active = mask[:, None] & jnp.take(gchg, src, axis=0)
    if relax_kind == "add_w":
        w_eff = jnp.where(lane_unitw[None, :] > 0,
                          jnp.asarray(1.0, w.dtype), w[:, None])
        msg = src_val + w_eff
    else:                                                  # 'mul_w'
        msg = src_val * w[:, None]
    msg = jnp.where(active, msg, jnp.asarray(identity, msg.dtype))
    init = jnp.full((num_segments, q), identity, msg.dtype)
    partial = (init.at[ids].min(msg) if kind == "min"
               else init.at[ids].add(msg))
    counts = (active.sum(axis=0, dtype=jnp.int32) if cfg.track_stats
              else jnp.zeros((q,), jnp.int32))
    return partial, counts


def _collapse_lanes(sem: Semiring, gx, sibling_flat, sibling_mask):
    """Laned rhizome collapse: ``gx`` (V, Q); sibling tables index the
    leading axis, the lane axis rides along."""
    sib = jnp.take(gx, sibling_flat, axis=0)       # (..., K, Q)
    sib = jnp.where(sibling_mask[..., None], sib,
                    jnp.asarray(sem.identity, sib.dtype))
    return (jnp.min(sib, axis=-2) if sem.segment == "min"
            else jnp.sum(sib, axis=-2))


def _lane_round_stacked(sem, arrays, cfg, S, R_max, lane_unitw, val, chg):
    """One stacked dense laned fixpoint round: relax -> inbox combine ->
    rhizome collapse -> per-lane predicate.  val/chg: (S, R_max, Q)."""
    q = val.shape[-1]
    total = S * R_max
    gval = val.reshape(total, q)
    gchg = chg.reshape(total, q)
    inbox, counts = _lane_relax_dense(
        cfg, arrays.edge_src_root_flat, arrays.edge_w, arrays.edge_mask,
        arrays.edge_dst_flat, gval, gchg, lane_unitw, total, "add_w", "min")
    cand = sem.combine(val, inbox.reshape(S, R_max, q))
    cand = _collapse_lanes(sem, cand.reshape(total, q),
                           arrays.sibling_flat, arrays.sibling_mask)
    new_chg = sem.improved(cand, val) & arrays.slot_valid[..., None]
    return cand, new_chg, counts


# --------------------------------------------------------------------------
# stacked laned fixpoint runner (BFS / SSSP / reachability / CC lanes)
# --------------------------------------------------------------------------

def make_stacked_lanes_fn(part: Partition,
                          cfg: EngineConfig = EngineConfig(),
                          sem: Semiring = actions.SSSP):
    """Builds the stacked laned fixpoint as a jitted fn of ((S, R_max, Q)
    init values, (Q,) lane_unitw, (S, R_max, Q) init changed) ->
    (values, LaneStats).  Q is encoded in the argument shapes, so one
    returned fn serves any lane count (jit specializes per Q).  Hold on
    to the returned fn to amortize tracing across calls — the serving
    loop and ``benchmarks/query_bench.py`` compile it once."""
    _check_cfg(cfg)
    _check_min(sem)
    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max

    @jax.jit
    def fn(init_val, lane_unitw, init_chg):
        q = init_val.shape[-1]

        def body(carry):
            val, chg, it, stats = carry
            live = chg.reshape(-1, q).any(axis=0)
            new_val, new_chg, counts = _lane_round_stacked(
                sem, arrays, cfg, S, R_max, lane_unitw, val, chg)
            stats = LaneStats(
                rounds=stats.rounds + live.astype(jnp.int32),
                messages=stats.messages + counts,
                work_actions=stats.work_actions
                + new_chg.sum(axis=(0, 1), dtype=jnp.int32),
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            return jnp.any(chg) & (it < cfg.max_iters)

        zero_q = jnp.zeros((q,), jnp.int32)
        stats0 = LaneStats(zero_q, zero_q, zero_q)
        val, chg, it, stats = lax.while_loop(
            cond, body,
            (init_val, init_chg, jnp.zeros((), jnp.int32), stats0))
        return val, stats

    return fn


def run_stacked_lanes(part: Partition, init_val, lane_unitw=None,
                      cfg: EngineConfig = EngineConfig(),
                      init_changed=None, sem: Semiring = actions.SSSP):
    """Single-device lane-batched execution. ``init_val``: (S, R_max, Q)
    float32 — one query per lane; ``lane_unitw`` (Q,) marks BFS-style
    lanes (relax with weight 1.0).  A lane converges when no slot of its
    column improves; the round keeps running while any lane is live.
    Returns ((S, R_max, Q) values, per-lane ``LaneStats``)."""
    init_val = jnp.asarray(init_val, jnp.float32)
    if init_val.ndim != 3:
        raise ValueError(f"init_val must be (S, R_max, Q); got "
                         f"{init_val.shape}")
    q = init_val.shape[-1]
    lane_unitw = (jnp.zeros((q,), jnp.int32) if lane_unitw is None
                  else jnp.asarray(lane_unitw, jnp.int32).reshape(q))
    fn = make_stacked_lanes_fn(part, cfg, sem)
    slot_valid = jnp.asarray(part.slot_vertex >= 0)
    if init_changed is not None:
        init_chg = jnp.asarray(init_changed) & slot_valid[..., None]
    else:
        init_chg = sem.improved(
            init_val, jnp.full_like(init_val, sem.identity)
        ) & slot_valid[..., None]
    return fn(init_val, lane_unitw, init_chg)


# --------------------------------------------------------------------------
# sharded laned fixpoint (shard_map over a real mesh)
# --------------------------------------------------------------------------

def make_sharded_lanes_fn(S: int, R_max: int, Q: int, mesh: Mesh,
                          axis_names=("data", "model"),
                          cfg: EngineConfig = EngineConfig(),
                          sem: Semiring = actions.SSSP):
    """shard_map laned fixpoint as a jit-able fn of (DeviceArrays,
    (S, R_max, Q) val, (Q,) lane_unitw) -> (val, LaneStats).  Same
    collective plan as ``engine.make_sharded_fn`` with the lane axis
    riding along: value/changed all_gather, (S, R_max, Q) inbox
    all_to_all, sibling collapse over the gathered table, per-lane
    psum'd liveness for the termination test."""
    _check_cfg(cfg)
    _check_min(sem)
    axis_names = engine._axis(axis_names)
    total = S * R_max
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays(*([spec] * len(DeviceArrays._fields))),
        spec,
        P(),                                   # lane_unitw: replicated
    )

    def shard_fn(arrays_l: DeviceArrays, val_l, lane_unitw):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val = val_l[0]                         # (R_max, Q)

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        def round_fn(val, chg):
            gval, gchg = gather(val), gather(chg)      # (S*R_max, Q)
            partial, counts = _lane_relax_dense(
                cfg, arrays_s.edge_src_root_flat, arrays_s.edge_w,
                arrays_s.edge_mask, arrays_s.edge_dst_flat,
                gval, gchg, lane_unitw, total, "add_w", "min")
            recv = lax.all_to_all(
                partial.reshape(S, R_max, Q), axis_names,
                split_axis=0, concat_axis=0, tiled=True)
            inbox = jnp.min(recv.reshape(S, R_max, Q), axis=0)
            cand = sem.combine(val, inbox)
            cand = _collapse_lanes(sem, gather(cand),
                                   arrays_s.sibling_flat,
                                   arrays_s.sibling_mask)
            new_chg = sem.improved(cand, val) & arrays_s.slot_valid[..., None]
            return cand, new_chg, counts

        def body(carry):
            val, chg, it, stats = carry
            live = lax.psum(
                chg.reshape(-1, Q).any(axis=0).astype(jnp.int32),
                axis_names) > 0
            new_val, new_chg, counts = round_fn(val, chg)
            stats = LaneStats(
                rounds=stats.rounds + live.astype(jnp.int32),
                messages=stats.messages + lax.psum(counts, axis_names),
                work_actions=stats.work_actions + lax.psum(
                    new_chg.sum(axis=0, dtype=jnp.int32), axis_names),
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            anyc = lax.psum(chg.any().astype(jnp.int32), axis_names)
            return (anyc > 0) & (it < cfg.max_iters)

        init_chg = (
            sem.improved(val, jnp.full_like(val, sem.identity))
            & arrays_s.slot_valid[..., None]
        )
        zero_q = jnp.zeros((Q,), jnp.int32)
        stats0 = LaneStats(zero_q, zero_q, zero_q)
        val, chg, it, stats = lax.while_loop(
            cond, body, (val, init_chg, jnp.zeros((), jnp.int32), stats0))
        return val[None], jax.tree.map(lambda x: x[None], stats)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec, LaneStats(*([spec] * 3))),
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_sharded_lanes(part: Partition, init_val, lane_unitw=None,
                      mesh: Mesh = None, axis_names=("data", "model"),
                      cfg: EngineConfig = EngineConfig(),
                      sem: Semiring = actions.SSSP):
    """shard_map laned execution; layout as in ``engine.run_sharded``."""
    init_val = jnp.asarray(init_val, jnp.float32)
    q = init_val.shape[-1]
    lane_unitw = (np.zeros((q,), np.int32) if lane_unitw is None
                  else np.asarray(lane_unitw, np.int32).reshape(q))
    fn, sharding = make_sharded_lanes_fn(
        part.S, part.R_max, q, mesh, axis_names, cfg, sem)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    val_dev = jax.device_put(init_val, sharding)
    val, stats = fn(arrays_dev, val_dev, jnp.asarray(lane_unitw))
    stats = jax.tree.map(lambda x: x[0], stats)
    return val, stats


# --------------------------------------------------------------------------
# personalized-PageRank lanes (sum semiring, per-lane seed/damping)
# --------------------------------------------------------------------------

def make_ppr_round(part: Partition, cfg: EngineConfig = EngineConfig(),
                   arrays: DeviceArrays | None = None):
    """Builds the jitted laned PPR round: (val, base, damping, live) ->
    (new_val, (Q,) max-abs delta, (Q,) message counts).  Pass ``arrays``
    to share one device copy of the static graph tables with other
    round fns over the same partition (the QueryServer does).

    One round is relax(mul_w) -> dense exchange -> rhizome-collapse(+)
    over the inbox -> per-lane damping update ``base + d_q * total_in``;
    ``base`` is the per-lane personalization table ((1-d_q) at the seed's
    replicas — see ``ppr_base_table``).  ``live`` (Q,) freezes converged
    lanes: their frontier column is masked off (they cost no messages)
    and their values are carried through unchanged, so a lane evicted by
    the server stays bit-stable while other lanes keep iterating."""
    _check_cfg(cfg)
    if arrays is None:
        arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    sem = actions.PAGERANK
    total = S * R_max

    def round_fn(val, base, damping, live):
        q = val.shape[-1]
        gchg = (arrays.slot_valid[..., None] & live[None, None, :]) \
            .reshape(total, q)
        inbox, counts = _lane_relax_dense(
            cfg, arrays.edge_src_root_flat, arrays.edge_w,
            arrays.edge_mask, arrays.edge_dst_flat,
            val.reshape(total, q), gchg, jnp.zeros((q,), jnp.int32),
            total, "mul_w", "sum")
        total_in = _collapse_lanes(
            sem, inbox, arrays.sibling_flat, arrays.sibling_mask)
        new = jnp.where(arrays.slot_valid[..., None],
                        base + damping[None, None, :] * total_in, 0.0)
        new = jnp.where(live[None, None, :], new, val)
        delta = jnp.abs(new - val).max(axis=(0, 1))
        return new, delta, counts

    return jax.jit(round_fn)


def run_ppr_lanes(part: Partition, seeds, dampings,
                  cfg: EngineConfig = EngineConfig(), tol: float = 1e-6,
                  max_rounds: int = 256):
    """Lane-batched personalized PageRank to tolerance.  ``seeds``: one
    personalization vertex per lane; ``dampings``: per-lane damping
    (scalar broadcasts).  A lane converges when its max-abs score delta
    drops to ``tol``; live lanes keep the shared round busy.  Returns
    ((S, R_max, Q) scores, per-lane ``LaneStats``)."""
    q = len(seeds)
    dampings = np.broadcast_to(np.asarray(dampings, np.float32), (q,)).copy()
    base = ppr_base_table(part, seeds, dampings)
    val0 = np.stack(
        [engine.init_values(part, actions.PAGERANK, {int(s): 1.0})
         for s in seeds], axis=-1).astype(np.float32)
    round_fn = make_ppr_round(part, cfg)

    def body(carry):
        val, live, it, stats = carry
        new_val, delta, counts = round_fn(
            val, jnp.asarray(base), jnp.asarray(dampings), live)
        stats = LaneStats(
            rounds=stats.rounds + live.astype(jnp.int32),
            messages=stats.messages + counts,
            work_actions=stats.work_actions + live.astype(jnp.int32)
            * jnp.sum(jnp.asarray(part.slot_vertex >= 0), dtype=jnp.int32),
        )
        return new_val, live & (delta > tol), it + 1, stats

    def cond(carry):
        _, live, it, _ = carry
        return jnp.any(live) & (it < max_rounds)

    zero_q = jnp.zeros((q,), jnp.int32)
    val, live, it, stats = lax.while_loop(
        cond, body,
        (jnp.asarray(val0), jnp.ones((q,), bool), jnp.zeros((), jnp.int32),
         LaneStats(zero_q, zero_q, zero_q)))
    return val, stats


# --------------------------------------------------------------------------
# lane state builders (also used by the QueryServer's masked injection)
# --------------------------------------------------------------------------

def init_lane_values(part: Partition, queries) -> tuple[np.ndarray,
                                                        np.ndarray]:
    """Builds ((S, R_max, Q) init values, (Q,) lane_unitw) for a batch of
    min-semiring queries.  ``queries``: list of ("bfs" | "sssp",
    sources) where sources is a vertex, a list of vertices (multi-source:
    all seeded at 0), or a {vertex: value} dict."""
    vals, unitw = [], []
    for kind, sources in queries:
        if kind not in ("bfs", "sssp"):
            raise ValueError(f"unknown min-lane query kind {kind!r}")
        if isinstance(sources, dict):
            src = {int(v): float(x) for v, x in sources.items()}
        elif isinstance(sources, (list, tuple, np.ndarray)):
            src = {int(v): 0.0 for v in sources}
        else:
            src = {int(sources): 0.0}
        vals.append(engine.init_values(part, actions.SSSP, src))
        unitw.append(1 if kind == "bfs" else 0)
    return (np.stack(vals, axis=-1).astype(np.float32),
            np.asarray(unitw, np.int32))


def ppr_base_table(part: Partition, seeds, dampings) -> np.ndarray:
    """(S, R_max, Q) per-lane personalization base: (1 - d_q) at every
    replica of lane q's seed vertex (consistent view), 0 elsewhere."""
    q = len(seeds)
    dampings = np.broadcast_to(np.asarray(dampings, np.float32), (q,))
    cols = [engine.init_values(part, actions.PAGERANK,
                               {int(s): float(1.0 - d)})
            for s, d in zip(seeds, dampings)]
    return np.stack(cols, axis=-1).astype(np.float32)
