"""Lane-batched multi-query fixpoint execution (ISSUE 2 tentpole; the
round machinery now lives in the unified exchange layer — ISSUE 3).

The paper's runtime keeps every compute cell busy by letting actions spawn
fine-grain work; serving heavy traffic means the unit of load is *many
concurrent source-rooted queries* over one shared rhizome-partitioned
graph.  Here the engine's value table grows a trailing **query-lane axis
Q**: values are ``(S, R_max, Q)``, the ``changed`` frontier is per-lane,
and one relax round advances every live query at once — the batching
answer to per-query underutilization in vertex-centric systems (iPregel;
Yan et al.), amortizing message/synchronization cost across queries.

Per-lane convergence is free: a lane whose frontier column is all-False
reads as the absorbing identity inside the relax, so it stops relaxing
while the round keeps running for live lanes; the fused kernel's frontier
chunk-skip bitmap becomes the OR across lanes (a grid cell is skipped
only when its edge chunk is dead in *every* lane — see
``kernels.fused_relax_reduce.fused_relax_reduce_lanes_pallas``).

One compiled round serves a **mixed BFS/SSSP batch**: all min-semiring
queries relax with 'add_w', and the per-lane ``lane_unitw`` flag swaps
the edge weight for the constant 1.0 (BFS levels are SSSP distances over
unit weights — the same float op, so a batched lane is bit-identical to
its solo ``engine.run_stacked`` run).  Sum-semiring lanes (personalized
PageRank, per-lane seed/damping) run as counted ``make_ppr_round`` rounds
with a per-lane tolerance-based convergence mask.

Both exchanges serve the lane axis: ``exchange='dense'`` ships the full
(S, R_max, Q) inbox, ``exchange='compact'`` ships only the §Perf
(target, distinct-slot) targeted tables with Q riding as a trailing dim —
converged lanes contribute the absorbing identity and add no message
volume (``LaneStats.exchanged`` accounts the per-lane difference).

Under ``use_pallas`` the laned fused kernel pads the lane axis to the
TPU lane tile (masked tail lanes) and honors the same VMEM budget as
the unlaned engine (``EngineConfig.vmem_budget_bytes``): when the
(S*R_max, Q) lane table outgrows the budget — which happens Q× sooner
than for a single query — the relax phase tiles it out of HBM with
per-cell double-buffered DMA of (vblk, Q) value tiles, bit-identically
for the min pool (``tests/test_fused_tiled.py``).
"""
from __future__ import annotations

import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import exchange
from repro.core import actions, engine
from repro.core.actions import Semiring
from repro.core.engine import DeviceArrays, EngineConfig
from repro.core.partition import Partition


UNREACHED = np.iinfo(np.int32).max


def decode_min_values(vv: np.ndarray, kind: str) -> np.ndarray:
    """Decode a min-lane's per-vertex values for its query kind: 'bfs' ->
    int64 levels with the UNREACHED sentinel, 'reachability' -> bool,
    'sssp' -> float64 distances (inf where unreachable).  The single
    decoding point for batched apps and the QueryServer."""
    if kind == "bfs":
        out = np.where(np.isfinite(vv), vv, 0).astype(np.int64)
        out[~np.isfinite(vv)] = UNREACHED
        return out
    if kind == "reachability":
        return np.isfinite(vv)
    if kind == "sssp":
        return vv.astype(np.float64)
    raise ValueError(f"unknown min-lane query kind {kind!r}")


class LaneStats(typing.NamedTuple):
    """Per-lane (Q,) counters — the Fig-6 statistics, one per query, plus
    the §Perf exchange-volume accounting (entries shipped through the
    inter-shard exchange while the lane was live; compact < dense)."""

    rounds: jax.Array        # rounds in which the lane was live
    messages: jax.Array      # actions delivered (active edges) per lane
    work_actions: jax.Array  # predicate-true slot updates per lane
    exchanged: jax.Array     # exchange entries shipped while live per lane


def _zero_stats(q: int) -> LaneStats:
    zero_q = jnp.zeros((q,), jnp.int32)
    return LaneStats(zero_q, zero_q, zero_q, zero_q)


def _check_cfg(cfg: EngineConfig):
    if cfg.collapse != "eager":
        raise ValueError("lane-batched runners support collapse='eager' only")
    if cfg.use_pallas and cfg.pallas_mode != "fused":
        raise ValueError(
            "lane-batched Pallas execution is fused-only (the pre-fusion "
            "'reduce' composition has no laned form)")


def _check_min(sem: Semiring):
    # the laned round relaxes with 'add_w' + the per-lane unitw flag, so a
    # semiring whose own relax differs (e.g. BFS 'add_one') must not be
    # accepted and silently re-relaxed with edge weights — BFS lanes are
    # expressed as lane_unitw=1 under the SSSP semiring instead
    if sem.segment != "min" or sem.relax_kind != "add_w":
        raise ValueError(
            "laned runners drive min-semiring 'add_w' fixpoints (express "
            "BFS lanes with lane_unitw=1, not the 'add_one' semiring); "
            "sum semirings run as make_ppr_round counted rounds")


def _volume(part: Partition, cfg: EngineConfig) -> int:
    return exchange.exchange_volume(part.S, part.R_max, part.P_t, cfg)


def _lane_round_stacked(sem, arrays, cfg, S, R_max, lane_unitw, val, chg):
    """One stacked laned fixpoint round — the unified exchange-layer
    composition (dense or compact) with the lane axis riding along."""
    return exchange.fixpoint_round_stacked(
        sem, arrays, cfg, S, R_max, val, chg, lane_unitw=lane_unitw)


# --------------------------------------------------------------------------
# stacked laned fixpoint runner (BFS / SSSP / reachability / CC lanes)
# --------------------------------------------------------------------------

def make_stacked_lanes_fn(part: Partition,
                          cfg: EngineConfig = EngineConfig(),
                          sem: Semiring = actions.SSSP):
    """Builds the stacked laned fixpoint as a jitted fn of ((S, R_max, Q)
    init values, (Q,) lane_unitw, (S, R_max, Q) init changed[, (Q,)
    lane_budget]) -> (values, LaneStats).  Q is encoded in the argument
    shapes, so one returned fn serves any lane count (jit specializes per
    Q).  Hold on to the returned fn to amortize tracing across calls —
    the serving loop and ``benchmarks/query_bench.py`` compile it once.

    ``lane_budget`` ((Q,) int32, optional) is a per-lane round budget:
    a lane that has been live for ``budget`` rounds is frozen in-trace
    (``exchange.fixpoint_round_stacked``'s ``lane_mask``) — its values
    stop improving and it costs no further messages, so a pathological
    query cannot pin the shared fixpoint past its budget."""
    _check_cfg(cfg)
    _check_min(sem)
    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    vol = _volume(part, cfg)

    @jax.jit
    def fn(init_val, lane_unitw, init_chg, lane_budget=None):
        q = init_val.shape[-1]

        def body(carry):
            val, chg, it, stats = carry
            live = chg.reshape(-1, q).any(axis=0)
            if lane_budget is None:
                mask = None
            else:
                mask = stats.rounds < lane_budget
                live = live & mask
            new_val, new_chg, counts = exchange.fixpoint_round_stacked(
                sem, arrays, cfg, S, R_max, val, chg,
                lane_unitw=lane_unitw, lane_mask=mask)
            stats = LaneStats(
                rounds=stats.rounds + live.astype(jnp.int32),
                messages=stats.messages + counts,
                work_actions=stats.work_actions
                + new_chg.sum(axis=(0, 1), dtype=jnp.int32),
                exchanged=stats.exchanged + live.astype(jnp.int32) * vol,
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, stats = carry
            if lane_budget is None:
                anyw = jnp.any(chg)
            else:
                anyw = jnp.any(chg.reshape(-1, q)
                               & (stats.rounds < lane_budget)[None, :])
            return anyw & (it < cfg.max_iters)

        val, chg, it, stats = lax.while_loop(
            cond, body,
            (init_val, init_chg, jnp.zeros((), jnp.int32), _zero_stats(q)))
        return val, stats

    return fn


def _lane_q_pad(q: int) -> int:
    """Lane-PADDED width of a laned fused launch (sizes the worklist
    planner's residency choice and DMA byte mirror)."""
    from repro.kernels import ops as kops
    from repro.kernels.fused_relax_reduce import _lane_pad
    return _lane_pad(q, interpret=kops._interpret())


def _run_stacked_lanes_hostloop(part, arrays, cfg, sem, init_val,
                                lane_unitw, init_chg, lane_budget=None):
    """Worklist-mode laned fixpoint: a Python round loop so the
    OR-across-lanes frontier can plan each round's sparse launch —
    identical values and LaneStats to the traced ``while_loop``
    (min lanes are bit-identical).  ``lane_budget`` freezes a lane after
    its budgeted round count, and the worklist planner sees the frozen
    lane as dead (its cells stop launching)."""
    S, R_max = part.S, part.R_max
    q = init_val.shape[-1]
    planner = engine.launch_planner(part, cfg, q_pad=_lane_q_pad(q))
    vol = _volume(part, cfg)
    budget = (None if lane_budget is None
              else np.asarray(lane_budget, np.int64).reshape(q))

    @jax.jit
    def round_fn(val, chg, worklist, lane_mask=None):
        return exchange.fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg, lane_unitw=lane_unitw,
            worklist=worklist, lane_mask=lane_mask)

    val, chg = init_val, init_chg
    chg_h = np.asarray(chg).reshape(-1, q)   # ONE download per round
    rounds = np.zeros(q, np.int64)
    messages = np.zeros(q, np.int64)
    work = np.zeros(q, np.int64)
    exchanged = np.zeros(q, np.int64)
    it = 0
    while it < cfg.max_iters:
        mask = None if budget is None else rounds < budget
        eff_chg = chg_h if mask is None else chg_h & mask[None, :]
        live = eff_chg.any(axis=0)
        if not live.any():
            break
        wl = engine.plan_round_worklist(planner, cfg, eff_chg.any(axis=1))
        val, chg, counts = round_fn(
            val, chg, wl,
            None if mask is None else jnp.asarray(mask))
        chg_h = np.asarray(chg).reshape(-1, q)
        rounds += live
        messages += np.asarray(counts, np.int64)
        work += chg_h.sum(axis=0)
        exchanged += live.astype(np.int64) * vol
        it += 1
    engine._count_dispatches("lanes_min", it, it)
    stats = LaneStats(*(jnp.asarray(x, jnp.int32) for x in
                        (rounds, messages, work, exchanged)))
    return val, stats


def run_stacked_lanes(part: Partition, init_val, lane_unitw=None,
                      cfg: EngineConfig = EngineConfig(),
                      init_changed=None, sem: Semiring = actions.SSSP,
                      lane_budget=None):
    """Single-device lane-batched execution. ``init_val``: (S, R_max, Q)
    float32 — one query per lane; ``lane_unitw`` (Q,) marks BFS-style
    lanes (relax with weight 1.0).  A lane converges when no slot of its
    column improves; the round keeps running while any lane is live.
    Returns ((S, R_max, Q) values, per-lane ``LaneStats``).

    ``lane_budget`` ((Q,) int, scalar broadcasts) caps each lane's live
    rounds: a budget-exhausted lane freezes (partial values carried
    through, no further cost) while other lanes run to convergence —
    the runner-level face of the QueryServer's per-request round budget.

    Under ``cfg.grid_mode='worklist'|'auto'`` (fused only) rounds run
    host-driven and each round's OR-across-lanes frontier plans a
    sparse worklist launch (see ``engine.run_stacked``); under
    ``'device_worklist'`` the same live-cell launch is compacted ON
    DEVICE, so the whole laned fixpoint stays one traced
    ``while_loop`` dispatch with zero per-round host syncs."""
    init_val = jnp.asarray(init_val, jnp.float32)
    if init_val.ndim != 3:
        raise ValueError(f"init_val must be (S, R_max, Q); got "
                         f"{init_val.shape}")
    q = init_val.shape[-1]
    lane_unitw = (jnp.zeros((q,), jnp.int32) if lane_unitw is None
                  else jnp.asarray(lane_unitw, jnp.int32).reshape(q))
    if lane_budget is not None:
        lane_budget = jnp.broadcast_to(
            jnp.asarray(lane_budget, jnp.int32), (q,))
    slot_valid = jnp.asarray(part.slot_vertex >= 0)
    if init_changed is not None:
        init_chg = jnp.asarray(init_changed) & slot_valid[..., None]
    else:
        init_chg = sem.improved(
            init_val, jnp.full_like(init_val, sem.identity)
        ) & slot_valid[..., None]
    if cfg.wants_worklist:
        _check_cfg(cfg)
        _check_min(sem)
        arrays = DeviceArrays.from_partition(part)
        return _run_stacked_lanes_hostloop(
            part, arrays, cfg, sem, init_val, lane_unitw, init_chg,
            lane_budget)
    fn = make_stacked_lanes_fn(part, cfg, sem)
    out = fn(init_val, lane_unitw, init_chg, lane_budget)
    # the traced while_loop (dense grid or device-compacted worklist)
    # was ONE dispatch with one result sync
    engine._count_dispatches("lanes_min", 1, 1)
    return out


# --------------------------------------------------------------------------
# sharded laned fixpoint (shard_map over a real mesh)
# --------------------------------------------------------------------------

def make_sharded_lanes_fn(S: int, R_max: int, Q: int, mesh: Mesh,
                          axis_names=("data", "model"),
                          cfg: EngineConfig = EngineConfig(),
                          sem: Semiring = actions.SSSP,
                          with_init_changed: bool = False):
    """shard_map laned fixpoint as a jit-able fn of (DeviceArrays,
    (S, R_max, Q) val, (Q,) lane_unitw) -> (val, LaneStats).  Same
    collective plan as ``engine.make_sharded_fn`` with the lane axis
    riding along (``exchange.make_shard_fixpoint_round``): value/changed
    all_gather, inbox all_to_all — the full (S, R_max, Q) table under
    ``exchange='dense'``, only the (S, P_t, Q) targeted compact tables
    under ``exchange='compact'`` — sibling collapse over the gathered
    table, per-lane psum'd liveness for the termination test.

    With ``with_init_changed=True`` the returned fn takes a fourth
    argument, an (S, R_max, Q) bool initial frontier, instead of
    deriving it from non-identity values — streaming warm-starts seed
    only the mutation-affected slots this way."""
    _check_cfg(cfg)
    _check_min(sem)
    cfg = engine._sharded_cfg(cfg, "make_sharded_lanes_fn")
    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays.specs(spec),
        spec,
        P(),                                   # lane_unitw: replicated
    )
    if with_init_changed:
        in_specs = in_specs + (spec,)

    def shard_fn(arrays_l: DeviceArrays, val_l, lane_unitw, *rest):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val = val_l[0]                         # (R_max, Q)
        vol = exchange.exchange_volume(
            S, R_max, arrays_s.inbox_slot_map.shape[-1], cfg)
        round_fn = exchange.make_shard_fixpoint_round(
            sem, arrays_s, cfg, S, R_max, axis_names,
            lane_unitw=lane_unitw)

        def body(carry):
            val, chg, it, stats = carry
            live = lax.psum(
                chg.reshape(-1, Q).any(axis=0).astype(jnp.int32),
                axis_names) > 0
            new_val, new_chg, counts = round_fn(val, chg)
            stats = LaneStats(
                rounds=stats.rounds + live.astype(jnp.int32),
                messages=stats.messages + lax.psum(counts, axis_names),
                work_actions=stats.work_actions + lax.psum(
                    new_chg.sum(axis=0, dtype=jnp.int32), axis_names),
                exchanged=stats.exchanged + live.astype(jnp.int32) * vol,
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            anyc = lax.psum(chg.any().astype(jnp.int32), axis_names)
            return (anyc > 0) & (it < cfg.max_iters)

        if with_init_changed:
            init_chg = rest[0][0] & arrays_s.slot_valid[..., None]
        else:
            init_chg = (
                sem.improved(val, jnp.full_like(val, sem.identity))
                & arrays_s.slot_valid[..., None]
            )
        val, chg, it, stats = lax.while_loop(
            cond, body,
            (val, init_chg, jnp.zeros((), jnp.int32), _zero_stats(Q)))
        return val[None], jax.tree.map(lambda x: x[None], stats)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec, LaneStats(*([spec] * 4))),
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def make_sharded_min_round(S: int, R_max: int, mesh: Mesh,
                           axis_names=("data", "model"),
                           cfg: EngineConfig = EngineConfig(),
                           sem: Semiring = actions.SSSP):
    """shard_map laned fixpoint round: (DeviceArrays, val, chg, unitw) ->
    (val, chg, (Q,) psum'd counts) — one tick of the sharded
    QueryServer's min pool (``make_sharded_lanes_fn`` runs the same round
    inside a traced while_loop; the server needs it un-looped so it can
    inject/evict lanes between ticks).  Counterpart of
    ``make_sharded_ppr_round`` for the sum pool."""
    _check_cfg(cfg)
    _check_min(sem)
    cfg = engine._sharded_cfg(cfg, "make_sharded_min_round")
    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays.specs(spec),
        spec, spec,
        P(),                                   # lane_unitw: replicated
    )

    def shard_fn(arrays_l: DeviceArrays, val_l, chg_l, unitw):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        round_fn = exchange.make_shard_fixpoint_round(
            sem, arrays_s, cfg, S, R_max, axis_names, lane_unitw=unitw)
        cand, new_chg, counts = round_fn(val_l[0], chg_l[0])
        counts = lax.psum(counts, axis_names)
        return cand[None], new_chg[None], counts[None]

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs,
        out_specs=(spec, spec, spec), check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_sharded_lanes(part: Partition, init_val, lane_unitw=None,
                      mesh: Mesh = None, axis_names=("data", "model"),
                      cfg: EngineConfig = EngineConfig(),
                      sem: Semiring = actions.SSSP,
                      init_changed=None):
    """shard_map laned execution; layout as in ``engine.run_sharded``.
    ``init_changed`` optionally seeds the first frontier (streaming
    warm-starts); default derives it from non-identity values."""
    init_val = jnp.asarray(init_val, jnp.float32)
    q = init_val.shape[-1]
    lane_unitw = (np.zeros((q,), np.int32) if lane_unitw is None
                  else np.asarray(lane_unitw, np.int32).reshape(q))
    fn, sharding = make_sharded_lanes_fn(
        part.S, part.R_max, q, mesh, axis_names, cfg, sem,
        with_init_changed=init_changed is not None)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    val_dev = jax.device_put(init_val, sharding)
    if init_changed is not None:
        chg_dev = jax.device_put(jnp.asarray(init_changed, bool), sharding)
        val, stats = fn(arrays_dev, val_dev, jnp.asarray(lane_unitw),
                        chg_dev)
    else:
        val, stats = fn(arrays_dev, val_dev, jnp.asarray(lane_unitw))
    stats = jax.tree.map(lambda x: x[0], stats)
    return val, stats


# --------------------------------------------------------------------------
# personalized-PageRank lanes (sum semiring, per-lane seed/damping)
# --------------------------------------------------------------------------

def make_ppr_round(part: Partition, cfg: EngineConfig = EngineConfig(),
                   arrays: DeviceArrays | None = None):
    """Builds the jitted laned PPR round: (val, base, damping, live) ->
    (new_val, (Q,) max-abs delta, (Q,) message counts).  Pass ``arrays``
    to share one device copy of the static graph tables with other
    round fns over the same partition (the QueryServer does).

    One round is relax(mul_w) -> exchange (dense or compact targeted) ->
    rhizome-collapse(+) over the inbox -> per-lane damping update
    ``base + d_q * total_in``; ``base`` is the per-lane personalization
    table ((1-d_q) at the seed's replicas — see ``ppr_base_table``).
    ``live`` (Q,) freezes converged lanes: their frontier column is
    masked off (they cost no messages) and their values are carried
    through unchanged, so a lane evicted by the server stays bit-stable
    while other lanes keep iterating."""
    _check_cfg(cfg)
    if arrays is None:
        arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    sem = actions.PAGERANK
    total = S * R_max

    def round_fn(val, base, damping, live):
        q = val.shape[-1]
        gchg = (arrays.slot_valid[..., None] & live[None, None, :]) \
            .reshape(total, q)
        total_in, counts = exchange.stacked_total_in(
            sem, arrays, cfg, S, R_max, val.reshape(total, q), gchg)
        new = jnp.where(arrays.slot_valid[..., None],
                        base + damping[None, None, :] * total_in, 0.0)
        new = jnp.where(live[None, None, :], new, val)
        delta = jnp.abs(new - val).max(axis=(0, 1))
        return new, delta, counts

    return jax.jit(round_fn)


def make_sharded_ppr_round(S: int, R_max: int, mesh: Mesh,
                           axis_names=("data", "model"),
                           cfg: EngineConfig = EngineConfig()):
    """shard_map laned PPR round: (DeviceArrays, val, base, damping, live)
    -> (new_val, (Q,) max-abs delta, (Q,) counts) — one counted round of
    the sharded serving loop, same semantics as ``make_ppr_round`` with
    real collectives (delta is pmax'd, counts psum'd across the mesh).
    The lane count is taken from the traced argument shapes, so one
    returned fn serves any Q (jit specializes per shape)."""
    _check_cfg(cfg)
    cfg = engine._sharded_cfg(cfg, "make_sharded_ppr_round")
    axis_names = exchange.axis_tuple(axis_names)
    sem = actions.PAGERANK
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays.specs(spec),
        spec, spec,
        P(),                                   # damping: replicated
        P(),                                   # live: replicated
    )

    def shard_fn(arrays_l: DeviceArrays, val_l, base_l, damping, live):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val, base = val_l[0], base_l[0]        # (R_max, Q)

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        chg = arrays_s.slot_valid[..., None] & live[None, :]
        total_in, counts = exchange.shard_total_in(
            sem, arrays_s, cfg, S, R_max, axis_names,
            gather(val), gather(chg))
        new = jnp.where(arrays_s.slot_valid[..., None],
                        base + damping[None, :] * total_in, 0.0)
        new = jnp.where(live[None, :], new, val)
        delta = lax.pmax(jnp.abs(new - val).max(axis=0), axis_names)
        counts = lax.psum(counts, axis_names)
        return new[None], delta[None], counts[None]

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs,
        out_specs=(spec, spec, spec), check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def make_sharded_ppr_delta_round(S: int, R_max: int, mesh: Mesh,
                                 axis_names=("data", "model"),
                                 cfg: EngineConfig = EngineConfig()):
    """shard_map laned **delta-PPR** round: (DeviceArrays, rank, delta,
    damping, tol) -> (new_rank, new_delta, new_changed, (Q,) psum'd
    counts) — the sharded twin of ``make_ppr_delta_round``, closing the
    ROADMAP leftover that the sharded PPR pool still ran full-frontier
    rounds.  Each lane diffuses only residual deltas above its own
    tolerance (value/frontier ``all_gather``, inbox exchange — dense or
    compact per ``cfg.exchange`` — rhizome-collapse(+)), so the serving
    tick's relax work shrinks as lanes converge exactly like the stacked
    delta path.  ``new_changed`` is returned sharded so the server's
    per-tick liveness probe never recomputes the predicate host-side."""
    _check_cfg(cfg)
    cfg = engine._sharded_cfg(cfg, "make_sharded_ppr_delta_round")
    axis_names = exchange.axis_tuple(axis_names)
    sem = actions.PAGERANK
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays.specs(spec),
        spec, spec,
        P(),                                   # damping: replicated
        P(),                                   # tol: replicated
    )

    def shard_fn(arrays_l: DeviceArrays, rank_l, delta_l, damping, tol):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        rank, delta = rank_l[0], delta_l[0]    # (R_max, Q)

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        chg = (jnp.abs(delta) > tol[None, :]) \
            & arrays_s.slot_valid[..., None]
        total_in, counts = exchange.shard_total_in(
            sem, arrays_s, cfg, S, R_max, axis_names,
            gather(delta), gather(chg))
        new_delta = jnp.where(arrays_s.slot_valid[..., None],
                              damping[None, :] * total_in, 0.0)
        new_chg = (jnp.abs(new_delta) > tol[None, :]) \
            & arrays_s.slot_valid[..., None]
        counts = lax.psum(counts, axis_names)
        return ((rank + new_delta)[None], new_delta[None], new_chg[None],
                counts[None])

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs,
        out_specs=(spec, spec, spec, spec), check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_ppr_lanes(part: Partition, seeds, dampings,
                  cfg: EngineConfig = EngineConfig(), tol: float = 1e-6,
                  max_rounds: int = 256):
    """Lane-batched personalized PageRank to tolerance.  ``seeds``: one
    personalization vertex per lane; ``dampings``: per-lane damping
    (scalar broadcasts).  A lane converges when its max-abs score delta
    drops to ``tol``; live lanes keep the shared round busy.  Returns
    ((S, R_max, Q) scores, per-lane ``LaneStats``)."""
    q = len(seeds)
    dampings = np.broadcast_to(np.asarray(dampings, np.float32), (q,)).copy()
    base = ppr_base_table(part, seeds, dampings)
    val0 = np.stack(
        [engine.init_values(part, actions.PAGERANK, {int(s): 1.0})
         for s in seeds], axis=-1).astype(np.float32)
    round_fn = make_ppr_round(part, cfg)
    vol = _volume(part, cfg)
    n_slots = jnp.sum(jnp.asarray(part.slot_vertex >= 0), dtype=jnp.int32)

    def body(carry):
        val, live, it, stats = carry
        new_val, delta, counts = round_fn(
            val, jnp.asarray(base), jnp.asarray(dampings), live)
        stats = LaneStats(
            rounds=stats.rounds + live.astype(jnp.int32),
            messages=stats.messages + counts,
            work_actions=stats.work_actions
            + live.astype(jnp.int32) * n_slots,
            exchanged=stats.exchanged + live.astype(jnp.int32) * vol,
        )
        return new_val, live & (delta > tol), it + 1, stats

    def cond(carry):
        _, live, it, _ = carry
        return jnp.any(live) & (it < max_rounds)

    val, live, it, stats = lax.while_loop(
        cond, body,
        (jnp.asarray(val0), jnp.ones((q,), bool), jnp.zeros((), jnp.int32),
         _zero_stats(q)))
    return val, stats


def make_ppr_delta_round(part: Partition,
                         cfg: EngineConfig = EngineConfig(),
                         arrays: DeviceArrays | None = None):
    """Builds the jitted laned **delta-PPR** round: (rank, delta,
    damping, tol, worklist) -> (new_rank, new_delta, new_changed,
    (Q,) counts) — ``new_changed`` is the next round's per-lane
    frontier, returned so the driver never recomputes (or re-downloads)
    the (S, R_max, Q) predicate host-side.

    The laned twin of ``exchange.delta_pagerank_round_stacked``: each
    lane propagates only residual deltas above its own tolerance, so the
    per-lane frontier — and with it the OR-across-lanes chunk skip and
    any worklist launch — shrinks as lanes converge, instead of every
    lane diffusing every slot every round (``make_ppr_round``)."""
    _check_cfg(cfg)
    if arrays is None:
        arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    sem = actions.PAGERANK
    total = S * R_max

    @jax.jit
    def round_fn(rank, delta, damping, tol, worklist=None):
        q = rank.shape[-1]
        chg = (jnp.abs(delta) > tol[None, None, :]) \
            & arrays.slot_valid[..., None]
        total_in, counts = exchange.stacked_total_in(
            sem, arrays, cfg, S, R_max, delta.reshape(total, q),
            chg.reshape(total, q), worklist=worklist)
        new_delta = jnp.where(arrays.slot_valid[..., None],
                              damping[None, None, :] * total_in, 0.0)
        new_chg = (jnp.abs(new_delta) > tol[None, None, :]) \
            & arrays.slot_valid[..., None]
        return rank + new_delta, new_delta, new_chg, counts

    return round_fn


def run_ppr_delta_lanes(part: Partition, seeds, dampings,
                        cfg: EngineConfig = EngineConfig(), tol=1e-7,
                        max_rounds: int = 256):
    """Lane-batched delta-PPR to tolerance: like ``run_ppr_lanes`` but
    push-based over residuals — a lane's frontier is the slots whose
    delta still exceeds its ``tol`` (scalar broadcasts; per-lane array
    accepted), so late rounds diffuse only the few still-hot vertices of
    the few still-live lanes.  Host-driven (the per-lane frontier steers
    termination and, under ``grid_mode='worklist'|'auto'``, the sparse
    launch plan).  Under ``'device_worklist'`` the residual-tolerance
    frontier test and worklist compaction both run on device, so the
    whole multi-lane fixpoint is ONE traced dispatch."""
    q = len(seeds)
    dampings = np.broadcast_to(
        np.asarray(dampings, np.float32), (q,)).copy()
    tols = np.broadcast_to(np.asarray(tol, np.float32), (q,)).copy()
    base = ppr_base_table(part, seeds, dampings)
    rank = delta = jnp.asarray(base)
    round_fn = make_ppr_delta_round(part, cfg)
    planner = (engine.launch_planner(part, cfg, q_pad=_lane_q_pad(q))
               if cfg.wants_worklist else None)
    vol = _volume(part, cfg)
    slot_valid = np.asarray(part.slot_vertex >= 0)

    if cfg.wants_device_worklist:
        damp_j, tol_j = jnp.asarray(dampings), jnp.asarray(tols)
        sv = jnp.asarray(slot_valid)[..., None]
        vol_j = jnp.asarray(vol, jnp.int32)

        @jax.jit
        def fixpoint(rank, delta):
            def body(carry):
                rank, delta, it, stats = carry
                live = ((jnp.abs(delta) > tol_j[None, None, :]) & sv) \
                    .reshape(-1, q).any(axis=0)
                nrank, ndelta, nchg, counts = round_fn(
                    rank, delta, damp_j, tol_j)
                stats = LaneStats(
                    rounds=stats.rounds + live.astype(jnp.int32),
                    messages=stats.messages + counts.astype(jnp.int32),
                    work_actions=stats.work_actions
                    + nchg.sum(axis=(0, 1), dtype=jnp.int32),
                    exchanged=stats.exchanged
                    + live.astype(jnp.int32) * vol_j,
                )
                return nrank, ndelta, it + 1, stats

            def cond(carry):
                _, delta, it, _ = carry
                anyc = jnp.any((jnp.abs(delta) > tol_j[None, None, :])
                               & sv)
                return anyc & (it < max_rounds)

            rank, delta, _, stats = lax.while_loop(
                cond, body,
                (rank, delta, jnp.zeros((), jnp.int32), _zero_stats(q)))
            return rank, stats

        rank, stats = fixpoint(rank, delta)
        engine._count_dispatches("ppr_delta_lanes", 1, 1)
        return rank, stats

    rounds = np.zeros(q, np.int64)
    messages = np.zeros(q, np.int64)
    work = np.zeros(q, np.int64)
    exchanged = np.zeros(q, np.int64)
    it = 0
    damp_j, tol_j = jnp.asarray(dampings), jnp.asarray(tols)
    # each round returns next round's per-lane frontier — computed on
    # device, downloaded ONCE per round for planning + accounting alike
    chg_h = (np.abs(base) > tols[None, None, :]) & slot_valid[..., None]
    while it < max_rounds:
        live = chg_h.any(axis=(0, 1))
        if not live.any():
            break
        wl = (engine.plan_round_worklist(
            planner, cfg, chg_h.reshape(-1, q).any(axis=1))
            if planner is not None else None)
        rank, delta, chg, counts = round_fn(rank, delta, damp_j, tol_j, wl)
        chg_h = np.asarray(chg)
        rounds += live
        messages += np.asarray(counts, np.int64)
        work += chg_h.sum(axis=(0, 1))
        exchanged += live.astype(np.int64) * vol
        it += 1
    engine._count_dispatches("ppr_delta_lanes", it, it)
    stats = LaneStats(*(jnp.asarray(x, jnp.int32) for x in
                        (rounds, messages, work, exchanged)))
    return rank, stats


# --------------------------------------------------------------------------
# lane state builders (also used by the QueryServer's masked injection)
# --------------------------------------------------------------------------

def init_lane_values(part: Partition, queries) -> tuple[np.ndarray,
                                                        np.ndarray]:
    """Builds ((S, R_max, Q) init values, (Q,) lane_unitw) for a batch of
    min-semiring queries.  ``queries``: list of ("bfs" | "sssp",
    sources) where sources is a vertex, a list of vertices (multi-source:
    all seeded at 0), or a {vertex: value} dict."""
    vals, unitw = [], []
    for kind, sources in queries:
        if kind not in ("bfs", "sssp"):
            raise ValueError(f"unknown min-lane query kind {kind!r}")
        if isinstance(sources, dict):
            src = {int(v): float(x) for v, x in sources.items()}
        elif isinstance(sources, (list, tuple, np.ndarray)):
            src = {int(v): 0.0 for v in sources}
        else:
            src = {int(sources): 0.0}
        vals.append(engine.init_values(part, actions.SSSP, src))
        unitw.append(1 if kind == "bfs" else 0)
    return (np.stack(vals, axis=-1).astype(np.float32),
            np.asarray(unitw, np.int32))


def ppr_base_table(part: Partition, seeds, dampings) -> np.ndarray:
    """(S, R_max, Q) per-lane personalization base: (1 - d_q) at every
    replica of lane q's seed vertex (consistent view), 0 elsewhere."""
    q = len(seeds)
    dampings = np.broadcast_to(np.asarray(dampings, np.float32), (q,))
    cols = [engine.init_values(part, actions.PAGERANK,
                               {int(s): float(1.0 - d)})
            for s, d in zip(seeds, dampings)]
    return np.stack(cols, axis=-1).astype(np.float32)
