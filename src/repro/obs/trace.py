"""Span / event tracing with Chrome trace-event JSON export.

A :class:`Tracer` records *complete* spans (``ph: "X"``) and *instant*
events (``ph: "i"``) against an injectable monotonic clock — the same
injection point ``QueryServer`` grew in PR 6, so deterministic-clock
tests produce deterministic traces.  ``to_chrome()`` emits the Chrome
trace-event format (a ``{"traceEvents": [...]}`` object with ``ts`` /
``dur`` in microseconds), loadable directly in Perfetto /
``chrome://tracing`` for round / tick / request timelines.

Spans nest naturally through the context manager::

    tracer = Tracer()
    with tracer.span("round", app="bfs", args={"round": 3}):
        ...
    tracer.save("trace.json")

Distinct subsystems go on distinct "threads" of the trace via the
``track`` argument (engine rounds, serving ticks, per-request
lifecycles each get a lane in the Perfetto UI).
"""
from __future__ import annotations

import json
import threading
import time


class Span:
    __slots__ = ("tracer", "name", "track", "args", "t0", "_closed")

    def __init__(self, tracer, name, track, args):
        self.tracer = tracer
        self.name = name
        self.track = track
        self.args = args
        self.t0 = tracer.now()
        self._closed = False

    def end(self, **extra_args):
        if self._closed:
            return
        self._closed = True
        if extra_args:
            self.args = dict(self.args or {}, **extra_args)
        self.tracer._emit_complete(self.name, self.track, self.t0,
                                   self.tracer.now() - self.t0, self.args)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.end()
        return False


class Tracer:
    """Collects trace events; exports Chrome trace-event JSON."""

    def __init__(self, clock=None, pid=0):
        self._clock = clock if clock is not None else time.monotonic
        self._epoch = self._clock()
        self._pid = pid
        self._lock = threading.Lock()
        self._events: list[dict] = []
        self._tracks: dict[str, int] = {}

    def now(self) -> float:
        """Seconds since this tracer's epoch (injectable clock)."""
        return self._clock() - self._epoch

    def _tid(self, track: str) -> int:
        tid = self._tracks.get(track)
        if tid is None:
            tid = len(self._tracks)
            self._tracks[track] = tid
        return tid

    def _emit_complete(self, name, track, t0, dur, args):
        ev = {"name": name, "ph": "X", "pid": self._pid,
              "tid": self._tid(track),
              "ts": round(t0 * 1e6, 3), "dur": round(dur * 1e6, 3)}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, track: str = "main",
             args: dict | None = None, **labels) -> Span:
        """Open a span; ``.end()`` (or the ``with`` exit) records it.
        Keyword labels merge into ``args``."""
        merged = dict(args or {})
        merged.update(labels)
        return Span(self, name, track, merged or None)

    def complete(self, name: str, track: str = "main", start: float = 0.0,
                 end: float | None = None, args: dict | None = None,
                 **labels):
        """Record a complete span from explicit tracer-time stamps (both
        in :meth:`now` seconds) — for lifecycles whose start was noted
        before the outcome was known (request queued→admitted→terminal)."""
        merged = dict(args or {})
        merged.update(labels)
        t1 = end if end is not None else self.now()
        self._emit_complete(name, track, start, max(t1 - start, 0.0),
                            merged or None)

    def instant(self, name: str, track: str = "main",
                args: dict | None = None, **labels):
        merged = dict(args or {})
        merged.update(labels)
        ev = {"name": name, "ph": "i", "s": "t", "pid": self._pid,
              "tid": self._tid(track),
              "ts": round(self.now() * 1e6, 3)}
        if merged:
            ev["args"] = merged
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, values: dict, track: str = "counters"):
        """Chrome counter event (``ph: "C"``) — renders as a stacked
        area chart in Perfetto (queue depth, frontier size, ...)."""
        ev = {"name": name, "ph": "C", "pid": self._pid,
              "tid": self._tid(track),
              "ts": round(self.now() * 1e6, 3),
              "args": {k: float(v) for k, v in values.items()}}
        with self._lock:
            self._events.append(ev)

    # -- export ----------------------------------------------------------

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def to_chrome(self) -> dict:
        """The Chrome trace-event JSON object (Perfetto-loadable)."""
        meta = []
        for track, tid in sorted(self._tracks.items(), key=lambda kv: kv[1]):
            meta.append({"name": "thread_name", "ph": "M",
                         "pid": self._pid, "tid": tid,
                         "args": {"name": track}})
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_chrome(), fh, indent=1)

    def clear(self):
        with self._lock:
            self._events.clear()
