"""repro.obs — the dependency-free flight recorder (ISSUE 7).

``metrics``: process-wide counters/gauges/histograms with snapshot/
delta semantics and Prometheus text exposition.  ``trace``: span/event
tracing on an injectable clock, exported as Chrome trace-event JSON
(Perfetto-loadable).  ``record``: the FlightRecorder tying both to
per-round engine records; ``report``: the session-summary renderer
(``python -m repro.obs.report session.json``).

Nothing here imports jax/numpy — instrumented hot paths pay one
attribute read when recording is off.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               registry)
from repro.obs.record import (FlightRecorder, RoundRecord, get_recorder,
                              install, load_session, metrics_to_json,
                              recording)
from repro.obs.trace import Span, Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "registry",
    "FlightRecorder", "RoundRecord", "get_recorder", "install",
    "load_session", "metrics_to_json", "recording",
    "Span", "Tracer",
]
