"""The flight recorder: one handle tying metrics + trace + round records.

A :class:`FlightRecorder` is *installed* process-wide (``install`` /
``recording``); instrumented code asks :func:`get_recorder` each time it
would record and does nothing when it returns ``None`` — the disabled
path is a single attribute read, adds no host↔device syncs, and leaves
every jit trace untouched (pinned by the obs-off parity test).

Enabled, the engine's host-driven round loops append one
:class:`RoundRecord` per round whose grid-cell / DMA columns come from
the same host planner mirror the differential harness asserts against
the kernels' ``with_debug`` counters — so the telemetry itself is held
to the PR 4/5 exact-counter bar.  ``save(path)`` writes a session JSON
(records + metrics snapshot + Chrome trace) that
``python -m repro.obs.report`` renders.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json

from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer


@dataclasses.dataclass
class RoundRecord:
    """One engine round, as accounted by the host planner mirror.

    ``cells``/``launched``/``tile_dmas``/``dma_bytes`` are the planner
    mirror of the actual launch (worklist: ``WorklistInfo``; dense grid:
    the two-level-skip live count) — zero on non-fused paths, where no
    Pallas grid exists.  ``shard_messages`` is the per-shard live-edge
    (message) count mirror feeding the skew gauge.

    Under a ``device_worklist`` windowed loop one record covers a
    K-round dispatch window: ``window`` is the 1-based window index
    (0 = host-driven per-round record), ``round`` the cumulative round
    count at window end, and the additive columns (messages, work,
    cells, DMA…) are summed over the window's live rounds — so window
    sums equal the per-round host-driven totals exactly."""

    run: str             # which runner/app emitted this round
    round: int           # 1-based round index within the run
    frontier: int        # live slots entering the round
    messages: int        # actions delivered (Fig-6 messages)
    work: int            # predicate-true slot updates
    pruned: int          # delivered but predicate-false
    grid: str            # 'dense' | 'worklist'
    path: str            # 'pinned' | 'tiled' | 'reduce' | 'jnp'
    cells: int           # live grid cells (planner mirror)
    launched: int        # launched cells (dense: total grid; wl: padded)
    tile_dmas: int       # value-tile DMAs (tiled path only)
    dma_bytes: int
    wall_s: float
    shard_messages: list | None = None
    window: int = 0      # dispatch-window index (0 = per-round record)


def _skew(counts) -> float:
    """max/mean load imbalance of a per-shard count vector (1.0 = perfectly
    balanced); 0 when nothing moved."""
    counts = list(counts)
    total = sum(counts)
    if not counts or total == 0:
        return 0.0
    return max(counts) / (total / len(counts))


class FlightRecorder:
    """Metrics registry + tracer + per-round records for one session.

    ``registry``/``tracer`` default to fresh private instances so
    concurrent sessions don't bleed into each other; pass
    ``metrics.registry()`` explicitly to feed the process-wide registry.
    ``keep_frontiers=True`` additionally stores each recorded round's
    frontier bitmap — test-only, for re-deriving mirrors."""

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer: Tracer | None = None, clock=None,
                 keep_frontiers: bool = False, meta: dict | None = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(clock=clock)
        self.rounds: list[RoundRecord] = []
        self.frontiers: list = []
        self.keep_frontiers = keep_frontiers
        self.meta = dict(meta or {})

    # -- engine rounds ---------------------------------------------------

    def add_round(self, record: RoundRecord, frontier_bitmap=None):
        self.rounds.append(record)
        if self.keep_frontiers:
            self.frontiers.append(frontier_bitmap)
        m, run = self.registry, record.run
        m.counter("engine_rounds_total",
                  "engine rounds executed").labels(run=run).inc()
        m.counter("engine_messages_total",
                  "actions delivered").labels(run=run).inc(record.messages)
        m.counter("engine_pruned_total",
                  "deliveries pruned by their predicate"
                  ).labels(run=run).inc(record.pruned)
        m.counter("engine_grid_cells_total",
                  "live fused-grid cells (planner mirror)"
                  ).labels(run=run).inc(record.cells)
        m.counter("engine_dma_bytes_total",
                  "value-tile DMA bytes (planner mirror)"
                  ).labels(run=run).inc(record.dma_bytes)
        m.gauge("engine_frontier",
                "live slots entering the last round"
                ).labels(run=run).set(record.frontier)
        m.counter("engine_wall_seconds_total",
                  "wall time inside engine rounds"
                  ).labels(run=run).inc(record.wall_s)
        if record.shard_messages:
            m.gauge("engine_shard_message_skew",
                    "per-shard message balance, max/mean (1.0 = even)"
                    ).labels(run=run).set(_skew(record.shard_messages))
        self.tracer.counter(
            f"engine/{run}", {"frontier": record.frontier,
                              "messages": record.messages,
                              "cells": record.cells})

    # -- persistence -----------------------------------------------------

    def to_session(self) -> dict:
        return {
            "meta": self.meta,
            "rounds": [dataclasses.asdict(r) for r in self.rounds],
            "metrics": metrics_to_json(self.registry.snapshot()),
            "trace": self.tracer.to_chrome(),
        }

    def save(self, path) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_session(), fh, indent=1)


def metrics_to_json(snapshot: dict) -> list:
    """Registry snapshot -> JSON-clean list (label tuples to dicts)."""
    out = []
    for name in sorted(snapshot):
        entry = snapshot[name]
        series = []
        for key in sorted(entry["series"]):
            val = entry["series"][key]
            row = {"labels": dict(key)}
            if entry["kind"] == "histogram":
                counts, (count, total) = val
                row["bucket_counts"] = list(counts)
                row["count"], row["sum"] = count, total
            else:
                row["value"] = val
            series.append(row)
        item = {"name": name, "kind": entry["kind"],
                "help": entry.get("help", ""), "series": series}
        if "buckets" in entry:
            item["buckets"] = list(entry["buckets"])
        out.append(item)
    return out


def load_session(path) -> dict:
    with open(path) as fh:
        return json.load(fh)


# -- the process-wide current recorder ----------------------------------

_active: FlightRecorder | None = None


def get_recorder() -> FlightRecorder | None:
    """The installed recorder, or None (the default — recording off)."""
    return _active


def install(recorder: FlightRecorder | None) -> FlightRecorder | None:
    """Install (or, with None, uninstall) the process-wide recorder;
    returns the previous one."""
    global _active
    prev, _active = _active, recorder
    return prev


@contextlib.contextmanager
def recording(recorder: FlightRecorder | None = None, **kw):
    """``with recording() as rec:`` — install a (fresh, by default)
    recorder for the block and restore the previous one after."""
    rec = recorder if recorder is not None else FlightRecorder(**kw)
    prev = install(rec)
    try:
        yield rec
    finally:
        install(prev)
