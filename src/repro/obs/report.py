"""Run-summary renderer for recorded flight-recorder sessions.

``python -m repro.obs.report session.json`` prints, per recorded run:
rounds, the live-frontier trajectory, messages (delivered / pruned),
exact grid cells and DMA bytes (the planner mirror), the kernel path
chosen (pinned/tiled × dense/worklist), wall time, and the per-shard
message skew (max/mean, 1.0 = perfectly balanced) — then the serving
counters (request statuses, cache hits/misses/invalidations,
preemptions, queue depth) when a server ran under the same recorder.

``render(session)`` returns the same text for programmatic use (the
quickstart and the tests call it on an in-memory session dict).
"""
from __future__ import annotations

import sys

from repro.obs.record import _skew, load_session


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GiB"


def _metric_series(session: dict, name: str) -> list:
    for entry in session.get("metrics", []):
        if entry["name"] == name:
            return entry["series"]
    return []


def _runs(rounds: list) -> dict:
    by_run: dict[str, list] = {}
    for r in rounds:
        by_run.setdefault(r["run"], []).append(r)
    return by_run


def render(session: dict) -> str:
    lines = []
    meta = session.get("meta") or {}
    if meta:
        kv = " ".join(f"{k}={v}" for k, v in sorted(meta.items()))
        lines.append(f"session: {kv}")

    rounds = session.get("rounds", [])
    if rounds:
        lines.append("== engine rounds ==")
    for run, rows in _runs(rounds).items():
        msgs = sum(r["messages"] for r in rows)
        pruned = sum(r["pruned"] for r in rows)
        cells = sum(r["cells"] for r in rows)
        dma = sum(r["dma_bytes"] for r in rows)
        wall = sum(r["wall_s"] for r in rows)
        paths = sorted({f"{r['grid']}/{r['path']}" for r in rows})
        lines.append(
            f"{run}: rounds={len(rows)} "
            f"frontier {rows[0]['frontier']}->{rows[-1]['frontier']} "
            f"messages={msgs} pruned={pruned} cells={cells} "
            f"dma={_fmt_bytes(dma)} wall={wall * 1e3:.1f}ms "
            f"path={','.join(paths)}")
        shard_rows = [r["shard_messages"] for r in rows
                      if r.get("shard_messages")]
        if shard_rows:
            S = len(shard_rows[0])
            totals = [sum(row[s] for row in shard_rows) for s in range(S)]
            skew = _skew(totals)
            mean = sum(totals) / max(len(totals), 1)
            lines.append(
                f"  shard messages: S={S} max={max(totals)} "
                f"mean={mean:.1f} skew(max/mean)={skew:.2f}")

    serve = {}
    for metric in ("serve_requests_total", "serve_cache_total",
                   "serve_preemptions_total", "serve_ticks_total"):
        series = _metric_series(session, metric)
        if series:
            serve[metric] = series
    if serve:
        lines.append("== serving ==")
        for row in serve.get("serve_requests_total", []):
            status = row["labels"].get("status", "?")
            lines.append(f"requests[{status}] = {row['value']}")
        for row in serve.get("serve_cache_total", []):
            ev = row["labels"].get("event", "?")
            lines.append(f"cache[{ev}] = {row['value']}")
        for row in serve.get("serve_preemptions_total", []):
            lines.append(f"preemptions = {row['value']}")
        for row in serve.get("serve_ticks_total", []):
            lines.append(f"server ticks = {row['value']}")
        depth = _metric_series(session, "serve_queue_depth")
        for row in depth:
            lines.append(f"queue depth (last) = {row['value']}")

    trace = session.get("trace", {})
    events = trace.get("traceEvents", [])
    spans = sum(1 for e in events if e.get("ph") == "X")
    if events:
        lines.append(f"trace: {len(events)} events ({spans} spans) — "
                     "load the session's 'trace' object in Perfetto")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    argv = argv if argv is not None else sys.argv[1:]
    if len(argv) != 1:
        print("usage: python -m repro.obs.report <session.json>",
              file=sys.stderr)
        return 2
    print(render(load_session(argv[0])), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
