"""Process-wide metrics registry: counters, gauges, histograms.

Dependency-free (stdlib only).  Three instrument kinds:

* ``Counter``   — monotonically increasing totals (``inc``);
* ``Gauge``     — last-write-wins instantaneous values (``set``);
* ``Histogram`` — fixed-bucket distributions (``observe``) with
  count/sum, rendered as cumulative Prometheus buckets.

Every instrument is label-aware: ``counter.labels(app="bfs").inc()``
keys a child series by its sorted label items.  ``snapshot()`` captures
the whole registry as a plain dict; ``delta(before)`` subtracts an
earlier snapshot (counters/histogram counts subtract, gauges keep the
latest value) — the idiom benches use to report a run's own activity on
a shared process-wide registry.  ``render_prometheus()`` emits the
text exposition format, so live telemetry and scrape endpoints share
one schema with the BENCH json columns.

The module-level :func:`registry` returns the process default; tests
construct private ``MetricsRegistry`` instances.
"""
from __future__ import annotations

import bisect
import threading

_INF = float("inf")


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _fmt_labels(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


def _fmt_value(v) -> str:
    if v == _INF:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() else repr(f)


class _Child:
    """One labeled series of a parent instrument."""

    def __init__(self, parent, key):
        self._parent = parent
        self._key = key


class _CounterChild(_Child):
    def inc(self, amount=1):
        if amount < 0:
            raise ValueError("counters only increase; got %r" % (amount,))
        with self._parent._lock:
            self._parent._values[self._key] = \
                self._parent._values.get(self._key, 0) + amount

    @property
    def value(self):
        with self._parent._lock:
            return self._parent._values.get(self._key, 0)


class _GaugeChild(_Child):
    def set(self, value):
        with self._parent._lock:
            self._parent._values[self._key] = value

    def inc(self, amount=1):
        with self._parent._lock:
            self._parent._values[self._key] = \
                self._parent._values.get(self._key, 0) + amount

    def dec(self, amount=1):
        self.inc(-amount)

    @property
    def value(self):
        with self._parent._lock:
            return self._parent._values.get(self._key, 0)


class _HistogramChild(_Child):
    def observe(self, value):
        with self._parent._lock:
            counts, stats = self._parent._series(self._key)
            i = bisect.bisect_left(self._parent.buckets, value)
            counts[i] += 1
            stats[0] += 1
            stats[1] += value

    @property
    def count(self):
        with self._parent._lock:
            return self._parent._series(self._key)[1][0]

    @property
    def sum(self):
        with self._parent._lock:
            return self._parent._series(self._key)[1][1]


class _Instrument:
    kind = None
    _child_cls = None

    def __init__(self, name, help=""):
        self.name = name
        self.help = help
        self._lock = threading.RLock()
        self._values: dict = {}

    def labels(self, **labels):
        return self._child_cls(self, _label_key(labels))

    # bare (unlabeled) convenience: counter.inc() == counter.labels().inc()
    def __getattr__(self, attr):
        child = self._child_cls(self, ())
        if hasattr(child, attr):
            return getattr(child, attr)
        raise AttributeError(attr)


class Counter(_Instrument):
    kind = "counter"
    _child_cls = _CounterChild

    def snapshot_values(self):
        with self._lock:
            return dict(self._values)


class Gauge(_Instrument):
    kind = "gauge"
    _child_cls = _GaugeChild

    def snapshot_values(self):
        with self._lock:
            return dict(self._values)


# latency-flavored default buckets (seconds), plus +Inf
DEFAULT_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.05, 0.1, 0.5,
                   1.0, 5.0, 10.0)


class Histogram(_Instrument):
    kind = "histogram"
    _child_cls = _HistogramChild

    def __init__(self, name, help="", buckets=DEFAULT_BUCKETS):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets))

    def _series(self, key):
        if key not in self._values:
            # per-bucket counts (one extra for +Inf) + [count, sum]
            self._values[key] = ([0] * (len(self.buckets) + 1), [0, 0.0])
        return self._values[key]

    def snapshot_values(self):
        with self._lock:
            return {k: (list(c), list(s))
                    for k, (c, s) in self._values.items()}


class MetricsRegistry:
    """A named collection of instruments with snapshot/delta semantics."""

    def __init__(self):
        self._lock = threading.RLock()
        self._instruments: dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name, help, **kw):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help, **kw)
                self._instruments[name] = inst
            elif not isinstance(inst, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {inst.kind}")
            return inst

    def counter(self, name, help="") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name, help="") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name, help="",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    # -- snapshot / delta ------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict capture: {name: {"kind", "help", "series"}} where
        series maps label-key tuples to values (or histogram state)."""
        with self._lock:
            insts = list(self._instruments.items())
        out = {}
        for name, inst in insts:
            entry = {"kind": inst.kind, "help": inst.help,
                     "series": inst.snapshot_values()}
            if inst.kind == "histogram":
                entry["buckets"] = list(inst.buckets)
            out[name] = entry
        return out

    def delta(self, before: dict) -> dict:
        """Subtract an earlier :meth:`snapshot`.  Counters and histogram
        bucket counts subtract; gauges keep their current value (they
        are instantaneous, not cumulative).  Series absent from
        ``before`` are kept whole."""
        now = self.snapshot()
        out = {}
        for name, entry in now.items():
            prev = before.get(name, {}).get("series", {})
            series = {}
            for key, val in entry["series"].items():
                if entry["kind"] == "counter" and key in prev:
                    series[key] = val - prev[key]
                elif entry["kind"] == "histogram" and key in prev:
                    pc, ps = prev[key]
                    counts, stats = val
                    series[key] = (
                        [c - p for c, p in zip(counts, pc)],
                        [stats[0] - ps[0], stats[1] - ps[1]])
                else:
                    series[key] = val
            out[name] = dict(entry, series=series)
        return out

    # -- exposition ------------------------------------------------------

    def render_prometheus(self, snapshot: dict | None = None) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        snap = snapshot if snapshot is not None else self.snapshot()
        lines = []
        for name in sorted(snap):
            entry = snap[name]
            if entry.get("help"):
                lines.append(f"# HELP {name} {entry['help']}")
            lines.append(f"# TYPE {name} {entry['kind']}")
            for key in sorted(entry["series"]):
                val = entry["series"][key]
                if entry["kind"] == "histogram":
                    counts, (count, total) = val
                    cum = 0
                    edges = list(entry["buckets"]) + [_INF]
                    for c, edge in zip(counts, edges):
                        cum += c
                        lk = key + (("le", _fmt_value(edge)),)
                        lines.append(f"{name}_bucket{_fmt_labels(lk)}"
                                     f" {cum}")
                    lines.append(
                        f"{name}_sum{_fmt_labels(key)} "
                        f"{_fmt_value(total)}")
                    lines.append(
                        f"{name}_count{_fmt_labels(key)} {count}")
                else:
                    lines.append(f"{name}{_fmt_labels(key)} "
                                 f"{_fmt_value(val)}")
        return "\n".join(lines) + "\n"

    def reset(self):
        with self._lock:
            self._instruments.clear()


_default = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide default registry."""
    return _default
