"""Fault-tolerant checkpointing: atomic, content-verified, async-capable.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (keyed by
its flattened path) + ``manifest.json`` (treedef, shapes, dtypes, crc32s,
step). Writes go to ``step_<N>.tmp`` and are renamed only after fsync —
a crash mid-save never corrupts the latest checkpoint (restart-safety).

``save(..., blocking=False)`` hands the host copy to a writer thread —
training continues while bytes hit disk (async checkpointing). On
multi-host deployments each host writes its own process-local shards
(``shard_suffix``); restore re-places leaves with ``device_put`` against
the current mesh, so an elastic re-mesh can load a checkpoint written by
a differently-sized fleet.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flat_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 shard_suffix: str = ""):
        self.dir = directory
        self.keep = keep
        self.shard_suffix = shard_suffix
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._async_error: BaseException | None = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = True,
             meta: dict | None = None) -> str:
        self.wait()
        leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = [(_flat_key(p), np.asarray(l)) for p, l in leaves_with_path]
        treedef = jax.tree.structure(tree)
        if blocking:
            return self._write(step, host, treedef, meta)
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host, treedef, meta),
            daemon=True)
        self._thread.start()
        return self._final_path(step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._async_error is not None:
            err, self._async_error = self._async_error, None
            raise RuntimeError(
                "async checkpoint write failed") from err

    def _write_guarded(self, step, host, treedef, meta):
        # writer-thread shim: a failed background save must not die
        # silently — the exception re-raises on the next save()/wait()
        try:
            self._write(step, host, treedef, meta)
        except BaseException as e:  # noqa: BLE001
            self._async_error = e

    def _final_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def _write(self, step: int, host, treedef, meta=None) -> str:
        final = self._final_path(step)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": str(treedef), "leaves": {},
                    "meta": meta if meta is not None else {}}
        for key, arr in host:
            fname = f"{key}{self.shard_suffix}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._final_path(s), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None, verify: bool = True):
        """Restore into the structure of ``like``. ``shardings`` (optional
        matching pytree) re-places leaves on the current mesh."""
        path = self._final_path(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves_with_path))
        out = []
        for (p, l), sh in zip(leaves_with_path, shard_leaves):
            key = _flat_key(p)
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"checkpoint leaf {key} corrupt "
                                  f"(crc {crc} != {meta['crc32']})")
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree.unflatten(treedef, out)

    def restore_meta(self, step: int) -> dict:
        """The JSON ``meta`` dict stored alongside step ``step``'s leaves
        (empty for checkpoints written without one)."""
        with open(os.path.join(self._final_path(step),
                               "manifest.json")) as f:
            return json.load(f).get("meta", {})

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
