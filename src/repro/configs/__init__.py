"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, cell_applicable

from repro.configs.paligemma_3b import CONFIG as _paligemma
from repro.configs.whisper_medium import CONFIG as _whisper
from repro.configs.granite_moe_1b import CONFIG as _granite
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.minitron_4b import CONFIG as _minitron
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.xlstm_125m import CONFIG as _xlstm
from repro.configs.jamba_52b import CONFIG as _jamba

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        _paligemma, _whisper, _granite, _deepseek, _command_r,
        _minitron, _qwen3, _phi3, _xlstm, _jamba,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "get_config", "ModelConfig", "ShapeSpec", "SHAPES",
           "cell_applicable"]
