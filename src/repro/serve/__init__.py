"""Serving API: the shared admission-control layer (bounded queues,
overload policies, result cache, fault injection — ISSUE 6), plus lazy
re-exports of the LM serving glue.

The admission layer is part of the graph-engine surface and imports
eagerly.  The LM step factories (``cache_axes_tree`` / ``make_serve_steps``)
live with the quarantined training substrate under ``repro.lm`` so both
share sharding rules; they are resolved lazily here so that importing
``repro.serve`` (or any of its submodules, which executes this package
``__init__``) does not drag the transformer stack onto the graph-engine
import surface.
"""
from repro.serve.admission import (
    AdmissionError, AdmissionQueue, FaultPlan, QueryStatus,
    QueryValidationError, ResultCache, ServeConfig,
)

_LM_EXPORTS = {"cache_axes_tree", "make_serve_steps"}

__all__ = [
    "AdmissionError", "AdmissionQueue", "FaultPlan", "QueryStatus",
    "QueryValidationError", "ResultCache", "ServeConfig",
    "cache_axes_tree", "make_serve_steps",
]


def __getattr__(name):
    if name in _LM_EXPORTS:
        from repro.lm.train import train_step
        return getattr(train_step, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
