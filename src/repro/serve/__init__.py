"""Serving API: batched prefill/decode with sharded caches, plus the
shared admission-control layer (bounded queues, overload policies,
result cache, fault injection — ISSUE 6).

Thin re-exports — the step factories live with the training substrate so
both share sharding rules; the batched driver is ``repro.launch.serve``.
"""
from repro.serve.admission import (
    AdmissionError, AdmissionQueue, FaultPlan, QueryStatus,
    QueryValidationError, ResultCache, ServeConfig,
)
from repro.train.train_step import cache_axes_tree, make_serve_steps

__all__ = [
    "AdmissionError", "AdmissionQueue", "FaultPlan", "QueryStatus",
    "QueryValidationError", "ResultCache", "ServeConfig",
    "cache_axes_tree", "make_serve_steps",
]
