"""Serving API: batched prefill/decode with sharded caches.

Thin re-exports — the step factories live with the training substrate so
both share sharding rules; the batched driver is ``repro.launch.serve``.
"""
from repro.train.train_step import cache_axes_tree, make_serve_steps

__all__ = ["make_serve_steps", "cache_axes_tree"]
