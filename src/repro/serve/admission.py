"""Shared admission-control layer for the serving loops (ISSUE 6
tentpole).

The paper's runtime makes congestion control a first-class design knob —
the CCA-Simulator exposes ``THROTTLE``, ``THROTTLE_CONGESTION_THRESHOLD``,
``ACTIONQUEUESIZE`` and ``DIFFUSE_QUEUE_SIZE`` because a fine-grain
message-driven system collapses when work is admitted faster than cells
can drain it.  This module is the serving-side analog, shared by the
graph ``QueryServer`` (``query.server``) and the LM ``ContinuousBatcher``
(``serve.scheduler``):

* ``AdmissionQueue`` — a bounded queue (``max_queue`` is the
  ACTIONQUEUESIZE analog) with a configurable overload policy:
  ``'block'`` (the submitter ticks the server until space frees — the
  THROTTLE cool-down), ``'reject'`` (typed rejection, no exception), or
  ``'shed'`` (evict the lowest-priority queued request to make room for
  a more urgent one).  Dequeue order is priority-first, then weighted
  per-tenant fairness (lowest lanes-in-use ÷ weight first, so no tenant
  is starved of its share), then FIFO — with one tenant and equal
  priorities this is exactly FIFO, keeping the non-overloaded serving
  path trace-identical to the unpoliced server.
* ``ResultCache`` — an LRU root-keyed result cache with a staleness
  bound, for the highly repetitive top-k PPR / BFS recommendation
  traffic.
* ``FaultPlan`` — deterministic fault injection (induced lane failure,
  delayed tick) so tests and the load harness can prove every failure
  path surfaces as a typed ``QueryResult`` status rather than an
  exception out of the serving loop.

Every overload outcome is a ``QueryStatus`` string on the result, never
an exception: the serving loop must degrade, not fall over.
"""
from __future__ import annotations

import collections
import dataclasses
import typing


class QueryStatus:
    """Typed terminal statuses a request can resolve to.  ``OK`` is the
    only status with a complete (non-partial) result; everything else is
    an overload / robustness outcome that the serving loop reports
    instead of raising."""

    OK = "ok"
    REJECTED = "rejected"              # bounded queue, policy='reject'
    SHED = "shed"                      # dropped by the shed policy
    DEADLINE_EXPIRED = "deadline_expired"  # SLO passed; partial values
    TIMEOUT = "timeout"                # wall-clock execution cap hit
    BUDGET_EXHAUSTED = "budget_exhausted"  # round budget hit; partial
    FAILED = "lane_failed"             # injected / detected lane failure
    RECOVERED = "recovered"            # completed after checkpoint restore
    DEGRADED = "degraded"              # recovery exhausted; partial values

    TERMINAL = frozenset((OK, REJECTED, SHED, DEADLINE_EXPIRED, TIMEOUT,
                          BUDGET_EXHAUSTED, FAILED, RECOVERED, DEGRADED))
    # statuses that still carry (partial) values — RECOVERED is not here
    # because it carries a *complete* result (like OK, after a restore)
    PARTIAL_VALUED = frozenset((DEADLINE_EXPIRED, TIMEOUT,
                                BUDGET_EXHAUSTED, DEGRADED))


class QueryValidationError(ValueError):
    """A request rejected at submit time (unknown kind, out-of-range or
    empty sources, NaN/negative damping, negative budgets) — typed so
    callers can distinguish bad input from overload outcomes."""


class AdmissionError(RuntimeError):
    """The 'block' policy could not make progress (queue full and the
    serving loop cannot drain — e.g. zero lanes for every queued kind)."""


@dataclasses.dataclass
class FaultPlan:
    """Deterministic fault-injection schedule, keyed on the server tick.

    ``lane_failures``: (tick, pool, lane) triples — at the start of that
    tick the occupied lane is killed; its request resolves with status
    ``QueryStatus.FAILED`` (values lost).  ``pool`` is ``'min'`` or
    ``'ppr'``.
    ``tick_delays``: (tick, seconds) pairs — the server's clock is
    advanced by ``seconds`` at that tick (a stalled tick), so wall-clock
    deadlines and timeouts fire exactly as they would under a real stall,
    without sleeping in tests.
    """

    lane_failures: tuple = ()
    tick_delays: tuple = ()

    def failures_at(self, tick: int):
        return [(pool, lane) for t, pool, lane in self.lane_failures
                if t == tick]

    def delay_at(self, tick: int) -> float:
        return float(sum(s for t, s in self.tick_delays if t == tick))


@dataclasses.dataclass
class ServeConfig:
    """Robustness knobs for a serving loop (the CCA-Simulator congestion
    knobs, serving-side).  The defaults — unbounded queue, no cache, no
    faults — reproduce the unpoliced PR 3 server trace-identically.

    max_queue: bounded admission queue length (ACTIONQUEUESIZE analog);
        None = unbounded (legacy behavior).
    overload_policy: 'block' | 'reject' | 'shed' — what happens to a
        submit when the queue is full (see ``AdmissionQueue``).
    block_max_ticks: safety valve for 'block': how many server ticks a
        blocked submit may spin before raising ``AdmissionError``.
    tenant_weights: tenant id -> weighted share of lanes (missing ids
        weigh 1.0).  Fairness is deficit-based: the queued tenant with
        the lowest lanes-in-use ÷ weight is served first at equal
        priority, so a heavy tenant cannot starve a light one.
    preempt: an urgent request may preempt the lowest-priority running
        lane when no lane is free (strictly greater priority only, so
        default-priority traffic never preempts and stays
        trace-identical).  The preempted request is re-queued at its
        original FIFO position and restarts.
    cache_size: root-keyed LRU result-cache capacity; 0 disables.
    cache_ttl_s: staleness bound for cache hits (None = never stale).
    faults: optional ``FaultPlan`` for fault injection.
    checkpoint_every: snapshot the server's lane/queue state to its
        attached ``CheckpointManager`` every K ticks (None disables —
        the default keeps the unpoliced path trace-identical).
    """

    max_queue: int | None = None
    overload_policy: str = "reject"
    block_max_ticks: int = 10000
    tenant_weights: dict = dataclasses.field(default_factory=dict)
    preempt: bool = True
    cache_size: int = 0
    cache_ttl_s: float | None = None
    faults: FaultPlan | None = None
    checkpoint_every: int | None = None

    def __post_init__(self):
        if self.overload_policy not in ("block", "reject", "shed"):
            raise ValueError(
                f"unknown overload_policy {self.overload_policy!r}: "
                "expected 'block', 'reject', or 'shed'")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None = unbounded)")
        if self.checkpoint_every is not None and self.checkpoint_every < 1:
            raise ValueError(
                "checkpoint_every must be >= 1 (or None = disabled)")


class _Entry(typing.NamedTuple):
    seq: int
    priority: int
    tenant: str
    item: object


class AdmissionQueue:
    """Bounded priority/tenant-fair admission queue.

    ``offer`` applies the overload policy; ``take`` pops the next
    admissible item under (priority desc, tenant deficit asc, FIFO)
    ordering.  With one tenant and uniform priorities the order is
    exactly FIFO — the non-overloaded path stays trace-identical to a
    plain list queue."""

    def __init__(self, max_queue: int | None = None,
                 policy: str = "reject", tenant_weights: dict | None = None):
        self.max_queue = max_queue
        self.policy = policy
        self.tenant_weights = dict(tenant_weights or {})
        self._entries: list[_Entry] = []
        self._seq = 0

    # ------------------------------------------------------------ plumbing
    def __len__(self):
        return len(self._entries)

    def __iter__(self):
        return (e.item for e in self._entries)

    def __eq__(self, other):
        if isinstance(other, AdmissionQueue):
            return self._entries == other._entries
        if isinstance(other, (list, tuple)):
            return [e.item for e in self._entries] == list(other)
        return NotImplemented

    @property
    def full(self) -> bool:
        return (self.max_queue is not None
                and len(self._entries) >= self.max_queue)

    @property
    def next_seq(self) -> int:
        """The seq the next plain push will get (recorded by the server
        so a preempted request can re-queue at its original position)."""
        return self._seq

    def remove(self, entry: _Entry):
        """Remove a specific entry previously returned by ``peek``."""
        self._entries.remove(entry)

    # ------------------------------------------------------------- enqueue
    def offer(self, item, priority: int | None = None,
              tenant: str | None = None):
        """Apply the overload policy.  Returns (decision, victim):

        decision: 'admitted' | 'rejected' | 'shed_incoming' | 'blocked';
        victim: a previously queued item evicted by the shed policy (its
        owner must resolve it with status SHED), else None.  'blocked'
        means the caller should drain the loop and re-offer."""
        priority = (getattr(item, "priority", 0) if priority is None
                    else priority)
        tenant = (getattr(item, "tenant", "default") if tenant is None
                  else tenant)
        if not self.full:
            self._push(item, priority, tenant)
            return "admitted", None
        if self.policy == "block":
            return "blocked", None
        if self.policy == "reject":
            return "rejected", None
        # shed: evict the lowest-priority queued entry (newest among
        # equals, preserving FIFO fairness for the older ones) iff the
        # incoming request outranks it; else the incoming one is shed
        victim = max(self._entries, key=lambda e: (-e.priority, e.seq))
        if priority > victim.priority:
            self._entries.remove(victim)
            self._push(item, priority, tenant)
            return "admitted", victim.item
        return "shed_incoming", None

    def _push(self, item, priority, tenant, seq: int | None = None):
        if seq is None:
            seq, self._seq = self._seq, self._seq + 1
        self._entries.append(_Entry(seq, priority, tenant, item))

    def put_back(self, item, priority: int, tenant: str, seq: int):
        """Re-queue a preempted item at its original FIFO position.
        Returns False (caller sheds the item) when the queue is full and
        the item does not outrank any queued entry."""
        if self.full:
            victim = max(self._entries, key=lambda e: (-e.priority, e.seq))
            if priority <= victim.priority:
                return False
            self._entries.remove(victim)
            # the displaced entry is genuinely lower priority: it is shed
            self._push(item, priority, tenant, seq)
            return victim.item
        self._push(item, priority, tenant, seq)
        return True

    # ------------------------------------------------------------- dequeue
    def _order_key(self, in_flight):
        def key(e: _Entry):
            w = float(self.tenant_weights.get(e.tenant, 1.0))
            deficit = in_flight.get(e.tenant, 0) / max(w, 1e-9)
            return (-e.priority, deficit, e.seq)
        return key

    def peek(self, pred=None, in_flight: dict | None = None):
        """Best queued entry admissible under ``pred`` (or None)."""
        cands = [e for e in self._entries
                 if pred is None or pred(e.item)]
        if not cands:
            return None
        return min(cands, key=self._order_key(in_flight or {}))

    def take(self, pred=None, in_flight: dict | None = None):
        """Pop and return the best admissible entry (or None)."""
        e = self.peek(pred, in_flight)
        if e is not None:
            self._entries.remove(e)
        return e

    def drain_if(self, pred):
        """Remove and return all queued items matching ``pred`` (e.g.
        queued-deadline expiry)."""
        out = [e for e in self._entries if pred(e.item)]
        for e in out:
            self._entries.remove(e)
        return [e.item for e in out]


class ResultCache:
    """Root-keyed LRU result cache with a staleness bound.

    Keys are canonicalized (kind, sources[, damping, tol]) tuples built
    by the server; values are whatever payload the server stores.  A hit
    older than ``ttl_s`` is evicted, never served stale."""

    def __init__(self, size: int, ttl_s: float | None = None):
        self.size = int(size)
        self.ttl_s = ttl_s
        self._d: collections.OrderedDict = collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self):
        return len(self._d)

    def get(self, key, now: float):
        if self.size <= 0:
            return None
        hit = self._d.get(key)
        if hit is not None:
            payload, stored_at = hit
            if self.ttl_s is not None and now - stored_at > self.ttl_s:
                del self._d[key]            # stale: drop, count as miss
            else:
                self._d.move_to_end(key)
                self.hits += 1
                return payload
        self.misses += 1
        return None

    def put(self, key, payload, now: float):
        if self.size <= 0:
            return
        self._d[key] = (payload, now)
        self._d.move_to_end(key)
        while len(self._d) > self.size:
            self._d.popitem(last=False)

    @staticmethod
    def _key_vertices(key):
        """Vertex ids a canonical cache key depends on: the sources
        element holds ints (min-pool) or (vertex, value) pairs (dict
        sources / ppr seeds)."""
        for item in key[1]:
            yield item[0] if isinstance(item, tuple) else item

    def invalidate(self, root: int) -> int:
        """Drop every cached result whose source set touches ``root`` —
        the hook streaming-graph mutation needs: an edge change at a
        vertex stales exactly the queries rooted there.  Returns the
        number of entries dropped (also tallied in ``invalidations``)."""
        root = int(root)
        doomed = [k for k in self._d if root in self._key_vertices(k)]
        for k in doomed:
            del self._d[k]
        self.invalidations += len(doomed)
        return len(doomed)

    def invalidate_all(self) -> int:
        """Flush the cache (whole-graph mutation); returns entries dropped."""
        n = len(self._d)
        self._d.clear()
        self.invalidations += n
        return n
