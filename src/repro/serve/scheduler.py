"""Continuous-batching serve scheduler.

Fixed-slot batched decoding: a pool of ``n_slots`` sequence slots shares
one compiled decode step (static shapes). Requests join free slots at any
step (their prompt is prefilled into the slot's cache region); finished
sequences (EOS or max-len) free their slot immediately — no
head-of-line blocking on long generations. Per-slot position indices and
an active mask keep the single decode_step exact for ragged progress.

This is the serving-side analog of the paper's always-keep-the-cell-busy
runtime: slots never idle waiting for the longest sequence in a batch.

Admission control is delegated to the shared ``serve.admission`` layer
(ISSUE 6): pass ``serve=ServeConfig(max_queue=..., overload_policy=...)``
to bound the queue — an overflowing submit resolves the request with a
typed ``status`` ('rejected' / 'shed') instead of growing the queue
without bound, and priority/tenant-fair ordering applies on dequeue.
The default config keeps the legacy unbounded-FIFO behavior exactly.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.lm.models.model import Model
from repro.serve.admission import AdmissionQueue, QueryStatus, ServeConfig


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray            # prompt ids
    max_new: int = 16
    eos_id: int = -1              # -1: never
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    priority: int = 0             # higher = dequeued first under overload
    tenant: str = "default"       # fair-share admission id
    status: str = QueryStatus.OK  # typed outcome ('rejected'/'shed'/...)


class ContinuousBatcher:
    def __init__(self, model: Model, params, n_slots: int, max_len: int,
                 serve: ServeConfig | None = None):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.serve = serve if serve is not None else ServeConfig()
        self.caches = model.init_cache(n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int32)       # next write index
        self.slot_req: list[Request | None] = [None] * n_slots
        self.queue = AdmissionQueue(
            self.serve.max_queue, self.serve.overload_policy,
            self.serve.tenant_weights)
        self.last_tok = np.zeros((n_slots, 1), np.int32)
        self.tick = 0
        self._retired: list[Request] = []
        self._obs_submit_t: dict[int, float] = {}

        self._decode = jax.jit(self._decode_step)

    # one shared decode over all slots; per-slot positions via vmapped index
    def _decode_step(self, params, toks, caches, positions):
        assert self.model.cfg.family != "enc_dec", "decoder-only for now"
        axes_tree = _cache_axes(caches)

        def one(tok, cache, pos):
            # vmap strips the slot axis; the model wants B=1 — reinsert it
            cache_b = jax.tree.map(
                lambda c, a: jnp.expand_dims(c, a) if a is not None else c,
                cache, axes_tree)
            logits, new_cache = self.model.decode_step(
                params, tok[None, None], cache_b, pos)
            new_cache = jax.tree.map(
                lambda c, a: jnp.squeeze(c, a) if a is not None else c,
                new_cache, axes_tree)
            return logits[0], new_cache

        return jax.vmap(one, in_axes=(0, axes_tree, 0),
                        out_axes=(0, axes_tree))(toks, caches, positions)

    def submit(self, req: Request):
        """Offer a request to the bounded queue.  Under overload the
        'reject'/'shed' policies resolve it (or a lower-priority queued
        victim) immediately with ``req.done=True`` and a typed
        ``req.status`` — never an exception, never unbounded growth.
        'block' ticks the decode loop until space frees."""
        rec = obs.get_recorder()
        if rec is not None:
            self._obs_submit_t[req.rid] = rec.tracer.now()
            rec.registry.counter(
                "lm_submitted_total",
                "LM requests offered to the batcher queue",
            ).labels(tenant=req.tenant).inc()
        if self.serve.overload_policy == "block":
            spins = 0
            while self.queue.full:
                if spins >= self.serve.block_max_ticks or not self.step():
                    req.done, req.status = True, QueryStatus.REJECTED
                    self._obs_request_end(req)
                    return
                spins += 1
        decision, victim = self.queue.offer(req)
        if victim is not None:
            victim.done, victim.status = True, QueryStatus.SHED
            self._obs_request_end(victim)
            self._retired.append(victim)
        if decision == "rejected":
            req.done, req.status = True, QueryStatus.REJECTED
            self._obs_request_end(req)
        elif decision == "shed_incoming":
            req.done, req.status = True, QueryStatus.SHED
            self._obs_request_end(req)

    def _obs_request_end(self, req: Request):
        rec = obs.get_recorder()
        t0 = self._obs_submit_t.pop(req.rid, None)
        if rec is None:
            return
        rec.registry.counter(
            "lm_requests_total", "LM requests resolved, by outcome",
        ).labels(status=req.status, tenant=req.tenant).inc()
        if t0 is not None:
            rec.tracer.complete("request", track="lm/requests", start=t0,
                                rid=req.rid, tenant=req.tenant,
                                status=req.status, tokens=len(req.out))

    def _in_flight(self) -> dict:
        c: dict = {}
        for r in self.slot_req:
            if r is not None:
                c[r.tenant] = c.get(r.tenant, 0) + 1
        return c

    def _admit(self):
        rec = obs.get_recorder()
        for s in range(self.n_slots):
            if self.slot_req[s] is None and len(self.queue):
                # priority / tenant-fair order comes from the shared
                # AdmissionQueue — high-priority prompts prefill first
                req = self.queue.take(in_flight=self._in_flight()).item
                self.slot_req[s] = req
                span = None
                if rec is not None:
                    rec.registry.counter(
                        "lm_admitted_total",
                        "LM requests admitted to a decode slot",
                    ).labels(tenant=req.tenant).inc()
                    span = rec.tracer.span(
                        "prefill", track="lm", rid=req.rid, slot=s,
                        priority=req.priority, prompt_len=len(req.tokens))
                # prefill the slot: single-sequence prefill into slot s
                sub_cache = jax.tree.map(lambda c: c[:, s : s + 1]
                                         if c.ndim > 1 else c, self.caches)
                logits, sub_cache = self.model.prefill(
                    self.params, {"tokens": jnp.asarray(req.tokens[None])},
                    sub_cache)
                self.caches = jax.tree.map(
                    lambda c, sc: c.at[:, s : s + 1].set(sc)
                    if c.ndim > 1 else c, self.caches, sub_cache)
                self.pos[s] = len(req.tokens)
                self.last_tok[s, 0] = int(jnp.argmax(logits[0, -1]))
                req.out.append(int(self.last_tok[s, 0]))
                if span is not None:
                    span.end()

    def step(self):
        """One global decode tick: admit, decode active slots, retire."""
        rec = obs.get_recorder()
        self.tick += 1
        span = None
        if rec is not None:
            span = rec.tracer.span("tick", track="lm", tick=self.tick)
        self._admit()
        active = [s for s in range(self.n_slots)
                  if self.slot_req[s] is not None]
        if not active:
            if span is not None:
                span.end(active=0, queue=len(self.queue))
            return False
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.last_tok[:, 0]), self.caches,
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for s in active:
            req = self.slot_req[s]
            self.pos[s] += 1
            tok = int(nxt[s])
            req.out.append(tok)
            self.last_tok[s, 0] = tok
            if (len(req.out) >= req.max_new or tok == req.eos_id
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.slot_req[s] = None      # slot freed immediately
                self._obs_request_end(req)
                self._retired.append(req)
        if rec is not None:
            span.end(active=len(active), queue=len(self.queue))
            rec.registry.counter(
                "lm_ticks_total", "LM batcher decode ticks").inc()
            rec.registry.gauge(
                "lm_queue_depth", "LM batcher queue depth",
            ).set(len(self.queue))
            rec.tracer.counter("lm", {"queue_depth": len(self.queue),
                                      "active_slots": len(active)})
        return True

    def run(self, max_ticks: int = 1000) -> list[Request]:
        """Tick until the queue and all slots drain (or ``max_ticks``);
        returns the requests that reached a terminal state during the
        run — retired sequences plus any shed queue victims."""
        self._retired.clear()
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        done, self._retired = self._retired, []
        return done


def _cache_axes(caches):
    """in_axes pytree mapping the slot/batch dim of each cache leaf."""
    def ax(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        if names and names[-1] in ("out",):
            return 0
        if names and names[-1] == "pos":
            return None
        return 1 if leaf.ndim > 1 else None  # (layers, B, ...) -> B axis
    return jax.tree_util.tree_map_with_path(ax, caches)
