"""Streaming graphs: batched mutations with incremental recompute
(paper §7 "recompute from there without starting from scratch").

`StreamingGraph` owns a mutable COO graph plus per-app *views* (the
plain graph for BFS/SSSP, the 1/out-degree-weighted graph for
delta-PageRank, the symmetrized zero-weight graph for CC), each mapped
by a spliced partition.  Mutation batches are buffered by
``insert_edges`` / ``delete_edges`` and applied by ``commit()``:

* **In-place partition splicing** — ``partition.splice_partition``
  regenerates only the shard rows the batch touched; counter-hashed
  placement makes the result field-for-field equal to a from-scratch
  ``build_partition`` of the post-mutation graph.
* **Adaptive rhizome growth** — the Eq. 1 cutoff is *pinned* to the
  initial graph (``PartitionConfig.indegree_cutoff``, the CCA
  exemplars' fixed ``RHIZOME_INDEGREE_CUTOFF``), so a vertex whose
  streamed in-degree crosses k·cutoff splits into its k-th rhizome
  replica online; the splice creates the slot and value migration seeds
  it with the root's current value.
* **Incremental result maintenance** — tracked queries are refreshed
  per batch instead of recomputed cold:

  - monotone min apps (BFS/SSSP/CC): old values are valid upper bounds
    after inserts, so the fixpoint warm-starts with ``init_changed``
    seeded only at the insert sources; deletes first run per-vertex
    *support invalidation* (a value is kept only while some surviving
    in-edge still realizes it — processed in increasing-value order,
    exact for positive weights) and re-lift only the invalidated
    region.  CC (zero weights, cyclic support) invalidates the deleted
    edges' whole components and reseeds them with self-labels.
    Min-semiring results are **bit-identical** to a cold fixpoint on
    the same partition (same f32 path-sum set, order-independent min).
  - delta-PageRank: ranks migrate as-is and the residual table is
    seeded with the exact base-case correction ``d·(A'-A)ᵀ p`` on the
    mutated sources' neighborhoods (negative residuals diffuse via the
    ``|delta| > tol`` frontier), so only the affected region re-runs.

Runners: ``runner='stacked'`` drives ``engine.run_stacked`` per query,
``'lanes'`` batches every tracked min query of a view into one laned
fixpoint (Q lanes), ``'sharded'`` does the same through
``lanes.run_sharded_lanes`` over a mesh.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro import obs
from repro.core import actions, engine
from repro.core.partition import (Partition, PartitionConfig, SpliceInfo,
                                  build_partition, splice_partition)
from repro.graph.graph import COOGraph

_MIN_APPS = ("bfs", "sssp", "cc")


# --------------------------------------------------------------------------
# value-table scatter/gather helpers (per-vertex <-> (S, R_max) slots)
# --------------------------------------------------------------------------

def scatter_vertex_values(part: Partition, vv: np.ndarray,
                          fill: float = np.inf) -> np.ndarray:
    """(n,) per-vertex values -> (S, R_max) float32 slot table; every
    replica of v gets ``vv[v]`` (consistent view), invalid slots get
    ``fill`` so they never participate."""
    out = np.full((part.S, part.R_max), fill, np.float32)
    sv = np.asarray(part.slot_vertex)
    valid = sv >= 0
    out[valid] = np.asarray(vv, np.float32)[sv[valid]]
    return out


def scatter_vertex_flags(part: Partition, flags: np.ndarray) -> np.ndarray:
    """(n,) bool -> (S, R_max) bool on every replica of flagged vertices."""
    out = np.zeros((part.S, part.R_max), bool)
    sv = np.asarray(part.slot_vertex)
    valid = sv >= 0
    out[valid] = np.asarray(flags, bool)[sv[valid]]
    return out


# --------------------------------------------------------------------------
# delete-side support invalidation (the bounded re-lift)
# --------------------------------------------------------------------------

def invalidate_unsupported(g: COOGraph, values: np.ndarray,
                           del_src, del_dst, del_w,
                           pinned: np.ndarray,
                           unit_w: bool) -> np.ndarray:
    """Which vertices' min-fixpoint values a deletion batch invalidates.

    ``values`` is the pre-delete fixpoint, ``g`` the POST-delete graph.
    A finite, non-pinned value survives only while some in-edge of the
    new graph still *supports* it (``f32(val[u] + w) == val[v]`` with u
    valid).  Candidates are processed in increasing value order, so for
    strictly positive effective weights every potential supporter is
    finalized first and the result is exact; cost is proportional to
    the affected region, not the graph.  ``unit_w`` uses weight 1 per
    edge (BFS levels); otherwise ``g.weight`` must be positive —
    non-positive weights fall back to invalidating every non-pinned
    finite vertex (a whole-value re-lift, still exact)."""
    n = g.n
    vals = np.asarray(values, np.float32)
    finite = np.isfinite(vals)
    E = g.num_edges
    w_eff = (np.ones(E, np.float32) if unit_w
             else np.asarray(g.weight, np.float32))
    if not unit_w and E and float(w_eff.min()) <= 0.0:
        return finite & ~pinned

    order_in = np.argsort(g.dst, kind="stable")
    in_indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(g.dst, minlength=n), out=in_indptr[1:])
    in_src = g.src[order_in]
    in_w = w_eff[order_in]
    order_out = np.argsort(g.src, kind="stable")
    out_indptr = np.zeros(n + 1, np.int64)
    np.cumsum(np.bincount(g.src, minlength=n), out=out_indptr[1:])
    out_dst = g.dst[order_out]
    out_w = w_eff[order_out]

    invalid = np.zeros(n, bool)
    revalidated = np.zeros(n, bool)
    heap: list[tuple[float, int]] = []
    dw = (np.ones(len(del_src), np.float32) if unit_w
          else np.asarray(del_w, np.float32))
    for u, v, w in zip(np.asarray(del_src), np.asarray(del_dst), dw):
        u, v = int(u), int(v)
        if pinned[v] or not finite[v] or not finite[u]:
            continue
        if np.float32(vals[u] + np.float32(w)) == vals[v]:
            heapq.heappush(heap, (float(vals[v]), v))
    while heap:
        _, v = heapq.heappop(heap)
        if revalidated[v] or invalid[v]:
            continue
        supported = False
        for i in range(in_indptr[v], in_indptr[v + 1]):
            u = int(in_src[i])
            if invalid[u] or not finite[u]:
                continue
            if np.float32(vals[u] + in_w[i]) == vals[v]:
                supported = True
                break
        if supported:
            revalidated[v] = True
            continue
        invalid[v] = True
        for i in range(out_indptr[v], out_indptr[v + 1]):
            x = int(out_dst[i])
            if pinned[x] or invalid[x] or revalidated[x] or not finite[x]:
                continue
            if np.float32(vals[v] + out_w[i]) == vals[x]:
                heapq.heappush(heap, (float(vals[x]), x))
    return invalid


# --------------------------------------------------------------------------
# per-batch bookkeeping
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MaintStats:
    """Incremental-maintenance accounting for one tracked query."""

    app: str
    mode: str                      # 'warm' (incremental) | 'cold'
    rounds: int
    messages: int
    work: int
    seeds: int                     # frontier vertices seeded
    invalidated: int               # vertices invalidated by deletes


@dataclasses.dataclass
class CommitInfo:
    """What one ``commit()`` did: splice + maintenance summary."""

    inserted: int
    deleted: int
    mutated_src: np.ndarray
    mutated_dst: np.ndarray
    splices: dict                  # view name -> SpliceInfo
    maint: dict                    # tracked key -> MaintStats
    replicas_added: int            # adaptive rhizome splits (base view)


@dataclasses.dataclass
class _View:
    name: str
    graph: COOGraph
    part: Partition


def _pr_weights(g: COOGraph) -> COOGraph:
    # the exact weighting apps.pagerank uses, so streamed pr views are
    # bit-compatible with cold pagerank partitions
    from repro.apps.pagerank import _pr_graph
    return _pr_graph(g)


class StreamingGraph:
    """Mutable graph + spliced partitions + incrementally-maintained
    query results (see module docstring)."""

    def __init__(self, g: COOGraph, pcfg: PartitionConfig,
                 cfg: engine.EngineConfig = engine.EngineConfig(),
                 runner: str = "stacked", mesh=None,
                 axis_names=("data", "model"),
                 staleness_slo: float | None = None,
                 staleness_metric: str = "edges"):
        if runner not in ("stacked", "lanes", "sharded"):
            raise ValueError(f"unknown runner {runner!r}")
        if staleness_metric not in ("edges", "pr_mass"):
            raise ValueError(f"unknown staleness metric "
                             f"{staleness_metric!r}")
        if staleness_slo is not None and not staleness_slo > 0:
            raise ValueError(f"staleness_slo must be > 0; got "
                             f"{staleness_slo!r}")
        if pcfg.indegree_cutoff is None:
            # pin Eq. 1's cutoff to the initial graph so streamed
            # in-degree growth splits rhizomes instead of re-deriving
            # every vertex's replica count from a moving global max
            indeg_max = max(int(g.in_degrees().max()) if g.n else 1, 1)
            pcfg = dataclasses.replace(
                pcfg,
                indegree_cutoff=max(
                    int(np.ceil(indeg_max / pcfg.rpvo_max)), 1))
        self.g = g
        self.pcfg = pcfg
        self.cfg = cfg
        self.runner = runner
        self.mesh = mesh
        self.axis_names = axis_names
        self._views: dict[str, _View] = {
            "base": _View("base", g, build_partition(g, pcfg))}
        self._pending_ins: list[tuple] = []
        self._pending_del: list[tuple] = []
        self.tracked: dict[tuple, dict] = {}
        self._servers: list[tuple] = []
        self._commits = 0
        # sym-view directed-pair support counts (lazy, see _ensure_view)
        self._mult: dict[int, int] | None = None
        # deferred-commit staleness SLO (see staleness())
        self.staleness_slo = staleness_slo
        self._staleness_metric = staleness_metric
        self.auto_refreshes = 0

    # ------------------------------------------------------------- views
    def view(self, name: str) -> _View:
        if name not in self._views:
            self._views[name] = self._make_view(name)
        return self._views[name]

    def _make_view(self, name: str) -> _View:
        if name == "pr":
            gv = _pr_weights(self.g)
        elif name == "sym":
            gv = self._build_sym()
        else:
            raise ValueError(f"unknown view {name!r}")
        return _View(name, gv, build_partition(gv, self.pcfg))

    def _build_sym(self) -> COOGraph:
        """Symmetrized zero-weight dedup'd view, with directed-pair
        support counts so later batches can maintain the edge *order*
        incrementally (append/delete only — a from-scratch dedup would
        reshuffle first-occurrence order and defeat the splice)."""
        g, n = self.g, self.g.n
        key = np.concatenate([
            g.src.astype(np.int64) * n + g.dst,
            g.dst.astype(np.int64) * n + g.src])
        self._mult = {}
        for k in key.tolist():
            self._mult[k] = self._mult.get(k, 0) + 1
        uniq, first = np.unique(key, return_index=True)
        keep = np.sort(first)
        sk = key[keep]
        return COOGraph(n, (sk // n).astype(np.int32),
                        (sk % n).astype(np.int32),
                        np.zeros(sk.size, np.float32))

    # ---------------------------------------------------------- tracking
    def track(self, app: str, root: int | None = None,
              damping: float = 0.85, tol: float = 1e-7,
              max_rounds: int = 256) -> np.ndarray:
        """Register a query for incremental maintenance; computes it
        cold once and returns the per-vertex values."""
        if app in ("bfs", "sssp"):
            assert root is not None
            key = (app, int(root))
            view = self.view("base")
            init = engine.init_values(
                view.part, actions.BFS if app == "bfs" else actions.SSSP,
                {int(root): 0.0})
            vals, _ = self._run_min_single(
                view, init, scatter_vertex_flags(
                    view.part, self._root_flag(int(root))),
                unitw=1 if app == "bfs" else 0)
            self.tracked[key] = {"vals": vals}
        elif app == "cc":
            key = ("cc", None)
            view = self.view("sym")
            ids = np.arange(self.g.n, dtype=np.float32)
            vals, _ = self._run_min_single(
                view, scatter_vertex_values(view.part, ids),
                scatter_vertex_flags(view.part, np.ones(self.g.n, bool)),
                unitw=0)
            self.tracked[key] = {"vals": vals}
        elif app == "pagerank":
            key = ("pagerank", None)
            view = self.view("pr")
            rank_t, _ = self._run_pr(view, damping, tol, max_rounds,
                                     None, None)
            self.tracked[key] = {
                "vals": engine.vertex_values(view.part, rank_t),
                "damping": float(damping), "tol": float(tol),
                "max_rounds": int(max_rounds)}
        else:
            raise ValueError(f"unknown app {app!r}")
        return self.tracked[key]["vals"]

    def values(self, app: str, root: int | None = None) -> np.ndarray:
        key = (app, int(root) if app in ("bfs", "sssp") else None)
        return self.tracked[key]["vals"]

    def _root_flag(self, root: int) -> np.ndarray:
        f = np.zeros(self.g.n, bool)
        f[root] = True
        return f

    # --------------------------------------------------------- mutations
    def insert_edges(self, src, dst, weight=None) -> None:
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        w = (np.ones(src.size, np.float32) if weight is None
             else np.asarray(weight, np.float32).reshape(-1))
        self._pending_ins.append((src, dst, w))
        self._maybe_auto_refresh()

    def delete_edges(self, src, dst) -> None:
        """Buffer deletion of every edge matching each (src, dst) pair."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        self._pending_del.append((src, dst))
        self._maybe_auto_refresh()

    # ------------------------------------------------- staleness SLO
    def staleness(self) -> float:
        """How stale the tracked results are under the deferred
        (uncommitted) mutations.  ``'edges'``: buffered edge-mutation
        count.  ``'pr_mass'``: an upper bound on the L1 norm of the
        zeroth-order delta-PageRank correction ``d·(A'-A)ᵀ p`` —
        ``d · Σ p[u]`` over the pending mutations' distinct source
        vertices (each mutated source redistributes at most its full
        damped rank mass) — i.e. staleness in rank units rather than
        edge counts.  Falls back to the edge count when no pagerank
        query is tracked."""
        n_pend = sum(int(x[0].size) for x in self._pending_ins) \
            + sum(int(x[0].size) for x in self._pending_del)
        if self._staleness_metric == "edges" \
                or ("pagerank", None) not in self.tracked:
            return float(n_pend)
        if n_pend == 0:
            return 0.0
        srcs = np.unique(np.concatenate(
            [x[0] for x in self._pending_ins]
            + [x[0] for x in self._pending_del]).astype(np.int64))
        p = np.asarray(self.tracked[("pagerank", None)]["vals"],
                       np.float64)
        d = self.tracked[("pagerank", None)]["damping"]
        return float(d * p[srcs].sum())

    def _maybe_auto_refresh(self):
        """Deferred-commit auto-refresh (the staleness SLO): buffering
        is free until the staleness crosses ``staleness_slo``, at which
        point the batch commits — bounded staleness without paying a
        splice per mutation."""
        if self.staleness_slo is None:
            return
        if self.staleness() > self.staleness_slo:
            self.auto_refreshes += 1
            rec = obs.get_recorder()
            if rec is not None:
                rec.registry.counter(
                    "stream_auto_refresh_total",
                    "commits triggered by the staleness SLO").inc()
            self.commit()

    def bind_server(self, server, cache_invalidation: str = "all") -> None:
        """Wire a ``QueryServer`` serving the base view: each commit
        applies the mutation between ticks (``server.apply_mutation``)
        and fires its cache-invalidation hooks.  ``cache_invalidation``:
        ``'all'`` flushes the result cache (exact — any root's result
        may change); ``'roots'`` fires ``invalidate_cache(root)`` per
        mutated endpoint (the PR 7 root-affine heuristic)."""
        assert cache_invalidation in ("all", "roots")
        self._servers.append((server, cache_invalidation))

    # ------------------------------------------------------------ commit
    def commit(self) -> CommitInfo:
        """Apply the buffered batch: splice every live view's partition,
        refresh every tracked query incrementally, notify bound servers,
        and record mutation spans/gauges on the flight recorder."""
        n = self.g.n
        ins = self._pending_ins
        dels = self._pending_del
        self._pending_ins, self._pending_del = [], []
        isrc = (np.concatenate([x[0] for x in ins]) if ins
                else np.zeros(0, np.int32))
        idst = (np.concatenate([x[1] for x in ins]) if ins
                else np.zeros(0, np.int32))
        iw = (np.concatenate([x[2] for x in ins]) if ins
              else np.zeros(0, np.float32))
        ksrc = (np.concatenate([x[0] for x in dels]) if dels
                else np.zeros(0, np.int32))
        kdst = (np.concatenate([x[1] for x in dels]) if dels
                else np.zeros(0, np.int32))

        old_g = self.g
        old_vals = {k: st["vals"].copy() for k, st in self.tracked.items()}

        # resolve deletions against the current edge list (all copies)
        kill_key = np.unique(ksrc.astype(np.int64) * n + kdst)
        edge_key = old_g.src.astype(np.int64) * n + old_g.dst
        keep = ~np.isin(edge_key, kill_key)
        dsrc = old_g.src[~keep]
        ddst = old_g.dst[~keep]
        dw = old_g.weight[~keep]

        self.g = COOGraph(
            n, np.concatenate([old_g.src[keep], isrc]),
            np.concatenate([old_g.dst[keep], idst]),
            np.concatenate([old_g.weight[keep], iw]))
        msrc = np.unique(np.concatenate([isrc, dsrc])).astype(np.int64)
        mdst = np.unique(np.concatenate([idst, ddst])).astype(np.int64)

        self._commits += 1
        rec = obs.get_recorder()
        span = (rec.tracer.span("mutation", track="stream",
                                batch=self._commits)
                if rec is not None else None)

        # ---- splice every live view ----
        splices: dict[str, SpliceInfo] = {}
        old_parts = {name: v.part for name, v in self._views.items()}
        for name, v in self._views.items():
            if name == "base":
                gv, vs, vd = self.g, msrc, mdst
            elif name == "pr":
                gv, vs, vd = _pr_weights(self.g), msrc, mdst
            elif name == "sym":
                gv, sym_ins, sym_del = self._sym_apply(
                    isrc, idst, dsrc, ddst)
                ends = np.unique(np.concatenate(
                    [sym_ins[0], sym_ins[1], sym_del[0], sym_del[1]]
                )).astype(np.int64)
                vs = vd = ends
                self._sym_ins, self._sym_del = sym_ins, sym_del
            v.part, splices[name] = splice_partition(
                v.part, gv, self.pcfg, vs, vd)
            v.graph = gv

        # ---- incremental maintenance of tracked queries ----
        maint: dict[tuple, MaintStats] = {}
        min_keys = [k for k in self.tracked if k[0] in ("bfs", "sssp")]
        group = self.runner in ("lanes", "sharded") and len(min_keys) > 0
        if group:
            self._maintain_min_group(min_keys, old_vals, old_parts,
                                     isrc, idst, dsrc, ddst, dw, maint)
        else:
            for key in min_keys:
                self._maintain_min(key, old_vals[key], old_parts,
                                   isrc, idst, dsrc, ddst, dw, maint)
        if ("cc", None) in self.tracked:
            self._maintain_cc(old_vals[("cc", None)], maint)
        if ("pagerank", None) in self.tracked:
            self._maintain_pr(old_vals[("pagerank", None)], old_g,
                              msrc, maint)

        info = CommitInfo(
            inserted=int(isrc.size), deleted=int(dsrc.size),
            mutated_src=msrc, mutated_dst=mdst, splices=splices,
            maint=maint,
            replicas_added=splices["base"].replicas_added)

        # ---- server + flight-recorder wiring ----
        seeds = np.unique(isrc).astype(np.int64)
        roots = np.unique(np.concatenate([msrc, mdst]))
        for server, mode in self._servers:
            server.apply_mutation(
                self.view("base").part, insert_seeds=seeds,
                has_deletes=dsrc.size > 0,
                affected_roots=None if mode == "all" else roots)
        if rec is not None:
            reg = rec.registry
            reg.counter("stream_mutations_total",
                        "edges inserted/deleted by commit()").labels(
                            kind="insert").inc(int(isrc.size))
            reg.counter("stream_mutations_total").labels(
                kind="delete").inc(int(dsrc.size))
            reg.counter("stream_replicas_added_total",
                        "adaptive rhizome splits").inc(
                            info.replicas_added)
            reg.gauge("stream_affected_vertices",
                      "mutation endpoints in the last batch").set(
                          int(roots.size))
            for name, sp in splices.items():
                reg.gauge("stream_shards_rebuilt",
                          "shard rows regenerated by the last splice"
                          ).labels(view=name).set(sp.shards_rebuilt)
            span.end(inserts=int(isrc.size), deletes=int(dsrc.size),
                     affected=int(roots.size),
                     shards_rebuilt=splices["base"].shards_rebuilt,
                     replicas_added=info.replicas_added)
        return info

    # ---------------------------------------------------- sym maintenance
    def _sym_apply(self, isrc, idst, dsrc, ddst):
        """Update the sym view's COO in append/delete order (support
        counting over directed pairs) and return its ins/del lists."""
        n = self.g.n
        gv = self._views["sym"].graph
        add_s, add_d = [], []
        for u, v in zip(isrc.tolist(), idst.tolist()):
            for a, b in ((u, v), (v, u)):
                k = a * n + b
                c = self._mult.get(k, 0)
                if c == 0:
                    add_s.append(a)
                    add_d.append(b)
                self._mult[k] = c + 1
        dead = set()
        # deletions remove ALL copies of each base pair; support drops by
        # the multiplicity of removed copies
        mult_removed: dict[tuple, int] = {}
        for u, v in zip(dsrc.tolist(), ddst.tolist()):
            mult_removed[(u, v)] = mult_removed.get((u, v), 0) + 1
        for (u, v), m in mult_removed.items():
            for a, b in ((u, v), (v, u)):
                k = a * n + b
                c = self._mult.get(k, 0) - m
                if c <= 0:
                    self._mult.pop(k, None)
                    dead.add(k)
                else:
                    self._mult[k] = c
        if dead:
            key = gv.src.astype(np.int64) * n + gv.dst
            keep = ~np.isin(key, np.fromiter(dead, np.int64))
            del_s = gv.src[~keep]
            del_d = gv.dst[~keep]
            gs, gd = gv.src[keep], gv.dst[keep]
        else:
            del_s = del_d = np.zeros(0, np.int32)
            gs, gd = gv.src, gv.dst
        new_s = np.concatenate([gs, np.asarray(add_s, np.int32)])
        new_d = np.concatenate([gd, np.asarray(add_d, np.int32)])
        gv = COOGraph(n, new_s, new_d, np.zeros(new_s.size, np.float32))
        return gv, (np.asarray(add_s, np.int32),
                    np.asarray(add_d, np.int32)), (del_s, del_d)

    # ------------------------------------------------------- min runners
    def _run_min_single(self, view: _View, init, chg, unitw: int):
        """One min query through the configured runner; returns
        ((n,) per-vertex values, (rounds, messages, work))."""
        from repro.query import lanes
        part = view.part
        if self.runner == "stacked":
            sem = actions.BFS if unitw else actions.SSSP
            val, st = engine.run_stacked(sem, part, init, self.cfg,
                                         init_changed=chg)
            stats = (int(st.iterations), int(st.messages),
                     int(st.work_actions))
        elif self.runner == "lanes":
            val, st = lanes.run_stacked_lanes(
                part, np.asarray(init, np.float32)[..., None],
                lane_unitw=np.asarray([unitw], np.int32), cfg=self.cfg,
                init_changed=np.asarray(chg, bool)[..., None])
            val = np.asarray(val)[..., 0]
            stats = (int(np.asarray(st.rounds)[0]),
                     int(np.asarray(st.messages)[0]),
                     int(np.asarray(st.work_actions)[0]))
        else:
            val, st = lanes.run_sharded_lanes(
                part, np.asarray(init, np.float32)[..., None],
                lane_unitw=np.asarray([unitw], np.int32),
                mesh=self.mesh, axis_names=self.axis_names, cfg=self.cfg,
                init_changed=np.asarray(chg, bool)[..., None])
            val = np.asarray(val)[..., 0]
            stats = (int(np.asarray(st.rounds)[0]),
                     int(np.asarray(st.messages)[0]),
                     int(np.asarray(st.work_actions)[0]))
        return engine.vertex_values(part, val), stats

    def _min_warm_state(self, key, vals, isrc, idst, dsrc, ddst, dw):
        """init/changed per-vertex state for one min query after the
        batch: support-invalidate deletes, seed insert sources + the
        valid boundary of the invalidated region."""
        app, root = key
        unit = app == "bfs"
        pinned = self._root_flag(root)
        invalid = (invalidate_unsupported(
            self.g, vals, dsrc, ddst, dw, pinned, unit_w=unit)
            if dsrc.size else np.zeros(self.g.n, bool))
        init_vv = np.asarray(vals, np.float32).copy()
        init_vv[invalid] = np.inf
        finite = np.isfinite(init_vv)
        chg_v = np.zeros(self.g.n, bool)
        if isrc.size:
            s = np.unique(isrc)
            chg_v[s[finite[s]]] = True
        if invalid.any():
            b = finite[self.g.src] & invalid[self.g.dst]
            chg_v[np.unique(self.g.src[b])] = True
        return init_vv, chg_v, int(invalid.sum())

    def _maintain_min(self, key, vals, old_parts, isrc, idst,
                      dsrc, ddst, dw, maint):
        app, root = key
        view = self.view("base")
        init_vv, chg_v, n_inv = self._min_warm_state(
            key, vals, isrc, idst, dsrc, ddst, dw)
        new_vals, (r, m, w) = self._run_min_single(
            view, scatter_vertex_values(view.part, init_vv),
            scatter_vertex_flags(view.part, chg_v),
            unitw=1 if app == "bfs" else 0)
        self.tracked[key]["vals"] = new_vals
        maint[key] = MaintStats(app=app, mode="warm", rounds=r,
                                messages=m, work=w,
                                seeds=int(chg_v.sum()), invalidated=n_inv)

    def _maintain_min_group(self, keys, old_vals, old_parts, isrc, idst,
                            dsrc, ddst, dw, maint):
        """All tracked base-view min queries in ONE laned fixpoint
        (Q = len(keys)); per-lane stats feed per-key MaintStats."""
        from repro.query import lanes
        view = self.view("base")
        part = view.part
        cols_init, cols_chg, unitw, inv_counts = [], [], [], []
        for key in keys:
            init_vv, chg_v, n_inv = self._min_warm_state(
                key, old_vals[key], isrc, idst, dsrc, ddst, dw)
            cols_init.append(scatter_vertex_values(part, init_vv))
            cols_chg.append(scatter_vertex_flags(part, chg_v))
            unitw.append(1 if key[0] == "bfs" else 0)
            inv_counts.append(n_inv)
        init = np.stack(cols_init, axis=-1)
        chg = np.stack(cols_chg, axis=-1)
        if self.runner == "lanes":
            val, st = lanes.run_stacked_lanes(
                part, init, lane_unitw=np.asarray(unitw, np.int32),
                cfg=self.cfg, init_changed=chg)
        else:
            val, st = lanes.run_sharded_lanes(
                part, init, lane_unitw=np.asarray(unitw, np.int32),
                mesh=self.mesh, axis_names=self.axis_names,
                cfg=self.cfg, init_changed=chg)
        val = np.asarray(val)
        for q, key in enumerate(keys):
            self.tracked[key]["vals"] = engine.vertex_values(
                part, val[..., q])
            maint[key] = MaintStats(
                app=key[0], mode="warm",
                rounds=int(np.asarray(st.rounds)[q]),
                messages=int(np.asarray(st.messages)[q]),
                work=int(np.asarray(st.work_actions)[q]),
                seeds=int(cols_chg[q].sum()), invalidated=inv_counts[q])

    def _maintain_cc(self, vals, maint):
        """CC after a batch: merged components re-flood from the sym
        inserts' endpoints (monotone); deleted sym edges invalidate the
        touched components wholesale (their min-label support is cyclic,
        so per-vertex invalidation does not apply) and each member
        reseeds with its own id."""
        view = self.view("sym")
        sym_ins, sym_del = self._sym_ins, self._sym_del
        n = self.g.n
        invalid = np.zeros(n, bool)
        if sym_del[0].size:
            affected = np.unique(np.asarray(
                vals, np.float32)[np.concatenate(
                    [sym_del[0], sym_del[1]]).astype(np.int64)])
            invalid = np.isin(np.asarray(vals, np.float32), affected)
        init_vv = np.asarray(vals, np.float32).copy()
        init_vv[invalid] = np.arange(n, dtype=np.float32)[invalid]
        chg_v = invalid.copy()
        if sym_ins[0].size:
            chg_v[np.unique(sym_ins[0]).astype(np.int64)] = True
        new_vals, (r, m, w) = self._run_min_single(
            view, scatter_vertex_values(view.part, init_vv),
            scatter_vertex_flags(view.part, chg_v), unitw=0)
        self.tracked[("cc", None)]["vals"] = new_vals
        maint[("cc", None)] = MaintStats(
            app="cc", mode="warm", rounds=r, messages=m, work=w,
            seeds=int(chg_v.sum()), invalidated=int(invalid.sum()))

    # -------------------------------------------------------- pagerank
    def _run_pr(self, view, damping, tol, max_rounds, init_rank,
                init_delta):
        if self.runner == "sharded":
            rank, st = engine.run_pagerank_delta_sharded(
                view.part, damping=damping, tol=tol, mesh=self.mesh,
                axis_names=self.axis_names, cfg=self.cfg,
                max_rounds=max_rounds, init_rank=init_rank,
                init_delta=init_delta)
        else:
            rank, st = engine.run_pagerank_delta(
                view.part, damping=damping, tol=tol, cfg=self.cfg,
                max_rounds=max_rounds, init_rank=init_rank,
                init_delta=init_delta)
        return rank, (int(st.iterations), int(st.messages),
                      int(st.work_actions))

    def _maintain_pr(self, old_ranks, old_g, msrc, maint):
        """Delta-PR maintenance: migrate old ranks, seed the residual
        table with the exact correction ``d·(A'-A)ᵀ p`` over the
        mutated sources' old/new out-edges (weights fold in 1/out_deg,
        so every out-edge of a mutated source contributes)."""
        st = self.tracked[("pagerank", None)]
        d, tol, mr = st["damping"], st["tol"], st["max_rounds"]
        p = np.asarray(old_ranks, np.float32)
        n = self.g.n
        c = np.zeros(n, np.float32)
        msk = np.zeros(n, bool)
        msk[msrc] = True
        w_old = (1.0 / np.maximum(old_g.out_degrees(), 1)).astype(
            np.float32)
        sel = msk[old_g.src]
        np.add.at(c, old_g.dst[sel],
                  (-d * p[old_g.src[sel]] * w_old[old_g.src[sel]]
                   ).astype(np.float32))
        w_new = (1.0 / np.maximum(self.g.out_degrees(), 1)).astype(
            np.float32)
        sel = msk[self.g.src]
        np.add.at(c, self.g.dst[sel],
                  (d * p[self.g.src[sel]] * w_new[self.g.src[sel]]
                   ).astype(np.float32))
        view = self.view("pr")
        # the round rule is rank += FUTURE deltas, so the zeroth-order
        # correction folds into the rank seed (cold: rank0 = delta0 = base)
        init_rank = scatter_vertex_values(view.part, p + c, fill=0.0)
        init_delta = scatter_vertex_values(view.part, c, fill=0.0)
        rank_t, (r, m, w) = self._run_pr(view, d, tol, mr,
                                         init_rank, init_delta)
        self.tracked[("pagerank", None)]["vals"] = engine.vertex_values(
            view.part, rank_t)
        maint[("pagerank", None)] = MaintStats(
            app="pagerank", mode="warm", rounds=r, messages=m, work=w,
            seeds=int((np.abs(c) > tol).sum()), invalidated=0)

    # ---------------------------------------------- checkpoint / WAL
    def snapshot(self) -> tuple[dict, dict]:
        """(array tree, JSON meta) of the full streaming state: the
        committed graph, every tracked query's values, and the
        **write-ahead log** — the buffered-but-uncommitted mutation
        batches.  A crash mid-commit restores to the pre-commit
        boundary with the batch still in the WAL; replaying it through
        the normal ``commit()`` path reproduces the interrupted commit
        EXACTLY (same splice, same warm-start maintenance, bit-identical
        min values) — commit is all-or-nothing."""
        tree = {
            "graph": {"src": np.asarray(self.g.src),
                      "dst": np.asarray(self.g.dst),
                      "weight": np.asarray(self.g.weight)},
            "tracked": {_skey(k): np.asarray(st["vals"])
                        for k, st in self.tracked.items()},
            "wal_ins": {str(i): {"src": s, "dst": d, "w": w}
                        for i, (s, d, w)
                        in enumerate(self._pending_ins)},
            "wal_del": {str(i): {"src": s, "dst": d}
                        for i, (s, d) in enumerate(self._pending_del)},
        }
        meta = {
            "n": int(self.g.n),
            "pcfg": _pcfg_to_dict(self.pcfg),
            "commits": self._commits,
            "auto_refreshes": self.auto_refreshes,
            "staleness_slo": self.staleness_slo,
            "staleness_metric": self._staleness_metric,
            "tracked": {_skey(k): {kk: vv for kk, vv in st.items()
                                   if kk != "vals"}
                        for k, st in self.tracked.items()},
            "n_wal_ins": len(self._pending_ins),
            "n_wal_del": len(self._pending_del),
        }
        return tree, meta

    def save_checkpoint(self, manager, blocking: bool = False) -> int:
        """Snapshot to a ``CheckpointManager`` at the current commit
        count (async by default).  Returns the checkpoint step."""
        tree, meta = self.snapshot()
        manager.save(self._commits, tree, blocking=blocking, meta=meta)
        return self._commits

    @classmethod
    def restore(cls, manager, *, step: int | None = None,
                cfg: engine.EngineConfig = engine.EngineConfig(),
                runner: str = "stacked", mesh=None,
                axis_names=("data", "model")) -> "StreamingGraph":
        """Rebuild a ``StreamingGraph`` from a checkpoint: committed
        graph and partition (deterministic ``build_partition``), tracked
        values (no cold recompute), and the WAL of uncommitted batches —
        call ``commit()`` to replay a batch interrupted mid-commit."""
        if step is None:
            step = manager.latest_step()
        if step is None:
            raise FileNotFoundError("no checkpoint to restore from")
        meta = manager.restore_meta(step)
        like = {
            "graph": {"src": 0, "dst": 0, "weight": 0},
            "tracked": {k: 0 for k in meta["tracked"]},
            "wal_ins": {str(i): {"src": 0, "dst": 0, "w": 0}
                        for i in range(meta["n_wal_ins"])},
            "wal_del": {str(i): {"src": 0, "dst": 0}
                        for i in range(meta["n_wal_del"])},
        }
        tree = manager.restore(step, like)
        g = COOGraph(meta["n"],
                     np.asarray(tree["graph"]["src"], np.int32),
                     np.asarray(tree["graph"]["dst"], np.int32),
                     np.asarray(tree["graph"]["weight"], np.float32))
        pcfg = _pcfg_from_dict(meta["pcfg"])
        sg = cls(g, pcfg, cfg=cfg, runner=runner, mesh=mesh,
                 axis_names=axis_names,
                 staleness_slo=meta["staleness_slo"],
                 staleness_metric=meta["staleness_metric"])
        sg._commits = meta["commits"]
        sg.auto_refreshes = meta["auto_refreshes"]
        for skey, params in meta["tracked"].items():
            entry = dict(params)
            entry["vals"] = np.asarray(tree["tracked"][skey])
            sg.tracked[_unskey(skey)] = entry
        sg._pending_ins = [
            (np.asarray(b["src"], np.int32),
             np.asarray(b["dst"], np.int32),
             np.asarray(b["w"], np.float32))
            for b in (tree["wal_ins"][str(i)]
                      for i in range(meta["n_wal_ins"]))]
        sg._pending_del = [
            (np.asarray(b["src"], np.int32),
             np.asarray(b["dst"], np.int32))
            for b in (tree["wal_del"][str(i)]
                      for i in range(meta["n_wal_del"]))]
        return sg


def _skey(key: tuple) -> str:
    app, root = key
    return f"{app}:{'' if root is None else int(root)}"


def _unskey(s: str) -> tuple:
    app, _, root = s.partition(":")
    return (app, int(root) if root else None)


def _pcfg_to_dict(pcfg: PartitionConfig) -> dict:
    d = dataclasses.asdict(pcfg)
    for k, v in d.items():
        if isinstance(v, tuple):
            d[k] = list(v)
    return d


def _pcfg_from_dict(d: dict) -> PartitionConfig:
    fields = {f.name for f in dataclasses.fields(PartitionConfig)}
    kw = {k: (tuple(v) if isinstance(v, list) else v)
          for k, v in d.items() if k in fields}
    return PartitionConfig(**kw)
