"""Vectorized cycle-level AM-CCA simulator (paper §6.1 methodology).

Models a chip of X×Y compute cells on a Mesh or Torus-Mesh NoC:

* one message traverses one hop per cycle (256-bit channels, single-flit
  messages), XY dimension-order routing, one message per (CC, direction)
  per cycle — extra claimants stall and are counted as *contention*;
* per-CC injection of one staged message per cycle (a CC either computes
  or stages a message);
* **throttling** (Eq. 2): a CC that saw contention on its links halts
  injection for ``T = hypot(dim_x, dim_y)`` cycles (halved on torus);
* **dual queues / lazy diffuse**: staged diffusions carry their own
  predicate and are re-checked at injection time — stale diffusions are
  pruned (Fig 6);
* rhizome-link sibling broadcasts and root→ghost relay latency are
  modeled as messages / injection delays.

Supports min-semiring applications (BFS, SSSP). Small-scale by design —
the analytic model (`repro.core.costmodel`) covers large runs.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partition import Partition

# --- energy constants (7nm, paper §6.1 energy cost model; pJ) -------------
E_HOP_PJ = 15.0        # one 256-bit flit across one NoC hop (mesh)
TORUS_HOP_FACTOR = 1.5 # torus consumes 50% more NoC resources [22]
E_ACTION_PJ = 25.0     # predicate + work: few integer ops + SRAM access
E_SRAM_PJ = 6.0        # 64-bit SRAM access [31]
E_LEAK_PJ_PER_CC_CYCLE = 0.05


@dataclasses.dataclass
class SimResult:
    cycles: int
    messages_injected: int
    hops_total: int
    actions_executed: int       # messages delivered (predicate evaluated)
    work_actions: int           # predicate fired -> work performed
    diffusions_staged: int
    diffusions_pruned: int      # pruned at injection time (lazy diffuse)
    contention_stall_cycles: int
    link_contention: np.ndarray  # (S, 4) stalls per (cc, direction)
    max_inflight: int
    energy_pj: float
    values: np.ndarray           # final per-slot values (S*R_max,)


def _xy_next_hop(cx, cy, dxs, dys, X, Y, torus):
    """XY routing: move in x first, then y. Returns (nx, ny, direction).
    Directions: 0=E,1=W,2=N,3=S."""
    gox = cx != dxs
    if torus:
        right = ((dxs - cx) % X) <= ((cx - dxs) % X)
        up = ((dys - cy) % Y) <= ((cy - dys) % Y)
    else:
        right = dxs > cx
        up = dys > cy
    stepx = np.where(right, 1, -1)
    stepy = np.where(up, 1, -1)
    nx = np.where(gox, (cx + stepx) % X if torus else cx + stepx, cx)
    ny = np.where(gox, cy, (cy + stepy) % Y if torus else cy + stepy)
    direction = np.where(gox, np.where(right, 0, 1), np.where(up, 2, 3))
    return nx, ny, direction


class AmccaSim:
    def __init__(self, part: Partition, torus: bool = True, seed: int = 0):
        self.part = part
        self.X, self.Y = part.cfg.dims()
        self.torus = torus
        self.S = part.S
        self.R_max = part.R_max
        self.rng = np.random.default_rng(seed)
        # Eq. 2 throttling period
        t = float(np.hypot(self.X, self.Y))
        self.throttle_T = int(np.ceil(t / 2 if torus else t))

        # flatten edges: for each vertex, its out-edges with owner cc + dst
        mask = part.edge_mask.reshape(-1)
        self.e_src = part.edge_src_vertex.reshape(-1)[mask]
        self.e_dst_flat = part.edge_dst_flat.reshape(-1)[mask]
        self.e_w = part.edge_w.reshape(-1)[mask]
        self.e_owner = part.edge_owner_cc.reshape(-1)[mask]
        order = np.argsort(self.e_src, kind="stable")
        self.e_src = self.e_src[order]
        self.e_dst_flat = self.e_dst_flat[order]
        self.e_w = self.e_w[order]
        self.e_owner = self.e_owner[order]
        self.v_ptr = np.zeros(part.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(self.e_src, minlength=part.n), out=self.v_ptr[1:])

    def _cc_xy(self, cc):
        return cc % self.X, cc // self.X

    def _dist(self, a, b):
        ax, ay = self._cc_xy(a)
        bx, by = self._cc_xy(b)
        dx = np.abs(ax - bx)
        dy = np.abs(ay - by)
        if self.torus:
            dx = np.minimum(dx, self.X - dx)
            dy = np.minimum(dy, self.Y - dy)
        return dx + dy

    def run_min_app(self, sources: dict[int, float], weights: bool,
                    max_cycles: int = 200_000, throttle: bool = True) -> SimResult:
        """BFS (weights=False: msg=val+1) or SSSP (weights=True: msg=val+w)."""
        part = self.part
        S, R_max = self.S, self.R_max
        val = np.full(S * R_max, np.inf, dtype=np.float64)
        best_diffused = np.full(part.n, np.inf)  # diffusion predicate state

        # staged outbox entries (lazy diffuse queue, one per message)
        ob_cc = np.zeros(0, np.int64)      # owner cc staging the message
        ob_dst = np.zeros(0, np.int64)     # dst flat slot
        ob_val = np.zeros(0, np.float64)   # payload
        ob_vertex = np.zeros(0, np.int64)  # diffusing vertex (for pruning)
        ob_stamp = np.zeros(0, np.float64) # level/dist at staging time
        ob_ready = np.zeros(0, np.int64)   # cycle at which injectable

        # in-flight messages
        fl_x = np.zeros(0, np.int64)
        fl_y = np.zeros(0, np.int64)
        fl_dst = np.zeros(0, np.int64)
        fl_val = np.zeros(0, np.float64)

        stats = dict(inj=0, hops=0, act=0, work=0, staged=0, pruned=0,
                     stall=0, maxfl=0)
        link_cont = np.zeros((S, 4), dtype=np.int64)
        throttle_until = np.zeros(S, dtype=np.int64)

        def stage_diffusion(vertices, vals, now):
            nonlocal ob_cc, ob_dst, ob_val, ob_vertex, ob_stamp, ob_ready
            for v, x in zip(vertices, vals):
                lo, hi = self.v_ptr[v], self.v_ptr[v + 1]
                if hi == lo:
                    continue
                owners = self.e_owner[lo:hi]
                root_cc = int(part.root_flat[v]) // R_max
                relay = self._dist(np.full(owners.shape, root_cc), owners)
                msg = x + (self.e_w[lo:hi] if weights
                           else np.ones(int(hi - lo)))
                ob_cc = np.concatenate([ob_cc, owners])
                ob_dst = np.concatenate([ob_dst, self.e_dst_flat[lo:hi]])
                ob_val = np.concatenate([ob_val, msg])
                ob_vertex = np.concatenate([ob_vertex, np.full(owners.shape, v)])
                ob_stamp = np.concatenate([ob_stamp, np.full(owners.shape, x)])
                ob_ready = np.concatenate([ob_ready, now + relay])
                stats["staged"] += int(hi - lo)

        # germinate: sources' root slots perform work and diffuse
        for v, x in sources.items():
            for k in range(part.cfg.rpvo_max):
                s0, sl0 = divmod(int(part.root_flat[v]), R_max)
                if part.sibling_mask[s0, sl0, k]:
                    f = int(part.sibling_flat[s0, sl0, k])
                    val[f] = x
            best_diffused[v] = x
            stage_diffusion([v], [x], now=0)

        cycle = 0
        contended_prev = np.zeros(S, dtype=bool)
        while cycle < max_cycles and (fl_x.size or ob_cc.size):
            cycle += 1
            # ---- injection: one staged message per CC per cycle ----------
            if ob_cc.size:
                # lazy-diffuse pruning: drop stale diffusions (Listing 6)
                live = ob_stamp <= best_diffused[ob_vertex] + 1e-12
                stats["pruned"] += int((~live).sum())
                ob_cc, ob_dst, ob_val = ob_cc[live], ob_dst[live], ob_val[live]
                ob_vertex, ob_stamp, ob_ready = (
                    ob_vertex[live], ob_stamp[live], ob_ready[live])
            if ob_cc.size:
                ready = ob_ready <= cycle
                if throttle:
                    ready &= throttle_until[ob_cc] <= cycle
                idx = np.nonzero(ready)[0]
                if idx.size:
                    # first ready entry per CC wins this cycle
                    _, first = np.unique(ob_cc[idx], return_index=True)
                    take = idx[first]
                    # messages to slots on the same CC are delivered locally
                    fl_x = np.concatenate([fl_x, ob_cc[take] % self.X])
                    fl_y = np.concatenate([fl_y, ob_cc[take] // self.X])
                    fl_dst = np.concatenate([fl_dst, ob_dst[take]])
                    fl_val = np.concatenate([fl_val, ob_val[take]])
                    stats["inj"] += int(take.size)
                    keep = np.ones(ob_cc.size, dtype=bool)
                    keep[take] = False
                    ob_cc, ob_dst, ob_val = ob_cc[keep], ob_dst[keep], ob_val[keep]
                    ob_vertex, ob_stamp, ob_ready = (
                        ob_vertex[keep], ob_stamp[keep], ob_ready[keep])

            stats["maxfl"] = max(stats["maxfl"], int(fl_x.size))

            # ---- network hop: one message per (cc, direction) ------------
            if fl_x.size:
                dcc = fl_dst // R_max
                dxs, dys = dcc % self.X, dcc // self.X
                at_dst = (fl_x == dxs) & (fl_y == dys)
                move = ~at_dst
                nx, ny, ddir = _xy_next_hop(fl_x, fl_y, dxs, dys,
                                            self.X, self.Y, self.torus)
                cur_cc = fl_y * self.X + fl_x
                key = cur_cc * 4 + ddir
                win = np.zeros(fl_x.size, dtype=bool)
                mi = np.nonzero(move)[0]
                if mi.size:
                    _, first = np.unique(key[mi], return_index=True)
                    win[mi[first]] = True
                    stalled = move & ~win
                    stats["stall"] += int(stalled.sum())
                    np.add.at(link_cont, (cur_cc[stalled], ddir[stalled]), 1)
                    # mark CCs with contended links for throttling
                    if throttle:
                        cs = np.unique(cur_cc[stalled])
                        throttle_until[cs] = cycle + self.throttle_T
                fl_x = np.where(win, nx, fl_x)
                fl_y = np.where(win, ny, fl_y)
                stats["hops"] += int(win.sum())

                # ---- arrivals: predicate + work + diffuse -----------------
                arr = at_dst
                if arr.any():
                    slots = fl_dst[arr]
                    vals = fl_val[arr]
                    stats["act"] += int(arr.sum())
                    old = val.copy()
                    np.minimum.at(val, slots, vals)
                    improved_slots = np.unique(slots[vals < old[slots]])
                    improved_slots = improved_slots[
                        val[improved_slots] < old[improved_slots]]
                    stats["work"] += int(improved_slots.size)
                    if improved_slots.size:
                        sh = improved_slots // R_max
                        sl = improved_slots % R_max
                        verts = part.slot_vertex[sh, sl]
                        # rhizome-link sibling broadcast (collapse bcast)
                        sib = part.sibling_flat[sh, sl]
                        sibm = part.sibling_mask[sh, sl]
                        bvals = np.repeat(val[improved_slots],
                                          sib.shape[1])[sibm.reshape(-1)]
                        bdst = sib.reshape(-1)[sibm.reshape(-1)]
                        self_m = bdst != np.repeat(improved_slots,
                                                   sib.shape[1])[sibm.reshape(-1)]
                        owners = improved_slots // R_max
                        bcc = np.repeat(owners, sib.shape[1])[sibm.reshape(-1)]
                        ob_cc = np.concatenate([ob_cc, bcc[self_m]])
                        ob_dst = np.concatenate([ob_dst, bdst[self_m]])
                        ob_val = np.concatenate([ob_val, bvals[self_m]])
                        ob_vertex = np.concatenate(
                            [ob_vertex,
                             np.repeat(verts, sib.shape[1])[sibm.reshape(-1)][self_m]])
                        ob_stamp = np.concatenate([ob_stamp, bvals[self_m]])
                        ob_ready = np.concatenate(
                            [ob_ready, np.full(self_m.sum(), cycle)])
                        # diffuse along out-edges, gated by best_diffused
                        newv = val[improved_slots]
                        gate = newv < best_diffused[verts] - 1e-12
                        dverts = verts[gate]
                        dvals = newv[gate]
                        best_diffused[dverts] = np.minimum(
                            best_diffused[dverts], dvals)
                        stage_diffusion(dverts, dvals, now=cycle)
                    fl_x, fl_y = fl_x[~arr], fl_y[~arr]
                    fl_dst, fl_val = fl_dst[~arr], fl_val[~arr]

            contended_prev = link_cont.sum(axis=1) > 0

        hop_e = E_HOP_PJ * (TORUS_HOP_FACTOR if self.torus else 1.0)
        energy = (stats["hops"] * hop_e
                  + stats["act"] * (E_ACTION_PJ + 2 * E_SRAM_PJ)
                  + cycle * self.S * E_LEAK_PJ_PER_CC_CYCLE)
        return SimResult(
            cycles=cycle, messages_injected=stats["inj"],
            hops_total=stats["hops"], actions_executed=stats["act"],
            work_actions=stats["work"], diffusions_staged=stats["staged"],
            diffusions_pruned=stats["pruned"],
            contention_stall_cycles=stats["stall"],
            link_contention=link_cont, max_inflight=stats["maxfl"],
            energy_pj=float(energy), values=val,
        )
