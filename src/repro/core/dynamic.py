"""Dynamic graph mutations + incremental recompute (paper §7 future work).

"Since the data structure is flexible and can grow and shrink a logical
future direction is to design and implement dynamic graph algorithms...
an action containing new edges to be inserted... When the action finishes
modifying the graph structure it can invoke a computation, such as BFS,
that recomputes from there without starting from scratch."

Implemented on the RPVO/Rhizome layout:

* ``insert_edges`` — structural mutation; the new in-edges follow Eq. 1's
  replica-cycling rule (the partition is rebuilt with the same config —
  pointer-level in-place splicing is the AM-CCA form; on TPU the static
  arrays are regenerated, value state migrates).
* ``bfs_incremental_insert`` — monotone warm-start: previous levels are a
  valid upper bound after inserts, so the engine restarts with the old
  values and ``changed`` seeded ONLY at the insert sources; rounds and
  messages scale with the affected region, not the graph.
* ``delete_edges`` — deletions can *raise* monotone values, which a
  min-fixpoint cannot do; the shipped strategy is delete + full recompute
  (affected-subtree invalidation is future work, as in the paper).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import actions, engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph


@dataclasses.dataclass
class DynamicGraph:
    """A mutable graph + its partition + last computed per-app state."""

    g: COOGraph
    part: Partition
    values: dict

    @classmethod
    def build(cls, g: COOGraph, cfg: PartitionConfig) -> "DynamicGraph":
        return cls(g=g, part=build_partition(g, cfg), values={})

    # ---------------------------------------------------------------- edits
    def insert_edges(self, src, dst, weight=None) -> np.ndarray:
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        w = (np.ones(src.shape, np.float32) if weight is None
             else np.asarray(weight, np.float32))
        self._migrate_from = self.part
        self.g = COOGraph(
            self.g.n,
            np.concatenate([self.g.src, src]),
            np.concatenate([self.g.dst, dst]),
            np.concatenate([self.g.weight, w]),
        )
        self.part = build_partition(self.g, self.part.cfg)
        return np.unique(src)

    def delete_edges(self, src, dst) -> np.ndarray:
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        # vectorized membership: (src, dst) pairs keyed as src*n + dst
        kill_key = np.unique(src * self.g.n + dst)
        edge_key = self.g.src.astype(np.int64) * self.g.n \
            + self.g.dst.astype(np.int64)
        keep = ~np.isin(edge_key, kill_key)
        self.g = COOGraph(self.g.n, self.g.src[keep], self.g.dst[keep],
                          self.g.weight[keep])
        self._migrate_from = self.part
        self.part = build_partition(self.g, self.part.cfg)
        # deletions can RAISE monotone values: every cached monotone app
        # is stale, not just BFS
        for app in ("bfs", "sssp", "cc"):
            self.values.pop(app, None)
        return np.unique(dst).astype(np.int32)

    # ---------------------------------------------------- incremental apps
    def bfs_full(self, root: int, cfg=engine.EngineConfig()):
        init = engine.init_values(self.part, actions.BFS, {root: 0.0})
        val, stats = engine.run_stacked(actions.BFS, self.part, init, cfg)
        self.values["bfs"] = np.asarray(val)
        return self._levels(val), stats

    def bfs_incremental_insert(self, seeds: np.ndarray,
                               cfg=engine.EngineConfig()):
        """Warm-start BFS after ``insert_edges`` (monotone-safe)."""
        assert "bfs" in self.values, "run bfs_full first"
        old_part = self._migrate_from
        old_levels = self.values["bfs"].reshape(-1)[old_part.root_flat]
        part = self.part
        init = np.full((part.S, part.R_max), np.inf, np.float32)
        gl = init.reshape(-1)
        rows = part.root_flat // part.R_max
        cols = part.root_flat % part.R_max
        sibf = part.sibling_flat[rows, cols]          # (n, K)
        sibm = part.sibling_mask[rows, cols]
        vals = np.repeat(old_levels[:, None], sibf.shape[1], axis=1)
        gl[sibf[sibm]] = vals[sibm].astype(np.float32)

        chg = np.zeros((part.S, part.R_max), dtype=bool)
        gc = chg.reshape(-1)
        finite_seeds = [int(v) for v in seeds
                        if np.isfinite(old_levels[int(v)])]
        for v in finite_seeds:
            gc[int(part.root_flat[v])] = True
        val, stats = engine.run_stacked(actions.BFS, part, init, cfg,
                                        init_changed=chg)
        self.values["bfs"] = np.asarray(val)
        return self._levels(val), stats

    def _levels(self, val):
        lv = engine.vertex_values(self.part, val)
        out = np.where(np.isfinite(lv), lv, 0).astype(np.int64)
        out[~np.isfinite(lv)] = np.iinfo(np.int32).max
        return out
