"""Analytic AM-CCA cost model for large graphs (paper Figs 7–10).

Replays a reference execution trace (per-round active vertices from
``repro.graph.reference``) against a Partition and estimates, without
simulating individual cycles:

* per-round message counts (diffusions + rhizome sibling broadcasts +
  root→ghost relays),
* per-link loads under XY dimension-order routing (difference arrays over
  row/column link segments → Fig 9 contention histograms),
* time-to-solution ≈ Σ_rounds max(serialization bounds): peak link load,
  peak CC injection, peak CC arrival, mean distance,
* energy per the §6.1 model (hop/action/SRAM/leakage terms; torus hops
  cost 1.5×).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partition import Partition
from repro.core.amcca_sim import (
    E_ACTION_PJ, E_HOP_PJ, E_LEAK_PJ_PER_CC_CYCLE, E_SRAM_PJ, TORUS_HOP_FACTOR,
)


@dataclasses.dataclass
class CostResult:
    cycles: float
    energy_pj: float
    messages: int
    hops: int
    rounds: int
    max_link_load: int
    link_loads: np.ndarray      # (num_h_links + num_v_links,)
    cc_arrivals: np.ndarray     # (S,)
    per_round_cycles: list


class CostModel:
    def __init__(self, part: Partition, torus: bool = True):
        self.part = part
        self.X, self.Y = part.cfg.dims()
        self.torus = torus
        self.S = part.S
        R_max = part.R_max

        mask = part.edge_mask.reshape(-1)
        self.e_src = part.edge_src_vertex.reshape(-1)[mask]
        e_dst_flat = part.edge_dst_flat.reshape(-1)[mask]
        self.e_owner = part.edge_owner_cc.reshape(-1)[mask]
        self.e_dst_cc = e_dst_flat // R_max
        order = np.argsort(self.e_src, kind="stable")
        for name in ("e_src", "e_owner", "e_dst_cc"):
            setattr(self, name, getattr(self, name)[order])
        self.v_ptr = np.zeros(part.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(self.e_src, minlength=part.n), out=self.v_ptr[1:])

        # per-vertex rhizome fan (sibling shards) and root cc
        self.root_cc = part.root_flat // R_max
        sib_sh = np.where(part.sibling_mask, part.sibling_flat // R_max, -1)
        self.slot_sib_shards = sib_sh  # (S, R_max, K)

    # ----- geometry -------------------------------------------------------
    def _xy(self, cc):
        return cc % self.X, cc // self.X

    def dist(self, a, b):
        ax, ay = self._xy(a)
        bx, by = self._xy(b)
        dx, dy = np.abs(ax - bx), np.abs(ay - by)
        if self.torus:
            dx = np.minimum(dx, self.X - dx)
            dy = np.minimum(dy, self.Y - dy)
        return dx + dy

    def _accumulate_links(self, src_cc, dst_cc, h_diff, v_diff):
        """XY routing: horizontal segment in the source row, then vertical
        segment in the destination column — O(msgs) difference updates."""
        sx, sy = self._xy(src_cc)
        dx, dy = self._xy(dst_cc)
        X, Y = self.X, self.Y
        if self.torus:
            right = ((dx - sx) % X) <= ((sx - dx) % X)
            lo = np.where(right, sx, dx)
            hi = np.where(right, dx, sx)
            wrap = np.where(right, (dx - sx) % X, (sx - dx) % X) != (hi - lo)
        else:
            lo, hi = np.minimum(sx, dx), np.maximum(sx, dx)
            wrap = np.zeros(sx.shape, dtype=bool)
        # horizontal links in row sy: link i = (i -> i+1). non-wrap: [lo,hi)
        nw = ~wrap
        np.add.at(h_diff, (sy[nw], lo[nw]), 1)
        np.add.at(h_diff, (sy[nw], hi[nw]), -1)
        if wrap.any():  # wrap-around uses [hi, X) and [0, lo)
            np.add.at(h_diff, (sy[wrap], hi[wrap]), 1)
            np.add.at(h_diff, (sy[wrap], np.full(wrap.sum(), X)), -1)
            np.add.at(h_diff, (sy[wrap], np.zeros(wrap.sum(), np.int64)), 1)
            np.add.at(h_diff, (sy[wrap], lo[wrap]), -1)
        if self.torus:
            up = ((dy - sy) % Y) <= ((sy - dy) % Y)
            lo2 = np.where(up, sy, dy)
            hi2 = np.where(up, dy, sy)
            wrap2 = np.where(up, (dy - sy) % Y, (sy - dy) % Y) != (hi2 - lo2)
        else:
            lo2, hi2 = np.minimum(sy, dy), np.maximum(sy, dy)
            wrap2 = np.zeros(sy.shape, dtype=bool)
        nw2 = ~wrap2
        np.add.at(v_diff, (dx[nw2], lo2[nw2]), 1)
        np.add.at(v_diff, (dx[nw2], hi2[nw2]), -1)
        if wrap2.any():
            np.add.at(v_diff, (dx[wrap2], hi2[wrap2]), 1)
            np.add.at(v_diff, (dx[wrap2], np.full(wrap2.sum(), Y)), -1)
            np.add.at(v_diff, (dx[wrap2], np.zeros(wrap2.sum(), np.int64)), 1)
            np.add.at(v_diff, (dx[wrap2], lo2[wrap2]), -1)

    # ----- replay ---------------------------------------------------------
    def replay(self, trace: list[np.ndarray]) -> CostResult:
        part = self.part
        h_diff = np.zeros((self.Y, self.X + 1), dtype=np.int64)
        v_diff = np.zeros((self.X, self.Y + 1), dtype=np.int64)
        cc_arr = np.zeros(self.S, dtype=np.int64)
        msgs = hops = 0
        per_round = []
        actions = 0
        for f in trace:
            f = np.asarray(f, dtype=np.int64)
            if f.size == 0:
                continue
            # out-edge diffusions of the active vertices
            segs = [np.arange(self.v_ptr[v], self.v_ptr[v + 1]) for v in f]
            eidx = np.concatenate(segs) if segs else np.zeros(0, np.int64)
            src_cc = self.e_owner[eidx]
            dst_cc = self.e_dst_cc[eidx]
            # root -> ghost relay messages
            relay_src = self.root_cc[self.e_src[eidx]]
            relay_dst = src_cc
            # rhizome sibling broadcasts: root -> each sibling replica shard
            r_cc = self.root_cc[f]
            nrep = part.num_replicas[f]
            fan = np.maximum(nrep - 1, 0)
            bc_src = np.repeat(r_cc, fan)
            sib = self.slot_sib_shards[
                self.root_cc[f], part.root_flat[f] % part.R_max]
            bc_dst_all = []
            for i, v in enumerate(f):
                shards = sib[i][sib[i] >= 0]
                bc_dst_all.append(shards[shards != r_cc[i]][: fan[i]])
            bc_dst = (np.concatenate(bc_dst_all) if bc_dst_all
                      else np.zeros(0, np.int64))
            bc_src = bc_src[: bc_dst.size]

            all_src = np.concatenate([src_cc, relay_src, bc_src])
            all_dst = np.concatenate([dst_cc, relay_dst, bc_dst])
            d = self.dist(all_src, all_dst)
            msgs += int(all_src.size)
            hops += int(d.sum())
            actions += int(eidx.size)
            self._accumulate_links(all_src, all_dst, h_diff, v_diff)
            np.add.at(cc_arr, all_dst, 1)

            inj_load = np.bincount(all_src, minlength=self.S).max()
            arr_load = np.bincount(all_dst, minlength=self.S).max()
            hload = np.cumsum(h_diff[:, :-1], axis=1)
            # round time: serialization bound (one msg/link/cycle, one
            # injection/CC/cycle, one action/CC/cycle) + pipeline latency
            per_round.append(float(max(inj_load, arr_load)
                                   + (d.mean() if d.size else 0.0)))

        h_loads = np.cumsum(h_diff[:, :-1], axis=1).reshape(-1)
        v_loads = np.cumsum(v_diff[:, :-1], axis=1).reshape(-1)
        link_loads = np.concatenate([h_loads, v_loads])
        # congestion bound over the whole run (links are reused across
        # rounds; the max-link serialization applies globally)
        cycles = max(float(link_loads.max() if link_loads.size else 0),
                     sum(per_round))
        hop_e = E_HOP_PJ * (TORUS_HOP_FACTOR if self.torus else 1.0)
        energy = (hops * hop_e + actions * (E_ACTION_PJ + 2 * E_SRAM_PJ)
                  + cycles * self.S * E_LEAK_PJ_PER_CC_CYCLE)
        return CostResult(
            cycles=cycles, energy_pj=float(energy), messages=msgs, hops=hops,
            rounds=len(per_round),
            max_link_load=int(link_loads.max() if link_loads.size else 0),
            link_loads=link_loads, cc_arrivals=cc_arr,
            per_round_cycles=per_round,
        )
