"""Diffusive fixpoint engine (paper §4–§5), TPU-native.

The paper's asynchronous message-driven execution is re-expressed as bulk
edge-parallel relaxation rounds whose fixpoint equals the asynchronous
fixpoint (monotone semirings ⇒ order-free). One round is the diffuse-queue
drain: diffusions generated in round k are evaluated in round k+1 against
the newest vertex state, so stale diffusions are *subsumed* exactly as the
paper's lazy-diffuse pruning does.

Two execution paths share the same per-round math:

* ``run_stacked``  — arrays stacked ``(S, …)`` on one device; collectives
  are reshapes/transposes.  Used for correctness tests at any shard count.
* ``run_sharded``  — ``shard_map`` over a mesh with real collectives:
  - value/changed broadcast  → ``all_gather``      (the diffusion fan-out)
  - inbox exchange           → ``all_to_all``      (messages to replicas)
  - rhizome collapse         → ``all_gather`` + sibling combine
    (the AND-gate LCO trigger, lowered to a counted reduction)
  - termination detection    → ``psum`` of the any-changed flag
    (the paper assumes a hardware idle signal; the collective is ours).

Per-round counters reproduce the paper's Fig-6 statistics: messages
(actions delivered), actions whose predicate fired (work performed), and
diffusions pruned.
"""
from __future__ import annotations

import dataclasses
import functools
import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.actions import Semiring
from repro.core.partition import Partition


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    collapse: str = "eager"      # 'eager' | 'deferred' (min-semirings only)
    exchange: str = "dense"      # 'dense' | 'compact' (targeted messages)
    max_iters: int = 4096
    use_pallas: bool = False     # use the Pallas segment-reduce kernel
    track_stats: bool = True


class DeviceArrays(typing.NamedTuple):
    """Static per-shard tensors; leading dim S (stacked) or sharded.

    The ``edge_dst_compact``/``inbox_slot_map``/``rz_*`` fields implement
    the §Perf *compact targeted exchange*: contributions travel as
    (target, slot) messages instead of a dense global inbox — the TPU form
    of the paper's message-driven semantics."""

    edge_src_root_flat: jax.Array  # (S, E_max) int32
    edge_dst_flat: jax.Array       # (S, E_max) int32 (sorted per shard)
    edge_w: jax.Array              # (S, E_max) f32
    edge_mask: jax.Array           # (S, E_max) bool
    sibling_flat: jax.Array        # (S, R_max, K) int32
    sibling_mask: jax.Array        # (S, R_max, K) bool
    slot_valid: jax.Array          # (S, R_max) bool
    edge_dst_compact: jax.Array    # (S, E_max) int32 -> [0, S*P_t)
    inbox_slot_map: jax.Array      # (S, S, P_t) int32, R_max = pad
    rz_local: jax.Array            # (S, R_rz_max) int32, R_max = pad
    rz_sibling_idx: jax.Array      # (S, R_rz_max, K) int32
    rz_sibling_mask: jax.Array     # (S, R_rz_max, K) bool

    @classmethod
    def from_partition(cls, part: Partition) -> "DeviceArrays":
        return cls(
            edge_src_root_flat=jnp.asarray(part.edge_src_root_flat, jnp.int32),
            edge_dst_flat=jnp.asarray(part.edge_dst_flat, jnp.int32),
            edge_w=jnp.asarray(part.edge_w, jnp.float32),
            edge_mask=jnp.asarray(part.edge_mask),
            sibling_flat=jnp.asarray(part.sibling_flat, jnp.int32),
            sibling_mask=jnp.asarray(part.sibling_mask),
            slot_valid=jnp.asarray(part.slot_vertex >= 0),
            edge_dst_compact=jnp.asarray(part.edge_dst_compact, jnp.int32),
            inbox_slot_map=jnp.asarray(part.inbox_slot_map, jnp.int32),
            rz_local=jnp.asarray(part.rz_local, jnp.int32),
            rz_sibling_idx=jnp.asarray(part.rz_sibling_idx, jnp.int32),
            rz_sibling_mask=jnp.asarray(part.rz_sibling_mask),
        )


class RunStats(typing.NamedTuple):
    iterations: jax.Array        # rounds executed
    messages: jax.Array          # actions delivered (edge messages)
    work_actions: jax.Array      # predicate-true slot updates
    pruned_actions: jax.Array    # delivered but predicate-false
    diffusions: jax.Array        # slots that diffused (entered the frontier)


def _segment_combine(sem: Semiring, data, ids, num_segments, use_pallas):
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.segment_combine(data, ids, num_segments, kind=sem.segment)
    return sem.segment_combine(data, ids, num_segments)


# --------------------------------------------------------------------------
# shared per-round math. `gather(x_local) -> flat global`, `exchange(partial)
# -> inbox` differ between stacked and sharded paths.
# --------------------------------------------------------------------------

def _relax_phase(sem, arrays_s, gval, gchg, total_slots, use_pallas):
    """Per-shard: read sources, build messages, partial-reduce the inbox."""
    src_val = jnp.take(gval, arrays_s.edge_src_root_flat, axis=0)
    active = arrays_s.edge_mask & jnp.take(gchg, arrays_s.edge_src_root_flat, axis=0)
    msg = jnp.where(active, sem.relax(src_val, arrays_s.edge_w),
                    jnp.asarray(sem.identity, src_val.dtype))
    partial = _segment_combine(
        sem, msg, arrays_s.edge_dst_flat, total_slots, use_pallas
    )
    return partial, active


def _reduce_axis0(sem: Semiring, x):
    return jnp.min(x, axis=0) if sem.segment == "min" else jnp.sum(x, axis=0)


def _collapse(sem, gx, sibling_flat, sibling_mask):
    """Rhizome collapse: AND-gate over all replicas of each slot's vertex."""
    sib = jnp.take(gx, sibling_flat, axis=0)
    sib = jnp.where(sibling_mask, sib, jnp.asarray(sem.identity, sib.dtype))
    return _reduce_axis0(sem, jnp.moveaxis(sib, -1, 0))


def _scatter_inbox(sem, recv_t, slot_map_t, R_max):
    """recv_t: (S_src, P_t) contributions; slot_map_t: (S_src, P_t) local
    slots (R_max = pad). Scatter-combine into (R_max,)."""
    init = jnp.full((R_max + 1,), sem.identity, recv_t.dtype)
    if sem.segment == "min":
        out = init.at[slot_map_t.reshape(-1)].min(recv_t.reshape(-1))
    else:
        out = init.at[slot_map_t.reshape(-1)].add(recv_t.reshape(-1))
    return out[:R_max]


def _compact_collapse(sem, cand, arrays_s_rz_local, rz_sib_idx, rz_sib_mask,
                      gather_fn, R_max, R_rz_max):
    """Collapse only rhizome slots: compact-gather them, all-gather the
    small table, combine siblings, scatter back (min-set is safe because
    collapsed ≼ cand under the semiring order)."""
    cand_pad = jnp.concatenate(
        [cand, jnp.full(cand.shape[:-1] + (1,), sem.identity, cand.dtype)],
        axis=-1)
    compact = jnp.take_along_axis(cand_pad, arrays_s_rz_local, axis=-1)
    g = gather_fn(compact)                       # (S*R_rz_max,) flat
    sib = jnp.take(g, rz_sib_idx, axis=0)
    sib = jnp.where(rz_sib_mask, sib, jnp.asarray(sem.identity, sib.dtype))
    collapsed = _reduce_axis0(sem, jnp.moveaxis(sib, -1, 0))
    upd = cand_pad.at[
        tuple(jnp.indices(arrays_s_rz_local.shape)[:-1])
        + (arrays_s_rz_local,)].min(collapsed) if sem.segment == "min" else None
    assert sem.segment == "min", "compact collapse requires a min semiring"
    return upd[..., :R_max]


# --------------------------------------------------------------------------
# fixpoint apps (BFS / SSSP)
# --------------------------------------------------------------------------

def _fixpoint_round_stacked(sem, arrays, cfg, S, R_max, val, chg):
    gval, gchg = val.reshape(-1), chg.reshape(-1)
    if cfg.exchange == "compact":
        P_t = arrays.inbox_slot_map.shape[-1]
        R_rz_max = arrays.rz_local.shape[-1]

        def relax_c(a):
            src_val = jnp.take(gval, a.edge_src_root_flat, axis=0)
            active = a.edge_mask & jnp.take(gchg, a.edge_src_root_flat, axis=0)
            msg = jnp.where(active, sem.relax(src_val, a.edge_w),
                            jnp.asarray(sem.identity, src_val.dtype))
            partial = _segment_combine(sem, msg, a.edge_dst_compact,
                                       S * P_t, cfg.use_pallas)
            return partial.reshape(S, P_t), active

        partial, active = jax.vmap(relax_c)(arrays)   # (S_src, S_tgt, P_t)
        recv = jnp.swapaxes(partial, 0, 1)            # (S_tgt, S_src, P_t)
        inbox = jax.vmap(lambda r, m: _scatter_inbox(sem, r, m, R_max))(
            recv, arrays.inbox_slot_map)
        cand = sem.combine(val, inbox)
        if cfg.collapse == "eager":
            cand = _compact_collapse(
                sem, cand, arrays.rz_local, arrays.rz_sibling_idx,
                arrays.rz_sibling_mask, lambda c: c.reshape(-1),
                R_max, R_rz_max)
        new_chg = sem.improved(cand, val) & arrays.slot_valid
        return cand, new_chg, active

    total = S * R_max
    partial, active = jax.vmap(
        lambda g, c, a: _relax_phase(sem, a, g, c, total, cfg.use_pallas),
        in_axes=(None, None, 0),
    )(gval, gchg, arrays)
    inbox = _reduce_axis0(sem, partial).reshape(S, R_max)
    cand = sem.combine(val, inbox)
    if cfg.collapse == "eager":
        cand = _collapse(sem, cand.reshape(-1), arrays.sibling_flat,
                         arrays.sibling_mask)
    new_chg = sem.improved(cand, val) & arrays.slot_valid
    return cand, new_chg, active


def run_stacked(sem: Semiring, part: Partition, init_val: np.ndarray,
                cfg: EngineConfig = EngineConfig(), init_changed=None):
    """Single-device stacked execution. ``init_val``: (S, R_max) float32.
    ``init_changed`` (optional bool (S, R_max)) seeds the first frontier —
    used by incremental recompute to re-diffuse only mutation sites."""
    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max

    def body(carry):
        val, chg, it, stats = carry
        new_val, new_chg, active = _fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg
        )
        if cfg.collapse == "deferred":
            # read-side collapse next round; converged means consistent
            new_val = _collapse(sem, new_val.reshape(-1), arrays.sibling_flat,
                                arrays.sibling_mask) if False else new_val
        stats = RunStats(
            iterations=stats.iterations + 1,
            messages=stats.messages + active.sum(),
            work_actions=stats.work_actions + new_chg.sum(),
            pruned_actions=stats.pruned_actions
            + active.sum() - jnp.minimum(new_chg.sum(), active.sum()),
            diffusions=stats.diffusions + new_chg.sum(),
        )
        return new_val, new_chg, it + 1, stats

    def cond(carry):
        _, chg, it, _ = carry
        return jnp.any(chg) & (it < cfg.max_iters)

    if init_changed is not None:
        init_chg = jnp.asarray(init_changed) & arrays.slot_valid
    else:
        init_chg = sem.improved(
            jnp.asarray(init_val),
            jnp.full_like(jnp.asarray(init_val), sem.identity)
        ) & arrays.slot_valid
        if sem.segment == "sum":
            init_chg = arrays.slot_valid
    zero = jnp.zeros((), jnp.int64) if jax.config.jax_enable_x64 else jnp.zeros((), jnp.int32)
    stats0 = RunStats(zero, zero, zero, zero, zero)
    val, chg, it, stats = lax.while_loop(
        cond, body, (jnp.asarray(init_val), init_chg, zero, stats0)
    )
    if cfg.collapse == "deferred":
        val = _collapse(sem, val.reshape(-1), arrays.sibling_flat,
                        arrays.sibling_mask)
    return val, stats


# --------------------------------------------------------------------------
# PageRank-style counted-iteration apps
# --------------------------------------------------------------------------

def run_pagerank_stacked(part: Partition, damping: float, iters: int,
                         cfg: EngineConfig = EngineConfig()):
    from repro.core.actions import PAGERANK as sem

    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    total = S * R_max
    base = (1.0 - damping) / part.n

    # initial score 1/n on every replica (consistent view)
    val0 = jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0)
    chg = arrays.slot_valid  # PR predicate is #t — always diffuse

    def body(_, val):
        gval = val.reshape(-1)
        gchg = chg.reshape(-1)
        partial, _ = jax.vmap(
            lambda g, c, a: _relax_phase(sem, a, g, c, total, cfg.use_pallas),
            in_axes=(None, None, 0),
        )(gval, gchg, arrays)
        inbox = _reduce_axis0(sem, partial).reshape(S, R_max)
        # rhizome-collapse(+): sum of sibling inboxes == total in-flow
        total_in = _collapse(sem, inbox.reshape(-1), arrays.sibling_flat,
                             arrays.sibling_mask)
        return jnp.where(arrays.slot_valid, base + damping * total_in, 0.0)

    val = lax.fori_loop(0, iters, body, val0)
    return val


# --------------------------------------------------------------------------
# sharded execution (shard_map over a real mesh)
# --------------------------------------------------------------------------

def _axis(axis_names):
    return axis_names if isinstance(axis_names, tuple) else (axis_names,)


def make_sharded_fn(sem: Semiring, S: int, R_max: int,
                    mesh: Mesh, axis_names=("data", "model"),
                    cfg: EngineConfig = EngineConfig()):
    """Builds the shard_map diffusive fixpoint as a jit-able fn of
    (DeviceArrays, val) — usable with concrete arrays (run_sharded) or
    ShapeDtypeStructs (AOT dry-run lowering)."""
    axis_names = _axis(axis_names)
    total = S * R_max
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays(*([spec] * len(DeviceArrays._fields))),
        spec,
    )

    def shard_fn(arrays_l: DeviceArrays, val_l):
        # strip leading local shard dim of size 1
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val = val_l[0]

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        def round_fn(val, chg):
            gval, gchg = gather(val), gather(chg)
            if cfg.exchange == "compact":
                P_t = arrays_s.inbox_slot_map.shape[-1]
                src_val = jnp.take(gval, arrays_s.edge_src_root_flat, axis=0)
                active = arrays_s.edge_mask & jnp.take(
                    gchg, arrays_s.edge_src_root_flat, axis=0)
                msg = jnp.where(active,
                                sem.relax(src_val, arrays_s.edge_w),
                                jnp.asarray(sem.identity, src_val.dtype))
                partial = _segment_combine(
                    sem, msg, arrays_s.edge_dst_compact, S * P_t,
                    cfg.use_pallas)
                # targeted exchange: only (target, distinct-slot) messages
                recv = lax.all_to_all(
                    partial.reshape(S, P_t), axis_names,
                    split_axis=0, concat_axis=0, tiled=True)
                inbox = _scatter_inbox(sem, recv, arrays_s.inbox_slot_map,
                                       R_max)
                cand = sem.combine(val, inbox)
                if cfg.collapse == "eager":
                    R_rz_max = arrays_s.rz_local.shape[-1]
                    cand = _compact_collapse(
                        sem, cand, arrays_s.rz_local,
                        arrays_s.rz_sibling_idx, arrays_s.rz_sibling_mask,
                        lambda c: lax.all_gather(c, axis_names, tiled=True),
                        R_max, R_rz_max)
                new_chg = sem.improved(cand, val) & arrays_s.slot_valid
                return cand, new_chg, active
            partial, active = _relax_phase(
                sem, arrays_s, gval, gchg, total, cfg.use_pallas
            )
            # inbox exchange: row t of `partial` belongs to shard t
            recv = lax.all_to_all(
                partial.reshape(S, R_max), axis_names,
                split_axis=0, concat_axis=0, tiled=True,
            )
            inbox = _reduce_axis0(sem, recv.reshape(S, R_max))
            cand = sem.combine(val, inbox)
            if cfg.collapse == "eager":
                cand = _collapse(sem, gather(cand), arrays_s.sibling_flat,
                                 arrays_s.sibling_mask)
            new_chg = sem.improved(cand, val) & arrays_s.slot_valid
            return cand, new_chg, active

        def body(carry):
            val, chg, it, stats = carry
            new_val, new_chg, active = round_fn(val, chg)
            stats = RunStats(
                iterations=stats.iterations + 1,
                messages=stats.messages + lax.psum(active.sum(), axis_names),
                work_actions=stats.work_actions
                + lax.psum(new_chg.sum(), axis_names),
                pruned_actions=stats.pruned_actions,
                diffusions=stats.diffusions
                + lax.psum(new_chg.sum(), axis_names),
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            anyc = lax.psum(chg.any().astype(jnp.int32), axis_names)
            return (anyc > 0) & (it < cfg.max_iters)

        init_chg = (
            sem.improved(val, jnp.full_like(val, sem.identity))
            & arrays_s.slot_valid
        )
        zero = jnp.zeros((), jnp.int32)
        stats0 = RunStats(zero, zero, zero, zero, zero)
        val, chg, it, stats = lax.while_loop(
            cond, body, (val, init_chg, zero, stats0)
        )
        if cfg.collapse == "deferred":
            val = _collapse(sem, lax.all_gather(val, axis_names, tiled=True),
                            arrays_s.sibling_flat, arrays_s.sibling_mask)
        return val[None], jax.tree.map(lambda x: x[None], stats)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec, RunStats(*([spec] * 5))),
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_sharded(sem: Semiring, part: Partition, init_val: np.ndarray,
                mesh: Mesh, axis_names=("data", "model"),
                cfg: EngineConfig = EngineConfig()):
    """shard_map execution. Leading (shard) dim of every array is split over
    ``axis_names``; requires prod(mesh[axis_names]) == part.S."""
    fn, sharding = make_sharded_fn(
        sem, part.S, part.R_max, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    val_dev = jax.device_put(jnp.asarray(init_val), sharding)
    val, stats = fn(arrays_dev, val_dev)
    stats = jax.tree.map(lambda x: x[0], stats)
    return val, stats


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def init_values(part: Partition, sem: Semiring, sources: dict[int, float]):
    """(S, R_max) initial values: semiring identity everywhere except all
    replicas of each source vertex (consistent initial view)."""
    val = np.full((part.S, part.R_max), sem.identity, dtype=np.float32)
    if sem.segment == "sum":
        val[:] = 0.0
    for v, x in sources.items():
        s0, sl0 = divmod(int(part.root_flat[v]), part.R_max)
        for k in range(part.cfg.rpvo_max):
            if part.sibling_mask[s0, sl0, k]:
                f = int(part.sibling_flat[s0, sl0, k])
                val[f // part.R_max, f % part.R_max] = x
    return val


def vertex_values(part: Partition, val) -> np.ndarray:
    """Extract the per-vertex (root-replica) values."""
    gval = np.asarray(val).reshape(-1)
    return gval[part.root_flat]
