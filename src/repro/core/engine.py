"""Diffusive fixpoint engine (paper §4–§5), TPU-native.

The paper's asynchronous message-driven execution is re-expressed as bulk
edge-parallel relaxation rounds whose fixpoint equals the asynchronous
fixpoint (monotone semirings ⇒ order-free). One round is the diffuse-queue
drain: diffusions generated in round k are evaluated in round k+1 against
the newest vertex state, so stale diffusions are *subsumed* exactly as the
paper's lazy-diffuse pruning does.

Two execution paths share the same per-round math:

* ``run_stacked``  — arrays stacked ``(S, …)`` on one device; collectives
  are reshapes/transposes.  Used for correctness tests at any shard count.
* ``run_sharded``  — ``shard_map`` over a mesh with real collectives:
  - value/changed broadcast  → ``all_gather``      (the diffusion fan-out)
  - inbox exchange           → ``all_to_all``      (messages to replicas)
  - rhizome collapse         → ``all_gather`` + sibling combine
    (the AND-gate LCO trigger, lowered to a counted reduction)
  - termination detection    → ``psum`` of the any-changed flag
    (the paper assumes a hardware idle signal; the collective is ours).

With ``EngineConfig.use_pallas`` the per-round relax phase — frontier
gather, semiring relax, active masking, and the inbox segment reduction —
dispatches through the fused ``kernels.fused_relax_reduce`` Pallas kernel:
one VMEM-resident pass, no ``(S, E_max)`` HBM intermediates, and grid
cells over frontier-dead edge chunks are skipped entirely (the TPU form of
the paper's diffusion pruning).  Without the flag the same math runs as
separate jnp ops — the oracle path.

Per-round counters reproduce the paper's Fig-6 statistics: messages
(actions delivered), actions whose predicate fired (work performed), and
diffusions pruned.
"""
from __future__ import annotations

import dataclasses
import functools
import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.actions import Semiring
from repro.core.partition import Partition


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    collapse: str = "eager"      # 'eager' | 'deferred' (min-semirings only)
    exchange: str = "dense"      # 'dense' | 'compact' (targeted messages)
    max_iters: int = 4096
    use_pallas: bool = False     # route the relax phase through Pallas
    # 'fused'  — one VMEM-resident gather+relax+mask+reduce kernel with
    #            frontier chunk skip (the hot path; default)
    # 'reduce' — jnp gather/relax/mask + the standalone segment-reduce
    #            kernel (the pre-fusion composition, kept for comparison)
    pallas_mode: str = "fused"
    # False skips the Fig-6 message counter (an O(E) boolean reduction per
    # round on the fused path); RunStats then reports zero messages/pruned
    track_stats: bool = True

    def __post_init__(self):
        if self.collapse not in ("eager", "deferred"):
            raise ValueError(f"collapse={self.collapse!r}")
        if self.exchange not in ("dense", "compact"):
            raise ValueError(f"exchange={self.exchange!r}")
        if self.pallas_mode not in ("fused", "reduce"):
            raise ValueError(f"pallas_mode={self.pallas_mode!r}")


class DeviceArrays(typing.NamedTuple):
    """Static per-shard tensors; leading dim S (stacked) or sharded.

    The ``edge_dst_compact``/``inbox_slot_map``/``rz_*`` fields implement
    the §Perf *compact targeted exchange*: contributions travel as
    (target, slot) messages instead of a dense global inbox — the TPU form
    of the paper's message-driven semantics."""

    edge_src_root_flat: jax.Array  # (S, E_max) int32
    edge_dst_flat: jax.Array       # (S, E_max) int32 (sorted per shard)
    edge_w: jax.Array              # (S, E_max) f32
    edge_mask: jax.Array           # (S, E_max) bool
    sibling_flat: jax.Array        # (S, R_max, K) int32
    sibling_mask: jax.Array        # (S, R_max, K) bool
    slot_valid: jax.Array          # (S, R_max) bool
    edge_dst_compact: jax.Array    # (S, E_max) int32 -> [0, S*P_t)
    inbox_slot_map: jax.Array      # (S, S, P_t) int32, R_max = pad
    rz_local: jax.Array            # (S, R_rz_max) int32, R_max = pad
    rz_sibling_idx: jax.Array      # (S, R_rz_max, K) int32
    rz_sibling_mask: jax.Array     # (S, R_rz_max, K) bool

    @classmethod
    def from_partition(cls, part: Partition) -> "DeviceArrays":
        return cls(
            edge_src_root_flat=jnp.asarray(part.edge_src_root_flat, jnp.int32),
            edge_dst_flat=jnp.asarray(part.edge_dst_flat, jnp.int32),
            edge_w=jnp.asarray(part.edge_w, jnp.float32),
            edge_mask=jnp.asarray(part.edge_mask),
            sibling_flat=jnp.asarray(part.sibling_flat, jnp.int32),
            sibling_mask=jnp.asarray(part.sibling_mask),
            slot_valid=jnp.asarray(part.slot_vertex >= 0),
            edge_dst_compact=jnp.asarray(part.edge_dst_compact, jnp.int32),
            inbox_slot_map=jnp.asarray(part.inbox_slot_map, jnp.int32),
            rz_local=jnp.asarray(part.rz_local, jnp.int32),
            rz_sibling_idx=jnp.asarray(part.rz_sibling_idx, jnp.int32),
            rz_sibling_mask=jnp.asarray(part.rz_sibling_mask),
        )


class RunStats(typing.NamedTuple):
    iterations: jax.Array        # rounds executed
    messages: jax.Array          # actions delivered (edge messages)
    work_actions: jax.Array      # predicate-true slot updates
    pruned_actions: jax.Array    # delivered but predicate-false
    diffusions: jax.Array        # slots that diffused (entered the frontier)


# --------------------------------------------------------------------------
# shared per-round math. The relax phase (gather sources, build messages,
# partial-reduce the inbox) has two implementations with identical
# semantics: a fused Pallas kernel (use_pallas) and separate jnp ops.
# --------------------------------------------------------------------------

def _fused_relax(sem: Semiring, edge_src, edge_w, edge_mask, edge_dst,
                 gval, gchg, num_segments, count_messages=True):
    """Relax phase through the fused Pallas kernel. Edge arrays may be any
    shape (flattened internally); returns ((num_segments,) partial, count
    of delivered messages)."""
    if sem.relax_kind is None:
        raise ValueError(
            f"semiring {sem.name!r} has no kernel relax form "
            "(relax_kind=None); construct it from actions.RELAX_FNS or "
            "run with use_pallas=False")
    from repro.kernels import ops as kops
    # the Fig-6 message count rides along for free: it is a reduction of
    # the same gather that builds the kernel's frontier chunk bitmap
    partial, count = kops.fused_relax_reduce(
        gval, gchg, edge_src.reshape(-1), edge_w.reshape(-1),
        edge_mask.reshape(-1), edge_dst.reshape(-1), num_segments,
        relax_kind=sem.relax_kind, kind=sem.segment)
    if not count_messages:
        count = jnp.zeros((), jnp.int32)
    return partial, count


def _shard_relax(sem: Semiring, arrays_s, gval, gchg, num_segments,
                 cfg: EngineConfig, compact: bool):
    """Per-shard relax phase: read sources, build messages, partial-reduce
    the inbox. Returns ((num_segments,) partial, message count)."""
    ids = arrays_s.edge_dst_compact if compact else arrays_s.edge_dst_flat
    if cfg.use_pallas and cfg.pallas_mode == "fused":
        return _fused_relax(sem, arrays_s.edge_src_root_flat, arrays_s.edge_w,
                            arrays_s.edge_mask, ids, gval, gchg, num_segments,
                            count_messages=cfg.track_stats)
    src_val = jnp.take(gval, arrays_s.edge_src_root_flat, axis=0)
    active = arrays_s.edge_mask & jnp.take(gchg, arrays_s.edge_src_root_flat,
                                           axis=0)
    msg = jnp.where(active, sem.relax(src_val, arrays_s.edge_w),
                    jnp.asarray(sem.identity, src_val.dtype))
    if cfg.use_pallas:   # 'reduce': XLA relax ops + Pallas segment reduce
        from repro.kernels import ops as kops
        partial = kops.segment_combine(msg, ids, num_segments,
                                       kind=sem.segment)
    else:
        partial = sem.segment_combine(msg, ids, num_segments)
    count = active.sum() if cfg.track_stats else jnp.zeros((), jnp.int32)
    return partial, count


def _stacked_dense_inbox(sem: Semiring, arrays, cfg: EngineConfig,
                         gval, gchg, total):
    """Stacked dense relax: the reduced (total,) global inbox + msg count.

    Fused path: all shards' edges address the same global slot space, so
    the whole stack collapses in ONE kernel launch (the kernel's in-place
    block accumulation replaces the (S, total) partial + axis-0 reduce)."""
    if cfg.use_pallas and cfg.pallas_mode == "fused":
        return _fused_relax(sem, arrays.edge_src_root_flat, arrays.edge_w,
                            arrays.edge_mask, arrays.edge_dst_flat,
                            gval, gchg, total,
                            count_messages=cfg.track_stats)
    partial, counts = jax.vmap(
        lambda a: _shard_relax(sem, a, gval, gchg, total, cfg, False)
    )(arrays)
    return _reduce_axis0(sem, partial), counts.sum()


def _stacked_compact_partial(sem: Semiring, arrays, cfg: EngineConfig, S,
                             P_t, gval, gchg):
    """Stacked compact relax: (S_src, S_tgt, P_t) partials + msg count.

    Fused path: source shards get disjoint id windows of width S*P_t, so
    one kernel launch over the flattened edge stack produces every
    per-source partial (compact slot meaning depends on the source shard,
    hence the offsets — contributions must NOT merge across sources)."""
    if cfg.use_pallas and cfg.pallas_mode == "fused":
        offs = (jnp.arange(S, dtype=jnp.int32) * (S * P_t))[:, None]
        ids = arrays.edge_dst_compact + offs
        flat, count = _fused_relax(
            sem, arrays.edge_src_root_flat, arrays.edge_w, arrays.edge_mask,
            ids, gval, gchg, S * S * P_t, count_messages=cfg.track_stats)
        return flat.reshape(S, S, P_t), count
    partial, counts = jax.vmap(
        lambda a: _shard_relax(sem, a, gval, gchg, S * P_t, cfg, True)
    )(arrays)
    return partial.reshape(S, S, P_t), counts.sum()


def _reduce_axis0(sem: Semiring, x):
    return jnp.min(x, axis=0) if sem.segment == "min" else jnp.sum(x, axis=0)


def _collapse(sem, gx, sibling_flat, sibling_mask):
    """Rhizome collapse: AND-gate over all replicas of each slot's vertex."""
    sib = jnp.take(gx, sibling_flat, axis=0)
    sib = jnp.where(sibling_mask, sib, jnp.asarray(sem.identity, sib.dtype))
    return _reduce_axis0(sem, jnp.moveaxis(sib, -1, 0))


def _scatter_inbox(sem, recv_t, slot_map_t, R_max):
    """recv_t: (S_src, P_t) contributions; slot_map_t: (S_src, P_t) local
    slots (R_max = pad). Scatter-combine into (R_max,)."""
    init = jnp.full((R_max + 1,), sem.identity, recv_t.dtype)
    if sem.segment == "min":
        out = init.at[slot_map_t.reshape(-1)].min(recv_t.reshape(-1))
    else:
        out = init.at[slot_map_t.reshape(-1)].add(recv_t.reshape(-1))
    return out[:R_max]


def _compact_collapse(sem, cand, rz_local, rz_sib_idx, rz_sib_mask,
                      gather_fn, R_max, R_rz_max):
    """Collapse only rhizome slots: compact-gather them, all-gather the
    small table, combine siblings, scatter back.  min semirings min-set
    (collapsed ≼ cand under the semiring order, so ``cand`` may be any
    combined candidate); sum semirings overwrite each rhizome slot with
    the sibling total (each sibling's own partial is included in the sum,
    so set — never add — keeps it exact), which requires ``cand`` to be
    bare inbox partials — summing combined val+inbox candidates would
    double-count every sibling's val (hence the min-only fixpoint
    runners; only the PageRank rounds pass sum semirings here)."""
    cand_pad = jnp.concatenate(
        [cand, jnp.full(cand.shape[:-1] + (1,), sem.identity, cand.dtype)],
        axis=-1)
    compact = jnp.take_along_axis(cand_pad, rz_local, axis=-1)
    g = gather_fn(compact)                       # (S*R_rz_max,) flat
    sib = jnp.take(g, rz_sib_idx, axis=0)
    sib = jnp.where(rz_sib_mask, sib, jnp.asarray(sem.identity, sib.dtype))
    collapsed = _reduce_axis0(sem, jnp.moveaxis(sib, -1, 0))
    idx = tuple(jnp.indices(rz_local.shape)[:-1]) + (rz_local,)
    if sem.segment == "min":
        upd = cand_pad.at[idx].min(collapsed)
    else:
        upd = cand_pad.at[idx].set(collapsed)
    return upd[..., :R_max]


# --------------------------------------------------------------------------
# fixpoint apps (BFS / SSSP)
# --------------------------------------------------------------------------

def _fixpoint_round_stacked(sem, arrays, cfg, S, R_max, val, chg):
    gval, gchg = val.reshape(-1), chg.reshape(-1)
    if cfg.exchange == "compact":
        P_t = arrays.inbox_slot_map.shape[-1]
        R_rz_max = arrays.rz_local.shape[-1]
        partial, msg_count = _stacked_compact_partial(
            sem, arrays, cfg, S, P_t, gval, gchg)   # (S_src, S_tgt, P_t)
        recv = jnp.swapaxes(partial, 0, 1)          # (S_tgt, S_src, P_t)
        inbox = jax.vmap(lambda r, m: _scatter_inbox(sem, r, m, R_max))(
            recv, arrays.inbox_slot_map)
        cand = sem.combine(val, inbox)
        if cfg.collapse == "eager":
            cand = _compact_collapse(
                sem, cand, arrays.rz_local, arrays.rz_sibling_idx,
                arrays.rz_sibling_mask, lambda c: c.reshape(-1),
                R_max, R_rz_max)
        new_chg = sem.improved(cand, val) & arrays.slot_valid
        return cand, new_chg, msg_count

    total = S * R_max
    inbox_flat, msg_count = _stacked_dense_inbox(
        sem, arrays, cfg, gval, gchg, total)
    cand = sem.combine(val, inbox_flat.reshape(S, R_max))
    if cfg.collapse == "eager":
        cand = _collapse(sem, cand.reshape(-1), arrays.sibling_flat,
                         arrays.sibling_mask)
    new_chg = sem.improved(cand, val) & arrays.slot_valid
    return cand, new_chg, msg_count


def run_stacked(sem: Semiring, part: Partition, init_val: np.ndarray,
                cfg: EngineConfig = EngineConfig(), init_changed=None):
    """Single-device stacked execution. ``init_val``: (S, R_max) float32.
    ``init_changed`` (optional bool (S, R_max)) seeds the first frontier —
    used by incremental recompute to re-diffuse only mutation sites."""
    if sem.segment != "min":
        raise ValueError(
            "run_stacked drives monotone min-semiring fixpoints; the "
            "collapse of a combined candidate is only sound there — use "
            "run_pagerank_stacked for counted sum-semiring rounds")
    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max

    def body(carry):
        val, chg, it, stats = carry
        new_val, new_chg, msg_count = _fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg
        )
        work = new_chg.sum()
        stats = RunStats(
            iterations=stats.iterations + 1,
            messages=stats.messages + msg_count,
            work_actions=stats.work_actions + work,
            pruned_actions=stats.pruned_actions
            + msg_count - jnp.minimum(work, msg_count),
            diffusions=stats.diffusions + work,
        )
        return new_val, new_chg, it + 1, stats

    def cond(carry):
        _, chg, it, _ = carry
        return jnp.any(chg) & (it < cfg.max_iters)

    if init_changed is not None:
        init_chg = jnp.asarray(init_changed) & arrays.slot_valid
    else:
        init_chg = sem.improved(
            jnp.asarray(init_val),
            jnp.full_like(jnp.asarray(init_val), sem.identity)
        ) & arrays.slot_valid
    zero = jnp.zeros((), jnp.int64) if jax.config.jax_enable_x64 else jnp.zeros((), jnp.int32)
    stats0 = RunStats(zero, zero, zero, zero, zero)
    val, chg, it, stats = lax.while_loop(
        cond, body, (jnp.asarray(init_val), init_chg, zero, stats0)
    )
    if cfg.collapse == "deferred":
        val = _collapse(sem, val.reshape(-1), arrays.sibling_flat,
                        arrays.sibling_mask)
    return val, stats


# --------------------------------------------------------------------------
# PageRank-style counted-iteration apps
# --------------------------------------------------------------------------

def _pagerank_round_stacked(sem, arrays, cfg, S, R_max, base, damping, val,
                            chg):
    """One stacked PageRank round: relax → exchange → rhizome-collapse(+)
    → damping update. Shared by run_pagerank_stacked and the engine
    benchmark so BENCH numbers measure the shipped hot path."""
    gval = val.reshape(-1)
    gchg = chg.reshape(-1)
    if cfg.exchange == "compact":
        P_t = arrays.inbox_slot_map.shape[-1]
        R_rz_max = arrays.rz_local.shape[-1]
        partial, msg_count = _stacked_compact_partial(
            sem, arrays, cfg, S, P_t, gval, gchg)
        recv = jnp.swapaxes(partial, 0, 1)
        inbox = jax.vmap(lambda r, m: _scatter_inbox(sem, r, m, R_max))(
            recv, arrays.inbox_slot_map)
        # rhizome-collapse(+) over the compact table: each rhizome slot
        # becomes the sum of its sibling inboxes == total in-flow
        total_in = _compact_collapse(
            sem, inbox, arrays.rz_local, arrays.rz_sibling_idx,
            arrays.rz_sibling_mask, lambda c: c.reshape(-1),
            R_max, R_rz_max)
    else:
        total = S * R_max
        inbox_flat, msg_count = _stacked_dense_inbox(
            sem, arrays, cfg, gval, gchg, total)
        inbox = inbox_flat.reshape(S, R_max)
        # rhizome-collapse(+): sum of sibling inboxes == total in-flow
        total_in = _collapse(sem, inbox.reshape(-1), arrays.sibling_flat,
                             arrays.sibling_mask)
    new_val = jnp.where(arrays.slot_valid, base + damping * total_in, 0.0)
    return new_val, msg_count


def run_pagerank_stacked(part: Partition, damping: float, iters: int,
                         cfg: EngineConfig = EngineConfig()):
    from repro.core.actions import PAGERANK as sem

    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    base = (1.0 - damping) / part.n

    # initial score 1/n on every replica (consistent view)
    val0 = jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0)
    chg = arrays.slot_valid  # PR predicate is #t — always diffuse

    def body(_, val):
        new_val, _ = _pagerank_round_stacked(
            sem, arrays, cfg, S, R_max, base, damping, val, chg)
        return new_val

    val = lax.fori_loop(0, iters, body, val0)
    return val


# --------------------------------------------------------------------------
# sharded execution (shard_map over a real mesh)
# --------------------------------------------------------------------------

def _axis(axis_names):
    return axis_names if isinstance(axis_names, tuple) else (axis_names,)


def make_sharded_fn(sem: Semiring, S: int, R_max: int,
                    mesh: Mesh, axis_names=("data", "model"),
                    cfg: EngineConfig = EngineConfig()):
    """Builds the shard_map diffusive fixpoint as a jit-able fn of
    (DeviceArrays, val) — usable with concrete arrays (run_sharded) or
    ShapeDtypeStructs (AOT dry-run lowering)."""
    if sem.segment != "min":
        raise ValueError(
            "make_sharded_fn drives monotone min-semiring fixpoints; use "
            "make_sharded_pagerank_fn for counted sum-semiring rounds")
    axis_names = _axis(axis_names)
    total = S * R_max
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays(*([spec] * len(DeviceArrays._fields))),
        spec,
    )

    def shard_fn(arrays_l: DeviceArrays, val_l):
        # strip leading local shard dim of size 1
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val = val_l[0]

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        def round_fn(val, chg):
            gval, gchg = gather(val), gather(chg)
            if cfg.exchange == "compact":
                P_t = arrays_s.inbox_slot_map.shape[-1]
                partial, msg_count = _shard_relax(
                    sem, arrays_s, gval, gchg, S * P_t, cfg, True)
                # targeted exchange: only (target, distinct-slot) messages
                recv = lax.all_to_all(
                    partial.reshape(S, P_t), axis_names,
                    split_axis=0, concat_axis=0, tiled=True)
                inbox = _scatter_inbox(sem, recv, arrays_s.inbox_slot_map,
                                       R_max)
                cand = sem.combine(val, inbox)
                if cfg.collapse == "eager":
                    R_rz_max = arrays_s.rz_local.shape[-1]
                    cand = _compact_collapse(
                        sem, cand, arrays_s.rz_local,
                        arrays_s.rz_sibling_idx, arrays_s.rz_sibling_mask,
                        gather, R_max, R_rz_max)
                new_chg = sem.improved(cand, val) & arrays_s.slot_valid
                return cand, new_chg, msg_count
            partial, msg_count = _shard_relax(
                sem, arrays_s, gval, gchg, total, cfg, False)
            # inbox exchange: row t of `partial` belongs to shard t
            recv = lax.all_to_all(
                partial.reshape(S, R_max), axis_names,
                split_axis=0, concat_axis=0, tiled=True,
            )
            inbox = _reduce_axis0(sem, recv.reshape(S, R_max))
            cand = sem.combine(val, inbox)
            if cfg.collapse == "eager":
                cand = _collapse(sem, gather(cand), arrays_s.sibling_flat,
                                 arrays_s.sibling_mask)
            new_chg = sem.improved(cand, val) & arrays_s.slot_valid
            return cand, new_chg, msg_count

        def body(carry):
            val, chg, it, stats = carry
            new_val, new_chg, msg_count = round_fn(val, chg)
            msgs = lax.psum(msg_count, axis_names)
            work = lax.psum(new_chg.sum(), axis_names)
            stats = RunStats(
                iterations=stats.iterations + 1,
                messages=stats.messages + msgs,
                work_actions=stats.work_actions + work,
                pruned_actions=stats.pruned_actions
                + msgs - jnp.minimum(work, msgs),
                diffusions=stats.diffusions + work,
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            anyc = lax.psum(chg.any().astype(jnp.int32), axis_names)
            return (anyc > 0) & (it < cfg.max_iters)

        init_chg = (
            sem.improved(val, jnp.full_like(val, sem.identity))
            & arrays_s.slot_valid
        )
        zero = jnp.zeros((), jnp.int32)
        stats0 = RunStats(zero, zero, zero, zero, zero)
        val, chg, it, stats = lax.while_loop(
            cond, body, (val, init_chg, zero, stats0)
        )
        if cfg.collapse == "deferred":
            val = _collapse(sem, lax.all_gather(val, axis_names, tiled=True),
                            arrays_s.sibling_flat, arrays_s.sibling_mask)
        return val[None], jax.tree.map(lambda x: x[None], stats)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec, RunStats(*([spec] * 5))),
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_sharded(sem: Semiring, part: Partition, init_val: np.ndarray,
                mesh: Mesh, axis_names=("data", "model"),
                cfg: EngineConfig = EngineConfig()):
    """shard_map execution. Leading (shard) dim of every array is split over
    ``axis_names``; requires prod(mesh[axis_names]) == part.S."""
    fn, sharding = make_sharded_fn(
        sem, part.S, part.R_max, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    val_dev = jax.device_put(jnp.asarray(init_val), sharding)
    val, stats = fn(arrays_dev, val_dev)
    stats = jax.tree.map(lambda x: x[0], stats)
    return val, stats


def make_sharded_pagerank_fn(S: int, R_max: int, n: int, damping: float,
                             iters: int, mesh: Mesh,
                             axis_names=("data", "model"),
                             cfg: EngineConfig = EngineConfig()):
    """shard_map PageRank: counted rounds of relax → exchange →
    rhizome-collapse(+) → damping update, dense or compact exchange, with
    the same fused-kernel hot path as the fixpoint apps."""
    from repro.core.actions import PAGERANK as sem

    axis_names = _axis(axis_names)
    total = S * R_max
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (DeviceArrays(*([spec] * len(DeviceArrays._fields))),)
    base = (1.0 - damping) / n

    def shard_fn(arrays_l: DeviceArrays):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        chg = arrays_s.slot_valid  # PR predicate is #t — always diffuse

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        def body(_, val):
            gval, gchg = gather(val), gather(chg)
            if cfg.exchange == "compact":
                P_t = arrays_s.inbox_slot_map.shape[-1]
                partial, _ = _shard_relax(
                    sem, arrays_s, gval, gchg, S * P_t, cfg, True)
                recv = lax.all_to_all(
                    partial.reshape(S, P_t), axis_names,
                    split_axis=0, concat_axis=0, tiled=True)
                inbox = _scatter_inbox(sem, recv, arrays_s.inbox_slot_map,
                                       R_max)
                total_in = _compact_collapse(
                    sem, inbox, arrays_s.rz_local, arrays_s.rz_sibling_idx,
                    arrays_s.rz_sibling_mask, gather, R_max,
                    arrays_s.rz_local.shape[-1])
            else:
                partial, _ = _shard_relax(
                    sem, arrays_s, gval, gchg, total, cfg, False)
                recv = lax.all_to_all(
                    partial.reshape(S, R_max), axis_names,
                    split_axis=0, concat_axis=0, tiled=True)
                inbox = _reduce_axis0(sem, recv.reshape(S, R_max))
                total_in = _collapse(sem, gather(inbox),
                                     arrays_s.sibling_flat,
                                     arrays_s.sibling_mask)
            return jnp.where(arrays_s.slot_valid,
                             base + damping * total_in, 0.0)

        val0 = jnp.where(arrays_s.slot_valid, 1.0 / n, 0.0)
        val = lax.fori_loop(0, iters, body, val0)
        return val[None]

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=spec,
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_pagerank_sharded(part: Partition, damping: float, iters: int,
                         mesh: Mesh, axis_names=("data", "model"),
                         cfg: EngineConfig = EngineConfig()):
    """shard_map PageRank execution; see ``run_sharded`` for layout."""
    fn, sharding = make_sharded_pagerank_fn(
        part.S, part.R_max, part.n, damping, iters, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    return fn(arrays_dev)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def init_values(part: Partition, sem: Semiring, sources: dict[int, float]):
    """(S, R_max) initial values: semiring identity everywhere except all
    replicas of each source vertex (consistent initial view)."""
    val = np.full((part.S, part.R_max), sem.identity, dtype=np.float32)
    if sem.segment == "sum":
        val[:] = 0.0
    for v, x in sources.items():
        s0, sl0 = divmod(int(part.root_flat[v]), part.R_max)
        for k in range(part.cfg.rpvo_max):
            if part.sibling_mask[s0, sl0, k]:
                f = int(part.sibling_flat[s0, sl0, k])
                val[f // part.R_max, f % part.R_max] = x
    return val


def vertex_values(part: Partition, val) -> np.ndarray:
    """Extract the per-vertex (root-replica) values."""
    gval = np.asarray(val).reshape(-1)
    return gval[part.root_flat]
