"""Diffusive fixpoint engine (paper §4–§5), TPU-native.

The paper's asynchronous message-driven execution is re-expressed as bulk
edge-parallel relaxation rounds whose fixpoint equals the asynchronous
fixpoint (monotone semirings ⇒ order-free). One round is the diffuse-queue
drain: diffusions generated in round k are evaluated in round k+1 against
the newest vertex state, so stale diffusions are *subsumed* exactly as the
paper's lazy-diffuse pruning does.

The per-round math — relax, dense or §Perf compact targeted exchange,
rhizome collapse — lives in the unified lane-generic exchange layer
(``repro.exchange``); this module is the *driver*: it owns the fixpoint
loops, termination collectives, and Fig-6 stats bookkeeping for the
single-query (unlaned) table layout.  ``repro.query.lanes`` drives the
same exchange layer with a trailing query-lane axis.

Two execution paths share the same per-round math:

* ``run_stacked``  — arrays stacked ``(S, …)`` on one device; collectives
  are reshapes/transposes.  Used for correctness tests at any shard count.
* ``run_sharded``  — ``shard_map`` over a mesh with real collectives:
  - value/changed broadcast  → ``all_gather``      (the diffusion fan-out)
  - inbox exchange           → ``all_to_all``      (messages to replicas)
  - rhizome collapse         → ``all_gather`` + sibling combine
    (the AND-gate LCO trigger, lowered to a counted reduction)
  - termination detection    → ``psum`` of the any-changed flag
    (the paper assumes a hardware idle signal; the collective is ours).

With ``EngineConfig.use_pallas`` the per-round relax phase dispatches
through the fused ``kernels.fused_relax_reduce`` Pallas kernel: one
VMEM-resident pass, no ``(S, E_max)`` HBM intermediates, and grid cells
over frontier-dead edge chunks are skipped entirely (the TPU form of the
paper's diffusion pruning).  Without the flag the same math runs as
separate jnp ops — the oracle path.

Per-round counters reproduce the paper's Fig-6 statistics: messages
(actions delivered), actions whose predicate fired (work performed), and
diffusions pruned.
"""
from __future__ import annotations

import dataclasses
import typing
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import exchange, obs
from repro.core.actions import Semiring
from repro.core.partition import Partition


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    collapse: str = "eager"      # 'eager' | 'deferred' (min-semirings only)
    exchange: str = "dense"      # 'dense' | 'compact' (targeted messages)
    max_iters: int = 4096
    use_pallas: bool = False     # route the relax phase through Pallas
    # 'fused'  — one VMEM-resident gather+relax+mask+reduce kernel with
    #            frontier chunk skip (the hot path; default)
    # 'reduce' — jnp gather/relax/mask + the standalone segment-reduce
    #            kernel (the pre-fusion composition, kept for comparison)
    pallas_mode: str = "fused"
    # False skips the Fig-6 message counter (an O(E) boolean reduction per
    # round on the fused path); RunStats then reports zero messages/pruned
    track_stats: bool = True
    # Fused-kernel grid shape (ISSUE 5):
    # 'dense'    — the classic (num_sblk, num_chunks) grid with per-cell
    #              early exit (launch cost ∝ total work)
    # 'worklist' — host-planned 1-D launch over the live (i, j) cells
    #              only (launch cost ∝ frontier); requires a host-driven
    #              round loop, so it applies to the stacked runners and
    #              the delta rounds — traced collective loops
    #              (run_sharded's while_loop, the laned sharded fixpoint)
    #              warn once and route to 'device_worklist', the traced
    #              form of the same sparse launch
    # 'auto'     — per round: worklist when the live fraction of the
    #              dense grid drops below WORKLIST_AUTO_THRESHOLD
    # 'device_worklist' — the live-cell list is compacted ON DEVICE
    #              (cumsum-scatter over the frontier chunk bitmap) and
    #              launched over the pow2-padded full grid with masked
    #              tail cells.  Fully traced — composes with
    #              jit/shard_map, so whole fixpoints run through
    #              lax.while_loop with zero host syncs (ISSUE 8)
    grid_mode: str = "dense"
    # Rounds per dispatch window for device_worklist loops that still
    # need periodic host visibility (an installed flight recorder, the
    # QueryServer default tick).  One download of the frontier
    # trajectory per window instead of per round.
    device_window: int = 8
    # SMEM byte budget for the fused kernel's scalar-prefetch tables
    # (chunk ranges, tile lists, worklist cells).  None disables the
    # guard; set to the real-TPU SMEM size to make select_kernel_path
    # warn and widen vblk before a ~100k-chunk launch would overflow.
    smem_budget_bytes: int | None = None
    # Checkpoint cadence for the resilient driver (core.resilient): a
    # crc-verified snapshot of value/frontier state + accounting every K
    # rounds.  None disables (and keeps every shipped loop here exactly
    # as before — run_stacked never checkpoints; only the resilient
    # driver reads this knob, so the obs-off path stays trace-identical).
    checkpoint_every: int | None = None
    # VMEM byte budget for the fused kernel's value-table residency: the
    # kernel pins the whole padded (S*R_max[, Q]) slot table in VMEM when
    # it fits the budget, else tiles it out of HBM with per-cell
    # double-buffered async DMA (see kernels.fused_relax_reduce.
    # select_kernel_path).  None defers to the REPRO_VMEM_BUDGET env var,
    # then to DEFAULT_VMEM_BUDGET_BYTES — so paper-scale partitions whose
    # slot table exceeds VMEM run fused via tiling instead of failing to
    # compile.
    vmem_budget_bytes: int | None = None

    def __post_init__(self):
        if self.collapse not in ("eager", "deferred"):
            raise ValueError(f"collapse={self.collapse!r}")
        if self.exchange not in ("dense", "compact"):
            raise ValueError(f"exchange={self.exchange!r}")
        if self.pallas_mode not in ("fused", "reduce"):
            raise ValueError(f"pallas_mode={self.pallas_mode!r}")
        if self.vmem_budget_bytes is not None \
                and self.vmem_budget_bytes <= 0:
            raise ValueError(
                f"vmem_budget_bytes={self.vmem_budget_bytes!r}")
        if self.grid_mode not in ("dense", "worklist", "auto",
                                  "device_worklist"):
            raise ValueError(f"grid_mode={self.grid_mode!r}")
        if self.device_window < 1:
            raise ValueError(f"device_window={self.device_window!r}")
        if self.checkpoint_every is not None and self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every={self.checkpoint_every!r}")
        if self.smem_budget_bytes is not None \
                and self.smem_budget_bytes <= 0:
            raise ValueError(
                f"smem_budget_bytes={self.smem_budget_bytes!r}")

    @property
    def wants_worklist(self) -> bool:
        """Whether runners should plan HOST-side sparse worklist launches
        (only meaningful on the fused Pallas path — the jnp oracle and
        the pre-fusion composition have no grid to sparsify)."""
        return (self.grid_mode in ("worklist", "auto") and self.use_pallas
                and self.pallas_mode == "fused")

    @property
    def wants_device_worklist(self) -> bool:
        """Whether the relax phase compacts its worklist on device —
        the traced launch mode that keeps whole fixpoints in one
        dispatch.  ``relax`` reads this straight off ``grid_mode``; the
        runners use it to pick the traced loop over the host loop."""
        return (self.grid_mode == "device_worklist" and self.use_pallas
                and self.pallas_mode == "fused")


class DeviceArrays(typing.NamedTuple):
    """Static per-shard tensors; leading dim S (stacked) or sharded.

    The ``edge_dst_compact``/``inbox_slot_map``/``rz_*`` fields implement
    the §Perf *compact targeted exchange*: contributions travel as
    (target, slot) messages instead of a dense global inbox — the TPU form
    of the paper's message-driven semantics."""

    edge_src_root_flat: jax.Array  # (S, E_max) int32
    edge_dst_flat: jax.Array       # (S, E_max) int32 (sorted per shard)
    edge_w: jax.Array              # (S, E_max) f32
    edge_mask: jax.Array           # (S, E_max) bool
    sibling_flat: jax.Array        # (S, R_max, K) int32
    sibling_mask: jax.Array        # (S, R_max, K) bool
    slot_valid: jax.Array          # (S, R_max) bool
    edge_dst_compact: jax.Array    # (S, E_max) int32 -> [0, S*P_t)
    inbox_slot_map: jax.Array      # (S, S, P_t) int32, R_max = pad
    rz_local: jax.Array            # (S, R_rz_max) int32, R_max = pad
    rz_sibling_idx: jax.Array      # (S, R_rz_max, K) int32
    rz_sibling_mask: jax.Array     # (S, R_rz_max, K) bool

    @classmethod
    def specs(cls, spec) -> "DeviceArrays":
        """Per-field shard_map spec tree (every field shares ``spec``) —
        the in_specs entry for every sharded runner over these tables."""
        return cls(*([spec] * len(cls._fields)))

    @classmethod
    def from_partition(cls, part: Partition) -> "DeviceArrays":
        return cls(
            edge_src_root_flat=jnp.asarray(part.edge_src_root_flat, jnp.int32),
            edge_dst_flat=jnp.asarray(part.edge_dst_flat, jnp.int32),
            edge_w=jnp.asarray(part.edge_w, jnp.float32),
            edge_mask=jnp.asarray(part.edge_mask),
            sibling_flat=jnp.asarray(part.sibling_flat, jnp.int32),
            sibling_mask=jnp.asarray(part.sibling_mask),
            slot_valid=jnp.asarray(part.slot_vertex >= 0),
            edge_dst_compact=jnp.asarray(part.edge_dst_compact, jnp.int32),
            inbox_slot_map=jnp.asarray(part.inbox_slot_map, jnp.int32),
            rz_local=jnp.asarray(part.rz_local, jnp.int32),
            rz_sibling_idx=jnp.asarray(part.rz_sibling_idx, jnp.int32),
            rz_sibling_mask=jnp.asarray(part.rz_sibling_mask),
        )


class RunStats(typing.NamedTuple):
    iterations: jax.Array        # rounds executed
    messages: jax.Array          # actions delivered (edge messages)
    work_actions: jax.Array      # predicate-true slot updates
    pruned_actions: jax.Array    # delivered but predicate-false
    diffusions: jax.Array        # slots that diffused (entered the frontier)


# --------------------------------------------------------------------------
# per-round math: unified exchange-layer compositions (kept under their
# historic names — benchmarks and kernel-parity tests drive the rounds
# directly to measure exactly what the runners ship)
# --------------------------------------------------------------------------

def _fixpoint_round_stacked(sem, arrays, cfg, S, R_max, val, chg,
                            worklist=None):
    return exchange.fixpoint_round_stacked(
        sem, arrays, cfg, S, R_max, val, chg, worklist=worklist)


def _pagerank_round_stacked(sem, arrays, cfg, S, R_max, base, damping, val,
                            chg, worklist=None):
    return exchange.pagerank_round_stacked(
        sem, arrays, cfg, S, R_max, base, damping, val, chg,
        worklist=worklist)


# --------------------------------------------------------------------------
# worklist launch planning (grid_mode='worklist'|'auto' host-driven rounds)
# --------------------------------------------------------------------------

# 'auto' plans a worklist launch only when the dense grid's live fraction
# drops below this — a dense frontier gains nothing from the 1-D launch
# but pays the planning pass
WORKLIST_AUTO_THRESHOLD = 0.25


def launch_planner(part: Partition, cfg: EngineConfig, q_pad: int = 1):
    """Host-side ``WorklistPlanner`` for the stacked fused launch under
    ``cfg`` — the planner must mirror the exact launch ``relax`` builds:
    dense exchange flattens ``edge_dst_flat`` over ``S*R_max`` segments;
    compact exchange offsets ``edge_dst_compact`` into per-source-shard
    id windows over ``S*S*P_t``.  ``q_pad`` is the lane-PADDED width of
    laned launches (sizes the residency choice and the DMA byte mirror).
    """
    from repro.kernels.fused_relax_reduce import (
        EBLK, WorklistPlanner, select_kernel_path, _round_up)
    S, R_max = part.S, part.R_max
    num_slots = S * R_max
    if cfg.exchange == "compact":
        P_t = part.P_t
        offs = (np.arange(S, dtype=np.int64) * (S * P_t))[:, None]
        ids = np.asarray(part.edge_dst_compact) + offs
        num_segments = S * S * P_t
    else:
        ids = np.asarray(part.edge_dst_flat)
        num_segments = S * R_max
    n_chunks = _round_up(ids.size, EBLK) // EBLK
    path, vblk = select_kernel_path(
        num_slots, q_pad, cfg.vmem_budget_bytes, n_chunks=n_chunks,
        smem_budget_bytes=cfg.smem_budget_bytes)
    return WorklistPlanner(
        ids, np.asarray(part.edge_mask), np.asarray(part.edge_src_root_flat),
        num_segments, num_slots=num_slots, path=path, vblk=vblk,
        lane_width=q_pad, smem_budget_bytes=cfg.smem_budget_bytes)


def plan_round_worklist(planner, cfg: EngineConfig, gchg,
                        with_info: bool = False):
    """One round's launch decision for a host-driven loop: a ``Worklist``
    under 'worklist' (and under 'auto' when the frontier is sparse
    enough), else None — the dense early-exit grid.  The auto threshold
    is applied inside ``plan`` so a dense round bails out before any
    per-cell planning work.  ``with_info=True`` also returns the
    planner's ``WorklistInfo`` accounting (None for dense rounds) — the
    flight recorder's per-round mirror, captured for free from the plan
    the launch actually uses."""
    thresh = (WORKLIST_AUTO_THRESHOLD if cfg.grid_mode == "auto"
              else None)
    wl, info = planner.plan(gchg, max_live_fraction=thresh)
    return (wl, info) if with_info else wl


def _obs_record_round(rec, run, part, cfg, planner, rnd, gchg, frontier,
                      mc, work, wl, info, wall_s):
    """Build + store one flight-recorder ``RoundRecord``: the grid-cell /
    DMA columns come from the planner mirror of the launch this round
    actually made (WorklistInfo for worklist launches, the dense-grid
    mirror otherwise), plus the per-shard message-volume mirror feeding
    the skew gauge.  Only ever called with a recorder installed — the
    obs-off hot path never reaches here."""
    grid = "dense" if wl is None else "worklist"
    if planner is not None and cfg.use_pallas \
            and cfg.pallas_mode == "fused":
        path = planner.path
        if wl is not None:
            cells, launched = info.cells, info.launched
            tile_dmas, dma_bytes = info.tile_dmas, info.dma_bytes
        else:
            d = planner.dense_mirror(gchg)
            cells, launched = d["cells"], d["launched"]
            tile_dmas, dma_bytes = d["tile_dmas"], d["dma_bytes"]
    else:
        path = cfg.pallas_mode if cfg.use_pallas else "jnp"
        cells = launched = tile_dmas = dma_bytes = 0
    shard = exchange.shard_message_mirror(
        part.edge_mask, part.edge_src_root_flat, gchg)
    rec.add_round(
        obs.RoundRecord(
            run=run, round=rnd, frontier=frontier, messages=mc, work=work,
            pruned=mc - min(work, mc), grid=grid, path=path, cells=cells,
            launched=launched, tile_dmas=tile_dmas, dma_bytes=dma_bytes,
            wall_s=wall_s, shard_messages=[int(x) for x in shard]),
        frontier_bitmap=gchg.copy() if rec.keep_frontiers else None)


# --------------------------------------------------------------------------
# fixpoint apps (BFS / SSSP)
# --------------------------------------------------------------------------

def run_stacked(sem: Semiring, part: Partition, init_val: np.ndarray,
                cfg: EngineConfig = EngineConfig(), init_changed=None):
    """Single-device stacked execution. ``init_val``: (S, R_max) float32.
    ``init_changed`` (optional bool (S, R_max)) seeds the first frontier —
    used by incremental recompute to re-diffuse only mutation sites.

    Under ``cfg.grid_mode='worklist'|'auto'`` (fused Pallas only) the
    fixpoint runs as a host-driven round loop: each round's frontier
    plans a sparse worklist launch (``launch_planner``), so launch cost
    tracks the live frontier instead of the dense grid.  Values and
    stats are identical to the traced loop (min semirings are
    bit-identical)."""
    if sem.segment != "min":
        raise ValueError(
            "run_stacked drives monotone min-semiring fixpoints; the "
            "collapse of a combined candidate is only sound there — use "
            "run_pagerank_stacked for counted sum-semiring rounds")
    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    # an installed flight recorder also routes through the host-driven
    # loop (bit-identical values/stats for min semirings — the loop the
    # worklist grid already runs) so each round can be recorded without
    # adding syncs to the traced while_loop; with no recorder the
    # dispatch below is exactly the pre-obs one.  device_worklist keeps
    # the traced loop (its compaction is traced) — a recorder there
    # switches to the K-round windowed device loop, which downloads the
    # frontier trajectory once per window instead of once per round
    if cfg.wants_device_worklist:
        if obs.get_recorder() is not None:
            return _run_stacked_deviceloop(sem, part, arrays, cfg,
                                           init_val, init_changed)
    elif cfg.wants_worklist or obs.get_recorder() is not None:
        return _run_stacked_hostloop(sem, part, arrays, cfg, init_val,
                                     init_changed)

    def body(carry):
        val, chg, it, stats = carry
        new_val, new_chg, msg_count = exchange.fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg
        )
        work = new_chg.sum()
        stats = RunStats(
            iterations=stats.iterations + 1,
            messages=stats.messages + msg_count,
            work_actions=stats.work_actions + work,
            pruned_actions=stats.pruned_actions
            + msg_count - jnp.minimum(work, msg_count),
            diffusions=stats.diffusions + work,
        )
        return new_val, new_chg, it + 1, stats

    def cond(carry):
        _, chg, it, _ = carry
        return jnp.any(chg) & (it < cfg.max_iters)

    if init_changed is not None:
        init_chg = jnp.asarray(init_changed) & arrays.slot_valid
    else:
        init_chg = sem.improved(
            jnp.asarray(init_val),
            jnp.full_like(jnp.asarray(init_val), sem.identity)
        ) & arrays.slot_valid
    zero = jnp.zeros((), jnp.int64) if jax.config.jax_enable_x64 else jnp.zeros((), jnp.int32)
    stats0 = RunStats(zero, zero, zero, zero, zero)
    val, chg, it, stats = lax.while_loop(
        cond, body, (jnp.asarray(init_val), init_chg, zero, stats0)
    )
    # the whole fixpoint was ONE traced dispatch; reading the results
    # below is its single host sync
    _count_dispatches(sem.name, 1, 1)
    if cfg.collapse == "deferred":
        val = exchange.collapse(sem, val.reshape(-1), arrays.sibling_flat,
                                arrays.sibling_mask)
    return val, stats


def _count_dispatches(run: str, dispatches: int, host_syncs: int):
    """Registry accounting for the BENCH dispatch/host-sync columns:
    how many jitted dispatches a fixpoint issued and how many
    device→host sync points (frontier/result downloads) it paid.  Host
    loops pay one of each per round; device_worklist loops one per
    K-round window — or one per whole fixpoint with no recorder."""
    m = obs.registry()
    m.counter(
        "engine_dispatches_total",
        "jitted dispatches issued by engine fixpoint loops"
    ).labels(run=run).inc(dispatches)
    m.counter(
        "engine_host_syncs_total",
        "device->host sync points paid by engine fixpoint loops"
    ).labels(run=run).inc(host_syncs)


def _host_stats(it, msgs, work, pruned):
    dtype = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    mk = lambda x: jnp.asarray(x, dtype)  # noqa: E731
    return RunStats(iterations=mk(it), messages=mk(msgs),
                    work_actions=mk(work), pruned_actions=mk(pruned),
                    diffusions=mk(work))


def _run_stacked_hostloop(sem, part, arrays, cfg, init_val, init_changed):
    """Worklist-mode fixpoint: the traced ``lax.while_loop`` becomes a
    Python loop so each round's frontier can plan its launch host-side.
    One jitted round fn serves every round — jit retraces only when the
    worklist's power-of-two length bucket changes (O(log cells) traces)
    or a dense round passes ``worklist=None``.

    Also the flight-recorder path for ``grid_mode='dense'``: with a
    recorder installed each round appends a ``RoundRecord`` (frontier,
    messages, planner-mirror cells/DMA, path decision, wall time) —
    recorder-only host work, after the round's existing frontier
    download."""
    S, R_max = part.S, part.R_max
    rec = obs.get_recorder()
    planner = (launch_planner(part, cfg)
               if cfg.wants_worklist
               or (rec is not None and cfg.use_pallas
                   and cfg.pallas_mode == "fused")
               else None)

    @jax.jit
    def round_fn(val, chg, worklist):
        return exchange.fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg, worklist=worklist)

    val = jnp.asarray(init_val)
    if init_changed is not None:
        chg = jnp.asarray(init_changed) & arrays.slot_valid
    else:
        chg = sem.improved(val, jnp.full_like(val, sem.identity)) \
            & arrays.slot_valid
    chg_h = np.asarray(chg)        # ONE frontier download per round:
    it = msgs = work_total = pruned = 0   # reused for plan + accounting
    while it < cfg.max_iters:
        if not chg_h.any():
            break
        gchg = chg_h.reshape(-1)
        wl = info = None
        if cfg.wants_worklist:
            wl, info = plan_round_worklist(planner, cfg, gchg,
                                           with_info=True)
        frontier = int(gchg.sum()) if rec is not None else 0
        t0 = rec.tracer.now() if rec is not None else 0.0
        span = (rec.tracer.span("round", track=f"engine/{sem.name}",
                                round=it + 1) if rec is not None else None)
        val, chg, mc = round_fn(val, chg, wl)
        chg_h = np.asarray(chg)
        mc, work = int(mc), int(chg_h.sum())
        it += 1
        msgs += mc
        work_total += work
        pruned += mc - min(work, mc)
        if rec is not None:
            wall = rec.tracer.now() - t0
            span.end(frontier=frontier, messages=mc)
            _obs_record_round(rec, sem.name, part, cfg, planner, it, gchg,
                              frontier, mc, work, wl, info, wall)
    _count_dispatches(sem.name, it, it)
    stats = _host_stats(it, msgs, work_total, pruned)
    if cfg.collapse == "deferred":
        val = exchange.collapse(sem, val.reshape(-1), arrays.sibling_flat,
                                arrays.sibling_mask)
    return val, stats


def _record_device_window(rec, run, part, planner, l_pad, window, it_end,
                          counts_h, ent, wall):
    """Post-hoc accounting for one K-round device window, recomputed
    from the frontier trajectory the dispatch returned: ``ent[r]`` is
    round r's ENTERING frontier bitmap (flattened), ``ent[k]`` the
    window's exit frontier, ``counts_h[r]`` the round's message count.
    Rounds whose entering frontier is empty are no-ops under every
    semiring (absorbing identity) — they ran on device but count as
    zero rounds, matching the host loop's early exit.  Appends ONE
    per-window ``RoundRecord`` (``window`` field set; per-round cells /
    DMA / shard mirrors summed over the window's live rounds, so window
    sums equal the per-round host-driven totals) and returns
    (live_rounds, messages, work, pruned)."""
    k = counts_h.shape[0]
    live_rounds = msgs = work = pruned = 0
    cells = tile_dmas = dma_bytes = 0
    shard_sum = None
    for r in range(k):
        if not ent[r].any():
            break
        live_rounds += 1
        mc = int(counts_h[r])
        wk = int(ent[r + 1].sum())
        msgs += mc
        work += wk
        pruned += mc - min(wk, mc)
        d = planner.dense_mirror(ent[r])
        cells += d["cells"]
        tile_dmas += d["tile_dmas"]
        dma_bytes += d["dma_bytes"]
        sh = np.asarray(exchange.shard_message_mirror(
            part.edge_mask, part.edge_src_root_flat, ent[r]))
        shard_sum = sh if shard_sum is None else shard_sum + sh
    if rec is not None:
        rec.add_round(
            obs.RoundRecord(
                run=run, round=it_end, frontier=int(ent[0].sum()),
                messages=msgs, work=work, pruned=pruned,
                grid="device_worklist", path=planner.path, cells=cells,
                launched=l_pad * live_rounds, tile_dmas=tile_dmas,
                dma_bytes=dma_bytes, wall_s=wall,
                shard_messages=([int(x) for x in shard_sum]
                                if shard_sum is not None else None),
                window=window),
            frontier_bitmap=ent[0].copy() if rec.keep_frontiers else None)
    return live_rounds, msgs, work, pruned


def _run_stacked_deviceloop(sem, part, arrays, cfg, init_val, init_changed):
    """Recorder-visible device_worklist fixpoint: K-round windows
    (``cfg.device_window``), each ONE traced dispatch through
    ``exchange.fixpoint_window_stacked``.  The host sees the frontier
    trajectory once per window — the flight recorder's per-window
    ``RoundRecord`` mirrors are recomputed post-hoc from it, never from
    extra syncs inside the loop.  With no recorder installed
    ``run_stacked`` skips this loop entirely and runs the whole
    fixpoint as a single traced while_loop dispatch."""
    S, R_max = part.S, part.R_max
    rec = obs.get_recorder()
    planner = launch_planner(part, cfg)
    from repro.kernels.fused_relax_reduce import _wl_pad_len
    l_pad = _wl_pad_len(planner.total_cells)

    window_fns: dict = {}

    def window_fn(k):
        if k not in window_fns:
            window_fns[k] = jax.jit(
                lambda v, c, _k=k: exchange.fixpoint_window_stacked(
                    sem, arrays, cfg, S, R_max, _k, v, c))
        return window_fns[k]

    val = jnp.asarray(init_val)
    if init_changed is not None:
        chg = jnp.asarray(init_changed) & arrays.slot_valid
    else:
        chg = sem.improved(val, jnp.full_like(val, sem.identity)) \
            & arrays.slot_valid
    chg_h = np.asarray(chg)
    it = msgs = work_total = pruned = 0
    window = 0
    while it < cfg.max_iters and chg_h.any():
        k = min(cfg.device_window, cfg.max_iters - it)
        window += 1
        t0 = rec.tracer.now() if rec is not None else 0.0
        span = (rec.tracer.span("window", track=f"engine/{sem.name}",
                                window=window) if rec is not None else None)
        val, chg, counts, frontiers = window_fn(k)(val, chg)
        chg_h = np.asarray(chg)
        wall = rec.tracer.now() - t0 if rec is not None else 0.0
        ent = np.concatenate(
            [np.asarray(frontiers).reshape(k, -1).astype(bool),
             chg_h.reshape(1, -1)], axis=0)
        live, w_msgs, w_work, w_pruned = _record_device_window(
            rec, sem.name, part, planner, l_pad, window,
            it + int((ent[:k].any(axis=1)).sum()), np.asarray(counts),
            ent, wall)
        it += live
        msgs += w_msgs
        work_total += w_work
        pruned += w_pruned
        if span is not None:
            span.end(frontier=int(ent[0].sum()), messages=w_msgs,
                     rounds=live)
    _count_dispatches(sem.name, window, window)
    stats = _host_stats(it, msgs, work_total, pruned)
    if cfg.collapse == "deferred":
        val = exchange.collapse(sem, val.reshape(-1), arrays.sibling_flat,
                                arrays.sibling_mask)
    return val, stats


# --------------------------------------------------------------------------
# PageRank-style counted-iteration apps
# --------------------------------------------------------------------------

def run_pagerank_stacked(part: Partition, damping: float, iters: int,
                         cfg: EngineConfig = EngineConfig()):
    from repro.core.actions import PAGERANK as sem

    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    base = (1.0 - damping) / part.n

    # initial score 1/n on every replica (consistent view)
    val0 = jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0)
    chg = arrays.slot_valid  # PR predicate is #t — always diffuse

    def body(_, val):
        new_val, _ = exchange.pagerank_round_stacked(
            sem, arrays, cfg, S, R_max, base, damping, val, chg)
        return new_val

    val = lax.fori_loop(0, iters, body, val0)
    return val


def _tol_table(part: Partition, tol):
    """Per-slot residual tolerance: a scalar passes through; an (n,)
    per-vertex array maps every replica of vertex v to ``tol[v]``
    (invalid slots get +inf — they never diffuse)."""
    tol_arr = np.asarray(tol, np.float32)
    if tol_arr.ndim == 0:
        return jnp.asarray(float(tol_arr), jnp.float32)
    if tol_arr.shape != (part.n,):
        raise ValueError(
            f"per-vertex tol must be shape ({part.n},); got {tol_arr.shape}")
    sv = np.asarray(part.slot_vertex)
    table = np.where(sv >= 0, tol_arr[np.maximum(sv, 0)], np.inf)
    return jnp.asarray(table, jnp.float32)


def run_pagerank_delta(part: Partition, damping: float = 0.85,
                       tol=1e-6, cfg: EngineConfig = EngineConfig(),
                       max_rounds: int = 256,
                       init_rank=None, init_delta=None):
    """Stacked **delta-PageRank**: push-based residual propagation with
    per-vertex pruning (ISSUE 5 tentpole).

    Ranks accumulate the Neumann series ``Σ_k (d·Aᵀ)^k base`` — the same
    fixpoint the dense power iteration converges to — but each round
    diffuses only residual deltas above ``tol`` (scalar or (n,)
    per-vertex), so the frontier *shrinks* as residuals decay (by ~d per
    round) and the fused kernel's chunk-skip / worklist launch / tile
    filter all fire for the sum semiring.  Dropping sub-tolerance
    residuals bounds the rank error by O(tol / (1-d)) per vertex.

    Runs host-driven (the termination test and any worklist planning
    need the frontier on host).  Returns ((S, R_max) ranks, RunStats
    with the Fig-6 accounting: messages delivered, slots whose residual
    stayed live (work), deliveries pruned below tolerance).

    ``init_rank`` / ``init_delta`` warm-start the Neumann accumulation:
    streaming maintenance seeds the migrated old ranks plus a (possibly
    negative) residual correction on mutated vertices, so only the
    affected region re-diffuses (frontier tests use ``|delta|``)."""
    from repro.core.actions import PAGERANK as sem

    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    base = (1.0 - damping) / part.n
    tol_t = _tol_table(part, tol)
    if init_rank is None:
        rank0 = delta0 = jnp.where(arrays.slot_valid, base, 0.0)
    else:
        rank0 = jnp.asarray(init_rank, jnp.float32)
        delta0 = jnp.asarray(init_delta, jnp.float32)
    if cfg.wants_device_worklist:
        return _run_pagerank_delta_deviceloop(
            sem, part, arrays, cfg, damping, tol_t, rank0, delta0,
            max_rounds)
    rec = obs.get_recorder()
    planner = (launch_planner(part, cfg)
               if cfg.wants_worklist
               or (rec is not None and cfg.use_pallas
                   and cfg.pallas_mode == "fused")
               else None)

    @jax.jit
    def round_fn(rank, delta, worklist):
        return exchange.delta_pagerank_round_stacked(
            sem, arrays, cfg, S, R_max, damping, tol_t, rank, delta,
            worklist=worklist)

    rank, delta = rank0, delta0
    # each round returns next round's frontier — computed on device,
    # downloaded ONCE per round for planning + accounting alike
    chg_h = np.asarray((jnp.abs(delta) > tol_t) & arrays.slot_valid)
    it = msgs = work_total = pruned = 0
    while it < max_rounds:
        if not chg_h.any():
            break
        gchg = chg_h.reshape(-1)
        wl = info = None
        if cfg.wants_worklist:
            wl, info = plan_round_worklist(planner, cfg, gchg,
                                           with_info=True)
        frontier = int(gchg.sum()) if rec is not None else 0
        t0 = rec.tracer.now() if rec is not None else 0.0
        span = (rec.tracer.span("round", track="engine/pagerank_delta",
                                round=it + 1) if rec is not None else None)
        rank, delta, chg, mc = round_fn(rank, delta, wl)
        chg_h = np.asarray(chg)
        mc, work = int(mc), int(chg_h.sum())
        it += 1
        msgs += mc
        work_total += work
        pruned += mc - min(work, mc)
        if rec is not None:
            wall = rec.tracer.now() - t0
            span.end(frontier=frontier, messages=mc)
            _obs_record_round(rec, "pagerank_delta", part, cfg, planner,
                              it, gchg, frontier, mc, work, wl, info, wall)
    _count_dispatches("pagerank_delta", it, it)
    return rank, _host_stats(it, msgs, work_total, pruned)


def _run_pagerank_delta_deviceloop(sem, part, arrays, cfg, damping, tol_t,
                                   rank0, delta0, max_rounds):
    """delta-PageRank under ``grid_mode='device_worklist'``: the
    residual-tolerance frontier test runs ON DEVICE, so with no flight
    recorder the whole fixpoint is ONE traced ``lax.while_loop``
    dispatch; with a recorder it runs in K-round windows
    (``cfg.device_window``) whose per-round accounting is recomputed
    post-hoc from the returned frontier trajectory."""
    S, R_max = part.S, part.R_max
    rec = obs.get_recorder()
    rank, delta = rank0, delta0

    if rec is None:
        @jax.jit
        def fixpoint(rank, delta):
            zero = (jnp.zeros((), jnp.int64)
                    if jax.config.jax_enable_x64
                    else jnp.zeros((), jnp.int32))

            def body(carry):
                rank, delta, it, msgs, work, pruned = carry
                nr, nd, nchg, mc = exchange.delta_pagerank_round_stacked(
                    sem, arrays, cfg, S, R_max, damping, tol_t, rank,
                    delta)
                mc = mc.astype(zero.dtype)
                wk = nchg.sum(dtype=zero.dtype)
                return (nr, nd, it + 1, msgs + mc, work + wk,
                        pruned + mc - jnp.minimum(wk, mc))

            def cond(carry):
                _, delta, it, _, _, _ = carry
                live = jnp.any((jnp.abs(delta) > tol_t)
                               & arrays.slot_valid)
                return live & (it < max_rounds)

            return lax.while_loop(
                cond, body, (rank, delta, zero, zero, zero, zero))

        rank, delta, it, msgs, work, pruned = fixpoint(rank, delta)
        _count_dispatches("pagerank_delta", 1, 1)
        return rank, _host_stats(int(it), int(msgs), int(work),
                                 int(pruned))

    planner = launch_planner(part, cfg)
    from repro.kernels.fused_relax_reduce import _wl_pad_len
    l_pad = _wl_pad_len(planner.total_cells)

    window_fns: dict = {}

    def window_fn(k):
        if k not in window_fns:
            window_fns[k] = jax.jit(
                lambda r, d, _k=k:
                exchange.delta_pagerank_window_stacked(
                    sem, arrays, cfg, S, R_max, _k, damping, tol_t, r, d))
        return window_fns[k]

    chg_h = np.asarray((jnp.abs(delta) > tol_t) & arrays.slot_valid)
    it = msgs = work_total = pruned = 0
    window = 0
    while it < max_rounds and chg_h.any():
        k = min(cfg.device_window, max_rounds - it)
        window += 1
        t0 = rec.tracer.now()
        span = rec.tracer.span("window", track="engine/pagerank_delta",
                               window=window)
        rank, delta, chg, counts, frontiers = window_fn(k)(rank, delta)
        chg_h = np.asarray(chg)
        wall = rec.tracer.now() - t0
        ent = np.concatenate(
            [np.asarray(frontiers).reshape(k, -1).astype(bool),
             chg_h.reshape(1, -1)], axis=0)
        live, w_msgs, w_work, w_pruned = _record_device_window(
            rec, "pagerank_delta", part, planner, l_pad, window,
            it + int((ent[:k].any(axis=1)).sum()), np.asarray(counts),
            ent, wall)
        it += live
        msgs += w_msgs
        work_total += w_work
        pruned += w_pruned
        span.end(frontier=int(ent[0].sum()), messages=w_msgs, rounds=live)
    _count_dispatches("pagerank_delta", window, window)
    return rank, _host_stats(it, msgs, work_total, pruned)


def make_sharded_pagerank_delta_fn(S: int, R_max: int, damping: float,
                                   tol: float, mesh: Mesh,
                                   axis_names=("data", "model"),
                                   cfg: EngineConfig = EngineConfig()):
    """shard_map delta-PageRank round as a jit-able fn of (DeviceArrays,
    rank, delta) -> (rank, delta, psum'd count, psum'd live-slot count).
    The serving loop drives it un-looped (the frontier-empty termination
    lives on host); host-planned worklist modes route to the traced
    ``device_worklist`` launch inside shard_map (``_sharded_cfg``)."""
    from repro.core.actions import PAGERANK as sem

    cfg = _sharded_cfg(cfg, "make_sharded_pagerank_delta_fn")
    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (DeviceArrays.specs(spec), spec, spec)

    def shard_fn(arrays_l: DeviceArrays, rank_l, delta_l):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        new_rank, new_delta, new_chg, counts = \
            exchange.delta_pagerank_round_shard(
                sem, arrays_s, cfg, S, R_max, axis_names, damping, tol,
                rank_l[0], delta_l[0])
        counts = lax.psum(counts, axis_names)
        work = lax.psum(new_chg.sum(), axis_names)
        return new_rank[None], new_delta[None], counts[None], work[None]

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs,
        out_specs=(spec, spec, spec, spec), check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_pagerank_delta_sharded(part: Partition, damping: float = 0.85,
                               tol: float = 1e-6, mesh: Mesh = None,
                               axis_names=("data", "model"),
                               cfg: EngineConfig = EngineConfig(),
                               max_rounds: int = 256,
                               init_rank=None, init_delta=None):
    """shard_map delta-PageRank execution (host-driven rounds over real
    collectives); layout as in ``run_sharded``.  Scalar ``tol`` only —
    a per-vertex table would need its own sharded layout."""
    if np.ndim(tol) != 0:
        raise ValueError("run_pagerank_delta_sharded takes a scalar tol")
    fn, sharding = make_sharded_pagerank_delta_fn(
        part.S, part.R_max, damping, float(tol), mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    slot_valid = np.asarray(part.slot_vertex) >= 0
    base = (1.0 - damping) / part.n
    if init_rank is None:
        init_rank = init_delta = jnp.where(jnp.asarray(slot_valid), base, 0.0)
    rank = jax.device_put(jnp.asarray(init_rank, jnp.float32), sharding)
    delta = jax.device_put(jnp.asarray(init_delta, jnp.float32), sharding)
    it = msgs = work_total = pruned = 0
    rec = obs.get_recorder()
    rec_path = "jnp"
    if rec is not None and cfg.use_pallas and cfg.pallas_mode == "fused":
        from repro.kernels.fused_relax_reduce import select_kernel_path
        rec_path, _ = select_kernel_path(
            part.S * part.R_max, 1, cfg.vmem_budget_bytes,
            smem_budget_bytes=cfg.smem_budget_bytes)
    elif cfg.use_pallas:
        rec_path = cfg.pallas_mode
    # the round's psum'd live-slot count IS the next round's frontier
    # size — only the initial frontier needs a host check
    live = bool(((np.abs(np.asarray(delta)) > tol) & slot_valid).any())
    while live and it < max_rounds:
        if rec is not None:
            # recorder-only frontier download: the per-shard message
            # mirror needs the live-residual bitmap host-side
            gchg = ((np.abs(np.asarray(delta)) > tol)
                    & slot_valid).reshape(-1)
            frontier = int(gchg.sum())
            t0 = rec.tracer.now()
            span = rec.tracer.span(
                "round", track="engine/pagerank_delta_sharded",
                round=it + 1)
        rank, delta, counts, work = fn(arrays_dev, rank, delta)
        mc, w = int(counts[0]), int(work[0])
        it += 1
        msgs += mc
        work_total += w
        pruned += mc - min(w, mc)
        live = w > 0
        if rec is not None:
            wall = rec.tracer.now() - t0
            span.end(frontier=frontier, messages=mc)
            shard = exchange.shard_message_mirror(
                part.edge_mask, part.edge_src_root_flat, gchg)
            rec.add_round(
                obs.RoundRecord(
                    run="pagerank_delta_sharded", round=it,
                    frontier=frontier, messages=mc, work=w,
                    pruned=mc - min(w, mc), grid="dense", path=rec_path,
                    cells=0, launched=0, tile_dmas=0, dma_bytes=0,
                    wall_s=wall,
                    shard_messages=[int(x) for x in shard]),
                frontier_bitmap=gchg.copy() if rec.keep_frontiers
                else None)
    _count_dispatches("pagerank_delta_sharded", it, it)
    return rank, _host_stats(it, msgs, work_total, pruned)


# --------------------------------------------------------------------------
# sharded execution (shard_map over a real mesh)
# --------------------------------------------------------------------------

_SHARDED_GRID_WARNED: set = set()


def _sharded_cfg(cfg: EngineConfig, where: str) -> EngineConfig:
    """Traced collective loops cannot run host-planned worklists — they
    used to silently drop ``grid_mode='worklist'|'auto'`` to the dense
    fallback.  Now: warn once per call-site and route to the traced
    ``'device_worklist'`` launch — the same sparse-launch intent, with
    the compaction done on device inside the collective loop."""
    if cfg.grid_mode in ("worklist", "auto") and cfg.use_pallas \
            and cfg.pallas_mode == "fused":
        if where not in _SHARDED_GRID_WARNED:
            _SHARDED_GRID_WARNED.add(where)
            warnings.warn(
                f"{where}: grid_mode={cfg.grid_mode!r} needs a "
                "host-driven round loop, which a traced collective loop "
                "cannot run; routing to grid_mode='device_worklist' "
                "(on-device compaction — same sparse launch, no host "
                "sync)", stacklevel=3)
        return dataclasses.replace(cfg, grid_mode="device_worklist")
    return cfg


def make_sharded_fn(sem: Semiring, S: int, R_max: int,
                    mesh: Mesh, axis_names=("data", "model"),
                    cfg: EngineConfig = EngineConfig()):
    """Builds the shard_map diffusive fixpoint as a jit-able fn of
    (DeviceArrays, val) — usable with concrete arrays (run_sharded) or
    ShapeDtypeStructs (AOT dry-run lowering)."""
    if sem.segment != "min":
        raise ValueError(
            "make_sharded_fn drives monotone min-semiring fixpoints; use "
            "make_sharded_pagerank_fn for counted sum-semiring rounds")
    cfg = _sharded_cfg(cfg, "make_sharded_fn")
    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays.specs(spec),
        spec,
    )

    def shard_fn(arrays_l: DeviceArrays, val_l):
        # strip leading local shard dim of size 1
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val = val_l[0]
        round_fn = exchange.make_shard_fixpoint_round(
            sem, arrays_s, cfg, S, R_max, axis_names)

        def body(carry):
            val, chg, it, stats = carry
            new_val, new_chg, msg_count = round_fn(val, chg)
            msgs = lax.psum(msg_count, axis_names)
            work = lax.psum(new_chg.sum(), axis_names)
            stats = RunStats(
                iterations=stats.iterations + 1,
                messages=stats.messages + msgs,
                work_actions=stats.work_actions + work,
                pruned_actions=stats.pruned_actions
                + msgs - jnp.minimum(work, msgs),
                diffusions=stats.diffusions + work,
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            anyc = lax.psum(chg.any().astype(jnp.int32), axis_names)
            return (anyc > 0) & (it < cfg.max_iters)

        init_chg = (
            sem.improved(val, jnp.full_like(val, sem.identity))
            & arrays_s.slot_valid
        )
        zero = jnp.zeros((), jnp.int32)
        stats0 = RunStats(zero, zero, zero, zero, zero)
        val, chg, it, stats = lax.while_loop(
            cond, body, (val, init_chg, zero, stats0)
        )
        if cfg.collapse == "deferred":
            val = exchange.collapse(
                sem, lax.all_gather(val, axis_names, tiled=True),
                arrays_s.sibling_flat, arrays_s.sibling_mask)
        return val[None], jax.tree.map(lambda x: x[None], stats)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec, RunStats(*([spec] * 5))),
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_sharded(sem: Semiring, part: Partition, init_val: np.ndarray,
                mesh: Mesh, axis_names=("data", "model"),
                cfg: EngineConfig = EngineConfig()):
    """shard_map execution. Leading (shard) dim of every array is split over
    ``axis_names``; requires prod(mesh[axis_names]) == part.S."""
    fn, sharding = make_sharded_fn(
        sem, part.S, part.R_max, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    val_dev = jax.device_put(jnp.asarray(init_val), sharding)
    val, stats = fn(arrays_dev, val_dev)
    _count_dispatches(f"{sem.name}_sharded", 1, 1)
    stats = jax.tree.map(lambda x: x[0], stats)
    return val, stats


def make_sharded_pagerank_fn(S: int, R_max: int, n: int, damping: float,
                             iters: int, mesh: Mesh,
                             axis_names=("data", "model"),
                             cfg: EngineConfig = EngineConfig()):
    """shard_map PageRank: counted rounds of relax → exchange →
    rhizome-collapse(+) → damping update, dense or compact exchange, with
    the same fused-kernel hot path as the fixpoint apps."""
    from repro.core.actions import PAGERANK as sem

    cfg = _sharded_cfg(cfg, "make_sharded_pagerank_fn")
    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (DeviceArrays.specs(spec),)
    base = (1.0 - damping) / n

    def shard_fn(arrays_l: DeviceArrays):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        chg = arrays_s.slot_valid  # PR predicate is #t — always diffuse

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        def body(_, val):
            total_in, _ = exchange.shard_total_in(
                sem, arrays_s, cfg, S, R_max, axis_names,
                gather(val), gather(chg))
            return jnp.where(arrays_s.slot_valid,
                             base + damping * total_in, 0.0)

        val0 = jnp.where(arrays_s.slot_valid, 1.0 / n, 0.0)
        val = lax.fori_loop(0, iters, body, val0)
        return val[None]

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=spec,
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_pagerank_sharded(part: Partition, damping: float, iters: int,
                         mesh: Mesh, axis_names=("data", "model"),
                         cfg: EngineConfig = EngineConfig()):
    """shard_map PageRank execution; see ``run_sharded`` for layout."""
    fn, sharding = make_sharded_pagerank_fn(
        part.S, part.R_max, part.n, damping, iters, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    return fn(arrays_dev)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def init_values(part: Partition, sem: Semiring, sources: dict[int, float]):
    """(S, R_max) initial values: semiring identity everywhere except all
    replicas of each source vertex (consistent initial view)."""
    val = np.full((part.S, part.R_max), sem.identity, dtype=np.float32)
    if sem.segment == "sum":
        val[:] = 0.0
    for v, x in sources.items():
        s0, sl0 = divmod(int(part.root_flat[v]), part.R_max)
        for k in range(part.cfg.rpvo_max):
            if part.sibling_mask[s0, sl0, k]:
                f = int(part.sibling_flat[s0, sl0, k])
                val[f // part.R_max, f % part.R_max] = x
    return val


def vertex_values(part: Partition, val) -> np.ndarray:
    """Extract the per-vertex (root-replica) values."""
    gval = np.asarray(val).reshape(-1)
    return gval[part.root_flat]
