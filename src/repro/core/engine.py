"""Diffusive fixpoint engine (paper §4–§5), TPU-native.

The paper's asynchronous message-driven execution is re-expressed as bulk
edge-parallel relaxation rounds whose fixpoint equals the asynchronous
fixpoint (monotone semirings ⇒ order-free). One round is the diffuse-queue
drain: diffusions generated in round k are evaluated in round k+1 against
the newest vertex state, so stale diffusions are *subsumed* exactly as the
paper's lazy-diffuse pruning does.

The per-round math — relax, dense or §Perf compact targeted exchange,
rhizome collapse — lives in the unified lane-generic exchange layer
(``repro.exchange``); this module is the *driver*: it owns the fixpoint
loops, termination collectives, and Fig-6 stats bookkeeping for the
single-query (unlaned) table layout.  ``repro.query.lanes`` drives the
same exchange layer with a trailing query-lane axis.

Two execution paths share the same per-round math:

* ``run_stacked``  — arrays stacked ``(S, …)`` on one device; collectives
  are reshapes/transposes.  Used for correctness tests at any shard count.
* ``run_sharded``  — ``shard_map`` over a mesh with real collectives:
  - value/changed broadcast  → ``all_gather``      (the diffusion fan-out)
  - inbox exchange           → ``all_to_all``      (messages to replicas)
  - rhizome collapse         → ``all_gather`` + sibling combine
    (the AND-gate LCO trigger, lowered to a counted reduction)
  - termination detection    → ``psum`` of the any-changed flag
    (the paper assumes a hardware idle signal; the collective is ours).

With ``EngineConfig.use_pallas`` the per-round relax phase dispatches
through the fused ``kernels.fused_relax_reduce`` Pallas kernel: one
VMEM-resident pass, no ``(S, E_max)`` HBM intermediates, and grid cells
over frontier-dead edge chunks are skipped entirely (the TPU form of the
paper's diffusion pruning).  Without the flag the same math runs as
separate jnp ops — the oracle path.

Per-round counters reproduce the paper's Fig-6 statistics: messages
(actions delivered), actions whose predicate fired (work performed), and
diffusions pruned.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import exchange
from repro.core.actions import Semiring
from repro.core.partition import Partition


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    collapse: str = "eager"      # 'eager' | 'deferred' (min-semirings only)
    exchange: str = "dense"      # 'dense' | 'compact' (targeted messages)
    max_iters: int = 4096
    use_pallas: bool = False     # route the relax phase through Pallas
    # 'fused'  — one VMEM-resident gather+relax+mask+reduce kernel with
    #            frontier chunk skip (the hot path; default)
    # 'reduce' — jnp gather/relax/mask + the standalone segment-reduce
    #            kernel (the pre-fusion composition, kept for comparison)
    pallas_mode: str = "fused"
    # False skips the Fig-6 message counter (an O(E) boolean reduction per
    # round on the fused path); RunStats then reports zero messages/pruned
    track_stats: bool = True
    # VMEM byte budget for the fused kernel's value-table residency: the
    # kernel pins the whole padded (S*R_max[, Q]) slot table in VMEM when
    # it fits the budget, else tiles it out of HBM with per-cell
    # double-buffered async DMA (see kernels.fused_relax_reduce.
    # select_kernel_path).  None defers to the REPRO_VMEM_BUDGET env var,
    # then to DEFAULT_VMEM_BUDGET_BYTES — so paper-scale partitions whose
    # slot table exceeds VMEM run fused via tiling instead of failing to
    # compile.
    vmem_budget_bytes: int | None = None

    def __post_init__(self):
        if self.collapse not in ("eager", "deferred"):
            raise ValueError(f"collapse={self.collapse!r}")
        if self.exchange not in ("dense", "compact"):
            raise ValueError(f"exchange={self.exchange!r}")
        if self.pallas_mode not in ("fused", "reduce"):
            raise ValueError(f"pallas_mode={self.pallas_mode!r}")
        if self.vmem_budget_bytes is not None \
                and self.vmem_budget_bytes <= 0:
            raise ValueError(
                f"vmem_budget_bytes={self.vmem_budget_bytes!r}")


class DeviceArrays(typing.NamedTuple):
    """Static per-shard tensors; leading dim S (stacked) or sharded.

    The ``edge_dst_compact``/``inbox_slot_map``/``rz_*`` fields implement
    the §Perf *compact targeted exchange*: contributions travel as
    (target, slot) messages instead of a dense global inbox — the TPU form
    of the paper's message-driven semantics."""

    edge_src_root_flat: jax.Array  # (S, E_max) int32
    edge_dst_flat: jax.Array       # (S, E_max) int32 (sorted per shard)
    edge_w: jax.Array              # (S, E_max) f32
    edge_mask: jax.Array           # (S, E_max) bool
    sibling_flat: jax.Array        # (S, R_max, K) int32
    sibling_mask: jax.Array        # (S, R_max, K) bool
    slot_valid: jax.Array          # (S, R_max) bool
    edge_dst_compact: jax.Array    # (S, E_max) int32 -> [0, S*P_t)
    inbox_slot_map: jax.Array      # (S, S, P_t) int32, R_max = pad
    rz_local: jax.Array            # (S, R_rz_max) int32, R_max = pad
    rz_sibling_idx: jax.Array      # (S, R_rz_max, K) int32
    rz_sibling_mask: jax.Array     # (S, R_rz_max, K) bool

    @classmethod
    def specs(cls, spec) -> "DeviceArrays":
        """Per-field shard_map spec tree (every field shares ``spec``) —
        the in_specs entry for every sharded runner over these tables."""
        return cls(*([spec] * len(cls._fields)))

    @classmethod
    def from_partition(cls, part: Partition) -> "DeviceArrays":
        return cls(
            edge_src_root_flat=jnp.asarray(part.edge_src_root_flat, jnp.int32),
            edge_dst_flat=jnp.asarray(part.edge_dst_flat, jnp.int32),
            edge_w=jnp.asarray(part.edge_w, jnp.float32),
            edge_mask=jnp.asarray(part.edge_mask),
            sibling_flat=jnp.asarray(part.sibling_flat, jnp.int32),
            sibling_mask=jnp.asarray(part.sibling_mask),
            slot_valid=jnp.asarray(part.slot_vertex >= 0),
            edge_dst_compact=jnp.asarray(part.edge_dst_compact, jnp.int32),
            inbox_slot_map=jnp.asarray(part.inbox_slot_map, jnp.int32),
            rz_local=jnp.asarray(part.rz_local, jnp.int32),
            rz_sibling_idx=jnp.asarray(part.rz_sibling_idx, jnp.int32),
            rz_sibling_mask=jnp.asarray(part.rz_sibling_mask),
        )


class RunStats(typing.NamedTuple):
    iterations: jax.Array        # rounds executed
    messages: jax.Array          # actions delivered (edge messages)
    work_actions: jax.Array      # predicate-true slot updates
    pruned_actions: jax.Array    # delivered but predicate-false
    diffusions: jax.Array        # slots that diffused (entered the frontier)


# --------------------------------------------------------------------------
# per-round math: unified exchange-layer compositions (kept under their
# historic names — benchmarks and kernel-parity tests drive the rounds
# directly to measure exactly what the runners ship)
# --------------------------------------------------------------------------

def _fixpoint_round_stacked(sem, arrays, cfg, S, R_max, val, chg):
    return exchange.fixpoint_round_stacked(
        sem, arrays, cfg, S, R_max, val, chg)


def _pagerank_round_stacked(sem, arrays, cfg, S, R_max, base, damping, val,
                            chg):
    return exchange.pagerank_round_stacked(
        sem, arrays, cfg, S, R_max, base, damping, val, chg)


# --------------------------------------------------------------------------
# fixpoint apps (BFS / SSSP)
# --------------------------------------------------------------------------

def run_stacked(sem: Semiring, part: Partition, init_val: np.ndarray,
                cfg: EngineConfig = EngineConfig(), init_changed=None):
    """Single-device stacked execution. ``init_val``: (S, R_max) float32.
    ``init_changed`` (optional bool (S, R_max)) seeds the first frontier —
    used by incremental recompute to re-diffuse only mutation sites."""
    if sem.segment != "min":
        raise ValueError(
            "run_stacked drives monotone min-semiring fixpoints; the "
            "collapse of a combined candidate is only sound there — use "
            "run_pagerank_stacked for counted sum-semiring rounds")
    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max

    def body(carry):
        val, chg, it, stats = carry
        new_val, new_chg, msg_count = exchange.fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg
        )
        work = new_chg.sum()
        stats = RunStats(
            iterations=stats.iterations + 1,
            messages=stats.messages + msg_count,
            work_actions=stats.work_actions + work,
            pruned_actions=stats.pruned_actions
            + msg_count - jnp.minimum(work, msg_count),
            diffusions=stats.diffusions + work,
        )
        return new_val, new_chg, it + 1, stats

    def cond(carry):
        _, chg, it, _ = carry
        return jnp.any(chg) & (it < cfg.max_iters)

    if init_changed is not None:
        init_chg = jnp.asarray(init_changed) & arrays.slot_valid
    else:
        init_chg = sem.improved(
            jnp.asarray(init_val),
            jnp.full_like(jnp.asarray(init_val), sem.identity)
        ) & arrays.slot_valid
    zero = jnp.zeros((), jnp.int64) if jax.config.jax_enable_x64 else jnp.zeros((), jnp.int32)
    stats0 = RunStats(zero, zero, zero, zero, zero)
    val, chg, it, stats = lax.while_loop(
        cond, body, (jnp.asarray(init_val), init_chg, zero, stats0)
    )
    if cfg.collapse == "deferred":
        val = exchange.collapse(sem, val.reshape(-1), arrays.sibling_flat,
                                arrays.sibling_mask)
    return val, stats


# --------------------------------------------------------------------------
# PageRank-style counted-iteration apps
# --------------------------------------------------------------------------

def run_pagerank_stacked(part: Partition, damping: float, iters: int,
                         cfg: EngineConfig = EngineConfig()):
    from repro.core.actions import PAGERANK as sem

    arrays = DeviceArrays.from_partition(part)
    S, R_max = part.S, part.R_max
    base = (1.0 - damping) / part.n

    # initial score 1/n on every replica (consistent view)
    val0 = jnp.where(arrays.slot_valid, 1.0 / part.n, 0.0)
    chg = arrays.slot_valid  # PR predicate is #t — always diffuse

    def body(_, val):
        new_val, _ = exchange.pagerank_round_stacked(
            sem, arrays, cfg, S, R_max, base, damping, val, chg)
        return new_val

    val = lax.fori_loop(0, iters, body, val0)
    return val


# --------------------------------------------------------------------------
# sharded execution (shard_map over a real mesh)
# --------------------------------------------------------------------------

def make_sharded_fn(sem: Semiring, S: int, R_max: int,
                    mesh: Mesh, axis_names=("data", "model"),
                    cfg: EngineConfig = EngineConfig()):
    """Builds the shard_map diffusive fixpoint as a jit-able fn of
    (DeviceArrays, val) — usable with concrete arrays (run_sharded) or
    ShapeDtypeStructs (AOT dry-run lowering)."""
    if sem.segment != "min":
        raise ValueError(
            "make_sharded_fn drives monotone min-semiring fixpoints; use "
            "make_sharded_pagerank_fn for counted sum-semiring rounds")
    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (
        DeviceArrays.specs(spec),
        spec,
    )

    def shard_fn(arrays_l: DeviceArrays, val_l):
        # strip leading local shard dim of size 1
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        val = val_l[0]
        round_fn = exchange.make_shard_fixpoint_round(
            sem, arrays_s, cfg, S, R_max, axis_names)

        def body(carry):
            val, chg, it, stats = carry
            new_val, new_chg, msg_count = round_fn(val, chg)
            msgs = lax.psum(msg_count, axis_names)
            work = lax.psum(new_chg.sum(), axis_names)
            stats = RunStats(
                iterations=stats.iterations + 1,
                messages=stats.messages + msgs,
                work_actions=stats.work_actions + work,
                pruned_actions=stats.pruned_actions
                + msgs - jnp.minimum(work, msgs),
                diffusions=stats.diffusions + work,
            )
            return new_val, new_chg, it + 1, stats

        def cond(carry):
            _, chg, it, _ = carry
            anyc = lax.psum(chg.any().astype(jnp.int32), axis_names)
            return (anyc > 0) & (it < cfg.max_iters)

        init_chg = (
            sem.improved(val, jnp.full_like(val, sem.identity))
            & arrays_s.slot_valid
        )
        zero = jnp.zeros((), jnp.int32)
        stats0 = RunStats(zero, zero, zero, zero, zero)
        val, chg, it, stats = lax.while_loop(
            cond, body, (val, init_chg, zero, stats0)
        )
        if cfg.collapse == "deferred":
            val = exchange.collapse(
                sem, lax.all_gather(val, axis_names, tiled=True),
                arrays_s.sibling_flat, arrays_s.sibling_mask)
        return val[None], jax.tree.map(lambda x: x[None], stats)

    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec, RunStats(*([spec] * 5))),
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_sharded(sem: Semiring, part: Partition, init_val: np.ndarray,
                mesh: Mesh, axis_names=("data", "model"),
                cfg: EngineConfig = EngineConfig()):
    """shard_map execution. Leading (shard) dim of every array is split over
    ``axis_names``; requires prod(mesh[axis_names]) == part.S."""
    fn, sharding = make_sharded_fn(
        sem, part.S, part.R_max, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    val_dev = jax.device_put(jnp.asarray(init_val), sharding)
    val, stats = fn(arrays_dev, val_dev)
    stats = jax.tree.map(lambda x: x[0], stats)
    return val, stats


def make_sharded_pagerank_fn(S: int, R_max: int, n: int, damping: float,
                             iters: int, mesh: Mesh,
                             axis_names=("data", "model"),
                             cfg: EngineConfig = EngineConfig()):
    """shard_map PageRank: counted rounds of relax → exchange →
    rhizome-collapse(+) → damping update, dense or compact exchange, with
    the same fused-kernel hot path as the fixpoint apps."""
    from repro.core.actions import PAGERANK as sem

    axis_names = exchange.axis_tuple(axis_names)
    spec = P(axis_names)
    from jax.experimental.shard_map import shard_map

    in_specs = (DeviceArrays.specs(spec),)
    base = (1.0 - damping) / n

    def shard_fn(arrays_l: DeviceArrays):
        arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
        chg = arrays_s.slot_valid  # PR predicate is #t — always diffuse

        def gather(x):
            return lax.all_gather(x, axis_names, tiled=True)

        def body(_, val):
            total_in, _ = exchange.shard_total_in(
                sem, arrays_s, cfg, S, R_max, axis_names,
                gather(val), gather(chg))
            return jnp.where(arrays_s.slot_valid,
                             base + damping * total_in, 0.0)

        val0 = jnp.where(arrays_s.slot_valid, 1.0 / n, 0.0)
        val = lax.fori_loop(0, iters, body, val0)
        return val[None]

    fn = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=spec,
        check_rep=False,
    )
    return jax.jit(fn), NamedSharding(mesh, spec)


def run_pagerank_sharded(part: Partition, damping: float, iters: int,
                         mesh: Mesh, axis_names=("data", "model"),
                         cfg: EngineConfig = EngineConfig()):
    """shard_map PageRank execution; see ``run_sharded`` for layout."""
    fn, sharding = make_sharded_pagerank_fn(
        part.S, part.R_max, part.n, damping, iters, mesh, axis_names, cfg)
    arrays = DeviceArrays.from_partition(part)
    arrays_dev = jax.tree.map(lambda x: jax.device_put(x, sharding), arrays)
    return fn(arrays_dev)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def init_values(part: Partition, sem: Semiring, sources: dict[int, float]):
    """(S, R_max) initial values: semiring identity everywhere except all
    replicas of each source vertex (consistent initial view)."""
    val = np.full((part.S, part.R_max), sem.identity, dtype=np.float32)
    if sem.segment == "sum":
        val[:] = 0.0
    for v, x in sources.items():
        s0, sl0 = divmod(int(part.root_flat[v]), part.R_max)
        for k in range(part.cfg.rpvo_max):
            if part.sibling_mask[s0, sl0, k]:
                f = int(part.sibling_flat[s0, sl0, k])
                val[f // part.R_max, f % part.R_max] = x
    return val


def vertex_values(part: Partition, val) -> np.ndarray:
    """Extract the per-vertex (root-replica) values."""
    gval = np.asarray(val).reshape(-1)
    return gval[part.root_flat]
