"""Action vocabulary (paper §5): an action = (predicate, work, diffuse).

The TPU engine executes actions in bulk as semiring relaxation steps; the
``Semiring`` here is the algebra of one action class:

* ``relax(src_val, w)``   — message payload built during *diffuse*.
* ``combine``             — how the inbox merges (min for BFS/SSSP, + for PR).
* ``improved(new, old)``  — the *predicate*: does this action perform work?
  (False ⇒ the action — and its diffusion — is pruned, Listing 6.)

``identity`` is the value of a pruned/padded message, so pruning is a
select, never a data-dependent shape.
"""
from __future__ import annotations

import dataclasses
import typing

import jax.numpy as jnp
import jax


@dataclasses.dataclass(frozen=True)
class Semiring:
    name: str
    identity: float                       # combine identity
    combine: typing.Callable              # (a, b) -> a⊕b, elementwise
    relax: typing.Callable                # (src_val, w) -> msg
    improved: typing.Callable             # (new, old) -> bool  (the predicate)
    segment: str                          # 'min' | 'sum' — inbox reduction kind
    # static relax selector for the fused Pallas kernel — the relax must be
    # expressible inside a grid cell: 'add_w' (min-plus), 'add_one' (BFS
    # level), 'mul_w' (plus-times).  Construct ``relax`` from RELAX_FNS
    # (as the built-ins below do) so the two can never disagree; None
    # means "no kernel form" and the fused path refuses to run.
    relax_kind: str | None = None

    def segment_combine(self, data, segment_ids, num_segments):
        """Inbox reduction. Empty segments get the combine identity."""
        init = jnp.full((num_segments,), self.identity, data.dtype)
        if self.segment == "min":
            return init.at[segment_ids].min(data, indices_are_sorted=True)
        if self.segment == "sum":
            return init.at[segment_ids].add(data, indices_are_sorted=True)
        raise ValueError(self.segment)


# the relax vocabulary expressible inside the fused Pallas kernel — the
# single source for both the jnp path (via Semiring.relax) and the kernel
# (via Semiring.relax_kind; see kernels.fused_relax_reduce._relax)
RELAX_FNS = {
    "add_w": lambda v, w: v + w,       # min-plus (SSSP)
    "add_one": lambda v, w: v + 1.0,   # BFS level relax (weight ignored)
    "mul_w": lambda v, w: v * w,       # plus-times (PageRank)
}


# BFS: level relaxation. msg = src_level + 1 (weights forced to 1).
BFS = Semiring(
    name="bfs",
    identity=jnp.inf,
    combine=jnp.minimum,
    relax=RELAX_FNS["add_one"],
    improved=lambda new, old: new < old,
    segment="min",
    relax_kind="add_one",
)

# SSSP: min-plus.
SSSP = Semiring(
    name="sssp",
    identity=jnp.inf,
    combine=jnp.minimum,
    relax=RELAX_FNS["add_w"],
    improved=lambda new, old: new < old,
    segment="min",
    relax_kind="add_w",
)

# PageRank: plus-times; edge weight is pre-folded to 1/out_deg(src).
PAGERANK = Semiring(
    name="pagerank",
    identity=0.0,
    combine=lambda a, b: a + b,
    relax=RELAX_FNS["mul_w"],
    improved=lambda new, old: jnp.full(new.shape, True),
    segment="sum",
    relax_kind="mul_w",
)

SEMIRINGS = {s.name: s for s in (BFS, SSSP, PAGERANK)}
