"""Local Control Objects (paper §4.1): AND-gate LCO and futures.

An AND-gate LCO accumulates values with an operator; when it has been
``set`` N times it fires its trigger action and resets (paper Fig 3:
rhizome-collapse for PageRank).  These are *functional* objects — ``set``
returns a new state — so they compose with JAX scans and with the AM-CCA
simulator's event loop alike.

In the dense TPU engine the same counted-trigger semantics lower to a
reduction collective (see ``repro.core.engine``); this module is the
event-driven form used by the simulator and by host-side orchestration.
"""
from __future__ import annotations

import dataclasses
import typing

import numpy as np

T = typing.TypeVar("T")


@dataclasses.dataclass(frozen=True)
class AndGate(typing.Generic[T]):
    """AND-gate LCO of arity ``target`` with combining operator ``op``."""

    target: int
    op: typing.Callable[[T, T], T]
    identity: T
    value: T = None  # type: ignore[assignment]
    count: int = 0

    def __post_init__(self):
        if self.value is None:
            object.__setattr__(self, "value", self.identity)

    def set(self, contribution: T) -> tuple["AndGate[T]", bool, T]:
        """Apply one contribution. Returns (new_state, fired, fired_value).

        When the gate fires it resets (count=0, value=identity) — matching
        the paper's "the score AND Gate is reset" semantics — and the
        caller runs the trigger action with ``fired_value``.
        """
        if self.count >= self.target:
            raise RuntimeError("AND-gate set after firing without reset")
        new_val = self.op(self.value, contribution)
        new_count = self.count + 1
        if new_count == self.target:
            return (
                AndGate(self.target, self.op, self.identity),
                True,
                new_val,
            )
        return (
            dataclasses.replace(self, value=new_val, count=new_count),
            False,
            new_val,
        )


@dataclasses.dataclass(frozen=True)
class Future(typing.Generic[T]):
    """Write-once future: continuations run when the value is set."""

    ready: bool = False
    value: T = None  # type: ignore[assignment]

    def set(self, value: T) -> "Future[T]":
        if self.ready:
            raise RuntimeError("future already set")
        return Future(True, value)


def and_gate_tree(values: np.ndarray, op, identity, fanin: int = 2):
    """Hierarchical AND-gate reduction (the hardware-signalling analog of
    §4's termination detection): combines ``values`` pairwise through a
    tree of AND gates; returns (result, depth). Used in tests to show the
    counted-trigger form computes the same result as one flat gate."""
    vals = list(values)
    depth = 0
    while len(vals) > 1:
        nxt = []
        for i in range(0, len(vals), fanin):
            grp = vals[i : i + fanin]
            gate = AndGate(target=len(grp), op=op, identity=identity)
            fired_val = identity
            for gvv in grp:
                gate, fired, fired_val = gate.set(gvv)
            assert fired
            nxt.append(fired_val)
        vals = nxt
        depth += 1
    return vals[0] if vals else identity, depth
