"""RPVO + Rhizome partitioning (paper §3, §6.1 "Graph Construction").

Maps a COO graph onto S shards (compute cells in the AM-CCA cost model,
TPU devices in the JAX engine):

* **RPVO (out-degree)** — each vertex's out-edges are chunked into
  ``local_edge_list_size`` ghost chunks; chunks are placed by an allocator
  (home / vicinity / random / balanced).  With ``ghost_alloc="home"`` all
  chunks stay at the root's shard — the paper's Fig 2a "simple vertex"
  baseline, whose padded per-shard edge width inflates with out-degree skew.
* **Rhizome (in-degree)** — Eq. 1: ``cutoff_chunk = indegree_max /
  rpvo_max``; every ``cutoff_chunk`` in-edges of a vertex are pointed at
  the next replica (cycling), so a hub's inbox is spread over up to
  ``rpvo_max`` replica slots on distinct shards.  Replicas are allocated
  by the *random* allocator (paper §6.1, Fig 4c).

Placement is **counter-based**: every random draw (root home, replica
home, ghost-chunk home, vicinity offset) is a splitmix64 hash of
``(cfg.seed, entity id)`` rather than a sequential RNG stream.  A
vertex's placement therefore never depends on how many *other* vertices
or edges exist, which is what makes `splice_partition` exact: rebuilding
only the shards a mutation batch touched yields, field for field, the
same `Partition` as `build_partition` on the post-mutation graph.

The result is a set of static, padded arrays directly consumable by the
JAX engine (`repro.core.engine`) and by the AM-CCA cost model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.graph import COOGraph


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    num_shards: int
    local_edge_list_size: int = 32
    rpvo_max: int = 1                 # 1 => plain RPVO (no rhizomes)
    ghost_alloc: str = "balanced"     # 'home' | 'vicinity' | 'random' | 'balanced'
    mesh_dims: tuple[int, int] | None = None  # (X, Y); default near-square
    torus: bool = True
    seed: int = 0
    # Eq. 1 cutoff override.  None derives ``ceil(indeg_max / rpvo_max)``
    # from the graph at build time; streaming pins it to the initial
    # graph's value (the CCA exemplars' fixed RHIZOME_INDEGREE_CUTOFF)
    # so replica counts depend only on each vertex's own in-degree.
    indegree_cutoff: int | None = None

    def dims(self) -> tuple[int, int]:
        if self.mesh_dims is not None:
            assert self.mesh_dims[0] * self.mesh_dims[1] == self.num_shards
            return self.mesh_dims
        x = int(np.floor(np.sqrt(self.num_shards)))
        while self.num_shards % x:
            x -= 1
        return (self.num_shards // x, x)


@dataclasses.dataclass
class Partition:
    """Sharded RPVO/Rhizome layout. ``flat`` replica id = shard * R_max + slot."""

    cfg: PartitionConfig
    n: int
    num_edges: int
    S: int
    E_max: int                      # padded edges per shard
    R_max: int                      # padded replica slots per shard
    num_replicas_total: int

    # --- per-edge, per-shard arrays, all shaped (S, E_max) ---
    edge_src_root_flat: np.ndarray  # flat id of src vertex's ROOT replica
    edge_dst_flat: np.ndarray       # flat id of the dst REPLICA this edge feeds
    edge_w: np.ndarray              # float32 weights
    edge_mask: np.ndarray           # bool, False on padding
    edge_src_vertex: np.ndarray     # int32 global src vertex (cost model)
    edge_dst_vertex: np.ndarray     # int32 global dst vertex (cost model)
    edge_owner_cc: np.ndarray       # int32 CC owning the ghost chunk (== shard)

    # --- per-slot tables, shaped (S, R_max) ---
    slot_vertex: np.ndarray         # vertex id of replica at slot (-1 pad)
    slot_is_root: np.ndarray        # bool
    sibling_flat: np.ndarray        # (S, R_max, rpvo_max) flat ids of ALL
    sibling_mask: np.ndarray        # replicas of the slot's vertex (+mask)

    # --- per-vertex tables ---
    root_flat: np.ndarray           # (n,) flat id of root replica
    num_replicas: np.ndarray        # (n,)
    out_deg: np.ndarray             # (n,) int64
    in_deg: np.ndarray              # (n,) int64

    # --- compact targeted-exchange plan (§Perf; message-driven semantics:
    #     contributions travel only to the replica's owner shard) ---
    P_t: int                        # padded distinct-dst slots per (src,tgt)
    edge_dst_compact: np.ndarray    # (S, E_max) int32 -> [0, S*P_t)
    inbox_slot_map: np.ndarray      # (S_tgt, S_src, P_t) local slot or R_max
    R_rz_max: int                   # padded rhizome slots per shard
    rz_local: np.ndarray            # (S, R_rz_max) local slot ids (R_max pad)
    rz_sibling_idx: np.ndarray      # (S, R_rz_max, K) global rz-compact ids
    rz_sibling_mask: np.ndarray     # (S, R_rz_max, K)

    # --- metrics (recorded for roofline / paper figures) ---
    metrics: dict

    def replica_shards_of(self, v: int) -> list[int]:
        sib = self.sibling_flat[self.root_flat[v] // self.R_max,
                                self.root_flat[v] % self.R_max]
        msk = self.sibling_mask[self.root_flat[v] // self.R_max,
                                self.root_flat[v] % self.R_max]
        return sorted({int(f) // self.R_max for f, m in zip(sib, msk) if m})


@dataclasses.dataclass
class SpliceInfo:
    """What `splice_partition` actually did (obs gauges + tests)."""

    shards_rebuilt: int
    shards_total: int
    rebuilt_ids: list
    replicas_added: int
    replicas_removed: int
    replicas_moved: int
    affected_edges: int
    full_rebuild: bool
    r_max_changed: bool
    e_max_changed: bool


def _vicinity_order(cfg: PartitionConfig) -> np.ndarray:
    """CC offsets sorted by Manhattan distance from origin (torus-aware)."""
    X, Y = cfg.dims()
    xs, ys = np.meshgrid(np.arange(X), np.arange(Y), indexing="ij")
    dx, dy = xs.ravel(), ys.ravel()
    if cfg.torus:
        ddx = np.minimum(dx, X - dx)
        ddy = np.minimum(dy, Y - dy)
    else:
        ddx, ddy = dx, dy
    order = np.argsort(ddx + ddy, kind="stable")
    return (dy[order] * X + dx[order]).astype(np.int64)  # cc ids by distance


# ---------------------------------------------------------------------------
# counter-based placement hashing (splitmix64)
# ---------------------------------------------------------------------------

_TAG_ROOT, _TAG_REPLICA, _TAG_CHUNK, _TAG_VICINITY = 1, 2, 3, 4
_MASK64 = (1 << 64) - 1


def _mix64(x: np.ndarray) -> np.ndarray:
    z = np.asarray(x, dtype=np.uint64) + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _hash_mod(seed: int, tag: int, key, sub, mod: int) -> np.ndarray:
    """Vectorized draw in [0, mod) as a pure function of (seed, tag, key, sub)."""
    base = np.uint64((seed * 0x9E3779B1 + tag * 0x85EBCA77) & _MASK64)
    a = _mix64(np.asarray(key, dtype=np.uint64) ^ base)
    h = _mix64(a ^ (np.asarray(sub, dtype=np.uint64) << np.uint64(1)))
    return (h % np.uint64(max(mod, 1))).astype(np.int64)


# ---------------------------------------------------------------------------
# placement: global assignment arrays (pure, vectorized)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Placement:
    S: int
    n: int
    E: int
    cutoff_chunk: int
    in_deg: np.ndarray
    out_deg: np.ndarray
    root_shard: np.ndarray
    num_replicas: np.ndarray
    R_total: int
    first_rid: np.ndarray           # (n+1,)
    rep_vertex: np.ndarray          # (R_total,)
    rep_index: np.ndarray
    rep_shard: np.ndarray
    rep_slot: np.ndarray
    rep_flat: np.ndarray
    R_max: int
    root_flat: np.ndarray           # (n,)
    edge_dst_rid: np.ndarray        # (E,) global replica id each edge feeds
    edge_shard: np.ndarray          # (E,)
    e_counts: np.ndarray            # (S,)
    e_starts: np.ndarray            # (S+1,)
    shard_sort: np.ndarray          # (E,) stable argsort of edge_shard
    E_max: int


def _placement(g: COOGraph, cfg: PartitionConfig) -> _Placement:
    S = cfg.num_shards
    n, E = g.n, g.num_edges
    in_deg = g.in_degrees()
    out_deg = g.out_degrees()

    # ---- 1. root homes: random allocation across the chip (paper §6.1) ----
    vids = np.arange(n, dtype=np.int64)
    root_shard = _hash_mod(cfg.seed, _TAG_ROOT, vids, 0, S)

    # ---- 2. rhizome replicas (Eq. 1) ----
    if cfg.indegree_cutoff is not None:
        cutoff_chunk = max(int(cfg.indegree_cutoff), 1)
    else:
        indeg_max = max(int(in_deg.max()) if n else 1, 1)
        cutoff_chunk = max(int(np.ceil(indeg_max / cfg.rpvo_max)), 1)
    num_replicas = np.minimum(
        cfg.rpvo_max, np.maximum(1, np.ceil(in_deg / cutoff_chunk).astype(np.int64))
    )
    R_total = int(num_replicas.sum())
    first_rid = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(num_replicas, out=first_rid[1:])

    # replica r of vertex v -> shard: r=0 at root home; r>0 random (paper)
    rep_vertex = np.repeat(vids, num_replicas)
    rep_index = np.arange(R_total, dtype=np.int64) - first_rid[rep_vertex]
    rep_shard = np.where(
        rep_index == 0,
        root_shard[rep_vertex],
        _hash_mod(cfg.seed, _TAG_REPLICA, rep_vertex, rep_index, S),
    ).astype(np.int64)

    # slots: order replicas per shard
    order = np.argsort(rep_shard, kind="stable")
    rep_slot = np.zeros(R_total, dtype=np.int64)
    counts = np.bincount(rep_shard, minlength=S)
    starts = np.zeros(S + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    rep_slot[order] = np.arange(R_total, dtype=np.int64) - starts[rep_shard[order]]
    R_max = max(int(counts.max()) if R_total else 1, 1)
    rep_flat = rep_shard * R_max + rep_slot
    root_flat = rep_flat[first_rid[:-1]] if n else np.zeros(0, np.int64)

    # ---- 3. in-edge -> replica assignment (cycling every cutoff_chunk) ----
    dst_order = np.argsort(g.dst, kind="stable")
    in_rank = np.zeros(E, dtype=np.int64)
    dst_counts = np.bincount(g.dst, minlength=n)
    dst_starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(dst_counts, out=dst_starts[1:])
    in_rank[dst_order] = np.arange(E, dtype=np.int64) - dst_starts[g.dst[dst_order]]
    dst_rep_index = (in_rank // cutoff_chunk) % np.maximum(num_replicas[g.dst], 1)
    edge_dst_rid = first_rid[g.dst] + dst_rep_index  # global replica id per edge

    # ---- 4. out-edge chunking (RPVO ghosts) + allocation ----
    src_order = np.argsort(g.src, kind="stable")
    out_rank = np.zeros(E, dtype=np.int64)
    src_counts = np.bincount(g.src, minlength=n)
    src_starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(src_counts, out=src_starts[1:])
    out_rank[src_order] = np.arange(E, dtype=np.int64) - src_starts[g.src[src_order]]
    chunk_of_edge = out_rank // max(cfg.local_edge_list_size, 1)

    # allocate chunks -> shards
    # chunk key: (src vertex, chunk index); dedupe to one placement per chunk
    chunk_key = g.src.astype(np.int64) * (np.int64(E) + 1) + chunk_of_edge
    uniq_keys, chunk_id_of_edge = np.unique(chunk_key, return_inverse=True)
    n_chunks = uniq_keys.size
    chunk_vertex = (uniq_keys // (E + 1)).astype(np.int64)
    chunk_index = (uniq_keys % (E + 1)).astype(np.int64)

    if cfg.ghost_alloc == "home":
        chunk_shard = root_shard[chunk_vertex]
    elif cfg.ghost_alloc == "random":
        chunk_shard = np.where(
            chunk_index == 0,
            root_shard[chunk_vertex],
            _hash_mod(cfg.seed, _TAG_CHUNK, chunk_vertex, chunk_index, S),
        )
    elif cfg.ghost_alloc == "vicinity":
        vic = _vicinity_order(cfg)
        win = min(S, 25)  # 5x5 neighborhood of the root CC
        offs = vic[1 + _hash_mod(cfg.seed, _TAG_VICINITY, chunk_vertex,
                                 chunk_index, max(win - 1, 1))]
        X, Yd = cfg.dims()
        hx, hy = root_shard[chunk_vertex] % X, root_shard[chunk_vertex] // X
        ox, oy = offs % X, offs // X
        near = ((hy + oy) % Yd) * X + (hx + ox) % X
        chunk_shard = np.where(chunk_index == 0, root_shard[chunk_vertex], near)
    elif cfg.ghost_alloc == "balanced":
        # greedy least-loaded by edges — the TPU-engine default (no NoC
        # locality to exploit under dense collectives; see DESIGN.md §2).
        # NOTE: globally load-dependent, so splice_partition falls back to
        # rebuilding every shard row under this allocator.
        chunk_sizes = np.bincount(chunk_id_of_edge, minlength=n_chunks)
        load = np.zeros(S, dtype=np.int64)
        chunk_shard = np.zeros(n_chunks, dtype=np.int64)
        csort = np.argsort(-chunk_sizes, kind="stable")
        for c in csort:
            s = int(np.argmin(load))
            chunk_shard[c] = s
            load[s] += chunk_sizes[c]
    else:
        raise ValueError(f"unknown ghost_alloc {cfg.ghost_alloc!r}")
    chunk_shard = chunk_shard.astype(np.int64)
    edge_shard = chunk_shard[chunk_id_of_edge] if E else np.zeros(0, np.int64)

    e_counts = np.bincount(edge_shard, minlength=S)
    e_starts = np.zeros(S + 1, dtype=np.int64)
    np.cumsum(e_counts, out=e_starts[1:])
    shard_sort = np.argsort(edge_shard, kind="stable")
    E_max = max(int(e_counts.max()) if E else 1, 1)

    return _Placement(
        S=S, n=n, E=E, cutoff_chunk=cutoff_chunk, in_deg=in_deg,
        out_deg=out_deg, root_shard=root_shard, num_replicas=num_replicas,
        R_total=R_total, first_rid=first_rid, rep_vertex=rep_vertex,
        rep_index=rep_index, rep_shard=rep_shard, rep_slot=rep_slot,
        rep_flat=rep_flat, R_max=R_max, root_flat=root_flat,
        edge_dst_rid=edge_dst_rid, edge_shard=edge_shard,
        e_counts=e_counts, e_starts=e_starts, shard_sort=shard_sort,
        E_max=E_max,
    )


def _vr_table(pl: _Placement, K: int) -> tuple[np.ndarray, np.ndarray]:
    """(vertex, replica index) -> flat id table, shaped (n, K), plus mask."""
    rid = pl.first_rid[:-1, None] + np.arange(K, dtype=np.int64)[None, :]
    mask = np.arange(K, dtype=np.int64)[None, :] < pl.num_replicas[:, None]
    rid = np.minimum(rid, np.maximum(pl.first_rid[1:, None] - 1, 0))
    flat = pl.rep_flat[rid] if pl.R_total else np.zeros((pl.n, K), np.int64)
    return flat, mask


# ---------------------------------------------------------------------------
# assembly: per-shard edge rows + compact plan, fresh or copied from old
# ---------------------------------------------------------------------------


def _assemble(g: COOGraph, cfg: PartitionConfig, pl: _Placement,
              old: Partition | None = None,
              rebuild: np.ndarray | None = None) -> Partition:
    S, n, E = pl.S, pl.n, pl.E
    R_max, E_max = pl.R_max, pl.E_max
    if old is None:
        rebuild = np.ones(S, dtype=bool)
    else:
        assert rebuild is not None
        # safety: a shard we plan to copy must hold exactly the same number
        # of edges as before — if not, the diff missed something; rebuild.
        old_counts = old.edge_mask.sum(axis=1)
        rebuild = rebuild | (old_counts != pl.e_counts)

    edge_src_root_flat = np.zeros((S, E_max), dtype=np.int64)
    edge_dst_flat = np.zeros((S, E_max), dtype=np.int64)
    edge_w = np.zeros((S, E_max), dtype=np.float32)
    edge_mask = np.zeros((S, E_max), dtype=bool)
    edge_src_vertex = np.zeros((S, E_max), dtype=np.int64)
    edge_dst_vertex = np.zeros((S, E_max), dtype=np.int64)

    # ---- per-shard padded edge arrays, sorted by destination flat ----
    for s in range(S):
        k = int(pl.e_counts[s])
        if k == 0:
            continue
        if rebuild[s]:
            es = pl.shard_sort[pl.e_starts[s]: pl.e_starts[s + 1]]
            dflat = pl.rep_flat[pl.edge_dst_rid[es]]
            local_order = np.argsort(dflat, kind="stable")
            es = es[local_order]
            edge_src_root_flat[s, :k] = pl.root_flat[g.src[es]]
            edge_dst_flat[s, :k] = pl.rep_flat[pl.edge_dst_rid[es]]
            edge_w[s, :k] = g.weight[es]
            edge_src_vertex[s, :k] = g.src[es]
            edge_dst_vertex[s, :k] = g.dst[es]
        else:
            # unchanged content: copy the old row, re-encoding flat ids for
            # a possibly different R_max (same (shard, slot) pairs).
            om = old.edge_mask[s]
            osrf = old.edge_src_root_flat[s][om]
            odf = old.edge_dst_flat[s][om]
            edge_src_root_flat[s, :k] = (osrf // old.R_max) * R_max + osrf % old.R_max
            edge_dst_flat[s, :k] = (odf // old.R_max) * R_max + odf % old.R_max
            edge_w[s, :k] = old.edge_w[s][om]
            edge_src_vertex[s, :k] = old.edge_src_vertex[s][om]
            edge_dst_vertex[s, :k] = old.edge_dst_vertex[s][om]
        edge_mask[s, :k] = True

    edge_owner_cc = np.broadcast_to(
        np.arange(S, dtype=np.int64)[:, None], (S, E_max)
    ).copy()

    # ---- slot tables + rhizome sibling links (always fresh; cheap) ----
    slot_vertex = np.full((S, R_max), -1, dtype=np.int64)
    slot_is_root = np.zeros((S, R_max), dtype=bool)
    slot_vertex[pl.rep_shard, pl.rep_slot] = pl.rep_vertex
    slot_is_root[pl.rep_shard, pl.rep_slot] = pl.rep_index == 0

    sibling_flat = np.zeros((S, R_max, cfg.rpvo_max), dtype=np.int64)
    sibling_mask = np.zeros((S, R_max, cfg.rpvo_max), dtype=bool)
    for r in range(cfg.rpvo_max):
        has = pl.num_replicas[pl.rep_vertex] > r
        sib_rid = pl.first_rid[pl.rep_vertex] + np.minimum(
            r, pl.num_replicas[pl.rep_vertex] - 1)
        sibling_flat[pl.rep_shard, pl.rep_slot, r] = pl.rep_flat[sib_rid]
        sibling_mask[pl.rep_shard, pl.rep_slot, r] = has

    # ---- compact targeted-exchange plan ----
    # distinct destination slots per (source shard, target shard); edges are
    # already sorted by dst flat, so distinct ranks are contiguous per target
    per_st_counts = np.zeros((S, S), dtype=np.int64)
    shard_uniques: list[tuple[np.ndarray, np.ndarray] | None] = []
    for s in range(S):
        if rebuild[s]:
            dst = edge_dst_flat[s][edge_mask[s]]
            uniq, inv = np.unique(dst, return_inverse=True)
            shard_uniques.append((uniq, inv))
            per_st_counts[s] = np.bincount(uniq // R_max, minlength=S)
        else:
            shard_uniques.append(None)
            # distinct-slot counts per target are exactly the non-sentinel
            # entries of the old inbox map's source column
            per_st_counts[s] = (old.inbox_slot_map[:, s, :] != old.R_max).sum(axis=1)
    P_t = max(int(per_st_counts.max()), 1)
    edge_dst_compact = np.zeros((S, E_max), dtype=np.int64)
    inbox_slot_map = np.full((S, S, P_t), R_max, dtype=np.int64)  # pad=R_max
    for s in range(S):
        if rebuild[s]:
            uniq, inv = shard_uniques[s]
            if uniq.size == 0:
                continue
            tgt = uniq // R_max
            t_starts = np.zeros(S + 1, dtype=np.int64)
            np.cumsum(np.bincount(tgt, minlength=S), out=t_starts[1:])
            rank = np.arange(uniq.size) - t_starts[tgt]
            compact_of_uniq = tgt * P_t + rank
            edge_dst_compact[s, : inv.size] = compact_of_uniq[inv]
            inbox_slot_map[tgt, s, rank] = uniq % R_max
        else:
            k = int(pl.e_counts[s])
            om = old.edge_mask[s]
            oc = old.edge_dst_compact[s][om]
            edge_dst_compact[s, :k] = (oc // old.P_t) * P_t + oc % old.P_t
            w = min(old.P_t, P_t)
            col = old.inbox_slot_map[:, s, :w]
            inbox_slot_map[:, s, :w] = np.where(col == old.R_max, R_max, col)

    # compact rhizome-collapse tables (only slots with >1 replica collapse)
    is_rz = sibling_mask.sum(axis=-1) > 1                      # (S, R_max)
    R_rz_max = max(int(is_rz.sum(axis=1).max()), 1)
    rz_local = np.full((S, R_rz_max), R_max, dtype=np.int64)
    rz_compact_of_flat = {}
    for s in range(S):
        slots = np.nonzero(is_rz[s])[0]
        rz_local[s, : slots.size] = slots
        for k, sl in enumerate(slots):
            rz_compact_of_flat[s * R_max + sl] = s * R_rz_max + k
    rz_sibling_idx = np.zeros((S, R_rz_max, cfg.rpvo_max), dtype=np.int64)
    rz_sibling_mask = np.zeros((S, R_rz_max, cfg.rpvo_max), dtype=bool)
    for s in range(S):
        slots = np.nonzero(is_rz[s])[0]
        for k, sl in enumerate(slots):
            for r in range(cfg.rpvo_max):
                if sibling_mask[s, sl, r]:
                    f = int(sibling_flat[s, sl, r])
                    rz_sibling_idx[s, k, r] = rz_compact_of_flat.get(f, 0)
                    rz_sibling_mask[s, k, r] = f in rz_compact_of_flat

    # ---- metrics ----
    ideal = max(E / S, 1e-9)
    metrics = {
        "E_max": E_max,
        "edge_balance": E_max / ideal,            # 1.0 == perfect
        "R_max": R_max,
        "replicas_total": pl.R_total,
        "replica_overhead": pl.R_total / max(n, 1),
        "cutoff_chunk": pl.cutoff_chunk,
        "max_inbox_per_slot": int(
            np.bincount(pl.edge_dst_rid, minlength=pl.R_total).max() if E else 0
        ),
        "shard_edge_counts": pl.e_counts,
    }

    return Partition(
        cfg=cfg, n=n, num_edges=E, S=S, E_max=E_max, R_max=R_max,
        num_replicas_total=pl.R_total,
        edge_src_root_flat=edge_src_root_flat, edge_dst_flat=edge_dst_flat,
        edge_w=edge_w, edge_mask=edge_mask,
        edge_src_vertex=edge_src_vertex, edge_dst_vertex=edge_dst_vertex,
        edge_owner_cc=edge_owner_cc,
        slot_vertex=slot_vertex, slot_is_root=slot_is_root,
        sibling_flat=sibling_flat, sibling_mask=sibling_mask,
        root_flat=pl.root_flat, num_replicas=pl.num_replicas,
        out_deg=pl.out_deg, in_deg=pl.in_deg,
        P_t=P_t, edge_dst_compact=edge_dst_compact,
        inbox_slot_map=inbox_slot_map,
        R_rz_max=R_rz_max, rz_local=rz_local,
        rz_sibling_idx=rz_sibling_idx, rz_sibling_mask=rz_sibling_mask,
        metrics=metrics,
    )


def build_partition(g: COOGraph, cfg: PartitionConfig) -> Partition:
    return _assemble(g, cfg, _placement(g, cfg))


def splice_partition(
    old: Partition,
    g: COOGraph,
    cfg: PartitionConfig,
    mutated_src: np.ndarray | None = None,
    mutated_dst: np.ndarray | None = None,
) -> tuple[Partition, SpliceInfo]:
    """Rebuild only the shard rows a mutation batch touched.

    ``g`` is the post-mutation graph; ``mutated_src`` / ``mutated_dst``
    are the endpoint vertex ids of every inserted, deleted, or
    reweighted edge (either may be None => conservative full rebuild).
    Because placement is counter-hashed, the result is field-for-field
    identical to ``build_partition(g, cfg)``: unaffected shard rows are
    copied (re-encoded for any R_max / P_t change) instead of re-sorted.

    A shard's edge row must be regenerated iff it holds — before or
    after the mutation — an edge whose src/dst was mutated, whose
    destination vertex gained/lost/moved a replica (adaptive rhizome
    growth), or whose source's root replica slot shifted.
    """
    assert old.n == g.n, "streaming splice keeps the vertex set fixed"
    assert old.cfg.rpvo_max == cfg.rpvo_max
    pl = _placement(g, cfg)
    S, n = pl.S, pl.n
    K = cfg.rpvo_max

    # old / new (vertex, replica index) -> (shard, slot)
    rows = old.root_flat // old.R_max
    cols = old.root_flat % old.R_max
    old_vr_flat = old.sibling_flat[rows, cols][:, :K]
    old_vr_mask = old.sibling_mask[rows, cols][:, :K]
    new_vr_flat, new_vr_mask = _vr_table(pl, K)

    pos_differs = (
        (old_vr_flat // old.R_max != new_vr_flat // pl.R_max)
        | (old_vr_flat % old.R_max != new_vr_flat % pl.R_max)
    )
    moved = (old_vr_mask != new_vr_mask) | (old_vr_mask & new_vr_mask & pos_differs)
    moved_any = moved.any(axis=1)
    root_moved = moved[:, 0] if K else np.zeros(n, bool)
    replicas_added = int((~old_vr_mask & new_vr_mask).sum())
    replicas_removed = int((old_vr_mask & ~new_vr_mask).sum())

    full = (
        mutated_src is None or mutated_dst is None
        or cfg.ghost_alloc == "balanced"
    )
    if full:
        rebuild = np.ones(S, dtype=bool)
        affected_edges = int(g.num_edges)
    else:
        mset = np.zeros(n, dtype=bool)
        mset[np.asarray(mutated_src, dtype=np.int64)] = True
        dset = np.zeros(n, dtype=bool)
        dset[np.asarray(mutated_dst, dtype=np.int64)] = True
        rebuild = np.zeros(S, dtype=bool)
        if g.num_edges:
            aff_new = (mset[g.src] | dset[g.dst]
                       | moved_any[g.dst] | root_moved[g.src])
            np.logical_or.at(rebuild, pl.edge_shard, aff_new)
        else:
            aff_new = np.zeros(0, bool)
        orow, _ = np.nonzero(old.edge_mask)
        osrc = old.edge_src_vertex[old.edge_mask]
        odst = old.edge_dst_vertex[old.edge_mask]
        aff_old = (mset[osrc] | dset[odst]
                   | moved_any[odst] | root_moved[osrc])
        np.logical_or.at(rebuild, orow, aff_old)
        affected_edges = int(aff_new.sum())

    part = _assemble(g, cfg, pl, old=old, rebuild=rebuild)
    info = SpliceInfo(
        shards_rebuilt=int(rebuild.sum()),
        shards_total=S,
        rebuilt_ids=np.nonzero(rebuild)[0].tolist(),
        replicas_added=replicas_added,
        replicas_removed=replicas_removed,
        replicas_moved=int(moved.sum()),
        affected_edges=affected_edges,
        full_rebuild=bool(rebuild.all()),
        r_max_changed=old.R_max != part.R_max,
        e_max_changed=old.E_max != part.E_max,
    )
    return part, info
