"""RPVO + Rhizome partitioning (paper §3, §6.1 "Graph Construction").

Maps a COO graph onto S shards (compute cells in the AM-CCA cost model,
TPU devices in the JAX engine):

* **RPVO (out-degree)** — each vertex's out-edges are chunked into
  ``local_edge_list_size`` ghost chunks; chunks are placed by an allocator
  (home / vicinity / random / balanced).  With ``ghost_alloc="home"`` all
  chunks stay at the root's shard — the paper's Fig 2a "simple vertex"
  baseline, whose padded per-shard edge width inflates with out-degree skew.
* **Rhizome (in-degree)** — Eq. 1: ``cutoff_chunk = indegree_max /
  rpvo_max``; every ``cutoff_chunk`` in-edges of a vertex are pointed at
  the next replica (cycling), so a hub's inbox is spread over up to
  ``rpvo_max`` replica slots on distinct shards.  Replicas are allocated
  by the *random* allocator (paper §6.1, Fig 4c).

The result is a set of static, padded arrays directly consumable by the
JAX engine (`repro.core.engine`) and by the AM-CCA cost model.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.graph import COOGraph


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    num_shards: int
    local_edge_list_size: int = 32
    rpvo_max: int = 1                 # 1 => plain RPVO (no rhizomes)
    ghost_alloc: str = "balanced"     # 'home' | 'vicinity' | 'random' | 'balanced'
    mesh_dims: tuple[int, int] | None = None  # (X, Y); default near-square
    torus: bool = True
    seed: int = 0

    def dims(self) -> tuple[int, int]:
        if self.mesh_dims is not None:
            assert self.mesh_dims[0] * self.mesh_dims[1] == self.num_shards
            return self.mesh_dims
        x = int(np.floor(np.sqrt(self.num_shards)))
        while self.num_shards % x:
            x -= 1
        return (self.num_shards // x, x)


@dataclasses.dataclass
class Partition:
    """Sharded RPVO/Rhizome layout. ``flat`` replica id = shard * R_max + slot."""

    cfg: PartitionConfig
    n: int
    num_edges: int
    S: int
    E_max: int                      # padded edges per shard
    R_max: int                      # padded replica slots per shard
    num_replicas_total: int

    # --- per-edge, per-shard arrays, all shaped (S, E_max) ---
    edge_src_root_flat: np.ndarray  # flat id of src vertex's ROOT replica
    edge_dst_flat: np.ndarray       # flat id of the dst REPLICA this edge feeds
    edge_w: np.ndarray              # float32 weights
    edge_mask: np.ndarray           # bool, False on padding
    edge_src_vertex: np.ndarray     # int32 global src vertex (cost model)
    edge_dst_vertex: np.ndarray     # int32 global dst vertex (cost model)
    edge_owner_cc: np.ndarray       # int32 CC owning the ghost chunk (== shard)

    # --- per-slot tables, shaped (S, R_max) ---
    slot_vertex: np.ndarray         # vertex id of replica at slot (-1 pad)
    slot_is_root: np.ndarray        # bool
    sibling_flat: np.ndarray        # (S, R_max, rpvo_max) flat ids of ALL
    sibling_mask: np.ndarray        # replicas of the slot's vertex (+mask)

    # --- per-vertex tables ---
    root_flat: np.ndarray           # (n,) flat id of root replica
    num_replicas: np.ndarray        # (n,)
    out_deg: np.ndarray             # (n,) int64
    in_deg: np.ndarray              # (n,) int64

    # --- compact targeted-exchange plan (§Perf; message-driven semantics:
    #     contributions travel only to the replica's owner shard) ---
    P_t: int                        # padded distinct-dst slots per (src,tgt)
    edge_dst_compact: np.ndarray    # (S, E_max) int32 -> [0, S*P_t)
    inbox_slot_map: np.ndarray      # (S_tgt, S_src, P_t) local slot or R_max
    R_rz_max: int                   # padded rhizome slots per shard
    rz_local: np.ndarray            # (S, R_rz_max) local slot ids (R_max pad)
    rz_sibling_idx: np.ndarray      # (S, R_rz_max, K) global rz-compact ids
    rz_sibling_mask: np.ndarray     # (S, R_rz_max, K)

    # --- metrics (recorded for roofline / paper figures) ---
    metrics: dict

    def replica_shards_of(self, v: int) -> list[int]:
        sib = self.sibling_flat[self.root_flat[v] // self.R_max,
                                self.root_flat[v] % self.R_max]
        msk = self.sibling_mask[self.root_flat[v] // self.R_max,
                                self.root_flat[v] % self.R_max]
        return sorted({int(f) // self.R_max for f, m in zip(sib, msk) if m})


def _vicinity_order(cfg: PartitionConfig) -> np.ndarray:
    """CC offsets sorted by Manhattan distance from origin (torus-aware)."""
    X, Y = cfg.dims()
    xs, ys = np.meshgrid(np.arange(X), np.arange(Y), indexing="ij")
    dx, dy = xs.ravel(), ys.ravel()
    if cfg.torus:
        ddx = np.minimum(dx, X - dx)
        ddy = np.minimum(dy, Y - dy)
    else:
        ddx, ddy = dx, dy
    order = np.argsort(ddx + ddy, kind="stable")
    return (dy[order] * X + dx[order]).astype(np.int64)  # cc ids by distance


def build_partition(g: COOGraph, cfg: PartitionConfig) -> Partition:
    rng = np.random.default_rng(cfg.seed)
    S = cfg.num_shards
    n, E = g.n, g.num_edges
    in_deg = g.in_degrees()
    out_deg = g.out_degrees()

    # ---- 1. root homes: random allocation across the chip (paper §6.1) ----
    root_shard = rng.integers(0, S, size=n).astype(np.int64)

    # ---- 2. rhizome replicas (Eq. 1) ----
    indeg_max = max(int(in_deg.max()) if n else 1, 1)
    cutoff_chunk = max(int(np.ceil(indeg_max / cfg.rpvo_max)), 1)
    num_replicas = np.minimum(
        cfg.rpvo_max, np.maximum(1, np.ceil(in_deg / cutoff_chunk).astype(np.int64))
    )
    R_total = int(num_replicas.sum())
    first_rid = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(num_replicas, out=first_rid[1:])

    # replica r of vertex v -> shard: r=0 at root home; r>0 random (paper)
    rep_vertex = np.repeat(np.arange(n, dtype=np.int64), num_replicas)
    rep_index = np.arange(R_total, dtype=np.int64) - first_rid[rep_vertex]
    rep_shard = np.where(
        rep_index == 0,
        root_shard[rep_vertex],
        rng.integers(0, S, size=R_total),
    ).astype(np.int64)

    # slots: order replicas per shard
    order = np.argsort(rep_shard, kind="stable")
    rep_slot = np.zeros(R_total, dtype=np.int64)
    counts = np.bincount(rep_shard, minlength=S)
    starts = np.zeros(S + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    rep_slot[order] = np.arange(R_total, dtype=np.int64) - starts[rep_shard[order]]
    R_max = max(int(counts.max()) if R_total else 1, 1)
    rep_flat = rep_shard * R_max + rep_slot
    root_flat = rep_flat[first_rid[:-1]] if n else np.zeros(0, np.int64)

    # ---- 3. in-edge -> replica assignment (cycling every cutoff_chunk) ----
    dst_order = np.argsort(g.dst, kind="stable")
    in_rank = np.zeros(E, dtype=np.int64)
    dst_counts = np.bincount(g.dst, minlength=n)
    dst_starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(dst_counts, out=dst_starts[1:])
    in_rank[dst_order] = np.arange(E, dtype=np.int64) - dst_starts[g.dst[dst_order]]
    dst_rep_index = (in_rank // cutoff_chunk) % np.maximum(num_replicas[g.dst], 1)
    edge_dst_rid = first_rid[g.dst] + dst_rep_index  # global replica id per edge

    # ---- 4. out-edge chunking (RPVO ghosts) + allocation ----
    src_order = np.argsort(g.src, kind="stable")
    out_rank = np.zeros(E, dtype=np.int64)
    src_counts = np.bincount(g.src, minlength=n)
    src_starts = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(src_counts, out=src_starts[1:])
    out_rank[src_order] = np.arange(E, dtype=np.int64) - src_starts[g.src[src_order]]
    chunk_of_edge = out_rank // max(cfg.local_edge_list_size, 1)

    # allocate chunks -> shards
    # chunk key: (src vertex, chunk index); dedupe to one placement per chunk
    chunk_key = g.src.astype(np.int64) * (E + 1) + chunk_of_edge
    uniq_keys, chunk_id_of_edge = np.unique(chunk_key, return_inverse=True)
    n_chunks = uniq_keys.size
    chunk_vertex = (uniq_keys // (E + 1)).astype(np.int64)
    chunk_index = (uniq_keys % (E + 1)).astype(np.int64)

    if cfg.ghost_alloc == "home":
        chunk_shard = root_shard[chunk_vertex]
    elif cfg.ghost_alloc == "random":
        chunk_shard = np.where(
            chunk_index == 0,
            root_shard[chunk_vertex],
            rng.integers(0, S, size=n_chunks),
        )
    elif cfg.ghost_alloc == "vicinity":
        vic = _vicinity_order(cfg)
        win = min(S, 25)  # 5x5 neighborhood of the root CC
        offs = vic[1 + rng.integers(0, max(win - 1, 1), size=n_chunks)]
        X, Yd = cfg.dims()
        hx, hy = root_shard[chunk_vertex] % X, root_shard[chunk_vertex] // X
        ox, oy = offs % X, offs // X
        near = ((hy + oy) % Yd) * X + (hx + ox) % X
        chunk_shard = np.where(chunk_index == 0, root_shard[chunk_vertex], near)
    elif cfg.ghost_alloc == "balanced":
        # greedy least-loaded by edges — the TPU-engine default (no NoC
        # locality to exploit under dense collectives; see DESIGN.md §2)
        chunk_sizes = np.bincount(chunk_id_of_edge, minlength=n_chunks)
        load = np.zeros(S, dtype=np.int64)
        chunk_shard = np.zeros(n_chunks, dtype=np.int64)
        csort = np.argsort(-chunk_sizes, kind="stable")
        for c in csort:
            s = int(np.argmin(load))
            chunk_shard[c] = s
            load[s] += chunk_sizes[c]
    else:
        raise ValueError(f"unknown ghost_alloc {cfg.ghost_alloc!r}")
    chunk_shard = chunk_shard.astype(np.int64)
    edge_shard = chunk_shard[chunk_id_of_edge]

    # ---- 5. per-shard padded edge arrays, sorted by destination flat ----
    e_counts = np.bincount(edge_shard, minlength=S)
    E_max = max(int(e_counts.max()) if E else 1, 1)

    def pad2(vals, fill, dtype):
        outv = np.full((S, E_max), fill, dtype=dtype)
        return outv

    edge_src_root_flat = pad2(None, 0, np.int64)
    edge_dst_flat = pad2(None, 0, np.int64)
    edge_w = np.zeros((S, E_max), dtype=np.float32)
    edge_mask = np.zeros((S, E_max), dtype=bool)
    edge_src_vertex = pad2(None, 0, np.int64)
    edge_dst_vertex = pad2(None, 0, np.int64)

    shard_sort = np.argsort(edge_shard, kind="stable")
    e_starts = np.zeros(S + 1, dtype=np.int64)
    np.cumsum(e_counts, out=e_starts[1:])
    for s in range(S):
        es = shard_sort[e_starts[s] : e_starts[s + 1]]
        if es.size == 0:
            continue
        dflat = rep_flat[edge_dst_rid[es]]
        local_order = np.argsort(dflat, kind="stable")
        es = es[local_order]
        k = es.size
        edge_src_root_flat[s, :k] = root_flat[g.src[es]]
        edge_dst_flat[s, :k] = rep_flat[edge_dst_rid[es]]
        edge_w[s, :k] = g.weight[es]
        edge_mask[s, :k] = True
        edge_src_vertex[s, :k] = g.src[es]
        edge_dst_vertex[s, :k] = g.dst[es]

    edge_owner_cc = np.broadcast_to(
        np.arange(S, dtype=np.int64)[:, None], (S, E_max)
    ).copy()

    # ---- 6. slot tables + rhizome sibling links ----
    slot_vertex = np.full((S, R_max), -1, dtype=np.int64)
    slot_is_root = np.zeros((S, R_max), dtype=bool)
    slot_vertex[rep_shard, rep_slot] = rep_vertex
    slot_is_root[rep_shard, rep_slot] = rep_index == 0

    sibling_flat = np.zeros((S, R_max, cfg.rpvo_max), dtype=np.int64)
    sibling_mask = np.zeros((S, R_max, cfg.rpvo_max), dtype=bool)
    for r in range(cfg.rpvo_max):
        has = num_replicas[rep_vertex] > r
        sib_rid = first_rid[rep_vertex] + np.minimum(r, num_replicas[rep_vertex] - 1)
        sibling_flat[rep_shard, rep_slot, r] = rep_flat[sib_rid]
        sibling_mask[rep_shard, rep_slot, r] = has

    # ---- 6b. compact targeted-exchange plan ----
    # distinct destination slots per (source shard, target shard); edges are
    # already sorted by dst flat, so distinct ranks are contiguous per target
    per_st_counts = np.zeros((S, S), dtype=np.int64)
    shard_uniques = []
    for s in range(S):
        dst = edge_dst_flat[s][edge_mask[s]]
        uniq, inv = np.unique(dst, return_inverse=True)
        shard_uniques.append((uniq, inv))
        tgt = uniq // R_max
        cnt = np.bincount(tgt, minlength=S)
        per_st_counts[s] = cnt
    P_t = max(int(per_st_counts.max()), 1)
    edge_dst_compact = np.zeros((S, E_max), dtype=np.int64)
    inbox_slot_map = np.full((S, S, P_t), R_max, dtype=np.int64)  # pad=R_max
    for s in range(S):
        uniq, inv = shard_uniques[s]
        if uniq.size == 0:
            continue
        tgt = uniq // R_max
        t_starts = np.zeros(S + 1, dtype=np.int64)
        np.cumsum(np.bincount(tgt, minlength=S), out=t_starts[1:])
        rank = np.arange(uniq.size) - t_starts[tgt]
        compact_of_uniq = tgt * P_t + rank
        edge_dst_compact[s, : inv.size] = compact_of_uniq[inv]
        inbox_slot_map[tgt, s, rank] = uniq % R_max

    # compact rhizome-collapse tables (only slots with >1 replica collapse)
    is_rz = sibling_mask.sum(axis=-1) > 1                      # (S, R_max)
    R_rz_max = max(int(is_rz.sum(axis=1).max()), 1)
    rz_local = np.full((S, R_rz_max), R_max, dtype=np.int64)
    rz_compact_of_flat = {}
    for s in range(S):
        slots = np.nonzero(is_rz[s])[0]
        rz_local[s, : slots.size] = slots
        for k, sl in enumerate(slots):
            rz_compact_of_flat[s * R_max + sl] = s * R_rz_max + k
    rz_sibling_idx = np.zeros((S, R_rz_max, cfg.rpvo_max), dtype=np.int64)
    rz_sibling_mask = np.zeros((S, R_rz_max, cfg.rpvo_max), dtype=bool)
    for s in range(S):
        slots = np.nonzero(is_rz[s])[0]
        for k, sl in enumerate(slots):
            for r in range(cfg.rpvo_max):
                if sibling_mask[s, sl, r]:
                    f = int(sibling_flat[s, sl, r])
                    rz_sibling_idx[s, k, r] = rz_compact_of_flat.get(f, 0)
                    rz_sibling_mask[s, k, r] = f in rz_compact_of_flat

    # ---- 7. metrics ----
    ideal = max(E / S, 1e-9)
    metrics = {
        "E_max": E_max,
        "edge_balance": E_max / ideal,            # 1.0 == perfect
        "R_max": R_max,
        "replicas_total": R_total,
        "replica_overhead": R_total / max(n, 1),
        "cutoff_chunk": cutoff_chunk,
        "max_inbox_per_slot": int(
            np.bincount(edge_dst_rid, minlength=R_total).max() if E else 0
        ),
        "shard_edge_counts": e_counts,
    }

    return Partition(
        cfg=cfg, n=n, num_edges=E, S=S, E_max=E_max, R_max=R_max,
        num_replicas_total=R_total,
        edge_src_root_flat=edge_src_root_flat, edge_dst_flat=edge_dst_flat,
        edge_w=edge_w, edge_mask=edge_mask,
        edge_src_vertex=edge_src_vertex, edge_dst_vertex=edge_dst_vertex,
        edge_owner_cc=edge_owner_cc,
        slot_vertex=slot_vertex, slot_is_root=slot_is_root,
        sibling_flat=sibling_flat, sibling_mask=sibling_mask,
        root_flat=root_flat, num_replicas=num_replicas,
        out_deg=out_deg, in_deg=in_deg,
        P_t=P_t, edge_dst_compact=edge_dst_compact,
        inbox_slot_map=inbox_slot_map,
        R_rz_max=R_rz_max, rz_local=rz_local,
        rz_sibling_idx=rz_sibling_idx, rz_sibling_mask=rz_sibling_mask,
        metrics=metrics,
    )
