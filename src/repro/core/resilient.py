"""Crash-safe fixpoint driver (ISSUE 10 tentpole).

Pregel-lineage systems checkpoint at superstep boundaries because round
boundaries are the natural consistency points; our round machinery
already exposes them.  This module wraps the per-round exchange
compositions (``repro.exchange``) in a host-driven driver that adds, at
every round boundary:

1. **chaos injection** — a seedable ``runtime.chaos.ChaosPlan`` fires
   engine-level faults (shard kills, dropped/duplicated inboxes,
   corrupted value tiles, delayed shards) deterministically;
2. **detection** — three independent detectors, each surfacing a typed
   ``FaultDetected``:
   * a **crc scrub** of the per-shard value rows against the previous
     round boundary (corrupted tiles);
   * the **host counter mirror** ``exchange.expected_round_messages``:
     a round whose reported message count disagrees with the mirror
     dropped or duplicated an inbox (the kernels' ``with_debug``
     counters assert the same totals in the differential tests);
   * the ``runtime.elastic.ShardPool`` **heartbeat window** (killed
     shards; delayed shards inside the window never trip it);
3. **recovery** — the ``RecoveryPolicy`` ladder: bounded same-round
   retry for transient faults, re-dispatch from the last checkpoint for
   state-loss faults (round 0's initial state is the implicit
   checkpoint), shard-pool **shrink** (rebuild the partition on the
   survivors and migrate per-vertex values), and finally graceful
   degradation to a typed ``'degraded'`` partial result;
4. **checkpointing** — every ``EngineConfig.checkpoint_every`` rounds
   the driver hands {value tables, frontier, accounting counters} to a
   ``CheckpointManager`` (async, atomic, crc-verified).  Counters ride
   in the checkpoint so a restored run's message/cell totals equal an
   uninterrupted run's exactly — the counter-gate kill/restore leg
   pins this.

Min-semiring fixpoints restored from any round boundary are
BIT-IDENTICAL to an uninterrupted run (monotone relaxation from
intermediate upper bounds reconverges to the same fixpoint, and the
replayed rounds are the same deterministic dispatches); sum-semiring
(delta-PageRank) runs agree within reassociation tolerance.

The shipped loops in ``core.engine`` are untouched: with no chaos, no
checkpoint manager, and obs off, nothing here runs — the obs-off jaxpr
parity bar holds.
"""
from __future__ import annotations

import dataclasses
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import exchange, obs
from repro.core import engine
from repro.core.actions import Semiring
from repro.core.engine import DeviceArrays, EngineConfig
from repro.core.partition import Partition, build_partition
from repro.runtime.chaos import (
    STATE_LOSS, ChaosPlan, FaultDetected, FaultEventRecord,
    FixpointReport, RecoveryPolicy)
from repro.runtime.elastic import ShardPool


# --------------------------------------------------------------------------
# per-shard crc scrub
# --------------------------------------------------------------------------

def shard_crcs(arrays_host) -> list[list[int]]:
    """Per-shard crc32 of each (S, ...) value table — the round-boundary
    integrity fingerprint the scrub compares against."""
    out = []
    for h in arrays_host:
        h = np.asarray(h)
        out.append([zlib.crc32(np.ascontiguousarray(h[s]).tobytes())
                    for s in range(h.shape[0])])
    return out


def _scrub_mismatch(before, now):
    """First (table, shard) whose crc changed since the last boundary,
    else None."""
    for t, (a, b) in enumerate(zip(before, now)):
        for s, (x, y) in enumerate(zip(a, b)):
            if x != y:
                return t, s
    return None


# --------------------------------------------------------------------------
# task layouts: stacked / laned / sharded drivers over one recovery core
# --------------------------------------------------------------------------

class StackedTask:
    """Single-device stacked min-semiring fixpoint (the ``run_stacked``
    layout) under the resilient driver.  ``graph`` (optional COOGraph)
    enables the ``on_dead='shrink'`` path — the partition is rebuilt on
    the surviving shards and per-vertex values migrate."""

    laned = False
    records = True

    def __init__(self, sem: Semiring, part: Partition, init_val,
                 cfg: EngineConfig = EngineConfig(), init_changed=None,
                 graph=None):
        if sem.segment != "min":
            raise ValueError("StackedTask drives min-semiring fixpoints; "
                             "use PagerankTask for counted sum rounds")
        self.sem = sem
        self.part = part
        self.cfg = cfg
        self.name = sem.name
        self.graph = graph
        self._init_val = init_val
        self._init_changed = init_changed
        self._bind(part)

    def _bind(self, part: Partition):
        self.part = part
        self.arrays = DeviceArrays.from_partition(part)
        S, R_max = part.S, part.R_max
        sem, cfg, arrays = self.sem, self.cfg, self.arrays

        @jax.jit
        def round_fn(val, chg, worklist):
            return exchange.fixpoint_round_stacked(
                sem, arrays, cfg, S, R_max, val, chg, worklist=worklist)

        self.round_fn = round_fn

    def init_state(self) -> dict:
        val = jnp.asarray(self._init_val, jnp.float32)
        if self._init_changed is not None:
            chg = jnp.asarray(self._init_changed) & self.arrays.slot_valid
        else:
            chg = self.sem.improved(
                val, jnp.full_like(val, self.sem.identity)
            ) & self.arrays.slot_valid
        return {"val": val, "chg": chg}

    def dispatch(self, state, wl):
        val, chg, mc = self.round_fn(state["val"], state["chg"], wl)
        return {"val": val, "chg": chg}, mc

    def host_frontier(self, state):
        return np.asarray(state["chg"])

    def plan_frontier(self, chg_h):
        return chg_h.reshape(-1)

    def drop_shard(self, state, s: int):
        return {**state,
                "chg": exchange.mask_shard_frontier(state["chg"], s)}

    def corrupt_shard(self, state, s: int):
        return {**state, "val": state["val"].at[s].set(-7.25)}

    def crc_arrays(self, state):
        return [state["val"]]

    def put(self, host_state):
        return {"val": jnp.asarray(host_state["val"], jnp.float32),
                "chg": jnp.asarray(host_state["chg"], bool)}

    def finalize(self, state):
        val = state["val"]
        if self.cfg.collapse == "deferred":
            val = exchange.collapse(self.sem, val.reshape(-1),
                                    self.arrays.sibling_flat,
                                    self.arrays.sibling_mask)
        return val

    # ------------------------------------------------------------- shrink
    @property
    def can_shrink(self) -> bool:
        return self.graph is not None

    def shrink(self, survivors: int, ckpt_val):
        """Rebuild on ``survivors`` shards; migrate per-vertex values
        from the (checkpointed) old layout and re-seed the full finite
        frontier so the min fixpoint reconverges from its upper bounds.
        Returns the new partition (the caller's pool/planner rebind)."""
        old_part = self.part
        new_part, _ = shrink_partition(self.graph, old_part.cfg, survivors)
        self._init_val = migrate_values(old_part, ckpt_val, new_part,
                                        self.sem)
        self._init_changed = None
        self._bind(new_part)
        return new_part


class PagerankTask:
    """Stacked delta-PageRank (sum semiring) under the resilient driver.
    Restores agree with uninterrupted runs within reassociation
    tolerance (the traced reductions are re-run, not re-ordered, so in
    practice replay is bit-exact on one device — the looser contract is
    what the differential suite asserts)."""

    laned = False
    records = True

    def __init__(self, part: Partition, damping: float = 0.85, tol=1e-6,
                 cfg: EngineConfig = EngineConfig(), max_rounds: int = 256,
                 init_rank=None, init_delta=None):
        from repro.core.actions import PAGERANK as sem
        self.sem = sem
        self.part = part
        self.cfg = cfg
        self.name = "pagerank_delta"
        self.damping = damping
        self.max_rounds = max_rounds
        self.arrays = DeviceArrays.from_partition(part)
        self.tol_t = engine._tol_table(part, tol)
        base = (1.0 - damping) / part.n
        if init_rank is None:
            self._rank0 = self._delta0 = jnp.where(
                self.arrays.slot_valid, base, 0.0)
        else:
            self._rank0 = jnp.asarray(init_rank, jnp.float32)
            self._delta0 = jnp.asarray(init_delta, jnp.float32)
        S, R_max = part.S, part.R_max
        arrays, tol_t = self.arrays, self.tol_t

        @jax.jit
        def round_fn(rank, delta, worklist):
            return exchange.delta_pagerank_round_stacked(
                sem, arrays, cfg, S, R_max, damping, tol_t, rank, delta,
                worklist=worklist)

        self.round_fn = round_fn

    def init_state(self) -> dict:
        chg = (jnp.abs(self._delta0) > self.tol_t) & self.arrays.slot_valid
        return {"rank": self._rank0, "delta": self._delta0, "chg": chg}

    def dispatch(self, state, wl):
        rank, delta, chg, mc = self.round_fn(state["rank"],
                                             state["delta"], wl)
        return {"rank": rank, "delta": delta, "chg": chg}, mc

    def host_frontier(self, state):
        return np.asarray(state["chg"])

    def plan_frontier(self, chg_h):
        return chg_h.reshape(-1)

    def drop_shard(self, state, s: int):
        # zeroing the residual rows both silences shard s's messages and
        # models the lost value mass a dropped inbox implies
        delta = state["delta"].at[s].set(0.0)
        return {**state, "delta": delta,
                "chg": exchange.mask_shard_frontier(state["chg"], s)}

    def corrupt_shard(self, state, s: int):
        return {**state, "delta": state["delta"].at[s].set(0.123)}

    def crc_arrays(self, state):
        return [state["rank"], state["delta"]]

    def put(self, host_state):
        return {"rank": jnp.asarray(host_state["rank"], jnp.float32),
                "delta": jnp.asarray(host_state["delta"], jnp.float32),
                "chg": jnp.asarray(host_state["chg"], bool)}

    def finalize(self, state):
        return state["rank"]

    can_shrink = False


class LanesTask:
    """Lane-batched min fixpoint (the ``query.lanes`` (S, R_max, Q)
    layout) under the resilient driver — the serving pools' restore
    path drives this shape.  Per-round message counts are per-lane;
    the counter-mirror detector compares their lane-summed total."""

    laned = True
    records = False

    def __init__(self, part: Partition, init_val, lane_unitw=None,
                 cfg: EngineConfig = EngineConfig(), init_changed=None,
                 sem: Semiring = None):
        from repro.core import actions
        from repro.query import lanes as lanes_mod
        sem = actions.SSSP if sem is None else sem
        lanes_mod._check_cfg(cfg)
        lanes_mod._check_min(sem)
        self.sem = sem
        self.part = part
        self.cfg = cfg
        self.name = "lanes_min"
        self.arrays = DeviceArrays.from_partition(part)
        init_val = jnp.asarray(init_val, jnp.float32)
        if init_val.ndim != 3:
            raise ValueError(f"init_val must be (S, R_max, Q); got "
                             f"{init_val.shape}")
        self.q = init_val.shape[-1]
        self._init_val = init_val
        self._init_changed = init_changed
        self.lane_unitw = (jnp.zeros((self.q,), jnp.int32)
                           if lane_unitw is None
                           else jnp.asarray(lane_unitw,
                                            jnp.int32).reshape(self.q))
        S, R_max = part.S, part.R_max
        arrays, unitw = self.arrays, self.lane_unitw

        @jax.jit
        def round_fn(val, chg, worklist):
            return exchange.fixpoint_round_stacked(
                sem, arrays, cfg, S, R_max, val, chg, lane_unitw=unitw,
                worklist=worklist)

        self.round_fn = round_fn
        self.q_pad = lanes_mod._lane_q_pad(self.q)

    def init_state(self) -> dict:
        val = self._init_val
        slot = self.arrays.slot_valid[..., None]
        if self._init_changed is not None:
            chg = jnp.asarray(self._init_changed) & slot
        else:
            chg = self.sem.improved(
                val, jnp.full_like(val, self.sem.identity)) & slot
        return {"val": val, "chg": chg}

    def dispatch(self, state, wl):
        val, chg, counts = self.round_fn(state["val"], state["chg"], wl)
        return {"val": val, "chg": chg}, counts

    def host_frontier(self, state):
        return np.asarray(state["chg"])

    def plan_frontier(self, chg_h):
        return chg_h.reshape(-1, self.q).any(axis=1)

    def drop_shard(self, state, s: int):
        return {**state,
                "chg": exchange.mask_shard_frontier(state["chg"], s)}

    def corrupt_shard(self, state, s: int):
        return {**state, "val": state["val"].at[s].set(-7.25)}

    def crc_arrays(self, state):
        return [state["val"]]

    def put(self, host_state):
        return {"val": jnp.asarray(host_state["val"], jnp.float32),
                "chg": jnp.asarray(host_state["chg"], bool)}

    def finalize(self, state):
        return state["val"]

    can_shrink = False


class ShardedTask:
    """shard_map min fixpoint over a real mesh under the resilient
    driver: per-round dispatches of the collective round body
    (``exchange.make_shard_fixpoint_round``) with psum'd counts, so the
    same chaos detectors and recovery ladder apply over real
    collectives.  Host-planned worklist modes route to the traced
    ``device_worklist`` launch exactly as the shipped sharded runners
    do (``engine._sharded_cfg``)."""

    laned = False
    records = False

    def __init__(self, sem: Semiring, part: Partition, init_val,
                 mesh: Mesh, axis_names=("data", "model"),
                 cfg: EngineConfig = EngineConfig()):
        if sem.segment != "min":
            raise ValueError("ShardedTask drives min-semiring fixpoints")
        self.sem = sem
        self.part = part
        self.cfg = engine._sharded_cfg(cfg, "ShardedTask")
        self.name = f"{sem.name}_sharded"
        self.mesh = mesh
        axis_names = exchange.axis_tuple(axis_names)
        spec = P(axis_names)
        self.sharding = NamedSharding(mesh, spec)
        S, R_max = part.S, part.R_max
        run_cfg = self.cfg
        from jax.experimental.shard_map import shard_map

        def shard_fn(arrays_l: DeviceArrays, val_l, chg_l):
            arrays_s = jax.tree.map(lambda x: x[0], arrays_l)
            body = exchange.make_shard_fixpoint_round(
                sem, arrays_s, run_cfg, S, R_max, axis_names)
            nval, nchg, mc = body(val_l[0], chg_l[0])
            mc = jax.lax.psum(mc, axis_names)
            return nval[None], nchg[None], mc[None]

        fn = shard_map(
            shard_fn, mesh=mesh,
            in_specs=(DeviceArrays.specs(spec), spec, spec),
            out_specs=(spec, spec, spec), check_rep=False)
        self._fn = jax.jit(fn)
        arrays = DeviceArrays.from_partition(part)
        self.arrays_dev = jax.tree.map(
            lambda x: jax.device_put(x, self.sharding), arrays)
        self.slot_valid = np.asarray(part.slot_vertex) >= 0
        self._init_val = np.asarray(init_val, np.float32)

    def init_state(self) -> dict:
        val = jax.device_put(jnp.asarray(self._init_val), self.sharding)
        chg_h = ((self._init_val != self.sem.identity)
                 if np.isfinite(self.sem.identity)
                 else np.isfinite(self._init_val)) & self.slot_valid
        chg = jax.device_put(jnp.asarray(chg_h), self.sharding)
        return {"val": val, "chg": chg}

    def dispatch(self, state, wl):
        # wl is always None here: the sharded round is a traced
        # collective (device_worklist handles sparsity in-trace)
        val, chg, mc = self._fn(self.arrays_dev, state["val"],
                                state["chg"])
        return {"val": val, "chg": chg}, mc[0]

    def host_frontier(self, state):
        return np.asarray(state["chg"])

    def plan_frontier(self, chg_h):
        return chg_h.reshape(-1)

    def drop_shard(self, state, s: int):
        chg = jax.device_put(state["chg"].at[s].set(False), self.sharding)
        return {**state, "chg": chg}

    def corrupt_shard(self, state, s: int):
        val = jax.device_put(state["val"].at[s].set(-7.25), self.sharding)
        return {**state, "val": val}

    def crc_arrays(self, state):
        return [state["val"]]

    def put(self, host_state):
        return {"val": jax.device_put(
                    jnp.asarray(host_state["val"], jnp.float32),
                    self.sharding),
                "chg": jax.device_put(
                    jnp.asarray(host_state["chg"], bool), self.sharding)}

    def finalize(self, state):
        return state["val"]

    can_shrink = False


# --------------------------------------------------------------------------
# shard-pool shrink (tentpole part 3)
# --------------------------------------------------------------------------

def shrink_partition(g, pcfg, survivors: int):
    """The surviving-layout rebuild after a shard death: the
    counter-hashed placement is a pure function of (graph, config), so
    the shrunken partition is BY CONSTRUCTION field-for-field equal to a
    from-scratch ``build_partition`` at the smaller shard count — the
    equality the elastic tests assert against an independent build.
    Returns (new partition, new config)."""
    if survivors < 1:
        raise ValueError("cannot shrink to zero shards")
    new_cfg = dataclasses.replace(pcfg, num_shards=survivors,
                                  mesh_dims=None)
    return build_partition(g, new_cfg), new_cfg


def migrate_values(old_part: Partition, old_val, new_part: Partition,
                   sem: Semiring) -> np.ndarray:
    """Per-vertex value migration across layouts: read each vertex's
    root-replica value on the old partition, write it to every replica
    slot of the new one (consistent initial view).  For min semirings
    the migrated values are valid upper bounds, so re-running the
    fixpoint from them (full frontier) reconverges exactly."""
    vv = engine.vertex_values(old_part, old_val)
    sv = np.asarray(new_part.slot_vertex)
    fill = sem.identity if sem.segment == "min" else 0.0
    return np.where(sv >= 0, vv[np.maximum(sv, 0)],
                    fill).astype(np.float32)


# --------------------------------------------------------------------------
# obs accounting
# --------------------------------------------------------------------------

def _count_fault(run: str, kind: str):
    obs.registry().counter(
        "engine_faults_total",
        "engine-level faults detected (crc / counter mirror / heartbeat)"
    ).labels(run=run, kind=kind).inc()


def _count_recovery(run: str, kind: str, action: str):
    obs.registry().counter(
        "engine_recoveries_total",
        "fault recoveries by action (retry / restore / shrink / degrade)"
    ).labels(run=run, kind=kind, action=action).inc()


# --------------------------------------------------------------------------
# the resilient driver
# --------------------------------------------------------------------------

def run_resilient(task, *, chaos: ChaosPlan | None = None,
                  policy: RecoveryPolicy | None = None, manager=None,
                  max_rounds: int | None = None):
    """Drive ``task``'s fixpoint to convergence under chaos, with
    checkpoint/restore recovery.  Returns ``(result, RunStats,
    FixpointReport)`` — the result/stats match the equivalent shipped
    runner exactly when no fault fires, and after recovery the
    min-semiring result AND the accounting totals equal an
    uninterrupted run's (counters ride in the checkpoint tree).

    ``manager``: an optional ``CheckpointManager``; snapshots are taken
    every ``task.cfg.checkpoint_every`` rounds (async, atomic,
    crc-verified).  Without one, round 0's initial state serves as the
    implicit in-memory checkpoint."""
    policy = policy or RecoveryPolicy()
    cfg = task.cfg
    K = cfg.checkpoint_every
    max_iters = (max_rounds if max_rounds is not None
                 else getattr(task, "max_rounds", cfg.max_iters))
    rec = obs.get_recorder()
    report = FixpointReport()
    part = task.part

    planner = (engine.launch_planner(part, cfg,
                                     q_pad=getattr(task, "q_pad", 1))
               if (cfg.wants_worklist
                   or (rec is not None and task.records and cfg.use_pallas
                       and cfg.pallas_mode == "fused"))
               else None)

    pool = ShardPool(part.S, window=policy.heartbeat_window)
    pool.heartbeat_all(0)
    state = task.init_state()
    counters = {"it": 0, "msgs": 0, "work": 0, "pruned": 0}
    mem_ckpt = (dict(state), dict(counters))
    scrub = chaos is not None
    crc = shard_crcs(task.crc_arrays(state)) if scrub else None
    killed: set[int] = set()
    delayed: dict[int, int] = {}
    retries_this_round = 0
    last_good_step: int | None = None
    degraded = False

    def ckpt_tree(st, cts):
        return {"state": st,
                "counters": {k: np.int64(v) for k, v in cts.items()}}

    def save_ckpt():
        nonlocal last_good_step
        t0 = time.perf_counter()
        manager.save(counters["it"], ckpt_tree(state, counters),
                     blocking=False,
                     meta={"round": counters["it"], "run": task.name,
                           "S": part.S, "R_max": part.R_max})
        report.checkpoint_write_s += time.perf_counter() - t0
        report.checkpoints_written += 1
        last_good_step = counters["it"]

    def record_fault(kind, shard, rnd, action, lost=0):
        report.faults.append(FaultEventRecord(
            kind=kind, shard=shard, round=rnd, action=action,
            rounds_lost=lost))
        _count_fault(task.name, kind)
        _count_recovery(task.name, kind, action)
        if rec is not None:
            rec.tracer.instant("fault", track="engine/faults", kind=kind,
                               shard=shard, round=rnd, action=action)

    def degrade(kind, shard, rnd):
        nonlocal degraded
        record_fault(kind, shard, rnd, "degrade")
        if not policy.degrade:
            raise FaultDetected(kind, shard, rnd,
                                "recovery budget exhausted")
        degraded = True

    def restore(kind, shard, rnd):
        """Re-dispatch from the last checkpoint (or round 0)."""
        nonlocal state, counters, crc, retries_this_round
        if report.restores >= policy.max_restores:
            degrade(kind, shard, rnd)
            return
        t0 = time.perf_counter()
        report.restores += 1
        rounds_before = counters["it"]
        restored = False
        if manager is not None and last_good_step is not None:
            manager.wait()
            tree = manager.restore(last_good_step,
                                   ckpt_tree(state, counters))
            state = task.put(tree["state"])
            counters = {k: int(v) for k, v in tree["counters"].items()}
            restored = True
        if not restored:
            state = dict(mem_ckpt[0])
            counters = dict(mem_ckpt[1])
        lost = max(rounds_before - counters["it"], 0)
        report.rounds_lost += lost
        killed.clear()
        delayed.clear()
        pool.revive_all(counters["it"])
        pool.heartbeat_all(counters["it"])
        crc = shard_crcs(task.crc_arrays(state)) if scrub else None
        retries_this_round = 0
        dt = time.perf_counter() - t0
        report.recovery_s += dt
        record_fault(kind, shard, rnd, "restore", lost)
        if rec is not None:
            now = rec.tracer.now()
            rec.tracer.complete("recovery", track="engine/faults",
                                start=now - dt, end=now, kind=kind)

    def shrink(kind, dead, rnd):
        """Rebuild the partition on the survivors; migrate values from
        the last checkpoint and reconverge on the smaller layout."""
        nonlocal state, counters, crc, planner, part, pool, \
            retries_this_round, last_good_step
        t0 = time.perf_counter()
        report.restores += 1
        rounds_before = counters["it"]
        ckpt_state, ckpt_counters = mem_ckpt
        if manager is not None and last_good_step is not None:
            manager.wait()
            tree = manager.restore(last_good_step,
                                   ckpt_tree(state, counters))
            ckpt_state = task.put(tree["state"])
            ckpt_counters = {k: int(v)
                             for k, v in tree["counters"].items()}
        survivors = part.S - len(dead)
        part = task.shrink(survivors, ckpt_state["val"])
        state = task.init_state()
        counters = dict(ckpt_counters)
        pool = ShardPool(part.S, window=policy.heartbeat_window)
        pool.heartbeat_all(counters["it"])
        planner = (engine.launch_planner(part, cfg)
                   if planner is not None else None)
        killed.clear()
        delayed.clear()
        crc = shard_crcs(task.crc_arrays(state)) if scrub else None
        retries_this_round = 0
        lost = max(rounds_before - counters["it"], 0)
        report.rounds_lost += lost
        last_good_step = None
        if manager is not None and K:
            save_ckpt()          # fresh shapes: stale steps never load
        report.recovery_s += time.perf_counter() - t0
        record_fault(kind, dead[0] if dead else None, rnd, "shrink", lost)

    while not degraded and counters["it"] < max_iters:
        chg_h = task.host_frontier(state)
        if not chg_h.any():
            # a corruption landing exactly on convergence must not slip
            # out as a clean result — final scrub before returning
            if scrub:
                m = _scrub_mismatch(crc,
                                    shard_crcs(task.crc_arrays(state)))
                if m is not None:
                    kind = ("kill_shard" if m[1] in killed
                            else "corrupt_tile")
                    restore(kind, m[1], counters["it"])
                    continue
            if killed and pool.dead() == [] and not degraded:
                # killed shards whose window hasn't elapsed by
                # convergence: the rounds since their death are suspect
                restore("kill_shard", sorted(killed)[0], counters["it"])
                continue
            break
        rnd = counters["it"] + 1

        # ---- chaos injection for this round (corruption lands between
        # round boundaries; the boundary scrub below is what catches it)
        pending_drop = pending_dup = None
        if chaos is not None:
            for e in chaos.events_at(rnd):
                chaos.mark_fired(e)
                if e.kind == "kill_shard":
                    killed.add(e.shard)
                elif e.kind == "corrupt_tile":
                    state = task.corrupt_shard(state, e.shard)
                elif e.kind == "drop_inbox":
                    pending_drop = e.shard
                elif e.kind == "dup_inbox":
                    pending_dup = e.shard
                elif e.kind == "delay_shard":
                    delayed[e.shard] = e.rounds

        # ---- detection: crc scrub over the previous round boundary
        if scrub:
            m = _scrub_mismatch(crc, shard_crcs(task.crc_arrays(state)))
            if m is not None:
                kind = "kill_shard" if m[1] in killed else "corrupt_tile"
                restore(kind, m[1], rnd)
                continue

        # ---- heartbeats + declare-dead
        silent = killed | {s for s, r in delayed.items() if r > 0}
        pool.heartbeat_all(rnd, except_shards=silent)
        for s in list(delayed):
            delayed[s] -= 1
            if delayed[s] <= 0:
                del delayed[s]
        newly_dead = pool.tick(rnd)
        if newly_dead:
            if policy.on_dead == "shrink" and task.can_shrink:
                shrink("kill_shard", newly_dead, rnd)
            else:
                restore("kill_shard", newly_dead[0], rnd)
            continue

        # ---- expected message total on the UNtampered frontier
        expected = (exchange.expected_round_messages(
            part.edge_mask, part.edge_src_root_flat, chg_h,
            laned=task.laned) if (scrub or pending_dup is not None
                                  or pending_drop is not None
                                  or retries_this_round > 0) else None)

        # ---- dispatch (possibly on a tampered frontier)
        dispatch_state = state
        plan_chg = chg_h
        if pending_drop is not None:
            dispatch_state = task.drop_shard(state, pending_drop)
            plan_chg = task.host_frontier(dispatch_state)
        wl = info = None
        if cfg.wants_worklist:
            wl, info = engine.plan_round_worklist(
                planner, cfg, task.plan_frontier(plan_chg),
                with_info=True)
        frontier = int(chg_h.sum()) if rec is not None else 0
        t0 = rec.tracer.now() if rec is not None else 0.0
        span = (rec.tracer.span("round", track=f"engine/{task.name}",
                                round=rnd) if rec is not None else None)
        new_state, counts = task.dispatch(dispatch_state, wl)
        mc = int(np.asarray(counts).sum())
        reported = mc
        if pending_dup is not None:
            # the duplicated inbox double-counts shard s's deliveries
            if task.laned:
                per_lane = chg_h.reshape(-1, chg_h.shape[-1])
                dup = sum(int(exchange.shard_message_mirror(
                    part.edge_mask, part.edge_src_root_flat,
                    per_lane[:, qq])[pending_dup])
                    for qq in range(per_lane.shape[1]))
            else:
                dup = int(exchange.shard_message_mirror(
                    part.edge_mask, part.edge_src_root_flat,
                    chg_h)[pending_dup])
            reported = mc + dup

        # ---- detection: counter-mirror integrity
        if expected is not None and reported != expected:
            if span is not None:
                span.end(frontier=frontier, messages=reported,
                         fault=True)
            kind = ("drop_inbox" if reported < expected
                    else "dup_inbox")
            if retries_this_round < policy.max_retries:
                retries_this_round += 1
                report.retries += 1
                record_fault(kind, pending_drop
                             if pending_drop is not None
                             else pending_dup, rnd, "retry")
                continue          # same round, intact pre-round state
            restore(kind, pending_drop if pending_drop is not None
                    else pending_dup, rnd)
            continue

        # ---- commit the round
        retries_this_round = 0
        state = new_state
        chg_next = task.host_frontier(state)
        work = int(chg_next.sum())
        counters["it"] = rnd
        counters["msgs"] += mc
        counters["work"] += work
        counters["pruned"] += mc - min(work, mc)
        if scrub:
            crc = shard_crcs(task.crc_arrays(state))
        if rec is not None:
            wall = rec.tracer.now() - t0
            span.end(frontier=frontier, messages=mc)
            if task.records:
                engine._obs_record_round(
                    rec, task.name, part, cfg, planner, rnd,
                    chg_h.reshape(-1), frontier, mc, work, wl, info,
                    wall)
        if manager is not None and K and counters["it"] % K == 0:
            save_ckpt()

    if manager is not None:
        manager.wait()
    engine._count_dispatches(task.name, counters["it"], counters["it"])
    if degraded:
        report.status = "degraded"
    elif report.faults:
        report.status = "recovered"
    stats = engine._host_stats(counters["it"], counters["msgs"],
                               counters["work"], counters["pruned"])
    return task.finalize(state), stats, report
