"""The paper's primary contribution: RPVO/Rhizome partitioning, the
diffusive execution engine, LCO synchronization, and the AM-CCA models."""
from repro.core.partition import PartitionConfig, Partition, build_partition
from repro.core.actions import Semiring, BFS, SSSP, PAGERANK, SEMIRINGS
from repro.core.lco import AndGate, Future, and_gate_tree
from repro.core import engine

__all__ = [
    "PartitionConfig", "Partition", "build_partition",
    "Semiring", "BFS", "SSSP", "PAGERANK", "SEMIRINGS",
    "AndGate", "Future", "and_gate_tree",
    "engine",
]
