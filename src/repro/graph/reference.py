"""Oracle implementations (the paper verifies against NetworkX; we verify
against these — plain numpy/heapq, no JAX).

Also produces per-iteration *frontier traces* (which vertices improved at
each relaxation round) consumed by the AM-CCA cost model to replay the
paper's message-level experiments.
"""
from __future__ import annotations

import heapq

import numpy as np

from repro.graph.graph import COOGraph

UNREACHED = np.iinfo(np.int32).max
INF = np.float32(np.inf)


def bfs_levels(g: COOGraph, root: int) -> np.ndarray:
    """BFS level per vertex; UNREACHED if not reachable from root."""
    indptr, indices, _ = g.csr()
    level = np.full(g.n, UNREACHED, dtype=np.int64)
    level[root] = 0
    frontier = [root]
    lvl = 0
    while frontier:
        nxt = []
        for u in frontier:
            for v in indices[indptr[u] : indptr[u + 1]]:
                if level[v] == UNREACHED:
                    level[v] = lvl + 1
                    nxt.append(int(v))
        frontier = nxt
        lvl += 1
    return level


def sssp_dijkstra(g: COOGraph, root: int) -> np.ndarray:
    """Single-source shortest paths (non-negative weights)."""
    indptr, indices, weights = g.csr()
    dist = np.full(g.n, np.inf, dtype=np.float64)
    dist[root] = 0.0
    pq = [(0.0, root)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for e in range(indptr[u], indptr[u + 1]):
            v = int(indices[e])
            nd = d + float(weights[e])
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return dist.astype(np.float64)


def pagerank(g: COOGraph, damping: float = 0.85, iters: int = 30) -> np.ndarray:
    """Power-iteration PageRank with the paper's per-iteration semantics:
    each vertex sends score/out_degree along out-edges; dangling vertices'
    mass is NOT redistributed (matches the message-count formulation of
    Listing 10, where a vertex only diffuses what it receives)."""
    out_deg = g.out_degrees().astype(np.float64)
    score = np.full(g.n, 1.0 / g.n, dtype=np.float64)
    base = (1.0 - damping) / g.n
    for _ in range(iters):
        contrib = np.where(out_deg > 0, score / np.maximum(out_deg, 1), 0.0)
        incoming = np.zeros(g.n, dtype=np.float64)
        np.add.at(incoming, g.dst, contrib[g.src])
        score = base + damping * incoming
    return score


def connected_components(g: COOGraph) -> np.ndarray:
    """Weakly connected components: per-vertex label = min vertex id in
    the component (edges treated as undirected). Plain numpy BFS."""
    indptr, indices, _ = COOGraph(
        g.n, np.concatenate([g.src, g.dst]),
        np.concatenate([g.dst, g.src]), None).csr()
    label = np.full(g.n, -1, dtype=np.int64)
    for v in range(g.n):
        if label[v] >= 0:
            continue
        label[v] = v            # v is the smallest unvisited id -> the label
        stack = [v]
        while stack:
            u = stack.pop()
            for w in indices[indptr[u] : indptr[u + 1]]:
                if label[w] < 0:
                    label[w] = v
                    stack.append(int(w))
    return label


def personalized_pagerank(g: COOGraph, seed: int, damping: float = 0.85,
                          tol: float = 1e-10,
                          max_iters: int = 1000) -> np.ndarray:
    """Personalized PageRank to tolerance: score = (1-d) * e_seed +
    d * A^T (score / outdeg), dangling mass not redistributed (the same
    per-iteration semantics as ``pagerank`` above)."""
    out_deg = g.out_degrees().astype(np.float64)
    score = np.zeros(g.n, dtype=np.float64)
    score[seed] = 1.0
    base = np.zeros(g.n, dtype=np.float64)
    base[seed] = 1.0 - damping
    for _ in range(max_iters):
        contrib = np.where(out_deg > 0, score / np.maximum(out_deg, 1), 0.0)
        incoming = np.zeros(g.n, dtype=np.float64)
        np.add.at(incoming, g.dst, contrib[g.src])
        new = base + damping * incoming
        delta = np.abs(new - score).max()
        score = new
        if delta <= tol:
            break
    return score


def bfs_frontier_trace(g: COOGraph, root: int) -> list[np.ndarray]:
    """List of per-round frontiers (vertex id arrays). Round k's frontier
    diffuses along its out-edges in round k+1 — the message trace the
    AM-CCA cost model replays."""
    level = bfs_levels(g, root)
    out = []
    lvl = 0
    while True:
        f = np.nonzero(level == lvl)[0].astype(np.int32)
        if f.size == 0:
            break
        out.append(f)
        lvl += 1
    return out


def sssp_relax_trace(g: COOGraph, root: int) -> list[np.ndarray]:
    """Bellman-Ford style rounds: vertices whose distance improved in round k.

    This is the synchronous-relaxation schedule our TPU engine executes;
    the asynchronous execution reaches the same fixpoint (monotone min-plus).
    """
    indptr, indices, weights = g.csr()
    dist = np.full(g.n, np.inf)
    dist[root] = 0.0
    changed = np.zeros(g.n, dtype=bool)
    changed[root] = True
    trace = [np.array([root], dtype=np.int32)]
    while changed.any():
        new = dist.copy()
        src_active = np.nonzero(changed)[0]
        for u in src_active:
            for e in range(indptr[u], indptr[u + 1]):
                v = int(indices[e])
                nd = dist[u] + float(weights[e])
                if nd < new[v]:
                    new[v] = nd
        changed = new < dist
        dist = new
        if changed.any():
            trace.append(np.nonzero(changed)[0].astype(np.int32))
    return trace
