"""Directed graph container + degree statistics (paper Table 1).

The container is a plain COO edge list in numpy: the partitioner
(``repro.core.partition``) turns it into the sharded RPVO/Rhizome layout,
and ``repro.graph.reference`` runs oracle algorithms on it.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class COOGraph:
    """A directed graph as parallel COO arrays.

    Attributes:
      n: number of vertices (ids are 0..n-1).
      src, dst: int32 arrays of shape (E,).
      weight: float32 array of shape (E,) (SSSP weights; 1.0 if unweighted).
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    weight: np.ndarray

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.weight is None:
            self.weight = np.ones(self.src.shape, dtype=np.float32)
        self.weight = np.asarray(self.weight, dtype=np.float32)
        assert self.src.shape == self.dst.shape == self.weight.shape
        if self.src.size:
            assert int(self.src.max()) < self.n and int(self.dst.max()) < self.n
            assert int(self.src.min()) >= 0 and int(self.dst.min()) >= 0

    @property
    def num_edges(self) -> int:
        return int(self.src.size)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int64)

    def in_degrees(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n).astype(np.int64)

    def with_random_weights(self, low: int = 1, high: int = 10, seed: int = 0) -> "COOGraph":
        """Paper §6.1: 'random weights are assigned to the edges ... to make
        the SSSP meaningful'."""
        rng = np.random.default_rng(seed)
        w = rng.integers(low, high + 1, size=self.src.shape).astype(np.float32)
        return COOGraph(self.n, self.src, self.dst, w)

    def dedup(self) -> "COOGraph":
        """Remove duplicate (src, dst) pairs, keeping the first weight."""
        key = self.src.astype(np.int64) * self.n + self.dst
        _, idx = np.unique(key, return_index=True)
        idx.sort()
        return COOGraph(self.n, self.src[idx], self.dst[idx], self.weight[idx])

    def csr(self):
        """Return (indptr, indices, weights) sorted by src (out-adjacency)."""
        order = np.argsort(self.src, kind="stable")
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(np.bincount(self.src, minlength=self.n), out=indptr[1:])
        return indptr, self.dst[order], self.weight[order]


def _pctile_pair(deg: np.ndarray, pct: float = 99.0) -> tuple[float, float]:
    return pct, float(np.percentile(deg, pct)) if deg.size else 0.0


def degree_stats(g: COOGraph) -> dict:
    """Table-1 style statistics: mean/std/max/<%, %tile> for in & out degrees."""
    kin = g.in_degrees()
    kout = g.out_degrees()
    stats = {"vertices": g.n, "edges": g.num_edges}
    for name, deg in (("in", kin), ("out", kout)):
        pct, tile = _pctile_pair(deg)
        stats[name] = {
            "mean": float(deg.mean()) if deg.size else 0.0,
            "std": float(deg.std()) if deg.size else 0.0,
            "max": int(deg.max()) if deg.size else 0,
            "pctile": (pct, tile),
        }
    # skew indicator used throughout: max/mean in-degree
    stats["in_skew"] = stats["in"]["max"] / max(stats["in"]["mean"], 1e-9)
    return stats
