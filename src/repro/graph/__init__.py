from repro.graph.graph import COOGraph, degree_stats
from repro.graph.generators import rmat, erdos_renyi, star, ring, ba_skewed
from repro.graph import reference

__all__ = [
    "COOGraph",
    "degree_stats",
    "rmat",
    "erdos_renyi",
    "star",
    "ring",
    "ba_skewed",
    "reference",
]
