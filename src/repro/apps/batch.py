"""Lane-batched and multi-source BFS / SSSP (ISSUE 2).

Built on the query-lane axis (``repro.query.lanes``): a batch of K
source-rooted queries runs as K lanes of one shared fixpoint — mixed
BFS/SSSP batches share one compiled round (BFS lanes relax with unit
weights), and a multi-source query is simply one lane seeded at several
vertices (distance/level to the *nearest* source).
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph
from repro.query.lanes import decode_min_values, init_lane_values, \
    run_sharded_lanes, run_stacked_lanes


def _extract(part, val, kinds):
    return [decode_min_values(engine.vertex_values(part, val[..., q]), kind)
            for q, kind in enumerate(kinds)]


def batched_queries(g: COOGraph, queries, part: Partition | None = None,
                    cfg: engine.EngineConfig = engine.EngineConfig(),
                    num_shards: int = 16, rpvo_max: int = 1,
                    mesh=None, axis_names=("data", "model")):
    """Runs a mixed batch of min-semiring queries as lanes of one shared
    fixpoint.  ``queries``: list of ("bfs" | "sssp", sources) — sources a
    vertex, a list (multi-source), or a {vertex: value} dict.  Returns
    (list of per-query (n,) results — int64 levels for BFS, float64
    distances for SSSP — per-lane LaneStats, partition)."""
    if part is None:
        part = build_partition(
            g, PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max))
    init, unitw = init_lane_values(part, queries)
    if mesh is None:
        val, stats = run_stacked_lanes(part, init, unitw, cfg)
    else:
        val, stats = run_sharded_lanes(part, init, unitw, mesh, axis_names,
                                       cfg)
    return _extract(part, np.asarray(val), [k for k, _ in queries]), \
        stats, part


def multi_source_bfs(g: COOGraph, roots, **kw):
    """Level to the nearest of ``roots`` per vertex ((n,) int64)."""
    (levels,), stats, part = batched_queries(g, [("bfs", list(roots))], **kw)
    return levels, stats, part


def multi_source_sssp(g: COOGraph, roots, **kw):
    """Distance to the nearest of ``roots`` per vertex ((n,) float64)."""
    (dist,), stats, part = batched_queries(g, [("sssp", list(roots))], **kw)
    return dist, stats, part
