"""Personalized PageRank on the query-lane axis (ISSUE 2).

Each lane is one personalization: score_q = (1 - d_q) * e_{s_q} + d_q *
A^T (score_q / outdeg), iterated to a per-lane tolerance on the shared
laned round (``repro.query.lanes.make_ppr_round``).  Per-lane seeds and
dampings coexist in one compiled step; dangling mass is not
redistributed, matching ``graph.reference`` and the engine's global
PageRank semantics.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph
from repro.query.lanes import run_ppr_lanes


def personalized_pagerank(g: COOGraph, seeds, dampings=0.85,
                          part: Partition | None = None,
                          cfg: engine.EngineConfig = engine.EngineConfig(),
                          tol: float = 1e-8, max_rounds: int = 256,
                          num_shards: int = 16, rpvo_max: int = 1):
    """Returns ((n, Q) float64 scores — one column per seed — per-lane
    LaneStats, partition).  ``part``, if given, must partition the
    1/out-degree weighted graph (``apps.pagerank._pr_graph``)."""
    if part is None:
        from repro.apps.pagerank import _pr_graph
        part = build_partition(
            _pr_graph(g),
            PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max))
    val, stats = run_ppr_lanes(part, [int(s) for s in seeds], dampings,
                               cfg, tol=tol, max_rounds=max_rounds)
    val = np.asarray(val)
    cols = [engine.vertex_values(part, val[..., q]).astype(np.float64)
            for q in range(val.shape[-1])]
    return np.stack(cols, axis=-1), stats, part
