"""Single-source shortest paths: min-plus diffusive relaxation.

Same action shape as BFS with ``msg = dist + w`` (paper §6: 'BFS and SSSP
actions take 2-3 cycles of compute').
"""
from __future__ import annotations

import numpy as np

from repro.core import actions, engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph


def sssp(g: COOGraph, root: int, part: Partition | None = None,
         cfg: engine.EngineConfig = engine.EngineConfig(),
         num_shards: int = 16, rpvo_max: int = 1,
         mesh=None, axis_names=("data", "model")):
    """Returns (dist (n,) float64 with inf for unreachable, stats, partition)."""
    if part is None:
        part = build_partition(
            g, PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max)
        )
    init = engine.init_values(part, actions.SSSP, {root: 0.0})
    if mesh is None:
        val, stats = engine.run_stacked(actions.SSSP, part, init, cfg)
    else:
        val, stats = engine.run_sharded(
            actions.SSSP, part, init, mesh, axis_names, cfg
        )
    return engine.vertex_values(part, val).astype(np.float64), stats, part
