"""PageRank as a diffusive action (paper Listing 10).

Each round every vertex diffuses ``score/out_degree`` along out-edges
(the per-edge factor is folded into the edge weight at partition time);
the inbox accumulates with ``+``; ``rhizome-collapse(+)`` all-reduces the
per-replica partial inboxes (the AND-gate fires when all replicas have
contributed), then the trigger applies the damping update.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph


def _pr_graph(g: COOGraph) -> COOGraph:
    out_deg = np.maximum(g.out_degrees(), 1).astype(np.float32)
    w = 1.0 / out_deg[g.src]
    return COOGraph(g.n, g.src, g.dst, w)


def pagerank(g: COOGraph, damping: float = 0.85, iters: int = 30,
             part: Partition | None = None,
             cfg: engine.EngineConfig = engine.EngineConfig(),
             num_shards: int = 16, rpvo_max: int = 1,
             mesh=None, axis_names=("data", "model")):
    """Returns (scores (n,) float64, partition)."""
    if part is None:
        part = build_partition(
            _pr_graph(g),
            PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max),
        )
    if mesh is None:
        val = engine.run_pagerank_stacked(part, damping, iters, cfg)
    else:
        val = engine.run_pagerank_sharded(
            part, damping, iters, mesh, axis_names, cfg)
    return engine.vertex_values(part, val).astype(np.float64), part


def pagerank_delta(g: COOGraph, damping: float = 0.85, tol=1e-7,
                   part: Partition | None = None,
                   cfg: engine.EngineConfig = engine.EngineConfig(),
                   num_shards: int = 16, rpvo_max: int = 1,
                   mesh=None, axis_names=("data", "model"),
                   max_rounds: int = 256):
    """Delta-PageRank (ISSUE 5): push-based residual propagation — only
    deltas above ``tol`` diffuse, so the frontier shrinks round over
    round and the engine's diffusion pruning (chunk skip, worklist
    launch, tile filter) finally fires for the sum semiring.  Converges
    to the ``pagerank`` fixpoint within O(tol / (1-damping)) per vertex.

    Returns (scores (n,) float64, RunStats, partition)."""
    if part is None:
        part = build_partition(
            _pr_graph(g),
            PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max),
        )
    if mesh is None:
        val, stats = engine.run_pagerank_delta(
            part, damping, tol, cfg, max_rounds)
    else:
        val, stats = engine.run_pagerank_delta_sharded(
            part, damping, tol, mesh, axis_names, cfg, max_rounds)
    return engine.vertex_values(part, val).astype(np.float64), stats, part
