"""Breadth-first search as a diffusive action (paper Listings 4/6/9).

The action's predicate is ``new_level < level``; work sets the level; the
diffusion relays ``level+1`` along out-edges; ``rhizome-collapse(bcast)``
keeps replicas consistent. In the bulk engine these are the BFS semiring's
``improved`` / ``combine`` / ``relax`` and the sibling collapse.
"""
from __future__ import annotations

import numpy as np

from repro.core import actions, engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph

UNREACHED = np.iinfo(np.int32).max


def bfs(g: COOGraph, root: int, part: Partition | None = None,
        cfg: engine.EngineConfig = engine.EngineConfig(),
        num_shards: int = 16, rpvo_max: int = 1,
        mesh=None, axis_names=("data", "model")):
    """Returns (levels (n,) int64, stats, partition)."""
    if part is None:
        part = build_partition(
            g, PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max)
        )
    init = engine.init_values(part, actions.BFS, {root: 0.0})
    if mesh is None:
        val, stats = engine.run_stacked(actions.BFS, part, init, cfg)
    else:
        val, stats = engine.run_sharded(
            actions.BFS, part, init, mesh, axis_names, cfg
        )
    lv = engine.vertex_values(part, val)
    levels = np.where(np.isfinite(lv), lv, 0).astype(np.int64)
    levels[~np.isfinite(lv)] = UNREACHED
    return levels, stats, part
