"""Connected components as a min-semiring diffusive fixpoint (ISSUE 2).

Weakly connected components by min-label propagation: every vertex starts
with its own id as value and diffuses it along the *symmetrized* edge set
with zero weights, so the relax ``v + 0`` copies labels and the fixpoint
assigns each vertex the minimum vertex id of its component.  Zero new
engine machinery — this is the SSSP semiring on a zero-weight graph —
and it exercises the query-lane axis with Q=1 (``run_stacked_lanes``).

Labels live in the engine's float32 value table, exact for vertex ids
below 2**24.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.partition import Partition, PartitionConfig, build_partition
from repro.graph.graph import COOGraph
from repro.query.lanes import run_stacked_lanes


def _symmetrized_zero_weight(g: COOGraph) -> COOGraph:
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    return COOGraph(g.n, src, dst, np.zeros(src.shape, np.float32)).dedup()


def cc(g: COOGraph, part: Partition | None = None,
       cfg: engine.EngineConfig = engine.EngineConfig(),
       num_shards: int = 16, rpvo_max: int = 1):
    """Returns (labels (n,) int64 — min vertex id per weakly connected
    component, per-lane stats, partition).  ``part``, if given, must be a
    partition of the symmetrized zero-weight graph."""
    if g.n >= (1 << 24):
        raise ValueError("float32 label table is exact only for n < 2**24")
    if part is None:
        part = build_partition(
            _symmetrized_zero_weight(g),
            PartitionConfig(num_shards=num_shards, rpvo_max=rpvo_max))
    # vertex-id initial values on every replica (consistent view); every
    # vertex is initially changed, so labels flood from round one
    init = np.where(part.slot_vertex >= 0,
                    part.slot_vertex.astype(np.float32), np.inf)
    val, stats = run_stacked_lanes(part, init[..., None],
                                   lane_unitw=np.zeros(1, np.int32), cfg=cfg)
    labels = engine.vertex_values(part, val[..., 0]).astype(np.int64)
    return labels, stats, part
