from repro.apps.bfs import bfs
from repro.apps.sssp import sssp
from repro.apps.pagerank import pagerank

__all__ = ["bfs", "sssp", "pagerank"]
