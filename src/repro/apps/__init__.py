from repro.apps.bfs import bfs
from repro.apps.sssp import sssp
from repro.apps.pagerank import pagerank, pagerank_delta
from repro.apps.cc import cc
from repro.apps.batch import batched_queries, multi_source_bfs, \
    multi_source_sssp
from repro.apps.ppr import personalized_pagerank

__all__ = ["bfs", "sssp", "pagerank", "pagerank_delta", "cc",
           "batched_queries",
           "multi_source_bfs", "multi_source_sssp", "personalized_pagerank"]
