"""Pallas TPU kernels for the engine's compute hot-spots.

``rhizome_segment_reduce`` — blocked semiring segment reduction (the
per-shard inbox collapse). ``ops`` holds the jit'd wrappers, ``ref`` the
pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
