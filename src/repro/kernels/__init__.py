"""Pallas TPU kernels for the engine's compute hot-spots.

``fused_relax_reduce`` — the per-round relax phase (frontier gather +
semiring relax + active mask + blocked segment reduction) fused into one
VMEM-resident pass with two-level grid-cell skipping.
``rhizome_segment_reduce`` — the standalone blocked semiring segment
reduction (the unfused inbox collapse, kept as the reduce-only fallback).
``ops`` holds the jit'd wrappers, ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
