"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on a real TPU backend the
same ``pallas_call`` compiles to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.fused_relax_reduce import (
    fused_relax_reduce_lanes_pallas, fused_relax_reduce_pallas,
)
from repro.kernels.rhizome_segment_reduce import segment_combine_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def segment_combine(data, segment_ids, num_segments: int, kind: str):
    """Semiring segment reduction (min | sum) over edge messages."""
    return segment_combine_pallas(
        data, segment_ids, num_segments, kind, interpret=_interpret()
    )


def fused_relax_reduce(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                       num_segments: int, relax_kind: str, kind: str,
                       vmem_budget_bytes=None, worklist=None,
                       smem_budget_bytes=None, grid_mode: str = "dense"):
    """Fused frontier gather + semiring relax + mask + segment reduction —
    the whole per-round relax phase in one Pallas pass.  Returns
    ((num_segments,) partial, active-edge message count).  The value
    table rides pinned in VMEM when it fits ``vmem_budget_bytes`` (None:
    REPRO_VMEM_BUDGET env var, then the default budget), else HBM-tiled
    with per-cell double-buffered async DMA — same results either way
    (bit-identical for min semirings).  A host-planned ``worklist``
    (see ``fused_relax_reduce.WorklistPlanner``) swaps the dense
    early-exit grid for the 1-D live-cell launch;
    ``grid_mode='device_worklist'`` compacts the live-cell list on
    device instead (traced — works inside jit/shard_map loops);
    ``smem_budget_bytes`` arms the scalar-prefetch table guard."""
    return fused_relax_reduce_pallas(
        gval, gchg, edge_src, edge_w, edge_mask, edge_dst, num_segments,
        relax_kind, kind, interpret=_interpret(), with_count=True,
        vmem_budget_bytes=vmem_budget_bytes, worklist=worklist,
        smem_budget_bytes=smem_budget_bytes, grid_mode=grid_mode
    )


def fused_relax_reduce_lanes(gval, gchg, lane_unitw, edge_src, edge_w,
                             edge_mask, edge_dst, num_segments: int,
                             relax_kind: str, kind: str,
                             vmem_budget_bytes=None, worklist=None,
                             smem_budget_bytes=None,
                             grid_mode: str = "dense"):
    """Lane-batched fused relax phase: per-lane (V, Q) values/frontiers
    over one shared edge structure, one launch for all queries.  Returns
    ((num_segments, Q) partial, (Q,) per-lane active-edge counts).  The
    lane axis is padded to the TPU lane tile (masked tail lanes) and the
    lane-padded table's residency follows ``vmem_budget_bytes`` as in
    ``fused_relax_reduce``; ``worklist`` (planned over the OR-across-
    lanes frontier) selects the live-cell launch, and
    ``grid_mode='device_worklist'`` compacts that list on device."""
    return fused_relax_reduce_lanes_pallas(
        gval, gchg, lane_unitw, edge_src, edge_w, edge_mask, edge_dst,
        num_segments, relax_kind, kind, interpret=_interpret(),
        with_count=True, vmem_budget_bytes=vmem_budget_bytes,
        worklist=worklist, smem_budget_bytes=smem_budget_bytes,
        grid_mode=grid_mode
    )
