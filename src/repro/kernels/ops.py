"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
body runs in Python for correctness validation; on a real TPU backend the
same ``pallas_call`` compiles to Mosaic.
"""
from __future__ import annotations

import jax

from repro.kernels.fused_relax_reduce import (
    fused_relax_reduce_lanes_pallas, fused_relax_reduce_pallas,
)
from repro.kernels.rhizome_segment_reduce import segment_combine_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def segment_combine(data, segment_ids, num_segments: int, kind: str):
    """Semiring segment reduction (min | sum) over edge messages."""
    return segment_combine_pallas(
        data, segment_ids, num_segments, kind, interpret=_interpret()
    )


def fused_relax_reduce(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                       num_segments: int, relax_kind: str, kind: str):
    """Fused frontier gather + semiring relax + mask + segment reduction —
    the whole per-round relax phase in one VMEM-resident Pallas pass.
    Returns ((num_segments,) partial, active-edge message count)."""
    return fused_relax_reduce_pallas(
        gval, gchg, edge_src, edge_w, edge_mask, edge_dst, num_segments,
        relax_kind, kind, interpret=_interpret(), with_count=True
    )


def fused_relax_reduce_lanes(gval, gchg, lane_unitw, edge_src, edge_w,
                             edge_mask, edge_dst, num_segments: int,
                             relax_kind: str, kind: str):
    """Lane-batched fused relax phase: per-lane (V, Q) values/frontiers
    over one shared edge structure, one launch for all queries.  Returns
    ((num_segments, Q) partial, (Q,) per-lane active-edge counts)."""
    return fused_relax_reduce_lanes_pallas(
        gval, gchg, lane_unitw, edge_src, edge_w, edge_mask, edge_dst,
        num_segments, relax_kind, kind, interpret=_interpret(),
        with_count=True
    )
