"""Pallas TPU kernel: blocked semiring segment reduction.

The compute hot-spot of the diffusive engine is the per-shard inbox
reduction: E_max edge messages collapse into R replica slots
(min for BFS/SSSP, + for PageRank). On GPU this is an atomic scatter;
TPU has no fast scatter, so we re-block it for the MXU/VPU
(hardware adaptation — DESIGN.md §2):

* the edge axis is tiled into ``EBLK`` chunks and the segment axis into
  ``SBLK`` blocks (both MXU-aligned multiples of 128);
* grid cell (i, j) builds an (EBLK × SBLK) hit mask
  ``ids == seg_base + col`` and reduces over edges:
  - sum: one-hot **matmul** ``hitᵀ @ msg`` — runs on the MXU systolic
    array, the TPU-native scatter-free reduction;
  - min: masked ``min`` over the edge axis — a VPU reduction;
* the output block for segment block *i* is revisited across all *j*
  edge chunks and accumulated in place (VMEM-resident);
* because the engine sorts edges by destination, each edge chunk touches
  a narrow segment range: a scalar-prefetched per-chunk [lo, hi) id range
  lets grid cells **skip** non-intersecting (i, j) pairs entirely — the
  sorted-CSR sparsity exploited without dynamic shapes.

Weak-typed, f32/bf16. Validated against ``ref.segment_combine_ref`` in
interpret mode (CPU); compiled path targets TPU VMEM via BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

EBLK = 512   # edge-axis tile
SBLK = 256   # segment-axis tile (lane-aligned)


def _kernel(chunk_lo_ref, chunk_hi_ref, ids_ref, msg_ref, out_ref, *, kind):
    i = pl.program_id(0)  # segment block
    j = pl.program_id(1)  # edge chunk

    identity = jnp.inf if kind == "min" else 0.0

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full((SBLK,), identity, out_ref.dtype)

    seg0 = i * SBLK
    # sorted-edges block skip: chunk j covers ids [chunk_lo[j], chunk_hi[j]]
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)

    @pl.when(intersects)
    def _compute():
        ids = ids_ref[...]                      # (EBLK,) int32
        msg = msg_ref[...]                      # (EBLK,)
        local = ids - seg0
        cols = jax.lax.broadcasted_iota(jnp.int32, (EBLK, SBLK), 1)
        hit = local[:, None] == cols            # (EBLK, SBLK)
        if kind == "sum":
            # one-hot matmul -> MXU systolic reduction
            contrib = jnp.dot(
                hit.astype(msg.dtype).T, msg,
                preferred_element_type=jnp.float32,
            ).astype(out_ref.dtype)
            out_ref[...] += contrib
        else:
            padded = jnp.where(hit, msg[:, None], jnp.asarray(identity, msg.dtype))
            contrib = jnp.min(padded, axis=0)   # VPU reduction over edges
            out_ref[...] = jnp.minimum(out_ref[...], contrib)


@functools.partial(jax.jit, static_argnames=("num_segments", "kind", "interpret"))
def segment_combine_pallas(data, segment_ids, num_segments: int, kind: str,
                           interpret: bool = True):
    """Blocked semiring segment reduce. data: (E,), ids: (E,) sorted or not;
    returns (num_segments,). Padding edges must carry id 0 with identity data
    or any id with identity data (identity never changes a reduction)."""
    e = data.shape[0]
    e_pad = -(-e // EBLK) * EBLK
    s_pad = -(-num_segments // SBLK) * SBLK
    identity = jnp.inf if kind == "min" else 0.0
    data_p = jnp.full((e_pad,), identity, data.dtype).at[:e].set(data)
    ids_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        segment_ids.astype(jnp.int32))

    # per-chunk id ranges for the sorted-skip (scalar-prefetch operands)
    idc = ids_p.reshape(e_pad // EBLK, EBLK)
    mask = (jnp.arange(e_pad) < e).reshape(e_pad // EBLK, EBLK)
    chunk_lo = jnp.where(mask, idc, jnp.iinfo(jnp.int32).max).min(axis=1)
    chunk_hi = jnp.where(mask, idc, -1).max(axis=1)

    grid = (s_pad // SBLK, e_pad // EBLK)
    out = pl.pallas_call(
        functools.partial(_kernel, kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((EBLK,), lambda i, j, lo, hi: (j,)),
                pl.BlockSpec((EBLK,), lambda i, j, lo, hi: (j,)),
            ],
            out_specs=pl.BlockSpec((SBLK,), lambda i, j, lo, hi: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((s_pad,), data.dtype),
        interpret=interpret,
    )(chunk_lo, chunk_hi, ids_p, data_p)
    return out[:num_segments]
