"""Pure-jnp oracles for the Pallas kernels.

Semantics contract (shared by kernel, oracle, and the engine):
empty segments hold the combine identity (+inf for min, 0 for sum).
"""
from __future__ import annotations

import jax.numpy as jnp


def segment_combine_ref(data, segment_ids, num_segments: int, kind: str):
    """Semiring segment reduction: the inbox partial-reduce of one shard.

    data: (E,) float; segment_ids: (E,) int32 in [0, num_segments);
    returns (num_segments,) float.
    """
    if kind == "min":
        init = jnp.full((num_segments,), jnp.inf, data.dtype)
        return init.at[segment_ids].min(data)
    if kind == "sum":
        init = jnp.zeros((num_segments,), data.dtype)
        return init.at[segment_ids].add(data)
    raise ValueError(kind)


def frontier_relax_ref(values, src_flat, weights, mask, kind: str):
    """Gather + relax: msg_e = values[src_e] (+ w_e | * w_e), masked to the
    semiring identity. values: (V,), src_flat/weights/mask: (E,)."""
    v = values[src_flat]
    if kind == "min":  # min-plus relax
        msg = v + weights
        return jnp.where(mask, msg, jnp.inf)
    if kind == "sum":  # plus-times relax
        msg = v * weights
        return jnp.where(mask, msg, 0.0)
    raise ValueError(kind)
