"""Pure-jnp oracles for the Pallas kernels.

Semantics contract (shared by kernel, oracle, and the engine):
empty segments hold the combine identity (+inf for min, 0 for sum).
"""
from __future__ import annotations

import jax.numpy as jnp


def segment_combine_ref(data, segment_ids, num_segments: int, kind: str):
    """Semiring segment reduction: the inbox partial-reduce of one shard.

    data: (E,) float; segment_ids: (E,) int32 in [0, num_segments);
    returns (num_segments,) float.
    """
    if kind == "min":
        init = jnp.full((num_segments,), jnp.inf, data.dtype)
        return init.at[segment_ids].min(data)
    if kind == "sum":
        init = jnp.zeros((num_segments,), data.dtype)
        return init.at[segment_ids].add(data)
    raise ValueError(kind)


def fused_relax_reduce_ref(gval, gchg, edge_src, edge_w, edge_mask,
                           edge_dst, num_segments: int, relax_kind: str,
                           kind: str):
    """Oracle for the fused frontier relax+reduce kernel: the unfused
    gather / relax / frontier-mask / segment-combine pipeline, with every
    intermediate materialized. Shapes as in ``fused_relax_reduce_pallas``."""
    from repro.core.actions import RELAX_FNS
    src_val = jnp.take(gval, edge_src, axis=0)
    active = edge_mask & jnp.take(gchg, edge_src, axis=0)
    msg = RELAX_FNS[relax_kind](src_val, edge_w)
    identity = jnp.inf if kind == "min" else 0.0
    msg = jnp.where(active, msg, jnp.asarray(identity, msg.dtype))
    return segment_combine_ref(msg, edge_dst, num_segments, kind)


def fused_relax_reduce_lanes_ref(gval, gchg, lane_unitw, edge_src, edge_w,
                                 edge_mask, edge_dst, num_segments: int,
                                 relax_kind: str, kind: str):
    """Oracle for the lane-batched fused kernel: per-lane gather / relax /
    frontier-mask / segment-combine with every (E, Q) intermediate
    materialized.  ``lane_unitw`` (Q,) swaps the edge weight for 1.0 per
    lane under 'add_w' (BFS lanes inside an SSSP launch)."""
    src_val = jnp.take(gval, edge_src, axis=0)            # (E, Q)
    active = edge_mask[:, None] & jnp.take(gchg, edge_src, axis=0)
    if relax_kind == "add_w":
        w_eff = jnp.where(jnp.asarray(lane_unitw)[None, :] > 0,
                          jnp.asarray(1.0, edge_w.dtype), edge_w[:, None])
        msg = src_val + w_eff
    elif relax_kind == "mul_w":
        msg = src_val * edge_w[:, None]
    else:
        raise ValueError(relax_kind)
    identity = jnp.inf if kind == "min" else 0.0
    msg = jnp.where(active, msg, jnp.asarray(identity, msg.dtype))
    init = jnp.full((num_segments, gval.shape[1]), identity, msg.dtype)
    if kind == "min":
        return init.at[edge_dst].min(msg)
    if kind == "sum":
        return init.at[edge_dst].add(msg)
    raise ValueError(kind)


def frontier_relax_ref(values, src_flat, weights, mask, kind: str):
    """Gather + relax: msg_e = values[src_e] (+ w_e | * w_e), masked to the
    semiring identity. values: (V,), src_flat/weights/mask: (E,)."""
    v = values[src_flat]
    if kind == "min":  # min-plus relax
        msg = v + weights
        return jnp.where(mask, msg, jnp.inf)
    if kind == "sum":  # plus-times relax
        msg = v * weights
        return jnp.where(mask, msg, 0.0)
    raise ValueError(kind)
