"""Pallas TPU kernel: fused frontier-aware relax + blocked segment reduce.

One engine round used to run as four separate XLA/Pallas ops, each
materializing an ``(S, E_max)`` HBM intermediate:

    src_val = gval[edge_src]                  # dense gather     (HBM f32)
    active  = edge_mask & gchg[edge_src]      # frontier mask    (HBM bool)
    msg     = where(active, relax(src_val, w), identity)   #     (HBM f32)
    inbox   = segment_reduce(msg, edge_dst)   # Pallas kernel

This kernel fuses the whole pipeline into one VMEM-resident pass: the
vertex value table is pinned in VMEM and the gather, semiring relax,
frontier masking, and blocked semiring reduction all happen inside the
grid cell — no per-edge float array ever round-trips HBM.  The
frontier mask is folded into the value table before launch (inactive
sources read as the absorbing identity: ``relax(identity, w) ==
identity`` for every supported semiring), so the cell needs a single
VMEM gather.

Blocking follows ``rhizome_segment_reduce``: the edge axis is tiled into
``EBLK`` chunks, the segment axis into ``SBLK`` blocks; cell (i, j)
builds an (EBLK x SBLK) hit mask and reduces over edges (one-hot MXU
matmul for ``sum``, masked VPU min for ``min``); output block *i* is
revisited across all *j* and accumulated in place.

Two levels of scalar-prefetched grid-cell skipping (the TPU form of the
paper's diffusion pruning — work stays proportional to the frontier):

1. **Sorted-range skip** — edges are sorted by destination, so chunk *j*
   covers segment ids ``[chunk_lo[j], chunk_hi[j]]``; cells whose segment
   block does not intersect are skipped (static sparsity of the CSR sort).
2. **Frontier chunk skip** — ``chunk_active[j]`` records whether ANY edge
   in chunk *j* has a changed (diffusing) source this round.  On late
   BFS/SSSP rounds the frontier is a tiny fraction of the graph, so most
   chunks are dead and their grid cells are skipped *entirely* across all
   segment blocks — the paper's "stale diffusions are subsumed" pruning,
   realized as predicated grid cells.  The bitmap is an O(E/EBLK) scalar
   vector computed from ``gchg`` by a fused reduction; it is the only
   per-round edge-proportional traffic besides the kernel's own block DMAs.

``fused_grid_cells`` mirrors the two skip predicates on the host so
benchmarks/tests can count exactly how many grid cells execute (see
``benchmarks/engine_bench.py``: the fused path must execute strictly
fewer cells than range-skip alone once the frontier thins).

Semiring relax is selected statically via ``relax_kind``
(``Semiring.relax_kind``, single-sourced with the jnp path through
``actions.RELAX_FNS``): 'add_w' (min-plus / SSSP), 'add_one' (BFS level
relax; the weight is ignored), 'mul_w' (plus-times / PageRank).
Validated against ``ref.fused_relax_reduce_ref`` in interpret mode (CPU);
the compiled path targets TPU VMEM via BlockSpecs.

**Scale constraint**: the whole padded value table rides into VMEM per
grid cell (``full_spec``), so on real hardware the kernel is limited to
partitions whose slot table fits alongside the edge blocks (~16 MB VMEM
⇒ roughly 3M f32 slots). Paper-scale graphs (R22+) need the value table
tiled with per-cell async DMA + double buffering — tracked as a ROADMAP
open item; interpret-mode CI does not exercise the limit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.actions import RELAX_FNS

EBLK = 512   # edge-axis tile
SBLK = 256   # segment-axis tile (lane-aligned)

RELAX_KINDS = tuple(RELAX_FNS)

# pairings for which the combine identity absorbs under relax —
# relax(identity, w) == identity — the property the frontier masking
# relies on (inactive sources are folded into the value table as the
# identity and must never contribute)
ABSORBING_PAIRS = frozenset(
    {("add_w", "min"), ("add_one", "min"), ("mul_w", "sum")})


def _relax(relax_kind: str, src_val, w):
    return RELAX_FNS[relax_kind](src_val, w)


def _kernel(chunk_lo_ref, chunk_hi_ref, chunk_act_ref,
            ids_ref, src_ref, w_ref, mask_ref, gval_ref,
            out_ref, *, relax_kind, kind):
    i = pl.program_id(0)  # segment block
    j = pl.program_id(1)  # edge chunk

    identity = jnp.inf if kind == "min" else 0.0

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full((SBLK,), identity, out_ref.dtype)

    seg0 = i * SBLK
    # level 1: sorted-edges range skip — chunk j covers [chunk_lo, chunk_hi]
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)
    # level 2: frontier skip — any changed source in this edge chunk?
    live = intersects & (chunk_act_ref[j] > 0)

    @pl.when(live)
    def _compute():
        src = src_ref[...]                       # (EBLK,) int32
        # fused frontier gather: the VMEM-resident value table is
        # pre-masked so frontier-inactive sources read as the absorbing
        # identity — relax(identity, w) == identity for every semiring
        # here (inf+w=inf, 0*w=0), so no per-edge gchg gather is needed
        src_val = jnp.take(gval_ref[...], src)
        msg = _relax(relax_kind, src_val, w_ref[...])
        msg = jnp.where(mask_ref[...] > 0, msg,
                        jnp.asarray(identity, msg.dtype))

        local = ids_ref[...] - seg0
        cols = jax.lax.broadcasted_iota(jnp.int32, (EBLK, SBLK), 1)
        hit = local[:, None] == cols             # (EBLK, SBLK)
        if kind == "sum":
            # one-hot matmul -> MXU systolic reduction
            contrib = jnp.dot(
                hit.astype(msg.dtype).T, msg,
                preferred_element_type=jnp.float32,
            ).astype(out_ref.dtype)
            out_ref[...] += contrib
        else:
            padded = jnp.where(hit, msg[:, None],
                               jnp.asarray(identity, msg.dtype))
            contrib = jnp.min(padded, axis=0)    # VPU reduction over edges
            out_ref[...] = jnp.minimum(out_ref[...], contrib)


def _kernel_lanes(chunk_lo_ref, chunk_hi_ref, chunk_act_ref,
                  ids_ref, src_ref, w_ref, mask_ref, unitw_ref, gval_ref,
                  out_ref, *, relax_kind, kind):
    """Lane-batched kernel body: the value table carries a trailing query
    axis ``Q`` and every edge relaxes all lanes at once.  ``unitw_ref``
    (Q,) selects, per lane, whether 'add_w' reads the edge weight or the
    constant 1.0 — BFS lanes are SSSP lanes over unit weights, so one
    launch serves a mixed BFS/SSSP batch with bit-identical per-lane math.
    The frontier chunk skip uses the OR across lanes (``chunk_act``): a
    grid cell is skipped only when its edge chunk is dead in EVERY lane."""
    i = pl.program_id(0)  # segment block
    j = pl.program_id(1)  # edge chunk

    identity = jnp.inf if kind == "min" else 0.0

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    seg0 = i * SBLK
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)
    live = intersects & (chunk_act_ref[j] > 0)

    @pl.when(live)
    def _compute():
        src = src_ref[...]                       # (EBLK,) int32
        src_val = jnp.take(gval_ref[...], src, axis=0)   # (EBLK, Q)
        w = w_ref[...]
        if relax_kind == "add_w":
            w_eff = jnp.where(unitw_ref[...][None, :] > 0,
                              jnp.asarray(1.0, w.dtype), w[:, None])
            msg = src_val + w_eff
        else:                                    # 'mul_w'
            msg = src_val * w[:, None]
        msg = jnp.where(mask_ref[...][:, None] > 0, msg,
                        jnp.asarray(identity, msg.dtype))

        local = ids_ref[...] - seg0
        cols = jax.lax.broadcasted_iota(jnp.int32, (EBLK, SBLK), 1)
        hit = local[:, None] == cols             # (EBLK, SBLK)
        if kind == "sum":
            # one-hot matmul -> (SBLK, Q) MXU systolic reduction
            contrib = jnp.dot(
                hit.astype(msg.dtype).T, msg,
                preferred_element_type=jnp.float32,
            ).astype(out_ref.dtype)
            out_ref[...] += contrib
        else:
            # statically unrolled per-lane loop: peak in-cell memory stays
            # (EBLK, SBLK) regardless of Q — a broadcast hit[:, :, None]
            # against msg would materialize an (EBLK, SBLK, Q) intermediate
            # per grid cell, which cannot fit VMEM for real batch widths
            contribs = []
            for lq in range(msg.shape[1]):
                padded = jnp.where(hit, msg[:, lq][:, None],
                                   jnp.asarray(identity, msg.dtype))
                contribs.append(jnp.min(padded, axis=0))  # (SBLK,) VPU
            contrib = jnp.stack(contribs, axis=-1)        # (SBLK, Q)
            out_ref[...] = jnp.minimum(out_ref[...], contrib)


def _chunk_tables(ids_p, src_p, mask_i, gchg_i):
    """Scalar-prefetch tables: per-chunk [lo, hi] id range + frontier bit.
    Also returns the total active-edge count (the Fig-6 message counter) —
    a free reduction of the gather the bitmap needs anyway."""
    e_pad = ids_p.shape[0]
    idc = ids_p.reshape(e_pad // EBLK, EBLK)
    valid = mask_i.reshape(e_pad // EBLK, EBLK) > 0
    chunk_lo = jnp.where(valid, idc, jnp.iinfo(jnp.int32).max).min(axis=1)
    chunk_hi = jnp.where(valid, idc, -1).max(axis=1)
    # "any active source" bitmap: gchg gather fused into a per-chunk any()
    src_act = jnp.where(valid, jnp.take(gchg_i, src_p.reshape(valid.shape)), 0)
    chunk_act = src_act.max(axis=1).astype(jnp.int32)
    return chunk_lo, chunk_hi, chunk_act, src_act.sum()


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count"))
def fused_relax_reduce_pallas(gval, gchg, edge_src, edge_w, edge_mask,
                              edge_dst, num_segments: int, relax_kind: str,
                              kind: str, interpret: bool = True,
                              with_count: bool = False):
    """Fused gather/relax/mask/segment-reduce.

    gval: (V,) f32 vertex (replica-slot) values; gchg: (V,) bool changed
    flags (the frontier); edge_src/edge_dst: (E,) int32 into [0, V) /
    [0, num_segments); edge_w: (E,) f32; edge_mask: (E,) bool (False on
    padding). Returns the (num_segments,) inbox partial — empty segments
    hold the combine identity — or, ``with_count=True``, a (partial,
    active-edge count) pair; the count is a byproduct of the frontier
    bitmap gather, not an extra pass. Edges should be sorted by
    ``edge_dst`` for the range skip to bite; correctness never depends
    on the sort.
    """
    assert relax_kind in RELAX_KINDS, relax_kind
    if (relax_kind, kind) not in ABSORBING_PAIRS:
        raise ValueError(
            f"non-absorbing relax/combine pairing {(relax_kind, kind)}: "
            "frontier masking requires relax(identity, w) == identity "
            f"(supported: {sorted(ABSORBING_PAIRS)})")
    e = edge_src.shape[0]
    e_pad = -(-e // EBLK) * EBLK
    s_pad = -(-num_segments // SBLK) * SBLK
    v = gval.shape[0]
    v_pad = -(-max(v, 1) // 128) * 128
    identity = jnp.inf if kind == "min" else 0.0

    # frontier masking folded into the value table (absorbing identity):
    # relax(identity, w) == identity for all supported semirings, so an
    # inactive source can never contribute — bit-identical to the oracle's
    # explicit where(active, ...) mask, one fewer VMEM gather per cell.
    gval_m = jnp.where(gchg, gval, jnp.asarray(identity, gval.dtype))
    gval_p = jnp.full((v_pad,), identity, gval.dtype).at[:v].set(gval_m)
    gchg_p = jnp.zeros((v_pad,), jnp.int32).at[:v].set(
        gchg.astype(jnp.int32))
    ids_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_dst.astype(jnp.int32))
    src_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_src.astype(jnp.int32))
    w_p = jnp.zeros((e_pad,), edge_w.dtype).at[:e].set(edge_w)
    mask_i = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_mask.astype(jnp.int32))

    chunk_lo, chunk_hi, chunk_act, msg_count = _chunk_tables(
        ids_p, src_p, mask_i, gchg_p)

    grid = (s_pad // SBLK, e_pad // EBLK)
    edge_spec = pl.BlockSpec((EBLK,), lambda i, j, lo, hi, act: (j,))
    full_spec = pl.BlockSpec((v_pad,), lambda i, j, lo, hi, act: (0,))
    out = pl.pallas_call(
        functools.partial(_kernel, relax_kind=relax_kind, kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      full_spec],
            out_specs=pl.BlockSpec((SBLK,), lambda i, j, lo, hi, act: (i,)),
        ),
        out_shape=jax.ShapeDtypeStruct((s_pad,), gval.dtype),
        interpret=interpret,
    )(chunk_lo, chunk_hi, chunk_act,
      ids_p, src_p, w_p, mask_i, gval_p)
    if with_count:
        return out[:num_segments], msg_count
    return out[:num_segments]


def _chunk_tables_lanes(ids_p, src_p, mask_i, gchg_iq):
    """Laned scalar-prefetch tables. ``gchg_iq``: (v_pad, Q) int32 per-lane
    frontier. The chunk-skip bit is the OR across lanes — a chunk is dead
    only when no lane has an active source in it; the per-lane active-edge
    counts (the Fig-6 message counters, one per query) ride along."""
    e_pad = ids_p.shape[0]
    idc = ids_p.reshape(e_pad // EBLK, EBLK)
    valid = mask_i.reshape(e_pad // EBLK, EBLK) > 0
    chunk_lo = jnp.where(valid, idc, jnp.iinfo(jnp.int32).max).min(axis=1)
    chunk_hi = jnp.where(valid, idc, -1).max(axis=1)
    src_act = jnp.where(
        valid[..., None],
        jnp.take(gchg_iq, src_p.reshape(valid.shape), axis=0), 0)
    chunk_act = src_act.max(axis=(1, 2)).astype(jnp.int32)
    return chunk_lo, chunk_hi, chunk_act, src_act.sum(axis=(0, 1))


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count"))
def fused_relax_reduce_lanes_pallas(gval, gchg, lane_unitw, edge_src, edge_w,
                                    edge_mask, edge_dst, num_segments: int,
                                    relax_kind: str, kind: str,
                                    interpret: bool = True,
                                    with_count: bool = False):
    """Lane-batched fused gather/relax/mask/segment-reduce (ISSUE 2).

    The single-query kernel grown a trailing query-lane axis ``Q``:
    ``gval``/``gchg`` are (V, Q) — per-lane values and per-lane frontiers
    over one shared edge structure — and the result is the (num_segments,
    Q) per-lane inbox partial (plus, with ``with_count=True``, the (Q,)
    per-lane active-edge counts).  ``lane_unitw`` (Q,) only matters for
    ``relax_kind='add_w'``: lanes with a nonzero flag relax with the
    constant weight 1.0 (BFS levels) instead of the edge weight (SSSP), so
    one launch serves a mixed BFS/SSSP batch.  A converged lane has an
    all-False ``gchg`` column: its sources read as the absorbing identity,
    so it contributes nothing while live lanes keep the round busy — and
    the chunk-skip bitmap is the OR across lanes, so a grid cell is
    skipped only when its edge chunk is frontier-dead in *every* lane.

    Same VMEM scale constraint as the single-query kernel, times Q: the
    whole (v_pad, Q) table rides into every grid cell.  The trailing lane
    axis is not padded to the 128-lane TPU tile — fine under interpret
    mode (this container); real-TPU lane padding is a ROADMAP open item.
    """
    assert relax_kind in ("add_w", "mul_w"), relax_kind
    if (relax_kind, kind) not in ABSORBING_PAIRS:
        raise ValueError(
            f"non-absorbing relax/combine pairing {(relax_kind, kind)}: "
            "frontier masking requires relax(identity, w) == identity "
            f"(supported: {sorted(ABSORBING_PAIRS)})")
    v, q = gval.shape
    e = edge_src.shape[0]
    e_pad = -(-e // EBLK) * EBLK
    s_pad = -(-num_segments // SBLK) * SBLK
    v_pad = -(-max(v, 1) // 128) * 128
    identity = jnp.inf if kind == "min" else 0.0

    gval_m = jnp.where(gchg, gval, jnp.asarray(identity, gval.dtype))
    gval_p = jnp.full((v_pad, q), identity, gval.dtype).at[:v].set(gval_m)
    gchg_p = jnp.zeros((v_pad, q), jnp.int32).at[:v].set(
        gchg.astype(jnp.int32))
    ids_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_dst.astype(jnp.int32))
    src_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_src.astype(jnp.int32))
    w_p = jnp.zeros((e_pad,), edge_w.dtype).at[:e].set(edge_w)
    mask_i = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_mask.astype(jnp.int32))
    unitw = jnp.asarray(lane_unitw, jnp.int32).reshape(q)

    chunk_lo, chunk_hi, chunk_act, msg_counts = _chunk_tables_lanes(
        ids_p, src_p, mask_i, gchg_p)

    grid = (s_pad // SBLK, e_pad // EBLK)
    edge_spec = pl.BlockSpec((EBLK,), lambda i, j, lo, hi, act: (j,))
    lane_spec = pl.BlockSpec((q,), lambda i, j, lo, hi, act: (0,))
    full_spec = pl.BlockSpec((v_pad, q), lambda i, j, lo, hi, act: (0, 0))
    out = pl.pallas_call(
        functools.partial(_kernel_lanes, relax_kind=relax_kind, kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      lane_spec, full_spec],
            out_specs=pl.BlockSpec((SBLK, q),
                                   lambda i, j, lo, hi, act: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((s_pad, q), gval.dtype),
        interpret=interpret,
    )(chunk_lo, chunk_hi, chunk_act,
      ids_p, src_p, w_p, mask_i, unitw, gval_p)
    if with_count:
        return out[:num_segments], msg_counts
    return out[:num_segments]


def fused_grid_cells(edge_dst, edge_mask, edge_src, gchg,
                     num_segments: int) -> dict:
    """Host-side mirror of both launch shapes for the dense exchange.

    ``fused_live``/``total_fused`` mirror THIS kernel's single flattened
    launch (edge_mask-aware per-chunk ranges + frontier bitmap);
    ``range_live``/``total_unfused`` mirror the unfused composition's S
    vmapped per-shard ``segment_combine_pallas`` launches, whose validity
    rule is positional (every in-shard slot counts, so engine padding
    edges carrying id 0 widen chunk ranges) and which has no frontier
    skip.  Edge arrays are (S, E_max) host arrays — or 1-D for a single
    flat launch; ``gchg`` is the (V,) frontier.
    """
    edge_dst = np.atleast_2d(np.asarray(edge_dst))
    edge_mask = np.atleast_2d(np.asarray(edge_mask))
    edge_src = np.atleast_2d(np.asarray(edge_src))
    gchg = np.asarray(gchg).reshape(-1)
    S, E_max = edge_dst.shape
    s_pad = -(-num_segments // SBLK) * SBLK
    seg0 = np.arange(s_pad // SBLK)[:, None] * SBLK        # (n_i, 1)

    # fused: one launch over the flattened edge stack
    e = S * E_max
    e_pad = -(-e // EBLK) * EBLK
    ids = np.zeros(e_pad, np.int64)
    ids[:e] = edge_dst.reshape(-1)
    msk = np.zeros(e_pad, bool)
    msk[:e] = edge_mask.reshape(-1)
    act = np.zeros(e_pad, bool)
    act[:e] = edge_mask.reshape(-1) & gchg[edge_src.reshape(-1)]
    idc, mkc, acc = (x.reshape(e_pad // EBLK, EBLK) for x in (ids, msk, act))
    lo = np.where(mkc, idc, np.iinfo(np.int64).max).min(axis=1)
    hi = np.where(mkc, idc, -1).max(axis=1)
    intersects = (hi[None, :] >= seg0) & (lo[None, :] < seg0 + SBLK)
    fused_live = int((intersects & acc.any(axis=1)[None, :]).sum())
    total_fused = int(intersects.size)

    # unfused: S per-shard launches, positional validity, range skip only
    ep = -(-E_max // EBLK) * EBLK
    ids_s = np.zeros((S, ep), np.int64)
    ids_s[:, :E_max] = edge_dst
    valid = np.zeros(ep, bool)
    valid[:E_max] = True
    idc2 = ids_s.reshape(S, ep // EBLK, EBLK)
    v2 = valid.reshape(ep // EBLK, EBLK)[None, :, :]
    lo2 = np.where(v2, idc2, np.iinfo(np.int64).max).min(axis=-1)
    hi2 = np.where(v2, idc2, -1).max(axis=-1)                # (S, n_j)
    inter2 = (hi2[:, None, :] >= seg0[None, :, :]) \
        & (lo2[:, None, :] < seg0[None, :, :] + SBLK)        # (S, n_i, n_j)
    return {
        "total_fused": total_fused,
        "total_unfused": int(inter2.size),
        "range_live": int(inter2.sum()),
        "fused_live": fused_live,
    }
