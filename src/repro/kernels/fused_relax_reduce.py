"""Pallas TPU kernel: fused frontier-aware relax + blocked segment reduce.

One engine round used to run as four separate XLA/Pallas ops, each
materializing an ``(S, E_max)`` HBM intermediate:

    src_val = gval[edge_src]                  # dense gather     (HBM f32)
    active  = edge_mask & gchg[edge_src]      # frontier mask    (HBM bool)
    msg     = where(active, relax(src_val, w), identity)   #     (HBM f32)
    inbox   = segment_reduce(msg, edge_dst)   # Pallas kernel

This kernel fuses the whole pipeline into one VMEM-resident pass: the
gather, semiring relax, frontier masking, and blocked semiring reduction
all happen inside the grid cell — no per-edge float array ever
round-trips HBM.  The frontier mask is folded into the value table
before launch (inactive sources read as the absorbing identity:
``relax(identity, w) == identity`` for every supported semiring), so the
cell needs a single VMEM gather.

Blocking follows ``rhizome_segment_reduce``: the edge axis is tiled into
``EBLK`` chunks, the segment axis into ``SBLK`` blocks; cell (i, j)
builds an (EBLK x SBLK) hit mask and reduces over edges (one-hot MXU
matmul for ``sum``, masked VPU min for ``min``); output block *i* is
revisited across all *j* and accumulated in place.

Two levels of scalar-prefetched grid-cell skipping (the TPU form of the
paper's diffusion pruning — work stays proportional to the frontier):

1. **Sorted-range skip** — edges are sorted by destination, so chunk *j*
   covers segment ids ``[chunk_lo[j], chunk_hi[j]]``; cells whose segment
   block does not intersect are skipped (static sparsity of the CSR sort).
2. **Frontier chunk skip** — ``chunk_active[j]`` records whether ANY edge
   in chunk *j* has a changed (diffusing) source this round.  On late
   BFS/SSSP rounds the frontier is a tiny fraction of the graph, so most
   chunks are dead and their grid cells are skipped *entirely* across all
   segment blocks — the paper's "stale diffusions are subsumed" pruning,
   realized as predicated grid cells.  The bitmap is an O(E/EBLK) scalar
   vector computed from ``gchg`` by a fused reduction; it is the only
   per-round edge-proportional traffic besides the kernel's own block DMAs.

``fused_grid_cells`` mirrors the two skip predicates on the host so
benchmarks/tests can count exactly how many grid cells execute (see
``benchmarks/engine_bench.py``); with a ``vblk`` it also mirrors the
tiled path's per-chunk tile counts and DMA issue/byte totals, and the
kernels' optional ``with_debug`` counters report the *kernel-side*
executed-cell / issued-DMA totals so the mirror is provably exact
(``tests/test_fused_kernel.py::test_grid_cell_dma_oracle_*``).

Semiring relax is selected statically via ``relax_kind``
(``Semiring.relax_kind``, single-sourced with the jnp path through
``actions.RELAX_FNS``): 'add_w' (min-plus / SSSP), 'add_one' (BFS level
relax; the weight is ignored), 'mul_w' (plus-times / PageRank).
Validated against ``ref.fused_relax_reduce_ref`` in interpret mode (CPU);
the compiled path targets TPU VMEM via BlockSpecs.

**Scale: budget-based pinned/tiled path selection.**  Two residency
strategies share the cell math, selected per launch from the slot
table's footprint against a VMEM budget (``vmem_budget_bytes`` on
``EngineConfig``, the ``REPRO_VMEM_BUDGET`` env var, or the
``DEFAULT_VMEM_BUDGET_BYTES`` fallback — see ``select_kernel_path``):

* **pinned** — the whole padded value table rides into VMEM per grid
  cell (``full_spec``).  Fastest when it fits (one resident copy, zero
  per-cell DMA), but caps partitions at roughly ``budget / 4`` f32
  slots (~3M at the 12 MiB default).
* **tiled**  — the value table stays in HBM (``memory_space=ANY``); the
  slot axis is cut into ``vblk``-wide tiles and each live grid cell
  async-copies (``pltpu.make_async_copy``) only the tiles its edge
  chunk's *frontier-active sources* touch, double-buffered so tile
  ``t+1``'s DMA overlaps tile ``t``'s relax+reduce.  Per-chunk tile
  lists ride the scalar prefetch (``chunk_ntiles`` / ``chunk_tiles``),
  so a sparse frontier pays DMA proportional to the tiles it actually
  diffuses from — the out-of-core form of the paper's rhizome scaling
  (slot state larger than any one fast memory).  Tile lists are
  per-edge-chunk (not per-(i, j) cell): a dst-range filter would shrink
  DMAs further and is future work.  Note the scalar-prefetch tables are
  O(E/EBLK) rows (as the pre-existing ``chunk_lo/hi/act`` already were),
  times ``t_max`` columns for the tile lists — at extreme chunk counts
  they outgrow real SMEM and belong in an HBM side table (ROADMAP);
  with the default budget ``t_max`` stays single-digit (vblk is large),
  so the chunk count, not the tile list, is the binding row dimension.

Both paths are bit-identical for min semirings (sum differs only by
float reassociation across tile partials).  The laned kernels grow the
same two paths with the trailing query axis padded to the TPU lane tile
(``LANE_TILE`` when compiling, a sublane multiple under interpret —
tail lanes are frontier-dead and masked, so padding never changes
results; see ``_lane_pad``).

**Sparsity-proportional worklist launches (ISSUE 5).**  The dense grid
above launches every ``(num_sblk, num_chunks)`` cell and early-exits the
dead ones — launch cost stays proportional to *total* work even when the
frontier is four cells wide.  Every kernel variant therefore has a
**worklist twin**: a host-built (``WorklistPlanner`` / ``plan_worklist``)
scalar-prefetched list of live ``(i, j)`` cell pairs, launched as a 1-D
grid over the power-of-two-padded live count.  Each worklist cell writes
its own ``(SBLK[, Q])`` partial (no out-block revisiting — revisit order
under a sparse worklist is non-consecutive, which Pallas out pipelining
does not guarantee), and a host-side scatter-combine folds the partials
into the inbox; padded cells emit the combine identity, so the scatter
is exact.  Bit-identical to the dense grid for min semirings; sum
differs only by scatter reassociation.

The worklist's tiled twin goes further than the per-chunk tile lists
(the ROADMAP dst-range item): tile lists are built **per cell** — only
the tiles of frontier-active sources whose edge lands in block *i*'s dst
range are fetched — and the worklist is ordered j-major so consecutive
cells sharing an edge chunk reuse tiles still resident in the 2-slot
VMEM scratch.  The planner simulates exactly the kernel's slot schedule
(``cell_slot`` / ``cell_fetch``), so the host DMA mirror is exact; a
cell whose dst-filtered tile list is empty contributes only the identity
and is dropped from the worklist entirely.

Scalar-prefetch tables live in SMEM; ``smem_table_bytes`` prices them
and ``select_kernel_path`` warns and widens ``vblk`` (shorter tile
lists) when a configurable ``smem_budget_bytes`` would be exceeded —
the real-TPU ~100k-chunk regime the ROADMAP flags.
"""
from __future__ import annotations

import functools
import os
import typing
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.actions import RELAX_FNS

EBLK = 512   # edge-axis tile
SBLK = 256   # segment-axis tile (lane-aligned)

LANE_TILE = 128          # TPU lane tile: laned compile pads Q up to this
INTERPRET_LANE_TILE = 8  # sublane multiple: cheap pad that still exercises
                         # the masked-tail machinery under interpret-mode CI

DEFAULT_VMEM_BUDGET_BYTES = 12 * 2**20   # ~3/4 of a 16 MiB TPU core VMEM
VMEM_BUDGET_ENV = "REPRO_VMEM_BUDGET"

WL_PAD = 8      # worklist launches are padded to >= this many cells and
                # then to a power of two, so jit retraces O(log cells)
                # times per partition instead of once per distinct count

RELAX_KINDS = tuple(RELAX_FNS)

# pairings for which the combine identity absorbs under relax —
# relax(identity, w) == identity — the property the frontier masking
# relies on (inactive sources are folded into the value table as the
# identity and must never contribute)
ABSORBING_PAIRS = frozenset(
    {("add_w", "min"), ("add_one", "min"), ("mul_w", "sum")})


def _relax(relax_kind: str, src_val, w):
    return RELAX_FNS[relax_kind](src_val, w)


def _round_up(x: int, m: int) -> int:
    return -(-max(x, 1) // m) * m


def _check_pair(relax_kind: str, kind: str):
    assert relax_kind in RELAX_KINDS, relax_kind
    if (relax_kind, kind) not in ABSORBING_PAIRS:
        raise ValueError(
            f"non-absorbing relax/combine pairing {(relax_kind, kind)}: "
            "frontier masking requires relax(identity, w) == identity "
            f"(supported: {sorted(ABSORBING_PAIRS)})")


# --------------------------------------------------------------------------
# budget-based pinned/tiled path selection
# --------------------------------------------------------------------------

def resolve_vmem_budget(vmem_budget_bytes=None) -> int:
    """The VMEM byte budget the value table must live within: an explicit
    argument wins, else the ``REPRO_VMEM_BUDGET`` env var (CI forces it
    tiny to route interpret-mode runs through the tiled path), else
    ``DEFAULT_VMEM_BUDGET_BYTES``."""
    if vmem_budget_bytes is not None:
        return int(vmem_budget_bytes)
    env = os.environ.get(VMEM_BUDGET_ENV)
    if env:
        return int(env)
    return DEFAULT_VMEM_BUDGET_BYTES


def smem_table_bytes(n_chunks: int, t_max: int = 0,
                     wl_cells: int = 0) -> int:
    """Byte footprint of the scalar-prefetch tables one fused launch pins
    in SMEM: the per-chunk ``chunk_lo/hi/act`` rows, plus (tiled) the
    ``chunk_ntiles``/``chunk_tiles`` tile lists, plus (worklist) the
    per-cell ``wl_i/wl_j`` pairs, ``nlive``, and — when both — the
    per-cell ``cell_ntiles``/``cell_tile``/``cell_slot``/``cell_fetch``
    tables.  All int32.  ``t_max`` is the tile-list width (0 = pinned),
    ``wl_cells`` the padded worklist length (0 = dense grid)."""
    rows = 3 * n_chunks                      # chunk_lo + chunk_hi + chunk_act
    if t_max and not wl_cells:
        rows += n_chunks * (1 + t_max)       # chunk_ntiles + chunk_tiles
    if wl_cells:
        rows += 2 * wl_cells + 1             # wl_i + wl_j + nlive
        if t_max:
            rows += wl_cells * (1 + 3 * t_max)   # ntiles + tile/slot/fetch
    return rows * 4


def select_kernel_path(num_slots: int, q_pad: int = 1,
                       vmem_budget_bytes=None, *, path=None, vblk=None,
                       n_chunks=None, wl_cells: int = 0,
                       smem_budget_bytes=None, return_info: bool = False):
    """Pick the fused kernel's residency strategy for a value table of
    ``num_slots`` (x ``q_pad`` lanes) f32 slots.

    Returns ``("pinned", None)`` when the whole padded table fits the
    budget, else ``("tiled", vblk)`` with ``vblk`` the largest 128-multiple
    slot-tile whose double buffer fits (floored at 128 — the smallest
    legal tile — even if that overshoots a pathologically small budget).
    ``path``/``vblk`` force the decision (differential tests pin both
    sides; benchmarks pin the tile to keep DMA counts comparable).

    With ``n_chunks`` and ``smem_budget_bytes`` the scalar-prefetch table
    footprint (``smem_table_bytes``; ``wl_cells`` prices a worklist
    launch on top) joins the decision: a tiled path whose tile lists
    would overflow the SMEM budget is widened (``vblk`` doubled — fewer,
    wider tiles shrink ``t_max``) with a warning until the tables fit or
    one tile covers the table; a still-overflowing chunk count is warned
    as needing the ROADMAP HBM side table.  ``return_info=True`` appends
    a dict with the footprint behind the decision.
    """
    budget = resolve_vmem_budget(vmem_budget_bytes)
    v_pad = _round_up(num_slots, 128)
    if path is None:
        path = "pinned" if v_pad * q_pad * 4 <= budget else "tiled"
    if path == "pinned":
        info = {"path": "pinned", "vblk": None, "smem_table_bytes":
                smem_table_bytes(n_chunks, 0, wl_cells) if n_chunks else None}
        if n_chunks is not None and smem_budget_bytes is not None \
                and info["smem_table_bytes"] > smem_budget_bytes:
            # pinned launches carry the same chunk_lo/hi/act rows; no
            # vblk to widen — the overflow needs the ROADMAP HBM side
            # table, so say so instead of silently compiling
            warnings.warn(
                f"fused-kernel scalar-prefetch tables ({n_chunks} chunks"
                f", wl_cells={wl_cells}) weigh "
                f"{info['smem_table_bytes']} bytes — over "
                f"smem_budget_bytes={smem_budget_bytes} on the pinned "
                "path; the chunk tables belong in an HBM side table "
                "(ROADMAP)", stacklevel=2)
        return ("pinned", None, info) if return_info else ("pinned", None)
    if path != "tiled":
        raise ValueError(f"unknown kernel path {path!r}")
    if vblk is None:
        vblk = max((budget // (2 * q_pad * 4)) // 128 * 128, 128)
        vblk = min(vblk, v_pad)
    if vblk % 128 or vblk <= 0:
        raise ValueError(f"vblk must be a positive multiple of 128; "
                         f"got {vblk}")
    vblk = int(vblk)
    info = {"path": "tiled", "vblk": vblk, "smem_table_bytes": None}
    if n_chunks is not None and smem_budget_bytes is not None:
        def footprint(vb):
            t_max = min(_round_up(num_slots, vb) // vb, EBLK)
            return smem_table_bytes(n_chunks, t_max, wl_cells)
        if footprint(vblk) > smem_budget_bytes:
            vblk0 = vblk
            while footprint(vblk) > smem_budget_bytes and vblk < v_pad:
                vblk *= 2    # fewer, wider tiles: halves the t_max rows
            warnings.warn(
                f"fused-kernel scalar-prefetch tables ({n_chunks} chunks, "
                f"wl_cells={wl_cells}) exceed smem_budget_bytes="
                f"{smem_budget_bytes} at vblk={vblk0}; widened to "
                f"vblk={vblk} ({footprint(vblk)} table bytes)"
                + ("" if footprint(vblk) <= smem_budget_bytes else
                   " — still over budget: the chunk tables themselves "
                   "outgrow SMEM and belong in an HBM side table "
                   "(ROADMAP)"),
                stacklevel=2)
        info["vblk"] = vblk
        info["smem_table_bytes"] = footprint(vblk)
    return ("tiled", vblk, info) if return_info else ("tiled", vblk)


def _lane_pad(q: int, interpret: bool, lane_tile=None) -> int:
    """Padded lane count: up to the 128-lane TPU tile when compiling;
    under interpret mode a sublane multiple keeps CI cheap while still
    exercising the masked-tail-lane machinery (the regression tests force
    ``lane_tile=LANE_TILE`` to prove the full tile)."""
    tile = lane_tile if lane_tile is not None else (
        INTERPRET_LANE_TILE if interpret else LANE_TILE)
    return _round_up(q, tile)


# --------------------------------------------------------------------------
# kernel bodies — pinned (full table in VMEM per cell)
# --------------------------------------------------------------------------

def _split_dbg(extras):
    """Trailing kernel refs: (dbg?, *scratch) -> (dbg | None, scratch)."""
    if len(extras) % 2:                  # dbg present: odd count
        return extras[0], extras[1:]
    return None, extras


def _init_dbg(dbg_ref, i, j):
    """Zero the [executed cells, issued DMAs] counters at the first cell
    (the grid is iterated sequentially, row-major)."""
    if dbg_ref is not None:
        @pl.when((i == 0) & (j == 0))
        def _dbg_init():
            dbg_ref[0] = 0
            dbg_ref[1] = 0


def _bump_dbg(dbg_ref, dmas):
    if dbg_ref is not None:
        dbg_ref[0] += 1
        dbg_ref[1] += dmas


def _kernel(chunk_lo_ref, chunk_hi_ref, chunk_act_ref,
            ids_ref, src_ref, w_ref, mask_ref, gval_ref,
            out_ref, *extras, relax_kind, kind):
    dbg_ref, _ = _split_dbg(extras)
    i = pl.program_id(0)  # segment block
    j = pl.program_id(1)  # edge chunk

    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, i, j)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full((SBLK,), identity, out_ref.dtype)

    seg0 = i * SBLK
    # level 1: sorted-edges range skip — chunk j covers [chunk_lo, chunk_hi]
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)
    # level 2: frontier skip — any changed source in this edge chunk?
    live = intersects & (chunk_act_ref[j] > 0)

    @pl.when(live)
    def _compute():
        src = src_ref[...]                       # (EBLK,) int32
        # fused frontier gather: the VMEM-resident value table is
        # pre-masked so frontier-inactive sources read as the absorbing
        # identity — relax(identity, w) == identity for every semiring
        # here (inf+w=inf, 0*w=0), so no per-edge gchg gather is needed
        src_val = jnp.take(gval_ref[...], src)
        msg = _relax(relax_kind, src_val, w_ref[...])
        msg = jnp.where(mask_ref[...] > 0, msg,
                        jnp.asarray(identity, msg.dtype))

        _seg_accumulate(out_ref, msg, ids_ref[...] - seg0, kind, identity)
        _bump_dbg(dbg_ref, 0)        # pinned: no manual value-tile DMAs


def _seg_contrib(msg, local, kind, identity, dtype):
    """(SBLK,) block contribution of (EBLK,) messages (one grid cell)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (EBLK, SBLK), 1)
    hit = local[:, None] == cols                 # (EBLK, SBLK)
    if kind == "sum":
        # one-hot matmul -> MXU systolic reduction
        return jnp.dot(
            hit.astype(msg.dtype).T, msg,
            preferred_element_type=jnp.float32,
        ).astype(dtype)
    padded = jnp.where(hit, msg[:, None],
                       jnp.asarray(identity, msg.dtype))
    return jnp.min(padded, axis=0)               # VPU reduction over edges


def _accumulate_block(out_ref, contrib, kind):
    """Combine a cell contribution into the out block (the worklist
    kernels' per-cell partial blocks carry a leading singleton)."""
    contrib = contrib.reshape(out_ref.shape)
    if kind == "sum":
        out_ref[...] += contrib
    else:
        out_ref[...] = jnp.minimum(out_ref[...], contrib)


def _seg_accumulate(out_ref, msg, local, kind, identity):
    """Accumulate (EBLK,) messages into the (SBLK,) out block."""
    _accumulate_block(
        out_ref, _seg_contrib(msg, local, kind, identity, out_ref.dtype),
        kind)


def _kernel_lanes(chunk_lo_ref, chunk_hi_ref, chunk_act_ref,
                  ids_ref, src_ref, w_ref, mask_ref, unitw_ref, gval_ref,
                  out_ref, *extras, relax_kind, kind):
    """Lane-batched kernel body: the value table carries a trailing query
    axis ``Q`` and every edge relaxes all lanes at once.  ``unitw_ref``
    (Q,) selects, per lane, whether 'add_w' reads the edge weight or the
    constant 1.0 — BFS lanes are SSSP lanes over unit weights, so one
    launch serves a mixed BFS/SSSP batch with bit-identical per-lane math.
    The frontier chunk skip uses the OR across lanes (``chunk_act``): a
    grid cell is skipped only when its edge chunk is dead in EVERY lane."""
    dbg_ref, _ = _split_dbg(extras)
    i = pl.program_id(0)  # segment block
    j = pl.program_id(1)  # edge chunk

    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, i, j)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    seg0 = i * SBLK
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)
    live = intersects & (chunk_act_ref[j] > 0)

    @pl.when(live)
    def _compute():
        src = src_ref[...]                       # (EBLK,) int32
        src_val = jnp.take(gval_ref[...], src, axis=0)   # (EBLK, Q)
        msg = _lane_msgs(relax_kind, src_val, w_ref[...], mask_ref[...],
                         unitw_ref[...], identity)
        _lane_accumulate(out_ref, msg, ids_ref[...] - seg0, kind, identity)
        _bump_dbg(dbg_ref, 0)        # pinned: no manual value-tile DMAs


def _lane_msgs(relax_kind, src_val, w, mask, unitw, identity):
    """(EBLK, Q) relaxed + masked messages for the laned kernels."""
    if relax_kind == "add_w":
        w_eff = jnp.where(unitw[None, :] > 0,
                          jnp.asarray(1.0, w.dtype), w[:, None])
        msg = src_val + w_eff
    else:                                        # 'mul_w'
        msg = src_val * w[:, None]
    return jnp.where(mask[:, None] > 0, msg,
                     jnp.asarray(identity, msg.dtype))


def _lane_contrib(msg, local, kind, identity, dtype):
    """(SBLK, Q) block contribution of (EBLK, Q) messages."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (EBLK, SBLK), 1)
    hit = local[:, None] == cols                 # (EBLK, SBLK)
    if kind == "sum":
        # one-hot matmul -> (SBLK, Q) MXU systolic reduction
        return jnp.dot(
            hit.astype(msg.dtype).T, msg,
            preferred_element_type=jnp.float32,
        ).astype(dtype)
    # statically unrolled per-lane loop: peak in-cell memory stays
    # (EBLK, SBLK) regardless of Q — a broadcast hit[:, :, None]
    # against msg would materialize an (EBLK, SBLK, Q) intermediate
    # per grid cell, which cannot fit VMEM for real batch widths
    contribs = []
    for lq in range(msg.shape[1]):
        padded = jnp.where(hit, msg[:, lq][:, None],
                           jnp.asarray(identity, msg.dtype))
        contribs.append(jnp.min(padded, axis=0))  # (SBLK,) VPU
    return jnp.stack(contribs, axis=-1)           # (SBLK, Q)


def _lane_accumulate(out_ref, msg, local, kind, identity):
    """Accumulate (EBLK, Q) messages into the (SBLK, Q) out block."""
    _accumulate_block(
        out_ref, _lane_contrib(msg, local, kind, identity, out_ref.dtype),
        kind)


# --------------------------------------------------------------------------
# kernel bodies — tiled (value table in HBM, per-cell double-buffered DMA)
# --------------------------------------------------------------------------

def _tile_loop(j, n, chunk_tiles_ref, gval_hbm, scratch, sem, vblk,
               tile_fn):
    """Double-buffered DMA loop over this chunk's ``n`` slot tiles: start
    the warm-up fetch, then per tile overlap tile t+1's async copy with
    tile t's compute (``tile_fn(slot, tile)`` reads ``scratch[slot]``).
    Every started DMA is waited; the caller guards on ``n >= 1``.
    ``gval_hbm`` may be (v_pad,) or (v_pad, Q) — the slice rank follows."""
    laned = len(gval_hbm.shape) == 2

    def get_dma(slot, t):
        tile = chunk_tiles_ref[j, t]
        rows = pl.ds(tile * vblk, vblk)
        src = gval_hbm.at[rows, :] if laned else gval_hbm.at[rows]
        return pltpu.make_async_copy(src, scratch.at[slot], sem.at[slot])

    get_dma(0, 0).start()                        # warm-up fetch

    def body(t, _):
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < n)
        def _prefetch():                         # overlap next tile's DMA
            get_dma(jax.lax.rem(t + 1, 2), t + 1).start()

        get_dma(slot, t).wait()
        tile_fn(slot, chunk_tiles_ref[j, t])
        return 0

    jax.lax.fori_loop(0, n, body, 0)


def _kernel_tiled(chunk_lo_ref, chunk_hi_ref, chunk_act_ref,
                  chunk_ntiles_ref, chunk_tiles_ref,
                  ids_ref, src_ref, w_ref, mask_ref, gval_hbm,
                  out_ref, *extras, relax_kind, kind, vblk):
    """Tiled cell: the value table stays in HBM; only the ``vblk``-wide
    slot tiles listed for this edge chunk (``chunk_tiles`` — the tiles
    its frontier-active sources live in) are async-copied into a
    double-buffered VMEM scratch, tile t+1's DMA overlapping tile t's
    relax+reduce.  Every edge contributes in exactly one tile (its
    source's), so per-tile accumulation into the out block is exact."""
    dbg_ref, (scratch, sem) = _split_dbg(extras)
    i = pl.program_id(0)  # segment block
    j = pl.program_id(1)  # edge chunk

    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, i, j)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full((SBLK,), identity, out_ref.dtype)

    seg0 = i * SBLK
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)
    # a live chunk has >= 1 active source, hence >= 1 tile to fetch
    live = intersects & (chunk_act_ref[j] > 0)

    @pl.when(live)
    def _compute():
        n = chunk_ntiles_ref[j]
        src = src_ref[...]                       # (EBLK,) int32
        w = w_ref[...]
        msk = mask_ref[...]
        local = ids_ref[...] - seg0

        def tile_fn(slot, tile):
            loc = src - tile * vblk
            in_tile = (loc >= 0) & (loc < vblk)
            # sources outside this tile read slot 0 and are masked off;
            # frontier-inactive sources *inside* the tile read the
            # pre-masked absorbing identity, exactly as on the pinned path
            sval = jnp.take(scratch[slot], jnp.where(in_tile, loc, 0))
            msg = _relax(relax_kind, sval, w)
            msg = jnp.where((msk > 0) & in_tile, msg,
                            jnp.asarray(identity, msg.dtype))
            _seg_accumulate(out_ref, msg, local, kind, identity)

        _tile_loop(j, n, chunk_tiles_ref, gval_hbm, scratch, sem, vblk,
                   tile_fn)
        _bump_dbg(dbg_ref, n)


def _kernel_tiled_lanes(chunk_lo_ref, chunk_hi_ref, chunk_act_ref,
                        chunk_ntiles_ref, chunk_tiles_ref,
                        ids_ref, src_ref, w_ref, mask_ref, unitw_ref,
                        gval_hbm, out_ref, *extras, relax_kind, kind, vblk):
    """Laned tiled cell: (vblk, Q) value tiles ride the double-buffered
    DMA; tile lists use the OR-across-lanes frontier (a tile is fetched
    iff ANY lane has an active source in it — the gather is vectorized
    over lanes, and inactive lanes read the pre-masked identity)."""
    dbg_ref, (scratch, sem) = _split_dbg(extras)
    i = pl.program_id(0)
    j = pl.program_id(1)

    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, i, j)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    seg0 = i * SBLK
    intersects = (chunk_hi_ref[j] >= seg0) & (chunk_lo_ref[j] < seg0 + SBLK)
    live = intersects & (chunk_act_ref[j] > 0)

    @pl.when(live)
    def _compute():
        n = chunk_ntiles_ref[j]
        src = src_ref[...]
        w = w_ref[...]
        msk = mask_ref[...]
        unitw = unitw_ref[...]
        local = ids_ref[...] - seg0

        def tile_fn(slot, tile):
            loc = src - tile * vblk
            in_tile = (loc >= 0) & (loc < vblk)
            sval = jnp.take(scratch[slot], jnp.where(in_tile, loc, 0),
                            axis=0)              # (EBLK, Q)
            msg = _lane_msgs(relax_kind, sval, w,
                             msk * in_tile.astype(msk.dtype), unitw,
                             identity)
            _lane_accumulate(out_ref, msg, local, kind, identity)

        _tile_loop(j, n, chunk_tiles_ref, gval_hbm, scratch, sem, vblk,
                   tile_fn)
        _bump_dbg(dbg_ref, n)


# --------------------------------------------------------------------------
# kernel bodies — worklist twins (1-D grid over live (i, j) cell pairs)
# --------------------------------------------------------------------------
#
# Every worklist cell writes its own (1, SBLK[, Q]) partial block — the
# launch's out is (l_pad, SBLK[, Q]) and a host-side scatter-combine by
# ``wl_i`` folds the partials into the inbox (see ``_scatter_partials``).
# Cells past ``nlive`` (the pad) and dead cells emit the combine
# identity, which the scatter absorbs — no first-visit bookkeeping, no
# out-block revisiting, and the 1-D grid is exactly as long as the
# padded live count.


def _kernel_wl(wl_i_ref, wl_j_ref, nlive_ref,
               ids_ref, src_ref, w_ref, mask_ref, gval_ref,
               out_ref, *extras, relax_kind, kind):
    """Pinned worklist cell: cell ``c`` works edge chunk ``wl_j[c]``
    against segment block ``wl_i[c]``; the full value table rides in."""
    dbg_ref, _ = _split_dbg(extras)
    c = pl.program_id(0)
    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, c, 0)
    out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    @pl.when(c < nlive_ref[0])
    def _compute():
        seg0 = wl_i_ref[c] * SBLK
        src_val = jnp.take(gval_ref[...], src_ref[...])
        msg = _relax(relax_kind, src_val, w_ref[...])
        msg = jnp.where(mask_ref[...] > 0, msg,
                        jnp.asarray(identity, msg.dtype))
        _accumulate_block(
            out_ref,
            _seg_contrib(msg, ids_ref[...] - seg0, kind, identity,
                         out_ref.dtype),
            kind)
        _bump_dbg(dbg_ref, 0)        # pinned: no manual value-tile DMAs


def _kernel_wl_lanes(wl_i_ref, wl_j_ref, nlive_ref,
                     ids_ref, src_ref, w_ref, mask_ref, unitw_ref,
                     gval_ref, out_ref, *extras, relax_kind, kind):
    dbg_ref, _ = _split_dbg(extras)
    c = pl.program_id(0)
    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, c, 0)
    out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    @pl.when(c < nlive_ref[0])
    def _compute():
        seg0 = wl_i_ref[c] * SBLK
        src_val = jnp.take(gval_ref[...], src_ref[...], axis=0)  # (EBLK, Q)
        msg = _lane_msgs(relax_kind, src_val, w_ref[...], mask_ref[...],
                         unitw_ref[...], identity)
        _accumulate_block(
            out_ref,
            _lane_contrib(msg, ids_ref[...] - seg0, kind, identity,
                          out_ref.dtype),
            kind)
        _bump_dbg(dbg_ref, 0)


def _wl_tile_loop(c, n, cell_tile_ref, cell_slot_ref, cell_fetch_ref,
                  gval_hbm, scratch, sem, vblk, tile_fn, t_max):
    """Worklist DMA loop: the planner pre-assigned each of this cell's
    ``n`` tiles a scratch slot and a fetch flag (0 = the tile is still
    resident from an earlier cell of the same edge chunk — the j-major
    reuse), so the kernel only issues the DMAs the host planned.  Tile
    t+1's fetch overlaps tile t's relax+reduce: the planner alternates
    fetch slots (a fetched tile never lands in the slot the previous
    tile is being read from), which keeps the prefetch safe.  Returns
    the number of DMAs issued (the ``with_debug`` counter)."""
    laned = len(gval_hbm.shape) == 2

    def get_dma(t):
        slot = cell_slot_ref[c, t]
        rows = pl.ds(cell_tile_ref[c, t] * vblk, vblk)
        src = gval_hbm.at[rows, :] if laned else gval_hbm.at[rows]
        return pltpu.make_async_copy(src, scratch.at[slot], sem.at[slot])

    @pl.when((n >= 1) & (cell_fetch_ref[c, 0] > 0))
    def _warmup():
        get_dma(0).start()

    def body(t, dmas):
        # t + 1 is clamped for the table read only; the (t + 1 < n)
        # predicate keeps the clamped duplicate from ever fetching
        t1 = jnp.minimum(t + 1, t_max - 1)

        @pl.when((t + 1 < n) & (cell_fetch_ref[c, t1] > 0))
        def _prefetch():
            get_dma(t1).start()

        @pl.when(cell_fetch_ref[c, t] > 0)
        def _wait():
            get_dma(t).wait()

        tile_fn(cell_slot_ref[c, t], cell_tile_ref[c, t])
        return dmas + cell_fetch_ref[c, t]

    return jax.lax.fori_loop(0, n, body, 0)


def _kernel_wl_tiled(wl_i_ref, wl_j_ref, nlive_ref, cell_ntiles_ref,
                     cell_tile_ref, cell_slot_ref, cell_fetch_ref,
                     ids_ref, src_ref, w_ref, mask_ref, gval_hbm,
                     out_ref, *extras, relax_kind, kind, vblk, t_max):
    """Tiled worklist cell: only the tiles of frontier-active sources
    whose edge lands in THIS cell's dst block (the per-cell dst-range
    filter) ride the DMA, and tiles resident from the previous same-
    chunk cell are reused instead of re-fetched."""
    dbg_ref, (scratch, sem) = _split_dbg(extras)
    c = pl.program_id(0)
    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, c, 0)
    out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    @pl.when(c < nlive_ref[0])
    def _compute():
        n = cell_ntiles_ref[c]
        seg0 = wl_i_ref[c] * SBLK
        src = src_ref[...]
        w = w_ref[...]
        msk = mask_ref[...]
        local = ids_ref[...] - seg0

        def tile_fn(slot, tile):
            loc = src - tile * vblk
            in_tile = (loc >= 0) & (loc < vblk)
            sval = jnp.take(scratch[slot], jnp.where(in_tile, loc, 0))
            msg = _relax(relax_kind, sval, w)
            msg = jnp.where((msk > 0) & in_tile, msg,
                            jnp.asarray(identity, msg.dtype))
            _accumulate_block(
                out_ref,
                _seg_contrib(msg, local, kind, identity, out_ref.dtype),
                kind)

        dmas = _wl_tile_loop(c, n, cell_tile_ref, cell_slot_ref,
                             cell_fetch_ref, gval_hbm, scratch, sem, vblk,
                             tile_fn, t_max)
        _bump_dbg(dbg_ref, dmas)


def _kernel_wl_tiled_lanes(wl_i_ref, wl_j_ref, nlive_ref, cell_ntiles_ref,
                           cell_tile_ref, cell_slot_ref, cell_fetch_ref,
                           ids_ref, src_ref, w_ref, mask_ref, unitw_ref,
                           gval_hbm, out_ref, *extras, relax_kind, kind,
                           vblk, t_max):
    dbg_ref, (scratch, sem) = _split_dbg(extras)
    c = pl.program_id(0)
    identity = jnp.inf if kind == "min" else 0.0
    _init_dbg(dbg_ref, c, 0)
    out_ref[...] = jnp.full(out_ref.shape, identity, out_ref.dtype)

    @pl.when(c < nlive_ref[0])
    def _compute():
        n = cell_ntiles_ref[c]
        seg0 = wl_i_ref[c] * SBLK
        src = src_ref[...]
        w = w_ref[...]
        msk = mask_ref[...]
        unitw = unitw_ref[...]
        local = ids_ref[...] - seg0

        def tile_fn(slot, tile):
            loc = src - tile * vblk
            in_tile = (loc >= 0) & (loc < vblk)
            sval = jnp.take(scratch[slot], jnp.where(in_tile, loc, 0),
                            axis=0)              # (EBLK, Q)
            msg = _lane_msgs(relax_kind, sval, w,
                             msk * in_tile.astype(msk.dtype), unitw,
                             identity)
            _accumulate_block(
                out_ref,
                _lane_contrib(msg, local, kind, identity, out_ref.dtype),
                kind)

        dmas = _wl_tile_loop(c, n, cell_tile_ref, cell_slot_ref,
                             cell_fetch_ref, gval_hbm, scratch, sem, vblk,
                             tile_fn, t_max)
        _bump_dbg(dbg_ref, dmas)


# --------------------------------------------------------------------------
# scalar-prefetch table builders
# --------------------------------------------------------------------------

def _chunk_tables(ids_p, src_p, mask_i, gchg_i):
    """Scalar-prefetch tables: per-chunk [lo, hi] id range + frontier bit.
    Also returns the total active-edge count (the Fig-6 message counter) —
    a free reduction of the gather the bitmap needs anyway — and the
    per-edge active rows the tiled path's tile lists are built from."""
    e_pad = ids_p.shape[0]
    idc = ids_p.reshape(e_pad // EBLK, EBLK)
    valid = mask_i.reshape(e_pad // EBLK, EBLK) > 0
    chunk_lo = jnp.where(valid, idc, jnp.iinfo(jnp.int32).max).min(axis=1)
    chunk_hi = jnp.where(valid, idc, -1).max(axis=1)
    # "any active source" bitmap: gchg gather fused into a per-chunk any()
    src_act = jnp.where(valid, jnp.take(gchg_i, src_p.reshape(valid.shape)), 0)
    chunk_act = src_act.max(axis=1).astype(jnp.int32)
    return chunk_lo, chunk_hi, chunk_act, src_act.sum(), src_act


def _chunk_tile_tables(src_p, src_act, v_pad: int, vblk: int):
    """Per-chunk slot-tile lists for the tiled kernels.

    ``src_act``: (n_chunks, EBLK) nonzero where the edge is valid AND its
    source is frontier-active (OR across lanes when laned).  Returns
    ((n_chunks,) tile counts, (n_chunks, t_max) tile indices packed left
    in ascending order; entries past the count are arbitrary in-range
    tiles and never fetched — the kernel's fori_loop stops at the count).

    Built by an in-chunk sort + adjacent-dedupe, so the work is
    O(E log EBLK) and *independent of the tile count* — a dense
    (n_chunks, n_tiles) hit matrix would be quadratic-ish at exactly the
    paper-scale (R22+: ~131k chunks x ~33k tiles) regime this path
    exists to serve.
    """
    n_chunks = src_act.shape[0]
    n_tiles = v_pad // vblk
    t_max = min(n_tiles, EBLK)   # a chunk of EBLK edges touches <= EBLK tiles
    tile_of = src_p.reshape(n_chunks, EBLK) // vblk
    # inactive edges carry the n_tiles sentinel so they sort past every
    # real tile; first-occurrence flags then mark each distinct live tile
    t = jnp.sort(jnp.where(src_act > 0, tile_of, n_tiles), axis=1)
    first = jnp.concatenate(
        [jnp.ones((n_chunks, 1), bool), t[:, 1:] != t[:, :-1]], axis=1)
    is_tile = first & (t < n_tiles)
    ntiles = is_tile.sum(axis=1).astype(jnp.int32)
    # pack distinct tiles left (stable: ascending slot order -> the
    # kernel's tile fetches walk HBM sequentially)
    order = jnp.argsort(~is_tile, axis=1, stable=True)[:, :t_max]
    tiles = jnp.take_along_axis(t, order, axis=1)
    # slots past the count may hold the sentinel; clamp into range
    # (never fetched, but keeps any address arithmetic in bounds)
    return ntiles, jnp.minimum(tiles, n_tiles - 1).astype(jnp.int32)


def _chunk_tables_lanes(ids_p, src_p, mask_i, gchg_iq):
    """Laned scalar-prefetch tables. ``gchg_iq``: (v_pad, Q) int32 per-lane
    frontier. The chunk-skip bit is the OR across lanes — a chunk is dead
    only when no lane has an active source in it; the per-lane active-edge
    counts (the Fig-6 message counters, one per query) ride along, as do
    the OR-across-lanes per-edge active rows for the tiled tile lists."""
    e_pad = ids_p.shape[0]
    idc = ids_p.reshape(e_pad // EBLK, EBLK)
    valid = mask_i.reshape(e_pad // EBLK, EBLK) > 0
    chunk_lo = jnp.where(valid, idc, jnp.iinfo(jnp.int32).max).min(axis=1)
    chunk_hi = jnp.where(valid, idc, -1).max(axis=1)
    src_act = jnp.where(
        valid[..., None],
        jnp.take(gchg_iq, src_p.reshape(valid.shape), axis=0), 0)
    chunk_act = src_act.max(axis=(1, 2)).astype(jnp.int32)
    return (chunk_lo, chunk_hi, chunk_act, src_act.sum(axis=(0, 1)),
            src_act.max(axis=2))


# --------------------------------------------------------------------------
# worklist planning (host side)
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class Worklist:
    """A planned sparse launch: the live (i, j) cell list plus, for the
    tiled path, the per-cell dst-filtered tile/slot/fetch schedule.

    Registered as a pytree so drivers can pass a fresh per-round plan
    through one jitted round function — the arrays are leaves (jit
    retraces only when the padded length bucket changes), the residency
    decision (``path``/``vblk``) is static aux data.  ``nlive`` rides as
    a (1,) int32 array for the same reason."""

    def __init__(self, wl_i, wl_j, nlive, cell_ntiles=None, cell_tile=None,
                 cell_slot=None, cell_fetch=None, *, path="pinned",
                 vblk=None):
        self.wl_i = wl_i
        self.wl_j = wl_j
        self.nlive = nlive
        self.cell_ntiles = cell_ntiles
        self.cell_tile = cell_tile
        self.cell_slot = cell_slot
        self.cell_fetch = cell_fetch
        self.path = path
        self.vblk = vblk

    @property
    def l_pad(self) -> int:
        return self.wl_i.shape[0]

    def tree_flatten(self):
        return ((self.wl_i, self.wl_j, self.nlive, self.cell_ntiles,
                 self.cell_tile, self.cell_slot, self.cell_fetch),
                (self.path, self.vblk))

    @classmethod
    def tree_unflatten(cls, aux, children):
        path, vblk = aux
        return cls(*children, path=path, vblk=vblk)


class WorklistInfo(typing.NamedTuple):
    """Host-side accounting of one plan (the ``fused_grid_cells`` mirror
    for worklist launches; never crosses into jit)."""

    cells: int           # live cells after the dst-range empty-cell drop
    launched: int        # padded 1-D grid length
    dense_live: int      # what the dense grid's two-level skip would run
    tile_dmas: int       # DMAs the 2-slot reuse schedule actually issues
    tile_needed: int     # tile visits before reuse (the no-reuse count)
    dma_bytes: int
    smem_table_bytes: int


def _wl_pad_len(nlive: int, pad_to: int = WL_PAD) -> int:
    return max(pad_to, 1 << max(nlive - 1, 0).bit_length())


class WorklistPlanner:
    """Precomputes the frontier-independent parts of worklist planning
    for one launch shape (one edge set + segment count [+ vblk]), so
    per-round plans only pay the frontier-dependent work.

    ``edge_dst``/``edge_mask``/``edge_src`` may be (S, E_max) stacked or
    flat — flattened exactly as the kernels flatten them; ``num_slots``
    (the value-table height) sizes the slot tiling when ``vblk`` is
    given.  ``plan(gchg)`` returns (Worklist, WorklistInfo); for laned
    launches pass the OR-across-lanes frontier."""

    def __init__(self, edge_dst, edge_mask, edge_src, num_segments: int,
                 *, num_slots: int | None = None, path: str = "pinned",
                 vblk: int | None = None, lane_width: int = 1,
                 smem_budget_bytes: int | None = None):
        ids = np.asarray(edge_dst).reshape(-1)
        mask = np.asarray(edge_mask).reshape(-1)
        srcs = np.asarray(edge_src).reshape(-1)
        e = ids.shape[0]
        e_pad = _round_up(e, EBLK)
        self.num_segments = int(num_segments)
        self.s_pad = _round_up(num_segments, SBLK)
        self.n_i = self.s_pad // SBLK
        self.n_chunks = e_pad // EBLK
        self.path = path
        self.vblk = int(vblk) if vblk is not None else None
        self.lane_width = int(lane_width)
        self.smem_budget_bytes = smem_budget_bytes
        self._smem_warned = False

        idc = np.zeros(e_pad, np.int64)
        idc[:e] = ids
        mkc = np.zeros(e_pad, bool)
        mkc[:e] = mask
        srcc = np.zeros(e_pad, np.int64)
        srcc[:e] = srcs
        self.ids = idc.reshape(self.n_chunks, EBLK)
        self.mask = mkc.reshape(self.n_chunks, EBLK)
        self.srcs = srcc.reshape(self.n_chunks, EBLK)
        lo = np.where(self.mask, self.ids, np.iinfo(np.int64).max).min(axis=1)
        hi = np.where(self.mask, self.ids, -1).max(axis=1)
        seg0 = np.arange(self.n_i)[:, None] * SBLK
        self.intersects = (hi[None, :] >= seg0) & (lo[None, :] < seg0 + SBLK)
        self.blk_of = self.ids // SBLK           # dst block of each edge
        if self.path == "tiled":
            if self.vblk is None:
                raise ValueError("tiled worklist planning needs vblk")
            v_pad = _round_up(num_slots if num_slots is not None
                              else int(srcc.max(initial=0)) + 1, self.vblk)
            self.n_tiles = v_pad // self.vblk
            self.t_max = min(self.n_tiles, EBLK)
            self.tile_of = self.srcs // self.vblk
        else:
            self.t_max = 0

    @property
    def total_cells(self) -> int:
        return self.n_i * self.n_chunks

    def _live_map(self, gchg):
        gchg = np.asarray(gchg).reshape(-1)
        act = self.mask & gchg[self.srcs]        # (n_chunks, EBLK)
        live = self.intersects & act.any(axis=1)[None, :]
        return act, live

    def live_fraction(self, gchg) -> float:
        """Fraction of the dense grid the two-level skip would execute —
        the signal ``grid_mode='auto'`` keys the dense/worklist choice on."""
        _, live = self._live_map(gchg)
        return live.sum() / max(self.total_cells, 1)

    def dense_mirror(self, gchg) -> dict:
        """Mirror of the DENSE grid's launch for this planner's edge set:
        live cells under the two-level skip, and — on the tiled path —
        the per-chunk tile-list DMA schedule (every live (i, j) cell
        fetches its chunk's distinct active-source tiles), matching
        ``fused_grid_cells``'s ``fused_live``/``fused_tile_dmas``/
        ``dma_bytes`` columns exactly.  The flight recorder uses this for
        rounds that kept the dense grid (grid_mode='dense', or 'auto'
        above the live-fraction threshold)."""
        act, live = self._live_map(gchg)
        out = {"cells": int(live.sum()), "launched": self.total_cells,
               "tile_dmas": 0, "dma_bytes": 0}
        if self.path == "tiled":
            # distinct tiles per chunk among frontier-active edges: the
            # WorklistPlanner.plan first-occurrence trick, per chunk row
            t = np.sort(np.where(act, self.tile_of, self.n_tiles), axis=1)
            first = np.concatenate(
                [np.ones((t.shape[0], 1), bool), t[:, 1:] != t[:, :-1]],
                axis=1)
            ntiles = (first & (t < self.n_tiles)).sum(axis=1)
            out["tile_dmas"] = int((live * ntiles[None, :]).sum())
            out["dma_bytes"] = out["tile_dmas"] * self.vblk \
                * self.lane_width * 4
        return out

    def plan(self, gchg, pad_to: int = WL_PAD, dst_filter: bool = True,
             max_live_fraction: float | None = None):
        """Plan one round's launch from the (V,) bool frontier.

        j-major cell order (j outer, i inner); with ``dst_filter`` a
        cell keeps only tiles of active sources whose edge's dst falls
        in its block — cells left tileless contribute nothing and are
        dropped.  Tile DMAs are scheduled against a 2-slot resident
        model (fetches alternate slots; a needed tile already resident
        is reused), exactly what ``_wl_tile_loop`` executes.

        ``max_live_fraction`` implements 'auto' cheaply: when the dense
        grid's live fraction is at/above it, return (None, None) BEFORE
        any per-cell work — a dense frontier gains nothing from the 1-D
        launch, and skipping here also skips the planner's per-cell
        cost, which is what degenerates on full frontiers.  Plans whose
        scalar-prefetch tables exceed ``smem_budget_bytes`` warn once
        per planner (the per-round cell count is frontier-dependent, so
        only the plan itself can price the worklist tables —
        ``select_kernel_path`` guards the static chunk/tile tables)."""
        act, live = self._live_map(gchg)
        dense_live = int(live.sum())
        if max_live_fraction is not None \
                and dense_live / max(self.total_cells, 1) \
                >= max_live_fraction:
            return None, None
        jj, ii = np.nonzero(live.T)              # j-major: sorted by j, then i
        if dst_filter:
            # per-cell active-and-in-block edge mask; a cell with no such
            # edge contributes only the identity — drop it outright
            sel = act[jj] & (self.blk_of[jj] == ii[:, None])
            keep = sel.any(axis=1)
            jj, ii, sel = jj[keep], ii[keep], sel[keep]
        else:
            sel = act[jj]
        nlive = int(ii.shape[0])
        l_pad = _wl_pad_len(nlive, pad_to)
        wl_i = np.zeros(l_pad, np.int32)
        wl_j = np.zeros(l_pad, np.int32)
        wl_i[:nlive] = ii
        wl_j[:nlive] = jj
        nlive_arr = np.asarray([nlive], np.int32)

        if self.path != "tiled":
            wl = Worklist(wl_i, wl_j, nlive_arr, path="pinned")
            info = WorklistInfo(
                cells=nlive, launched=l_pad, dense_live=dense_live,
                tile_dmas=0, tile_needed=0, dma_bytes=0,
                smem_table_bytes=smem_table_bytes(self.n_chunks, 0, l_pad))
            return wl, self._check_smem(info)

        t_max = self.t_max
        cell_ntiles = np.zeros(l_pad, np.int32)
        cell_tile = np.zeros((l_pad, t_max), np.int32)
        cell_slot = np.zeros((l_pad, t_max), np.int32)
        cell_fetch = np.zeros((l_pad, t_max), np.int32)
        # vectorized per-cell distinct-tile extraction: in-row sort with
        # an out-of-range sentinel on filtered edges + first-occurrence
        # flags (the _chunk_tile_tables trick, one row per live CELL) —
        # only the inherently-sequential 2-slot schedule loops in Python
        t = np.sort(np.where(sel, self.tile_of[jj], self.n_tiles), axis=1)
        first = np.concatenate(
            [np.ones((nlive, 1), bool), t[:, 1:] != t[:, :-1]], axis=1)
        is_tile = first & (t < self.n_tiles)
        cell_ntiles[:nlive] = is_tile.sum(axis=1)
        resident = [-1, -1]                      # the kernel's 2-slot scratch
        prev_slot = 1                            # first fetch lands in slot 0
        fetches = needed = 0
        for c in range(nlive):
            tiles = t[c][is_tile[c]]             # distinct, ascending
            needed += tiles.shape[0]
            for k, tile in enumerate(tiles):
                if tile == resident[0]:
                    slot, fetch = 0, 0
                elif tile == resident[1]:
                    slot, fetch = 1, 0
                else:
                    slot, fetch = 1 - prev_slot, 1
                    resident[slot] = tile
                    fetches += 1
                cell_tile[c, k] = tile
                cell_slot[c, k] = slot
                cell_fetch[c, k] = fetch
                prev_slot = slot
        wl = Worklist(wl_i, wl_j, nlive_arr, cell_ntiles, cell_tile,
                      cell_slot, cell_fetch, path="tiled", vblk=self.vblk)
        info = WorklistInfo(
            cells=nlive, launched=l_pad, dense_live=dense_live,
            tile_dmas=fetches, tile_needed=needed,
            dma_bytes=fetches * self.vblk * self.lane_width * 4,
            smem_table_bytes=smem_table_bytes(self.n_chunks, t_max, l_pad))
        return wl, self._check_smem(info)

    def _check_smem(self, info: WorklistInfo) -> WorklistInfo:
        if self.smem_budget_bytes is not None and not self._smem_warned \
                and info.smem_table_bytes > self.smem_budget_bytes:
            self._smem_warned = True
            warnings.warn(
                f"worklist scalar-prefetch tables ({info.launched} cells, "
                f"{self.n_chunks} chunks, t_max={self.t_max}) weigh "
                f"{info.smem_table_bytes} bytes — over smem_budget_bytes="
                f"{self.smem_budget_bytes}; prefer grid_mode='auto' (dense "
                "frontiers keep the dense grid) or a wider vblk",
                stacklevel=3)
        return info


def plan_worklist(edge_dst, edge_mask, edge_src, gchg, num_segments: int,
                  *, num_slots=None, path="pinned", vblk=None,
                  lane_width: int = 1, pad_to: int = WL_PAD,
                  dst_filter: bool = True):
    """One-shot worklist plan (see ``WorklistPlanner`` for the reusable
    form drivers amortize across rounds).  ``gchg`` is the (V,) frontier
    (OR across lanes for laned launches); it also sizes the slot table
    unless ``num_slots`` overrides."""
    if num_slots is None:
        num_slots = np.asarray(gchg).reshape(-1).shape[0]
    planner = WorklistPlanner(
        edge_dst, edge_mask, edge_src, num_segments, num_slots=num_slots,
        path=path, vblk=vblk, lane_width=lane_width)
    return planner.plan(gchg, pad_to=pad_to, dst_filter=dst_filter)


# --------------------------------------------------------------------------
# device-side worklist compaction (grid_mode='device_worklist')
# --------------------------------------------------------------------------
# The traced twin of WorklistPlanner.plan: the live-cell list is built
# from the same jnp chunk tables the dense kernels prefetch, compacted
# j-major by a cumsum-scatter, and fed to the UNCHANGED worklist kernels
# as scalar-prefetch operands (which are ordinary pallas_call inputs, so
# traced values are fine — only host planning demands concreteness).
# The launch length is the pow2-padded FULL cell grid, a static shape,
# so whole fixpoints run inside one `lax.while_loop` / `shard_map` trace
# with the tail masked by the kernels' `c < nlive` guard.  The device
# list applies no dst filter and no cross-cell tile reuse (both are
# inherently sequential host passes), so its exact host oracle is
# ``WorklistPlanner.plan(gchg, dst_filter=False)``: cells == the dense
# grid's live count, DMAs == ``tile_needed`` (the no-reuse schedule).


def device_worklist_pad(num_edges: int, num_segments: int) -> int:
    """Static 1-D launch length of the device-compacted worklist: the
    pow2-padded full (i, j) cell grid.  Round-invariant by construction,
    so a whole fixpoint traces once."""
    n_i = _round_up(num_segments, SBLK) // SBLK
    n_chunks = _round_up(num_edges, EBLK) // EBLK
    return _wl_pad_len(n_i * n_chunks)


def _compact_live_cells(chunk_lo, chunk_hi, chunk_act, n_i: int,
                        l_pad: int):
    """Cumsum-scatter frontier compaction: the (n_i, n_chunks) live-cell
    matrix (the dense grid's two-level skip), flattened j-major — the
    exact cell order ``WorklistPlanner.plan`` emits via
    ``np.nonzero(live.T)`` — into fixed-shape ``wl_i``/``wl_j`` plus the
    (1,) live count.  Dead cells scatter out of bounds and are dropped;
    the padded tail keeps index 0 (cell (0, 0)), never executed."""
    n_chunks = chunk_lo.shape[0]
    seg0 = jnp.arange(n_i, dtype=jnp.int32)[:, None] * SBLK
    intersects = (chunk_hi[None, :] >= seg0) & (chunk_lo[None, :]
                                                < seg0 + SBLK)
    live = (intersects & (chunk_act[None, :] > 0)).T.reshape(-1)
    k = jnp.arange(n_chunks * n_i, dtype=jnp.int32)
    pos = jnp.cumsum(live.astype(jnp.int32)) - 1
    idx = jnp.where(live, pos, l_pad)
    wl_i = jnp.zeros((l_pad,), jnp.int32).at[idx].set(k % n_i,
                                                      mode="drop")
    wl_j = jnp.zeros((l_pad,), jnp.int32).at[idx].set(k // n_i,
                                                      mode="drop")
    nlive = live.sum(dtype=jnp.int32).reshape(1)
    return wl_i, wl_j, nlive


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "l_pad", "vblk", "num_slots"))
def _device_worklist_arrays(gchg, edge_src, edge_mask, edge_dst,
                            num_segments, l_pad, vblk=None,
                            num_slots=None):
    """Jitted device-worklist builder.  ``gchg`` may be (V,) or laned
    (V, Q) — laned frontiers are OR'd across lanes exactly as the host
    planner plans them.  With ``vblk`` also returns the per-cell tile
    tables for the tiled kernels: each cell fetches its CHUNK's distinct
    active-source tiles (the dense mirror's per-chunk lists), slots
    alternating per position so the double-buffered prefetch stays safe,
    every tile fetched (no cross-cell reuse)."""
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    n_i = _round_up(num_segments, SBLK) // SBLK
    gchg_i = jnp.asarray(gchg)
    if gchg_i.ndim == 2:
        gchg_i = gchg_i.any(axis=-1)
    gchg_i = gchg_i.astype(jnp.int32)
    ids_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_dst.astype(jnp.int32))
    src_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_src.astype(jnp.int32))
    mask_i = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_mask.astype(jnp.int32))
    chunk_lo, chunk_hi, chunk_act, _, src_act = _chunk_tables(
        ids_p, src_p, mask_i, gchg_i)
    wl_i, wl_j, nlive = _compact_live_cells(chunk_lo, chunk_hi, chunk_act,
                                            n_i, l_pad)
    if vblk is None:
        return wl_i, wl_j, nlive
    v_pad = _round_up(num_slots, vblk)
    ntiles, tiles = _chunk_tile_tables(src_p, src_act, v_pad, vblk)
    t_max = tiles.shape[1]
    cell_ntiles = jnp.take(ntiles, wl_j)
    cell_tile = jnp.take(tiles, wl_j, axis=0)
    tpos = jnp.arange(t_max, dtype=jnp.int32)[None, :]
    cell_slot = jnp.broadcast_to(tpos % 2, cell_tile.shape)
    cell_fetch = jnp.ones(cell_tile.shape, jnp.int32)
    return (wl_i, wl_j, nlive, cell_ntiles, cell_tile, cell_slot,
            cell_fetch)


def build_device_worklist(gchg, edge_src, edge_mask, edge_dst,
                          num_segments: int, path: str, vblk, num_slots):
    """The ``grid_mode='device_worklist'`` plan: a :class:`Worklist`
    whose leaves are traced device arrays — works under jit/shard_map,
    where host planning (``grid_mode='worklist'``) cannot."""
    l_pad = device_worklist_pad(edge_src.shape[0], num_segments)
    if path == "tiled":
        (wl_i, wl_j, nlive, cell_ntiles, cell_tile, cell_slot,
         cell_fetch) = _device_worklist_arrays(
            gchg, edge_src, edge_mask, edge_dst,
            num_segments=num_segments, l_pad=l_pad, vblk=vblk,
            num_slots=num_slots)
        return Worklist(wl_i, wl_j, nlive, cell_ntiles, cell_tile,
                        cell_slot, cell_fetch, path="tiled", vblk=vblk)
    wl_i, wl_j, nlive = _device_worklist_arrays(
        gchg, edge_src, edge_mask, edge_dst, num_segments=num_segments,
        l_pad=l_pad)
    return Worklist(wl_i, wl_j, nlive, path="pinned")


# --------------------------------------------------------------------------
# single-query launches
# --------------------------------------------------------------------------

def _pad_edges(edge_src, edge_w, edge_mask, edge_dst, e_pad: int):
    e = edge_src.shape[0]
    ids_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_dst.astype(jnp.int32))
    src_p = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_src.astype(jnp.int32))
    w_p = jnp.zeros((e_pad,), edge_w.dtype).at[:e].set(edge_w)
    mask_i = jnp.zeros((e_pad,), jnp.int32).at[:e].set(
        edge_mask.astype(jnp.int32))
    return ids_p, src_p, w_p, mask_i


def _masked_value_tables(gval, gchg, identity, v_pad: int, q_pad=None):
    """Frontier masking folded into the value table (absorbing identity):
    relax(identity, w) == identity for all supported semirings, so an
    inactive source can never contribute — bit-identical to the oracle's
    explicit where(active, ...) mask, one fewer in-cell gather.  Pads
    slots (and, when ``q_pad`` is given, lanes — tail lanes stay
    frontier-dead identity columns) to the launch shape."""
    gval_m = jnp.where(gchg, gval, jnp.asarray(identity, gval.dtype))
    if q_pad is None:
        v = gval.shape[0]
        gval_p = jnp.full((v_pad,), identity, gval.dtype).at[:v].set(gval_m)
        gchg_p = jnp.zeros((v_pad,), jnp.int32).at[:v].set(
            gchg.astype(jnp.int32))
    else:
        v, q = gval.shape
        gval_p = jnp.full((v_pad, q_pad), identity, gval.dtype) \
            .at[:v, :q].set(gval_m)
        gchg_p = jnp.zeros((v_pad, q_pad), jnp.int32).at[:v, :q].set(
            gchg.astype(jnp.int32))
    return gval_p, gchg_p


def _pack_result(raw, slicer, msg_count, with_count, with_debug):
    """Launch epilogue shared by all four wrappers: split off the debug
    counters, strip the padding (``slicer``), then return out /
    (out, count) / (out, dbg) / (out, count, dbg)."""
    out, dbg = raw if with_debug else (raw, None)
    res = (slicer(out),)
    if with_count:
        res += (msg_count,)
    if with_debug:
        res += (dbg,)
    return res[0] if len(res) == 1 else res


_DBG_SPEC = pl.BlockSpec(memory_space=pltpu.SMEM)
_DBG_SHAPE = jax.ShapeDtypeStruct((2,), jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug"))
def _fused_pinned(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                  num_segments, relax_kind, kind, interpret, with_count,
                  with_debug):
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(gval.shape[0], 128)
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)

    chunk_lo, chunk_hi, chunk_act, msg_count, _ = _chunk_tables(
        ids_p, src_p, mask_i, gchg_p)

    grid = (s_pad // SBLK, e_pad // EBLK)
    edge_spec = pl.BlockSpec((EBLK,), lambda i, j, lo, hi, act: (j,))
    full_spec = pl.BlockSpec((v_pad,), lambda i, j, lo, hi, act: (0,))
    out_spec = pl.BlockSpec((SBLK,), lambda i, j, lo, hi, act: (i,))
    out_shape = jax.ShapeDtypeStruct((s_pad,), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel, relax_kind=relax_kind, kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      full_spec],
            out_specs=out_spec,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(chunk_lo, chunk_hi, chunk_act,
      ids_p, src_p, w_p, mask_i, gval_p)
    return _pack_result(out, lambda o: o[:num_segments], msg_count,
                        with_count, with_debug)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug", "vblk"))
def _fused_tiled(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                 num_segments, relax_kind, kind, interpret, with_count,
                 with_debug, vblk):
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(gval.shape[0], vblk)   # uniform vblk-wide tiles
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)

    chunk_lo, chunk_hi, chunk_act, msg_count, src_act = _chunk_tables(
        ids_p, src_p, mask_i, gchg_p)
    chunk_ntiles, chunk_tiles = _chunk_tile_tables(
        src_p, src_act, v_pad, vblk)

    grid = (s_pad // SBLK, e_pad // EBLK)
    edge_spec = pl.BlockSpec((EBLK,), lambda i, j, *sc: (j,))
    out_spec = pl.BlockSpec((SBLK,), lambda i, j, *sc: (i,))
    out_shape = jax.ShapeDtypeStruct((s_pad,), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_tiled, relax_kind=relax_kind, kind=kind,
                          vblk=vblk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=out_spec,
            scratch_shapes=[pltpu.VMEM((2, vblk), gval.dtype),
                            pltpu.SemaphoreType.DMA((2,))],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(chunk_lo, chunk_hi, chunk_act, chunk_ntiles, chunk_tiles,
      ids_p, src_p, w_p, mask_i, gval_p)
    return _pack_result(out, lambda o: o[:num_segments], msg_count,
                        with_count, with_debug)


def _scatter_partials(partials, wl_i, n_i, kind, identity):
    """Fold the (l_pad, SBLK[, Q]) per-cell worklist partials into the
    (n_i, SBLK[, Q]) blocked inbox.  Dead and padded cells hold the
    combine identity, so scattering every row is exact."""
    init = jnp.full((n_i,) + partials.shape[1:], identity, partials.dtype)
    if kind == "min":
        return init.at[wl_i].min(partials)
    return init.at[wl_i].add(partials)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug"))
def _fused_pinned_wl(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                     wl_i, wl_j, nlive, num_segments, relax_kind, kind,
                     interpret, with_count, with_debug):
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(gval.shape[0], 128)
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)
    msg_count = (mask_i * jnp.take(gchg_p, src_p)).sum()

    l_pad = wl_i.shape[0]
    edge_spec = pl.BlockSpec((EBLK,), lambda c, wi, wj, nl: (wj[c],))
    full_spec = pl.BlockSpec((v_pad,), lambda c, *sc: (0,))
    out_spec = pl.BlockSpec((1, SBLK), lambda c, wi, wj, nl: (c, 0))
    out_shape = jax.ShapeDtypeStruct((l_pad, SBLK), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_wl, relax_kind=relax_kind, kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(l_pad,),
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      full_spec],
            out_specs=out_spec,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(wl_i, wl_j, nlive, ids_p, src_p, w_p, mask_i, gval_p)

    def slicer(partials):
        folded = _scatter_partials(partials, wl_i, s_pad // SBLK, kind,
                                   identity)
        return folded.reshape(s_pad)[:num_segments]

    return _pack_result(out, slicer, msg_count, with_count, with_debug)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug", "vblk"))
def _fused_tiled_wl(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                    wl_i, wl_j, nlive, cell_ntiles, cell_tile, cell_slot,
                    cell_fetch, num_segments, relax_kind, kind, interpret,
                    with_count, with_debug, vblk):
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(gval.shape[0], vblk)   # uniform vblk-wide tiles
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)
    msg_count = (mask_i * jnp.take(gchg_p, src_p)).sum()

    l_pad = wl_i.shape[0]
    t_max = cell_tile.shape[1]
    edge_spec = pl.BlockSpec((EBLK,), lambda c, wi, wj, *sc: (wj[c],))
    out_spec = pl.BlockSpec((1, SBLK), lambda c, wi, wj, *sc: (c, 0))
    out_shape = jax.ShapeDtypeStruct((l_pad, SBLK), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_wl_tiled, relax_kind=relax_kind,
                          kind=kind, vblk=vblk, t_max=t_max),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=7,
            grid=(l_pad,),
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=out_spec,
            scratch_shapes=[pltpu.VMEM((2, vblk), gval.dtype),
                            pltpu.SemaphoreType.DMA((2,))],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(wl_i, wl_j, nlive, cell_ntiles, cell_tile, cell_slot, cell_fetch,
      ids_p, src_p, w_p, mask_i, gval_p)

    def slicer(partials):
        folded = _scatter_partials(partials, wl_i, s_pad // SBLK, kind,
                                   identity)
        return folded.reshape(s_pad)[:num_segments]

    return _pack_result(out, slicer, msg_count, with_count, with_debug)


def _require_concrete(x, what: str):
    if isinstance(x, jax.core.Tracer):
        raise ValueError(
            f"grid_mode='worklist' plans the launch host-side, so {what} "
            "must be concrete — under jit, build the plan outside the "
            "trace (WorklistPlanner.plan) and pass it via worklist=")


def _launch_worklist(gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
                     num_segments, path, vblk, lane_width=1):
    """Concrete-input convenience: plan the worklist at launch time (the
    differential tests drive this; round drivers pre-plan instead)."""
    _require_concrete(gchg, "the frontier")
    gchg_np = np.asarray(gchg)
    if gchg_np.ndim == 2:                        # laned: OR across lanes
        gchg_np = gchg_np.any(axis=-1)
    wl, _ = plan_worklist(
        np.asarray(edge_dst), np.asarray(edge_mask), np.asarray(edge_src),
        gchg_np, num_segments, num_slots=gval.shape[0], path=path,
        vblk=vblk, lane_width=lane_width)
    return wl


def fused_relax_reduce_pallas(gval, gchg, edge_src, edge_w, edge_mask,
                              edge_dst, num_segments: int, relax_kind: str,
                              kind: str, interpret: bool = True,
                              with_count: bool = False,
                              vmem_budget_bytes=None, path=None, vblk=None,
                              with_debug: bool = False,
                              grid_mode: str = "dense", worklist=None,
                              smem_budget_bytes=None):
    """Fused gather/relax/mask/segment-reduce.

    gval: (V,) f32 vertex (replica-slot) values; gchg: (V,) bool changed
    flags (the frontier); edge_src/edge_dst: (E,) int32 into [0, V) /
    [0, num_segments); edge_w: (E,) f32; edge_mask: (E,) bool (False on
    padding). Returns the (num_segments,) inbox partial — empty segments
    hold the combine identity.  ``with_count=True`` appends the
    active-edge count (a byproduct of the frontier bitmap gather, not an
    extra pass); ``with_debug=True`` appends the kernel-side (2,) int32
    [executed grid cells, issued value-tile DMAs] counters that
    ``fused_grid_cells`` mirrors on the host.  Edges should be sorted by
    ``edge_dst`` for the range skip to bite; correctness never depends
    on the sort.

    Residency is selected by ``select_kernel_path`` from the slot count
    against ``vmem_budget_bytes`` (pinned when the table fits, else
    HBM-tiled with per-cell double-buffered DMA); ``path``/``vblk``
    force it.  Both paths are bit-identical for min semirings.

    ``grid_mode='worklist'`` (or an explicit ``worklist=`` plan) swaps
    the dense early-exit grid for the 1-D live-cell worklist launch —
    the launch count, and on the tiled path the dst-filtered reuse-aware
    DMA schedule, scale with the live frontier.  Bit-identical to the
    dense grid for min semirings (sum differs only by the partial
    scatter's reassociation).  ``smem_budget_bytes`` arms the
    scalar-prefetch table guard in ``select_kernel_path``.
    """
    _check_pair(relax_kind, kind)
    e_pad = _round_up(edge_src.shape[0], EBLK)
    path, vblk = select_kernel_path(
        gval.shape[0], 1, vmem_budget_bytes, path=path, vblk=vblk,
        n_chunks=e_pad // EBLK, smem_budget_bytes=smem_budget_bytes)
    if worklist is None and grid_mode == "worklist":
        worklist = _launch_worklist(
            gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
            num_segments, path, vblk)
    elif worklist is None and grid_mode == "device_worklist":
        worklist = build_device_worklist(
            gchg, edge_src, edge_mask, edge_dst, num_segments, path, vblk,
            gval.shape[0])
    args = (gval, gchg, edge_src, edge_w, edge_mask, edge_dst)
    if worklist is not None:
        wl = worklist
        if wl.path == "pinned":
            return _fused_pinned_wl(
                *args, jnp.asarray(wl.wl_i), jnp.asarray(wl.wl_j),
                jnp.asarray(wl.nlive), num_segments=num_segments,
                relax_kind=relax_kind, kind=kind, interpret=interpret,
                with_count=with_count, with_debug=with_debug)
        return _fused_tiled_wl(
            *args, jnp.asarray(wl.wl_i), jnp.asarray(wl.wl_j),
            jnp.asarray(wl.nlive), jnp.asarray(wl.cell_ntiles),
            jnp.asarray(wl.cell_tile), jnp.asarray(wl.cell_slot),
            jnp.asarray(wl.cell_fetch), num_segments=num_segments,
            relax_kind=relax_kind, kind=kind, interpret=interpret,
            with_count=with_count, with_debug=with_debug, vblk=wl.vblk)
    if path == "pinned":
        return _fused_pinned(*args, num_segments=num_segments,
                             relax_kind=relax_kind, kind=kind,
                             interpret=interpret, with_count=with_count,
                             with_debug=with_debug)
    return _fused_tiled(*args, num_segments=num_segments,
                        relax_kind=relax_kind, kind=kind,
                        interpret=interpret, with_count=with_count,
                        with_debug=with_debug, vblk=vblk)


# --------------------------------------------------------------------------
# lane-batched launches
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug", "q_pad"))
def _fused_lanes_pinned(gval, gchg, lane_unitw, edge_src, edge_w, edge_mask,
                        edge_dst, num_segments, relax_kind, kind, interpret,
                        with_count, with_debug, q_pad):
    v, q = gval.shape
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(v, 128)
    identity = jnp.inf if kind == "min" else 0.0

    # lane padding: tail lanes hold the identity with an all-False
    # frontier, so they relax to the identity everywhere and are sliced
    # off the output — masked tail lanes, bit-identical to no padding
    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad,
                                          q_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)
    unitw = jnp.zeros((q_pad,), jnp.int32).at[:q].set(
        jnp.asarray(lane_unitw, jnp.int32).reshape(q))

    chunk_lo, chunk_hi, chunk_act, msg_counts, _ = _chunk_tables_lanes(
        ids_p, src_p, mask_i, gchg_p)

    grid = (s_pad // SBLK, e_pad // EBLK)
    edge_spec = pl.BlockSpec((EBLK,), lambda i, j, lo, hi, act: (j,))
    lane_spec = pl.BlockSpec((q_pad,), lambda i, j, lo, hi, act: (0,))
    full_spec = pl.BlockSpec((v_pad, q_pad),
                             lambda i, j, lo, hi, act: (0, 0))
    out_spec = pl.BlockSpec((SBLK, q_pad), lambda i, j, lo, hi, act: (i, 0))
    out_shape = jax.ShapeDtypeStruct((s_pad, q_pad), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_lanes, relax_kind=relax_kind, kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      lane_spec, full_spec],
            out_specs=out_spec,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(chunk_lo, chunk_hi, chunk_act,
      ids_p, src_p, w_p, mask_i, unitw, gval_p)
    return _pack_result(out, lambda o: o[:num_segments, :q],
                        msg_counts[:q], with_count, with_debug)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug", "q_pad", "vblk"))
def _fused_lanes_tiled(gval, gchg, lane_unitw, edge_src, edge_w, edge_mask,
                       edge_dst, num_segments, relax_kind, kind, interpret,
                       with_count, with_debug, q_pad, vblk):
    v, q = gval.shape
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(v, vblk)
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad,
                                          q_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)
    unitw = jnp.zeros((q_pad,), jnp.int32).at[:q].set(
        jnp.asarray(lane_unitw, jnp.int32).reshape(q))

    chunk_lo, chunk_hi, chunk_act, msg_counts, src_act = \
        _chunk_tables_lanes(ids_p, src_p, mask_i, gchg_p)
    chunk_ntiles, chunk_tiles = _chunk_tile_tables(
        src_p, src_act, v_pad, vblk)

    grid = (s_pad // SBLK, e_pad // EBLK)
    edge_spec = pl.BlockSpec((EBLK,), lambda i, j, *sc: (j,))
    lane_spec = pl.BlockSpec((q_pad,), lambda i, j, *sc: (0,))
    out_spec = pl.BlockSpec((SBLK, q_pad), lambda i, j, *sc: (i, 0))
    out_shape = jax.ShapeDtypeStruct((s_pad, q_pad), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_tiled_lanes, relax_kind=relax_kind,
                          kind=kind, vblk=vblk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=grid,
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      lane_spec, pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=out_spec,
            scratch_shapes=[pltpu.VMEM((2, vblk, q_pad), gval.dtype),
                            pltpu.SemaphoreType.DMA((2,))],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(chunk_lo, chunk_hi, chunk_act, chunk_ntiles, chunk_tiles,
      ids_p, src_p, w_p, mask_i, unitw, gval_p)
    return _pack_result(out, lambda o: o[:num_segments, :q],
                        msg_counts[:q], with_count, with_debug)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug", "q_pad"))
def _fused_lanes_pinned_wl(gval, gchg, lane_unitw, edge_src, edge_w,
                           edge_mask, edge_dst, wl_i, wl_j, nlive,
                           num_segments, relax_kind, kind, interpret,
                           with_count, with_debug, q_pad):
    v, q = gval.shape
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(v, 128)
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad,
                                          q_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)
    unitw = jnp.zeros((q_pad,), jnp.int32).at[:q].set(
        jnp.asarray(lane_unitw, jnp.int32).reshape(q))
    msg_counts = (mask_i[:, None] * jnp.take(gchg_p, src_p, axis=0)) \
        .sum(axis=0)

    l_pad = wl_i.shape[0]
    edge_spec = pl.BlockSpec((EBLK,), lambda c, wi, wj, nl: (wj[c],))
    lane_spec = pl.BlockSpec((q_pad,), lambda c, *sc: (0,))
    full_spec = pl.BlockSpec((v_pad, q_pad), lambda c, *sc: (0, 0))
    out_spec = pl.BlockSpec((1, SBLK, q_pad),
                            lambda c, wi, wj, nl: (c, 0, 0))
    out_shape = jax.ShapeDtypeStruct((l_pad, SBLK, q_pad), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_wl_lanes, relax_kind=relax_kind,
                          kind=kind),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(l_pad,),
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      lane_spec, full_spec],
            out_specs=out_spec,
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(wl_i, wl_j, nlive, ids_p, src_p, w_p, mask_i, unitw, gval_p)

    def slicer(partials):
        folded = _scatter_partials(partials, wl_i, s_pad // SBLK, kind,
                                   identity)
        return folded.reshape(s_pad, q_pad)[:num_segments, :q]

    return _pack_result(out, slicer, msg_counts[:q], with_count,
                        with_debug)


@functools.partial(
    jax.jit,
    static_argnames=("num_segments", "relax_kind", "kind", "interpret",
                     "with_count", "with_debug", "q_pad", "vblk"))
def _fused_lanes_tiled_wl(gval, gchg, lane_unitw, edge_src, edge_w,
                          edge_mask, edge_dst, wl_i, wl_j, nlive,
                          cell_ntiles, cell_tile, cell_slot, cell_fetch,
                          num_segments, relax_kind, kind, interpret,
                          with_count, with_debug, q_pad, vblk):
    v, q = gval.shape
    e = edge_src.shape[0]
    e_pad = _round_up(e, EBLK)
    s_pad = _round_up(num_segments, SBLK)
    v_pad = _round_up(v, vblk)
    identity = jnp.inf if kind == "min" else 0.0

    gval_p, gchg_p = _masked_value_tables(gval, gchg, identity, v_pad,
                                          q_pad)
    ids_p, src_p, w_p, mask_i = _pad_edges(
        edge_src, edge_w, edge_mask, edge_dst, e_pad)
    unitw = jnp.zeros((q_pad,), jnp.int32).at[:q].set(
        jnp.asarray(lane_unitw, jnp.int32).reshape(q))
    msg_counts = (mask_i[:, None] * jnp.take(gchg_p, src_p, axis=0)) \
        .sum(axis=0)

    l_pad = wl_i.shape[0]
    t_max = cell_tile.shape[1]
    edge_spec = pl.BlockSpec((EBLK,), lambda c, wi, wj, *sc: (wj[c],))
    lane_spec = pl.BlockSpec((q_pad,), lambda c, *sc: (0,))
    out_spec = pl.BlockSpec((1, SBLK, q_pad),
                            lambda c, wi, wj, *sc: (c, 0, 0))
    out_shape = jax.ShapeDtypeStruct((l_pad, SBLK, q_pad), gval.dtype)
    if with_debug:
        out_spec = [out_spec, _DBG_SPEC]
        out_shape = [out_shape, _DBG_SHAPE]
    out = pl.pallas_call(
        functools.partial(_kernel_wl_tiled_lanes, relax_kind=relax_kind,
                          kind=kind, vblk=vblk, t_max=t_max),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=7,
            grid=(l_pad,),
            in_specs=[edge_spec, edge_spec, edge_spec, edge_spec,
                      lane_spec, pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=out_spec,
            scratch_shapes=[pltpu.VMEM((2, vblk, q_pad), gval.dtype),
                            pltpu.SemaphoreType.DMA((2,))],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(wl_i, wl_j, nlive, cell_ntiles, cell_tile, cell_slot, cell_fetch,
      ids_p, src_p, w_p, mask_i, unitw, gval_p)

    def slicer(partials):
        folded = _scatter_partials(partials, wl_i, s_pad // SBLK, kind,
                                   identity)
        return folded.reshape(s_pad, q_pad)[:num_segments, :q]

    return _pack_result(out, slicer, msg_counts[:q], with_count,
                        with_debug)


def fused_relax_reduce_lanes_pallas(gval, gchg, lane_unitw, edge_src, edge_w,
                                    edge_mask, edge_dst, num_segments: int,
                                    relax_kind: str, kind: str,
                                    interpret: bool = True,
                                    with_count: bool = False,
                                    vmem_budget_bytes=None, path=None,
                                    vblk=None, lane_tile=None,
                                    with_debug: bool = False,
                                    grid_mode: str = "dense",
                                    worklist=None, smem_budget_bytes=None):
    """Lane-batched fused gather/relax/mask/segment-reduce (ISSUE 2).

    The single-query kernel grown a trailing query-lane axis ``Q``:
    ``gval``/``gchg`` are (V, Q) — per-lane values and per-lane frontiers
    over one shared edge structure — and the result is the (num_segments,
    Q) per-lane inbox partial (plus, with ``with_count=True``, the (Q,)
    per-lane active-edge counts).  ``lane_unitw`` (Q,) only matters for
    ``relax_kind='add_w'``: lanes with a nonzero flag relax with the
    constant weight 1.0 (BFS levels) instead of the edge weight (SSSP), so
    one launch serves a mixed BFS/SSSP batch.  A converged lane has an
    all-False ``gchg`` column: its sources read as the absorbing identity,
    so it contributes nothing while live lanes keep the round busy — and
    the chunk-skip bitmap is the OR across lanes, so a grid cell is
    skipped only when its edge chunk is frontier-dead in *every* lane.

    The lane axis is padded up to the TPU lane tile (``LANE_TILE=128``
    when compiling; a sublane multiple under interpret mode — force with
    ``lane_tile=``): tail lanes carry the identity with an all-False
    frontier, so they are masked out of every reduction and sliced off
    the output — a padded batch is bit-identical to the unpadded math.
    Residency (pinned vs HBM-tiled with per-cell double-buffered DMA of
    (vblk, Q) value tiles) follows ``select_kernel_path`` on the
    lane-padded table, exactly as in the single-query kernel.
    """
    assert relax_kind in ("add_w", "mul_w"), relax_kind
    _check_pair(relax_kind, kind)
    v, q = gval.shape
    q_pad = _lane_pad(q, interpret, lane_tile)
    e_pad = _round_up(edge_src.shape[0], EBLK)
    path, vblk = select_kernel_path(
        v, q_pad, vmem_budget_bytes, path=path, vblk=vblk,
        n_chunks=e_pad // EBLK, smem_budget_bytes=smem_budget_bytes)
    if worklist is None and grid_mode == "worklist":
        worklist = _launch_worklist(
            gval, gchg, edge_src, edge_w, edge_mask, edge_dst,
            num_segments, path, vblk, lane_width=q_pad)
    elif worklist is None and grid_mode == "device_worklist":
        worklist = build_device_worklist(
            gchg, edge_src, edge_mask, edge_dst, num_segments, path, vblk,
            v)
    args = (gval, gchg, lane_unitw, edge_src, edge_w, edge_mask, edge_dst)
    if worklist is not None:
        wl = worklist
        if wl.path == "pinned":
            return _fused_lanes_pinned_wl(
                *args, jnp.asarray(wl.wl_i), jnp.asarray(wl.wl_j),
                jnp.asarray(wl.nlive), num_segments=num_segments,
                relax_kind=relax_kind, kind=kind, interpret=interpret,
                with_count=with_count, with_debug=with_debug, q_pad=q_pad)
        return _fused_lanes_tiled_wl(
            *args, jnp.asarray(wl.wl_i), jnp.asarray(wl.wl_j),
            jnp.asarray(wl.nlive), jnp.asarray(wl.cell_ntiles),
            jnp.asarray(wl.cell_tile), jnp.asarray(wl.cell_slot),
            jnp.asarray(wl.cell_fetch), num_segments=num_segments,
            relax_kind=relax_kind, kind=kind, interpret=interpret,
            with_count=with_count, with_debug=with_debug, q_pad=q_pad,
            vblk=wl.vblk)
    if path == "pinned":
        return _fused_lanes_pinned(
            *args, num_segments=num_segments, relax_kind=relax_kind,
            kind=kind, interpret=interpret, with_count=with_count,
            with_debug=with_debug, q_pad=q_pad)
    return _fused_lanes_tiled(
        *args, num_segments=num_segments, relax_kind=relax_kind, kind=kind,
        interpret=interpret, with_count=with_count, with_debug=with_debug,
        q_pad=q_pad, vblk=vblk)


# --------------------------------------------------------------------------
# host-side launch mirror (grid-cell and DMA accounting)
# --------------------------------------------------------------------------

def fused_grid_cells(edge_dst, edge_mask, edge_src, gchg,
                     num_segments: int, vblk: int | None = None,
                     lane_width: int = 1, grid_mode: str = "dense",
                     pad_to: int = WL_PAD, dst_filter: bool = True) -> dict:
    """Host-side mirror of both launch shapes for the dense exchange.

    ``fused_live``/``total_fused`` mirror THIS kernel's single flattened
    launch (edge_mask-aware per-chunk ranges + frontier bitmap);
    ``range_live``/``total_unfused`` mirror the unfused composition's S
    vmapped per-shard ``segment_combine_pallas`` launches, whose validity
    rule is positional (every in-shard slot counts, so engine padding
    edges carrying id 0 widen chunk ranges) and which has no frontier
    skip.  Edge arrays are (S, E_max) host arrays — or 1-D for a single
    flat launch; ``gchg`` is the (V,) frontier (OR across lanes when
    mirroring a laned launch).

    With ``vblk`` the dict also mirrors the tiled path's DMA plan:
    ``chunk_ntiles`` (per edge chunk, the number of distinct vblk-wide
    slot tiles its frontier-active sources touch), ``fused_tile_dmas``
    (tile fetches summed over live cells — every live (i, j) cell
    fetches its chunk's tiles), and ``dma_bytes`` (those fetches *
    vblk * lane_width * 4 bytes).  The cell/DMA *counts* must match the
    kernels' ``with_debug`` counters exactly; for the byte column of a
    laned launch, pass the lane-PADDED width (``_lane_pad`` of Q — the
    kernel DMAs (vblk, q_pad) tiles), not the logical lane count.
    """
    edge_dst = np.atleast_2d(np.asarray(edge_dst))
    edge_mask = np.atleast_2d(np.asarray(edge_mask))
    edge_src = np.atleast_2d(np.asarray(edge_src))
    gchg = np.asarray(gchg).reshape(-1)
    S, E_max = edge_dst.shape
    s_pad = -(-num_segments // SBLK) * SBLK
    seg0 = np.arange(s_pad // SBLK)[:, None] * SBLK        # (n_i, 1)

    # fused: one launch over the flattened edge stack
    e = S * E_max
    e_pad = -(-e // EBLK) * EBLK
    ids = np.zeros(e_pad, np.int64)
    ids[:e] = edge_dst.reshape(-1)
    msk = np.zeros(e_pad, bool)
    msk[:e] = edge_mask.reshape(-1)
    act = np.zeros(e_pad, bool)
    act[:e] = edge_mask.reshape(-1) & gchg[edge_src.reshape(-1)]
    srcs = np.zeros(e_pad, np.int64)
    srcs[:e] = edge_src.reshape(-1)
    idc, mkc, acc = (x.reshape(e_pad // EBLK, EBLK) for x in (ids, msk, act))
    lo = np.where(mkc, idc, np.iinfo(np.int64).max).min(axis=1)
    hi = np.where(mkc, idc, -1).max(axis=1)
    intersects = (hi[None, :] >= seg0) & (lo[None, :] < seg0 + SBLK)
    live_mat = intersects & acc.any(axis=1)[None, :]       # (n_i, n_j)
    fused_live = int(live_mat.sum())
    total_fused = int(intersects.size)

    # unfused: S per-shard launches, positional validity, range skip only
    ep = -(-E_max // EBLK) * EBLK
    ids_s = np.zeros((S, ep), np.int64)
    ids_s[:, :E_max] = edge_dst
    valid = np.zeros(ep, bool)
    valid[:E_max] = True
    idc2 = ids_s.reshape(S, ep // EBLK, EBLK)
    v2 = valid.reshape(ep // EBLK, EBLK)[None, :, :]
    lo2 = np.where(v2, idc2, np.iinfo(np.int64).max).min(axis=-1)
    hi2 = np.where(v2, idc2, -1).max(axis=-1)                # (S, n_j)
    inter2 = (hi2[:, None, :] >= seg0[None, :, :]) \
        & (lo2[:, None, :] < seg0[None, :, :] + SBLK)        # (S, n_i, n_j)
    out = {
        "total_fused": total_fused,
        "total_unfused": int(inter2.size),
        "range_live": int(inter2.sum()),
        "fused_live": fused_live,
    }
    if vblk is not None:
        # tiled-path DMA mirror: distinct source tiles per chunk among
        # frontier-active valid edges, fetched once per live (i, j) cell
        tile_of = (srcs // vblk).reshape(e_pad // EBLK, EBLK)
        ntiles = np.array([len(np.unique(t[a])) for t, a in
                           zip(tile_of, acc)], np.int64)
        tile_dmas = int((live_mat * ntiles[None, :]).sum())
        out["chunk_ntiles"] = ntiles.tolist()
        out["fused_tile_dmas"] = tile_dmas
        out["dma_bytes"] = tile_dmas * int(vblk) * int(lane_width) * 4
    if grid_mode == "worklist":
        # worklist-launch mirror: the planner is the host-side oracle —
        # cells launched (after the dst-filter empty-cell drop) and the
        # reuse-aware DMA schedule, matched EXACTLY by the worklist
        # kernels' with_debug counters
        _, info = plan_worklist(
            edge_dst, edge_mask, edge_src, gchg, num_segments,
            num_slots=gchg.shape[0],
            path="tiled" if vblk is not None else "pinned", vblk=vblk,
            lane_width=lane_width, pad_to=pad_to, dst_filter=dst_filter)
        out["wl_cells"] = info.cells
        out["wl_launched"] = info.launched
        out["wl_tile_dmas"] = info.tile_dmas
        out["wl_tile_needed"] = info.tile_needed
        out["wl_dma_bytes"] = info.dma_bytes
        out["smem_table_bytes"] = info.smem_table_bytes
    elif grid_mode == "device_worklist":
        # device-compaction mirror: no dst filter, no cross-cell reuse —
        # cells are exactly the dense grid's live count and DMAs the
        # per-chunk tile lists summed over live cells (the no-reuse
        # schedule), matched by the kernels' with_debug counters
        out["wl_cells"] = fused_live
        out["wl_launched"] = device_worklist_pad(e, num_segments)
        out["wl_tile_dmas"] = out.get("fused_tile_dmas", 0)
        out["wl_tile_needed"] = out.get("fused_tile_dmas", 0)
        out["wl_dma_bytes"] = out.get("dma_bytes", 0)
        out["smem_table_bytes"] = smem_table_bytes(
            e_pad // EBLK,
            0 if vblk is None
            else min(_round_up(int(gchg.shape[0]), vblk) // vblk, EBLK),
            out["wl_launched"])
    elif vblk is not None:
        out["smem_table_bytes"] = smem_table_bytes(
            e_pad // EBLK,
            min(_round_up(int(gchg.shape[0]), vblk) // vblk, EBLK))
    return out
