"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

The layer stack is split into ``n_stages`` contiguous groups; stage s's
parameters live on pod s (leading stage dim sharded over ``pod``).
Microbatches flow through a shard_map'd schedule: at tick t, stage s
processes microbatch t−s and hands its activation to stage s+1 via
``collective_permute`` — inter-pod traffic is exactly one activation
tensor per tick, the right shape for the sparse pod-to-pod links.

Autodiff flows through the ppermutes, so ``jax.grad`` of the pipelined
loss gives GPipe with full activation stash (1F1B scheduling is a future
refinement; the dry-run proves the collective schedule compiles on the
2×16×16 mesh).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def pipeline_apply(stage_fn, n_stages: int, n_micro: int, mesh: Mesh,
                   pod_axis: str = "pod", inner_spec: P = P()):
    """Build a pipelined fn(stacked_params, x) -> y.

    stage_fn(stage_params, x_micro) -> x_micro applies ONE stage.
    stacked_params: pytree with leading dim n_stages (sharded over pod).
    x: (n_micro, micro_batch, ...) — microbatch-major input.
    """
    assert mesh.shape[pod_axis] == n_stages

    def shard_fn(params_l, x):
        # params_l leaves: (1, ...) local stage params
        params_s = jax.tree.map(lambda p: p[0], params_l)
        stage = lax.axis_index(pod_axis)
        n_t = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        buf = jnp.zeros_like(x[0])          # current activation at this stage
        outs = jnp.zeros_like(x)            # collected at the last stage

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if valid); others take recv
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            x_in = jnp.where(stage == 0,
                             x[mb_idx],
                             buf)
            y = stage_fn(params_s, x_in)
            # pass to the next stage
            nxt = lax.ppermute(y, pod_axis, perm)
            # last stage emits microbatch t-(n_stages-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = (stage == n_stages - 1) & (t >= n_stages - 1)
            outs = lax.cond(
                emit,
                lambda o: lax.dynamic_update_index_in_dim(
                    o, y, out_idx, axis=0),
                lambda o: o,
                outs)
            return nxt, outs

        buf, outs = lax.fori_loop(0, n_t, tick, (buf, outs))
        # broadcast the last stage's outputs to every pod (loss is computed
        # replicated; cheap relative to the stage compute)
        outs = lax.ppermute(
            outs, pod_axis,
            [(n_stages - 1, i) for i in range(n_stages - 1)]) + jnp.where(
            stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return outs

    param_spec = P(pod_axis)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(param_spec, inner_spec),
        out_specs=inner_spec,
        check_rep=False,
    )
    return fn


def stage_shardings(mesh: Mesh, params_stacked, pod_axis: str = "pod"):
    spec = P(pod_axis)
    return jax.tree.map(
        lambda p: NamedSharding(mesh, spec), params_stacked)
