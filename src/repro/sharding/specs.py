"""Logical-axis sharding rules -> PartitionSpecs (DP/TP/SP/EP/FSDP).

Every parameter and annotated activation carries a tuple of *logical* axis
names. A ruleset maps logical names to the abstract roles ``dp`` / ``tp``
(or None); ``ShardCtx`` binds roles to concrete mesh axes — ``dp`` spans
``("pod", "data")`` on the multi-pod mesh, ``tp`` is ``("model",)``.

``constrain``/``spec_for`` drop any mapping that does not divide the
actual dimension (e.g. 8 KV heads on a 16-way model axis fall back to
replicated) — sharding validity is structural, never a crash.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> role ('dp' | 'tp' | None). Anything unlisted is None.
RULESETS: dict[str, dict[str, str | None]] = {
    # TP for compute-parallel dims, FSDP (dp) for the storage-heavy embed
    # dim of weights, SP for the sequence dim of activations.
    "default": {
        "vocab": "tp",
        "embed": "dp",           # FSDP storage shard of weight matrices
        "heads": "tp",
        "kv_heads": "tp",
        "mlp": "tp",
        "experts": "tp",         # EP: experts over the model axis
        "expert_mlp": None,
        "mamba_inner": "tp",
        "lstm_inner": "tp",
        # activations
        "act_batch": "dp",
        "act_seq": "tp",         # sequence parallelism at layer boundaries
        "act_embed": None,
        "act_vocab": "tp",
        "act_heads": "tp",
        "act_kv_heads": "tp",
        "act_experts": "tp",
        "act_kv_seq": None,
        "act_mlp": "tp",
        "act_mamba_inner": "tp",
        "act_frames": None,
    },
    # optimized variant (§Perf): KV-cache sequence dim sharded over 'tp' —
    # exact for any kv-head count (incl. MQA), keeps the decode working set
    # per chip at cache/|tp| instead of the full cache
    "opt": {
        "vocab": "tp", "embed": "dp", "heads": "tp", "kv_heads": "tp",
        "mlp": "tp", "experts": "tp", "expert_mlp": None,
        "mamba_inner": "tp", "lstm_inner": "tp",
        "act_batch": "dp", "act_seq": "tp", "act_embed": None,
        "act_vocab": "tp", "act_heads": "tp", "act_kv_heads": "tp",
        "act_experts": "tp", "act_kv_seq": "tp", "act_mlp": "tp",
        "act_mamba_inner": "tp", "act_frames": None,
    },
    # pure tensor-parallel (no FSDP): small models / serving
    "tp_only": {
        "vocab": "tp", "embed": None, "heads": "tp", "kv_heads": "tp",
        "mlp": "tp", "experts": "tp", "mamba_inner": "tp", "lstm_inner": "tp",
        "act_batch": "dp", "act_seq": None, "act_vocab": "tp",
        "act_heads": "tp", "act_kv_heads": "tp", "act_experts": "tp",
        "act_kv_seq": "tp",   # decode: shard the KV-cache sequence dim
        "act_mlp": "tp", "act_mamba_inner": "tp",
    },
}


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Binds logical rules to a concrete mesh. mesh=None => no-op (tests)."""
    mesh: Mesh | None = None
    rules: str = "default"
    dp: tuple[str, ...] = ("data",)
    tp: tuple[str, ...] = ("model",)

    def role_axes(self, role: str | None):
        if role == "dp":
            return self.dp
        if role == "tp":
            return self.tp
        return None

    def axis_size(self, role: str) -> int:
        if self.mesh is None:
            return 1
        axes = self.role_axes(role)
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def spec_for(axes: tuple[str | None, ...], ctx: ShardCtx,
             shape: tuple[int, ...] | None = None) -> P:
    """PartitionSpec for logical axes; drops non-dividing mappings."""
    rules = RULESETS[ctx.rules]
    entries = []
    used: set[str] = set()
    for i, name in enumerate(axes):
        role = rules.get(name) if name else None
        mesh_axes = ctx.role_axes(role)
        if mesh_axes is None or any(a in used for a in mesh_axes):
            entries.append(None)
            continue
        if shape is not None and ctx.mesh is not None:
            size = int(np.prod([ctx.mesh.shape[a] for a in mesh_axes]))
            if shape[i] % size != 0:
                entries.append(None)
                continue
        used.update(mesh_axes)
        entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*entries)


def constrain(x, axes: tuple[str | None, ...], ctx: ShardCtx | None):
    """with_sharding_constraint when a mesh is bound; identity otherwise."""
    if ctx is None or ctx.mesh is None:
        return x
    spec = spec_for(axes, ctx, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def sharding_for(axes, ctx: ShardCtx, shape) -> NamedSharding:
    assert ctx.mesh is not None
    return NamedSharding(ctx.mesh, spec_for(axes, ctx, shape))
