from repro.sharding.specs import ShardCtx, spec_for, constrain, RULESETS

__all__ = ["ShardCtx", "spec_for", "constrain", "RULESETS"]
