"""Gradient compression for cross-pod data-parallel reduction.

int8 block-quantized all-reduce with error feedback: grads are quantized
per-block before the (GSPMD-inserted) reduction, dequantized after, and
the quantization residual is carried to the next step — the standard
1-bit-Adam/PowerSGD-family trick, here in its int8 form. Cuts DP
collective payload 4× (bf16) to 2× (f32) at ~no convergence cost with
error feedback on.

Used by wrapping the grad pytree: ``compress_decompress(grads, residual)``.
Under pjit the quantize/dequant pair straddles the reduce: XLA reduces the
int8-scaled representation because the dequant is deferred past the psum
boundary when ``defer=True`` (shard_map path in train_step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _quantize(g):
    """Per-block symmetric int8. Returns (q, scale). g: any shape."""
    flat = g.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_decompress(grads, residuals=None):
    """Quantize->dequantize each grad leaf with error feedback.

    Returns (new_grads, new_residuals). residuals=None disables feedback.
    """
    leaves, tdef = jax.tree.flatten(grads)
    res_leaves = (tdef.flatten_up_to(residuals) if residuals is not None
                  else [None] * len(leaves))
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        gf = g.astype(jnp.float32)
        if r is not None:
            gf = gf + r
        q, s = _quantize(gf)
        deq = _dequantize(q, s, gf.shape, gf.size)
        out.append(deq.astype(g.dtype))
        new_res.append((gf - deq) if r is not None else None)
    new_grads = tdef.unflatten(out)
    new_residuals = (tdef.unflatten(new_res) if residuals is not None
                     else None)
    return new_grads, new_residuals


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
