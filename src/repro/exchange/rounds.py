"""Round compositions over the lane-generic exchange primitives.

One relax → exchange → rhizome-collapse composition per execution layout
(stacked / shard_map) and per app class (monotone fixpoint / counted
PageRank-style rounds), each serving both the unlaned ``(V,)`` and the
lane-batched ``(V, Q)`` table layouts.  ``core.engine`` and
``query.lanes`` are thin drivers over these — the while/fori loop,
termination collective, and stats bookkeeping live there; the per-round
math lives here, once.

The ``cfg`` threaded through every composition also carries the fused
kernel's VMEM budget (``EngineConfig.vmem_budget_bytes``): the relax
phase pins the value table in VMEM when it fits, else runs the
HBM-tiled double-buffered-DMA kernel — transparently to every round
shape here (see ``kernels.fused_relax_reduce.select_kernel_path``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.actions import Semiring
from repro.exchange.primitives import (
    collapse, compact_collapse, reduce_axis0, relax, scatter_inbox,
    stacked_compact_partial, stacked_dense_inbox,
)


def axis_tuple(axis_names):
    return axis_names if isinstance(axis_names, tuple) else (axis_names,)


def _flat(table):
    """Collapse the leading (shard, slot) dims; trailing Q rides."""
    return table.reshape((-1,) + table.shape[2:])


# --------------------------------------------------------------------------
# stacked layout: all shards resident as a leading S axis on one device
# --------------------------------------------------------------------------

def stacked_inbox(sem: Semiring, arrays, cfg, S: int, R_max: int,
                  gval, gchg, lane_unitw=None, worklist=None):
    """Relax + exchange on the stacked layout.

    Dense: one reduced global inbox.  Compact (§Perf targeted): per-source
    (target, distinct-slot) partials, axis-swapped in place of the real
    ``all_to_all``, scatter-combined per target.  Returns the
    ((S, R_max[, Q]) inbox, message count — scalar or (Q,)).

    ``worklist`` is a host-planned sparse launch for the fused relax —
    planned against THIS exchange's launch shape (the compact path's
    offset ids differ from the dense flat ids; see
    ``core.engine.launch_planner``)."""
    if cfg.exchange == "compact":
        P_t = arrays.inbox_slot_map.shape[-1]
        partial, counts = stacked_compact_partial(
            sem, arrays, cfg, S, P_t, gval, gchg, lane_unitw, worklist)
        recv = jnp.swapaxes(partial, 0, 1)       # (S_tgt, S_src, P_t[, Q])
        inbox = jax.vmap(lambda r, m: scatter_inbox(sem, r, m, R_max))(
            recv, arrays.inbox_slot_map)
        return inbox, counts
    flat, counts = stacked_dense_inbox(
        sem, arrays, cfg, gval, gchg, S * R_max, lane_unitw, worklist)
    return flat.reshape((S, R_max) + flat.shape[1:]), counts


def stacked_collapse(sem: Semiring, arrays, cfg, table):
    """Eager rhizome collapse of a stacked (S, R_max[, Q]) table — dense
    sibling collapse, or the compact rhizome-only gather/scatter."""
    if cfg.exchange == "compact":
        R_max = table.shape[1]
        return compact_collapse(
            sem, table, arrays.rz_local, arrays.rz_sibling_idx,
            arrays.rz_sibling_mask, _flat, R_max,
            arrays.rz_local.shape[-1])
    out = collapse(sem, _flat(table), arrays.sibling_flat,
                   arrays.sibling_mask)
    return out


def fixpoint_round_stacked(sem: Semiring, arrays, cfg, S: int, R_max: int,
                           val, chg, lane_unitw=None, worklist=None,
                           lane_mask=None):
    """One stacked fixpoint round: relax → exchange → combine → eager
    rhizome collapse → predicate.  ``val``/``chg``: (S, R_max) or
    (S, R_max, Q).  Returns (new val, new changed, message count).

    ``lane_mask`` ((Q,) bool) freezes masked-off lanes for this round —
    their frontier reads all-False (no relax work, no messages) and they
    emit no next-round frontier, while their values carry through
    unchanged.  This is the per-request round-budget plumbing: a lane
    past its budget is silenced in-round instead of torn down."""
    laned = val.ndim == 3
    if lane_mask is not None:
        chg = chg & lane_mask[None, None, :]
    gval, gchg = _flat(val), _flat(chg)
    inbox, counts = stacked_inbox(
        sem, arrays, cfg, S, R_max, gval, gchg, lane_unitw, worklist)
    cand = sem.combine(val, inbox)
    if cfg.collapse == "eager":
        cand = stacked_collapse(sem, arrays, cfg, cand)
    slot = arrays.slot_valid[..., None] if laned else arrays.slot_valid
    new_chg = sem.improved(cand, val) & slot
    if lane_mask is not None:
        cand = jnp.where(lane_mask[None, None, :], cand, val)
        new_chg = new_chg & lane_mask[None, None, :]
    return cand, new_chg, counts


def stacked_total_in(sem: Semiring, arrays, cfg, S: int, R_max: int,
                     gval, gchg, lane_unitw=None, worklist=None):
    """Relax → exchange → rhizome-collapse(⊕) of the *bare inbox* — the
    total in-flow per slot that counted (PageRank-style) rounds consume.
    The collapse sees inbox partials, never combined candidates, so the
    sum-semiring sibling-total overwrite is exact."""
    inbox, counts = stacked_inbox(
        sem, arrays, cfg, S, R_max, gval, gchg, lane_unitw, worklist)
    return stacked_collapse(sem, arrays, cfg, inbox), counts


def pagerank_round_stacked(sem: Semiring, arrays, cfg, S: int, R_max: int,
                           base, damping, val, chg, worklist=None):
    """One stacked PageRank round: relax → exchange → rhizome-collapse(+)
    → damping update.  Shared by run_pagerank_stacked and the engine
    benchmark so BENCH numbers measure the shipped hot path."""
    total_in, counts = stacked_total_in(
        sem, arrays, cfg, S, R_max, _flat(val), _flat(chg),
        worklist=worklist)
    new_val = jnp.where(arrays.slot_valid, base + damping * total_in, 0.0)
    return new_val, counts


def delta_pagerank_round_stacked(sem: Semiring, arrays, cfg, S: int,
                                 R_max: int, damping, tol, rank, delta,
                                 worklist=None):
    """One stacked **delta-PageRank** round (the diffusion-pruned sum
    semiring, paper Listing 10 with lazy residuals).

    Ranks follow the Neumann series ``rank = Σ_k (d·Aᵀ)^k base`` — the
    same fixpoint as the dense power iteration — but each round ships
    only the *residual delta*, and only where it still exceeds ``tol``
    (scalar or per-slot): the frontier ``|delta| > tol`` masks the relax
    (absolute value, so streaming's negative incremental corrections
    diffuse too; cold deltas are nonnegative, making this bit-identical),
    sub-tolerance residuals are dropped (the paper's pruned diffusions),
    and the sum semiring finally has a genuinely shrinking frontier for
    the chunk-skip / worklist / tile-filter stack to prune against.

    Returns (new rank, new delta, new changed, message count); callers
    seed ``rank = delta = base`` (see ``engine.run_pagerank_delta``)."""
    chg = (jnp.abs(delta) > tol) & arrays.slot_valid
    total_in, counts = stacked_total_in(
        sem, arrays, cfg, S, R_max, _flat(delta), _flat(chg),
        worklist=worklist)
    new_delta = jnp.where(arrays.slot_valid, damping * total_in, 0.0)
    new_rank = rank + new_delta
    new_chg = (jnp.abs(new_delta) > tol) & arrays.slot_valid
    return new_rank, new_delta, new_chg, counts


# --------------------------------------------------------------------------
# K-round windows: whole round sequences inside ONE traced dispatch
# --------------------------------------------------------------------------
# The device-resident loop machinery (ISSUE 8): `lax.scan` the stacked
# round bodies K times so drivers dispatch once per WINDOW instead of
# once per round.  A round whose entering frontier is empty is a no-op
# under every semiring here (all sources read the absorbing identity,
# min candidates equal val, delta residuals are zero), so windows that
# overrun convergence stay exact — drivers trim the trailing dead
# rounds from the returned per-round stacks.  Each step also emits the
# frontier ENTERING that round, giving the host the full trajectory for
# post-hoc planner-mirror accounting with zero extra syncs.


def fixpoint_window_stacked(sem: Semiring, arrays, cfg, S: int, R_max: int,
                            k: int, val, chg, lane_unitw=None,
                            lane_mask=None):
    """K stacked fixpoint rounds under one ``lax.scan``.  Returns
    (val, chg, (k[, Q]) per-round message counts, (k, S, R_max[, Q])
    per-round entering frontiers)."""

    def step(carry, _):
        val, chg = carry
        nval, nchg, counts = fixpoint_round_stacked(
            sem, arrays, cfg, S, R_max, val, chg, lane_unitw,
            lane_mask=lane_mask)
        return (nval, nchg), (counts, chg)

    (val, chg), (counts, frontiers) = lax.scan(
        step, (val, chg), None, length=k)
    return val, chg, counts, frontiers


def delta_pagerank_window_stacked(sem: Semiring, arrays, cfg, S: int,
                                  R_max: int, k: int, damping, tol, rank,
                                  delta):
    """K stacked delta-PageRank rounds under one ``lax.scan``.  Returns
    (rank, delta, chg, (k,) counts, (k, S, R_max) entering frontiers)."""

    def step(carry, _):
        rank, delta = carry
        chg = (jnp.abs(delta) > tol) & arrays.slot_valid
        nr, nd, _, counts = delta_pagerank_round_stacked(
            sem, arrays, cfg, S, R_max, damping, tol, rank, delta)
        return (nr, nd), (counts, chg)

    (rank, delta), (counts, frontiers) = lax.scan(
        step, (rank, delta), None, length=k)
    new_chg = (jnp.abs(delta) > tol) & arrays.slot_valid
    return rank, delta, new_chg, counts, frontiers


# --------------------------------------------------------------------------
# shard_map layout: one shard per device, real collectives
# --------------------------------------------------------------------------

def shard_inbox(sem: Semiring, arrays_s, cfg, S: int, R_max: int,
                axis_names, gval, gchg, lane_unitw=None):
    """Per-shard relax + real inbox exchange.

    Dense: (S, R_max[, Q]) partial → ``all_to_all`` → axis-0 reduce.
    Compact: only (target, distinct-slot) contributions travel — the
    (S, P_t[, Q]) targeted tables ride the ``all_to_all`` and scatter
    into local slots.  Returns ((R_max[, Q]) inbox, message count)."""
    if cfg.exchange == "compact":
        P_t = arrays_s.inbox_slot_map.shape[-1]
        partial, counts = relax(
            sem, cfg, arrays_s.edge_src_root_flat, arrays_s.edge_w,
            arrays_s.edge_mask, arrays_s.edge_dst_compact, gval, gchg,
            S * P_t, lane_unitw)
        recv = lax.all_to_all(
            partial.reshape((S, P_t) + partial.shape[1:]), axis_names,
            split_axis=0, concat_axis=0, tiled=True)
        inbox = scatter_inbox(sem, recv, arrays_s.inbox_slot_map, R_max)
        return inbox, counts
    partial, counts = relax(
        sem, cfg, arrays_s.edge_src_root_flat, arrays_s.edge_w,
        arrays_s.edge_mask, arrays_s.edge_dst_flat, gval, gchg,
        S * R_max, lane_unitw)
    # inbox exchange: row t of `partial` belongs to shard t
    recv = lax.all_to_all(
        partial.reshape((S, R_max) + partial.shape[1:]), axis_names,
        split_axis=0, concat_axis=0, tiled=True)
    return reduce_axis0(sem, recv), counts


def shard_collapse(sem: Semiring, arrays_s, cfg, table, gather, R_max: int):
    """Eager rhizome collapse of a per-shard (R_max[, Q]) table; ``gather``
    is the tiled ``all_gather`` over the mesh axes."""
    if cfg.exchange == "compact":
        return compact_collapse(
            sem, table, arrays_s.rz_local, arrays_s.rz_sibling_idx,
            arrays_s.rz_sibling_mask, gather, R_max,
            arrays_s.rz_local.shape[-1])
    return collapse(sem, gather(table), arrays_s.sibling_flat,
                    arrays_s.sibling_mask)


def make_shard_fixpoint_round(sem: Semiring, arrays_s, cfg, S: int,
                              R_max: int, axis_names, lane_unitw=None):
    """Builds the per-shard fixpoint round body (runs inside shard_map):
    (val, chg[, lane_mask]) → (new val, new changed, message count), with
    the same collective plan for unlaned (R_max,) and laned (R_max, Q)
    tables — value/changed ``all_gather`` (the diffusion fan-out), inbox
    ``all_to_all``, sibling collapse over the gathered table.

    The optional ``lane_mask`` ((Q,) bool, replicated) is the round-budget
    plumbing (see ``fixpoint_round_stacked``): masked-off lanes relax
    nothing, ship nothing, and carry their values through unchanged."""
    axis_names = axis_tuple(axis_names)

    def gather(x):
        return lax.all_gather(x, axis_names, tiled=True)

    def round_fn(val, chg, lane_mask=None):
        laned = val.ndim == 2
        if lane_mask is not None:
            chg = chg & lane_mask[None, :]
        gval, gchg = gather(val), gather(chg)
        inbox, counts = shard_inbox(
            sem, arrays_s, cfg, S, R_max, axis_names, gval, gchg,
            lane_unitw)
        cand = sem.combine(val, inbox)
        if cfg.collapse == "eager":
            cand = shard_collapse(sem, arrays_s, cfg, cand, gather, R_max)
        slot = arrays_s.slot_valid[..., None] if laned \
            else arrays_s.slot_valid
        new_chg = sem.improved(cand, val) & slot
        if lane_mask is not None:
            cand = jnp.where(lane_mask[None, :], cand, val)
            new_chg = new_chg & lane_mask[None, :]
        return cand, new_chg, counts

    return round_fn


def shard_total_in(sem: Semiring, arrays_s, cfg, S: int, R_max: int,
                   axis_names, gval, gchg, lane_unitw=None):
    """Sharded relax → exchange → rhizome-collapse(⊕) of the bare inbox
    (see ``stacked_total_in``)."""
    axis_names = axis_tuple(axis_names)

    def gather(x):
        return lax.all_gather(x, axis_names, tiled=True)

    inbox, counts = shard_inbox(
        sem, arrays_s, cfg, S, R_max, axis_names, gval, gchg, lane_unitw)
    return shard_collapse(sem, arrays_s, cfg, inbox, gather, R_max), counts


def delta_pagerank_round_shard(sem: Semiring, arrays_s, cfg, S: int,
                               R_max: int, axis_names, damping, tol,
                               rank, delta):
    """Per-shard delta-PageRank round (runs inside shard_map): the
    sharded twin of ``delta_pagerank_round_stacked`` — value/frontier
    ``all_gather``, relax over the shrinking residual frontier, inbox
    exchange, rhizome-collapse(+).  Counts are local (callers psum)."""
    axis_names = axis_tuple(axis_names)

    def gather(x):
        return lax.all_gather(x, axis_names, tiled=True)

    chg = (jnp.abs(delta) > tol) & arrays_s.slot_valid
    total_in, counts = shard_total_in(
        sem, arrays_s, cfg, S, R_max, axis_names, gather(delta),
        gather(chg))
    new_delta = jnp.where(arrays_s.slot_valid, damping * total_in, 0.0)
    new_rank = rank + new_delta
    new_chg = (jnp.abs(new_delta) > tol) & arrays_s.slot_valid
    return new_rank, new_delta, new_chg, counts


# --------------------------------------------------------------------------
# host-side accounting mirrors (flight-recorder feeds; never traced)
# --------------------------------------------------------------------------

def shard_message_mirror(edge_mask, edge_src_root_flat, gchg):
    """Per-shard message-volume mirror: how many live edge messages each
    shard's edge list delivers this round — ``edge_mask & frontier[src]``
    summed per shard, exactly the population ``relax`` counts (so the
    vector sums to the round's kernel-side message count).  Host-side
    numpy over the (S, E_max) partition arrays; feeds the flight
    recorder's per-shard skew/balance gauge (the "message balance across
    workers" axis of the distributed-graph-systems evaluation
    literature)."""
    import numpy as np

    mask = np.asarray(edge_mask)
    srcs = np.asarray(edge_src_root_flat)
    g = np.asarray(gchg).reshape(-1)
    return (mask & g[srcs]).sum(axis=tuple(range(1, mask.ndim)))


def expected_round_messages(edge_mask, edge_src_root_flat, gchg,
                            laned: bool = False) -> int:
    """The exact message total a clean round on frontier ``gchg`` must
    report — ``shard_message_mirror`` summed over shards.  This is the
    resilient driver's inbox-integrity detector: a dispatched round whose
    reported count falls short (a dropped inbox) or overshoots (a
    duplicated inbox) of this host mirror raises a typed
    ``FaultDetected`` instead of silently converging to a wrong-work
    fixpoint.  With ``laned=True`` the trailing axis of ``gchg`` is the
    query-lane axis Q and the expectation sums over lanes, matching the
    laned ``relax`` population count."""
    import numpy as np

    g = np.asarray(gchg)
    if not laned:
        return int(shard_message_mirror(
            edge_mask, edge_src_root_flat, g).sum())
    gq = g.reshape(-1, g.shape[-1])
    return int(sum(
        shard_message_mirror(edge_mask, edge_src_root_flat,
                             gq[:, q]).sum()
        for q in range(gq.shape[1])))


def mask_shard_frontier(chg, shard: int):
    """Frontier ``chg`` ((S, R_max[, Q])) with shard ``shard``'s rows
    forced False — the chaos injector's 'dropped inbox': that shard's
    outgoing messages silently vanish for one round.  Returns a new
    array; the caller keeps the untampered original for retry."""
    return chg.at[shard].set(False) if hasattr(chg, "at") else _mask_np(
        chg, shard)


def _mask_np(chg, shard: int):
    import numpy as np

    out = np.array(chg, copy=True)
    out[shard] = False
    return out
