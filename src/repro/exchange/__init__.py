"""Unified lane-generic exchange layer (ISSUE 3 tentpole).

One implementation of the engine's per-round machinery — relax, inter-shard
exchange (dense inbox or §Perf compact targeted), and rhizome collapse —
parameterized over an *optional trailing query-lane axis Q*.  Every runner
(`core.engine.run_stacked` / `run_sharded`, `query.lanes.run_stacked_lanes`
/ `make_sharded_lanes_fn`, the PageRank/PPR rounds) dispatches through the
round compositions here instead of carrying its own hand-specialized copy.

Shapes: value/frontier tables are ``(V,)`` (single query) or ``(V, Q)``
(lane-batched); the primitives detect the lane axis from rank, so the same
code path serves both and a converged lane — an all-False frontier column —
reads as the absorbing identity and contributes no messages.
"""
from repro.exchange.primitives import (
    collapse, compact_collapse, exchange_volume, reduce_axis0, relax,
    scatter_inbox, stacked_compact_partial, stacked_dense_inbox,
)
from repro.exchange.rounds import (
    axis_tuple, delta_pagerank_round_shard, delta_pagerank_round_stacked,
    delta_pagerank_window_stacked, expected_round_messages,
    fixpoint_round_stacked, fixpoint_window_stacked,
    make_shard_fixpoint_round, mask_shard_frontier,
    pagerank_round_stacked, shard_collapse, shard_inbox,
    shard_message_mirror, shard_total_in, stacked_collapse, stacked_inbox,
    stacked_total_in,
)

__all__ = [
    "axis_tuple", "collapse", "compact_collapse",
    "delta_pagerank_round_shard", "delta_pagerank_round_stacked",
    "delta_pagerank_window_stacked", "exchange_volume",
    "expected_round_messages",
    "fixpoint_round_stacked", "fixpoint_window_stacked",
    "make_shard_fixpoint_round", "mask_shard_frontier",
    "pagerank_round_stacked", "reduce_axis0",
    "relax", "scatter_inbox", "shard_collapse", "shard_inbox",
    "shard_message_mirror", "shard_total_in", "stacked_collapse",
    "stacked_compact_partial",
    "stacked_dense_inbox", "stacked_inbox", "stacked_total_in",
]
