"""Lane-generic relax / exchange / collapse primitives.

Every function here accepts value and frontier tables either **unlaned**
(``(V,)`` — one query, the classic engine layout) or **laned** (``(V, Q)``
— a trailing query-lane axis, one column per concurrent query) and picks
the matching kernel / jnp form.  The lane axis is detected from rank, so
the round compositions in ``exchange.rounds`` are written once.

The arrays argument is duck-typed against ``core.engine.DeviceArrays``
(the static per-shard partition tables); ``cfg`` against
``core.engine.EngineConfig`` — this module must not import ``core.engine``
(the engine imports *us*).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.actions import Semiring


def reduce_axis0(sem: Semiring, x):
    """Semiring reduction over axis 0 (trailing axes — incl. Q — ride)."""
    return jnp.min(x, axis=0) if sem.segment == "min" else jnp.sum(x, axis=0)


def _identity(sem: Semiring, dtype):
    return jnp.asarray(sem.identity, dtype)


# --------------------------------------------------------------------------
# relax phase: gather frontier sources, build messages, partial-reduce
# --------------------------------------------------------------------------

def relax(sem: Semiring, cfg, edge_src, edge_w, edge_mask, ids, gval, gchg,
          num_segments: int, lane_unitw=None, worklist=None):
    """Relax phase over one edge set (flattened internally).

    ``gval``/``gchg``: (V,) or (V, Q).  Returns ((num_segments[, Q])
    partial, message count — scalar unlaned, (Q,) per-lane laned).

    Laned 'add_w' honors ``lane_unitw``: lanes with a nonzero flag relax
    with the constant weight 1.0 (BFS levels inside an SSSP launch).

    ``worklist`` — a host-planned live-cell launch (see
    ``kernels.fused_relax_reduce.WorklistPlanner``) — swaps the fused
    kernel's dense early-exit grid for the 1-D sparse launch; it only
    applies to the fused Pallas path (the jnp oracle has no grid) and is
    built per round by the host-driven engine loops
    (``EngineConfig.grid_mode``).  With ``cfg.grid_mode=
    'device_worklist'`` (and no explicit plan) the live-cell list is
    compacted ON DEVICE instead — fully traced, so the same round
    composes into `lax.while_loop` / `shard_map` fixpoints with zero
    host syncs.
    """
    laned = gval.ndim == 2
    src = edge_src.reshape(-1)
    idsf = ids.reshape(-1)
    w = edge_w.reshape(-1)
    mask = edge_mask.reshape(-1)
    # only the device mode is forwarded to the kernel dispatch: host
    # modes ('worklist'/'auto') arrive as a pre-planned worklist= (or
    # keep the dense grid on rounds the planner declined)
    grid_mode = ("device_worklist"
                 if (worklist is None
                     and getattr(cfg, "grid_mode", "dense")
                     == "device_worklist")
                 else "dense")

    if not laned:
        if cfg.use_pallas and cfg.pallas_mode == "fused":
            if sem.relax_kind is None:
                raise ValueError(
                    f"semiring {sem.name!r} has no kernel relax form "
                    "(relax_kind=None); construct it from actions.RELAX_FNS "
                    "or run with use_pallas=False")
            from repro.kernels import ops as kops
            # the Fig-6 message count rides along for free: it is a
            # reduction of the same gather that builds the kernel's
            # frontier chunk bitmap.  The cfg's VMEM budget selects the
            # value table's residency (pinned vs HBM-tiled DMA).
            partial, count = kops.fused_relax_reduce(
                gval, gchg, src, w, mask, idsf, num_segments,
                relax_kind=sem.relax_kind, kind=sem.segment,
                vmem_budget_bytes=getattr(cfg, "vmem_budget_bytes", None),
                worklist=worklist,
                smem_budget_bytes=getattr(cfg, "smem_budget_bytes", None),
                grid_mode=grid_mode)
            if not cfg.track_stats:
                count = jnp.zeros((), jnp.int32)
            return partial, count
        src_val = jnp.take(gval, edge_src, axis=0)
        active = edge_mask & jnp.take(gchg, edge_src, axis=0)
        msg = jnp.where(active, sem.relax(src_val, edge_w),
                        _identity(sem, src_val.dtype))
        if cfg.use_pallas:  # 'reduce': XLA relax ops + Pallas segment reduce
            from repro.kernels import ops as kops
            partial = kops.segment_combine(
                msg.reshape(-1), idsf, num_segments, kind=sem.segment)
        else:
            partial = sem.segment_combine(msg.reshape(-1), idsf, num_segments)
        count = active.sum() if cfg.track_stats else jnp.zeros((), jnp.int32)
        return partial, count

    # --- laned: (V, Q) tables over one shared edge structure ---
    q = gval.shape[-1]
    if sem.relax_kind not in ("add_w", "mul_w"):
        raise ValueError(
            f"laned relax supports relax_kind 'add_w'|'mul_w', got "
            f"{sem.relax_kind!r} (express BFS lanes with lane_unitw=1)")
    unitw = (jnp.zeros((q,), jnp.int32) if lane_unitw is None
             else jnp.asarray(lane_unitw, jnp.int32).reshape(q))
    if cfg.use_pallas:
        if cfg.pallas_mode != "fused":
            raise ValueError(
                "laned Pallas execution is fused-only (the pre-fusion "
                "'reduce' composition has no laned form)")
        from repro.kernels import ops as kops
        partial, counts = kops.fused_relax_reduce_lanes(
            gval, gchg, unitw, src, w, mask, idsf, num_segments,
            relax_kind=sem.relax_kind, kind=sem.segment,
            vmem_budget_bytes=getattr(cfg, "vmem_budget_bytes", None),
            worklist=worklist,
            smem_budget_bytes=getattr(cfg, "smem_budget_bytes", None),
            grid_mode=grid_mode)
        if not cfg.track_stats:
            counts = jnp.zeros((q,), jnp.int32)
        return partial, counts
    src_val = jnp.take(gval, src, axis=0)                    # (E, Q)
    active = mask[:, None] & jnp.take(gchg, src, axis=0)
    if sem.relax_kind == "add_w":
        w_eff = jnp.where(unitw[None, :] > 0,
                          jnp.asarray(1.0, w.dtype), w[:, None])
        msg = src_val + w_eff
    else:                                                    # 'mul_w'
        msg = src_val * w[:, None]
    msg = jnp.where(active, msg, _identity(sem, msg.dtype))
    init = jnp.full((num_segments, q), sem.identity, msg.dtype)
    partial = (init.at[idsf].min(msg) if sem.segment == "min"
               else init.at[idsf].add(msg))
    counts = (active.sum(axis=0, dtype=jnp.int32) if cfg.track_stats
              else jnp.zeros((q,), jnp.int32))
    return partial, counts


# --------------------------------------------------------------------------
# stacked relax compositions (all shards resident on one device)
# --------------------------------------------------------------------------

def stacked_dense_inbox(sem: Semiring, arrays, cfg, gval, gchg, total: int,
                        lane_unitw=None, worklist=None):
    """Stacked dense relax: the reduced (total[, Q]) global inbox + count.

    Fused path: all shards' edges address the same global slot space, so
    the whole stack collapses in ONE kernel launch (the kernel's in-place
    block accumulation replaces the (S, total) partial + axis-0 reduce)."""
    if cfg.use_pallas and cfg.pallas_mode == "fused":
        return relax(sem, cfg, arrays.edge_src_root_flat, arrays.edge_w,
                     arrays.edge_mask, arrays.edge_dst_flat, gval, gchg,
                     total, lane_unitw, worklist=worklist)
    partial, counts = jax.vmap(
        lambda s, w, m, i: relax(sem, cfg, s, w, m, i, gval, gchg, total,
                                 lane_unitw)
    )(arrays.edge_src_root_flat, arrays.edge_w, arrays.edge_mask,
      arrays.edge_dst_flat)
    return reduce_axis0(sem, partial), counts.sum(axis=0)


def stacked_compact_partial(sem: Semiring, arrays, cfg, S: int, P_t: int,
                            gval, gchg, lane_unitw=None, worklist=None):
    """Stacked compact relax: (S_src, S_tgt, P_t[, Q]) partials + count.

    Fused path: source shards get disjoint id windows of width S*P_t, so
    one kernel launch over the flattened edge stack produces every
    per-source partial (compact slot meaning depends on the source shard,
    hence the offsets — contributions must NOT merge across sources)."""
    if cfg.use_pallas and cfg.pallas_mode == "fused":
        offs = (jnp.arange(S, dtype=jnp.int32) * (S * P_t))[:, None]
        ids = arrays.edge_dst_compact + offs
        flat, count = relax(sem, cfg, arrays.edge_src_root_flat,
                            arrays.edge_w, arrays.edge_mask, ids, gval,
                            gchg, S * S * P_t, lane_unitw,
                            worklist=worklist)
        return flat.reshape((S, S, P_t) + flat.shape[1:]), count
    partial, counts = jax.vmap(
        lambda s, w, m, i: relax(sem, cfg, s, w, m, i, gval, gchg,
                                 S * P_t, lane_unitw)
    )(arrays.edge_src_root_flat, arrays.edge_w, arrays.edge_mask,
      arrays.edge_dst_compact)
    return partial.reshape((S, S, P_t) + partial.shape[2:]), \
        counts.sum(axis=0)


# --------------------------------------------------------------------------
# inbox scatter + rhizome collapse
# --------------------------------------------------------------------------

def scatter_inbox(sem: Semiring, recv_t, slot_map_t, R_max: int):
    """recv_t: (S_src, P_t[, Q]) contributions; slot_map_t: (S_src, P_t)
    local slots (R_max = pad).  Scatter-combine into (R_max[, Q])."""
    tail = recv_t.shape[slot_map_t.ndim:]
    init = jnp.full((R_max + 1,) + tail, sem.identity, recv_t.dtype)
    flat = recv_t.reshape((-1,) + tail)
    idx = slot_map_t.reshape(-1)
    out = (init.at[idx].min(flat) if sem.segment == "min"
           else init.at[idx].add(flat))
    return out[:R_max]


def collapse(sem: Semiring, gx, sibling_flat, sibling_mask):
    """Rhizome collapse: AND-gate over all replicas of each slot's vertex.

    ``gx``: (V,) or (V, Q) gathered table; sibling tables index the
    leading axis (the lane axis rides along).  Returns the sibling-
    combined table shaped like ``sibling_flat`` (+ Q)."""
    laned = gx.ndim == 2
    sib = jnp.take(gx, sibling_flat, axis=0)     # (..., K[, Q])
    mask = sibling_mask[..., None] if laned else sibling_mask
    sib = jnp.where(mask, sib, _identity(sem, sib.dtype))
    axis = -2 if laned else -1
    return (jnp.min(sib, axis=axis) if sem.segment == "min"
            else jnp.sum(sib, axis=axis))


def compact_collapse(sem: Semiring, cand, rz_local, rz_sib_idx, rz_sib_mask,
                     gather_fn, R_max: int, R_rz_max: int):
    """Collapse only rhizome slots: compact-gather them, all-gather the
    small table, combine siblings, scatter back.  ``cand``:
    (..., R_max[, Q]).  min semirings min-set (collapsed ≼ cand under the
    semiring order, so ``cand`` may be any combined candidate); sum
    semirings overwrite each rhizome slot with the sibling total (each
    sibling's own partial is included in the sum, so set — never add —
    keeps it exact), which requires ``cand`` to be bare inbox partials —
    summing combined val+inbox candidates would double-count every
    sibling's val (hence the min-only fixpoint runners; only the
    PageRank/PPR rounds pass sum semirings here)."""
    laned = cand.ndim == rz_local.ndim + 1
    slot_axis = -2 if laned else -1
    pad_shape = list(cand.shape)
    pad_shape[slot_axis] = 1
    cand_pad = jnp.concatenate(
        [cand, jnp.full(pad_shape, sem.identity, cand.dtype)],
        axis=slot_axis)
    rz_idx = rz_local[..., None] if laned else rz_local
    compact = jnp.take_along_axis(cand_pad, rz_idx, axis=slot_axis)
    g = gather_fn(compact)                       # (S*R_rz_max[, Q]) flat
    sib = jnp.take(g, rz_sib_idx, axis=0)        # (..., K[, Q])
    mask = rz_sib_mask[..., None] if laned else rz_sib_mask
    sib = jnp.where(mask, sib, _identity(sem, sib.dtype))
    k_axis = -2 if laned else -1
    collapsed = (jnp.min(sib, axis=k_axis) if sem.segment == "min"
                 else jnp.sum(sib, axis=k_axis))
    idx = tuple(jnp.indices(rz_local.shape)[:-1]) + (rz_local,)
    if sem.segment == "min":
        upd = cand_pad.at[idx].min(collapsed)
    else:
        upd = cand_pad.at[idx].set(collapsed)
    return upd[..., :R_max, :] if laned else upd[..., :R_max]


# --------------------------------------------------------------------------
# exchange-volume accounting (the §Perf message-reduction metric)
# --------------------------------------------------------------------------

def exchange_volume(S: int, R_max: int, P_t: int, cfg) -> int:
    """Entries that transit the inter-shard exchange per round, per live
    lane: every shard ships its per-target partial — (S, R_max) rows of
    the dense global inbox, or (S, P_t) targeted (target, distinct-slot)
    compact tables.  The compact win is exactly the paper's message
    reduction: P_t < R_max whenever shards feed only a subset of each
    target's slots (always, on skewed partitions).  On the stacked path
    no collective runs, but the exchanged tensors are the same size, so
    the same accounting holds; a converged lane is excluded by the caller
    (its column is all identity — it adds no message volume)."""
    width = P_t if cfg.exchange == "compact" else R_max
    return S * S * width
