"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]: 40L d8192 64H GQA
kv=8, d_ff 22528, vocab 256000, no biases, tied embeddings."""
from repro.lm.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab=256000,
    mlp_act="swiglu", pos="rope", rope_theta=8e6, tie_embeddings=True,
)
