"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained experts — 64 routed
top-6 + 2 shared (d_ff 1408 each); first layer is a dense FFN (d_ff
10944); 28L, GQA kv=16(MHA), vocab 102400."""
from repro.lm.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=102400,
    mlp_act="swiglu", pos="rope",
    moe=MoEConfig(num_experts=64, top_k=6, d_expert_ff=1408,
                  num_shared=2, d_shared_ff=1408,
                  first_dense_layers=1, first_dense_d_ff=10944),
)
