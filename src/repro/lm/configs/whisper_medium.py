"""Whisper-medium [arXiv:2212.04356]: 24-layer encoder (conv/audio
frontend stubbed: input_specs supplies 1500 frame embeddings) + 24-layer
decoder with cross-attention. MHA (kv=16), GELU MLP, sinusoidal positions,
attention biases, tied embeddings."""
from repro.lm.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="enc_dec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab=51865,
    mlp_act="gelu", pos="sinusoidal", attn_bias=True, tie_embeddings=True,
    encoder=EncoderConfig(n_layers=24, n_frames=1500),
)
