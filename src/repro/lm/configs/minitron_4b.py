"""Minitron-4B [arXiv:2407.14679]: pruned Nemotron — 32L d3072 24H GQA
kv=8, squared-ReLU non-gated FFN d_ff 9216, vocab 256000."""
from repro.lm.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=9216, vocab=256000,
    mlp_act="relu2", pos="rope",
)
