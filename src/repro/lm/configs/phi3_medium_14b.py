"""Phi-3-medium 14B [arXiv:2404.14219]: 40L d5120 40H GQA kv=10,
RoPE + SwiGLU, d_ff 17920, vocab 100352."""
from repro.lm.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    d_ff=17920, vocab=100352,
    mlp_act="swiglu", pos="rope",
)
