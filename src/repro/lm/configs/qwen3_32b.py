"""Qwen3-32B [hf:Qwen/Qwen3-8B family]: 64L d5120 64H GQA kv=8,
head_dim 128, qk-norm, d_ff 25600, vocab 151936."""
from repro.lm.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab=151936,
    mlp_act="swiglu", pos="rope", rope_theta=1e6, qk_norm=True,
)
