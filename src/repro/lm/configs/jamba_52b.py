"""Jamba-v0.1 52B [arXiv:2403.19887]: 32L in periods of 8 — attention at
period index 4, Mamba elsewhere (1:7); MoE (16 experts top-2, d_ff 14336)
every other layer. GQA kv=8, vocab 65536. Hybrid => long_500k eligible."""
from repro.lm.configs.base import HybridConfig, MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    mlp_act="swiglu", pos="none",  # jamba uses no positional encoding
    moe=MoEConfig(num_experts=16, top_k=2, d_expert_ff=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    hybrid=HybridConfig(period=8, attn_index=4),
    subquadratic=True,
)
