"""Model / shape / run configuration dataclasses.

One ``ModelConfig`` covers every assigned architecture family (dense,
MoE, enc-dec, VLM, SSM, hybrid) — family-specific sub-configs are
optional fields. ``reduced()`` derives the CPU smoke-test variant.
"""
from __future__ import annotations

import dataclasses
import typing


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert_ff: int
    num_shared: int = 0              # shared (always-on) experts
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    moe_period: int = 1              # MoE FFN every `period` layers
    first_dense_layers: int = 0      # leading dense-FFN layers (deepseek)
    first_dense_d_ff: int = 0
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style layer pattern: period of `period` layers with attention
    at index `attn_index`, Mamba elsewhere."""
    period: int = 8
    attn_index: int = 4


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder; the conv/audio frontend is a stub —
    ``input_specs`` supplies precomputed frame embeddings."""
    n_layers: int = 24
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense|moe|enc_dec|vlm|ssm|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 => d_model // n_heads
    mlp_act: str = "swiglu"          # swiglu|geglu|relu2
    pos: str = "rope"                # rope|sinusoidal|learned|none
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_bias: bool = False
    mlp_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    hybrid: HybridConfig | None = None
    encoder: EncoderConfig | None = None
    n_patches: int = 0               # VLM stub prefix length
    xlstm_pattern: str = ""          # e.g. "ms" = alternate mLSTM/sLSTM
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "bfloat16"
    remat: bool = True
    # logical->physical sharding rule-set name (sharding/specs.py)
    sharding_rules: str = "default"
    subquadratic: bool = False       # supports long_500k decode
    # beyond-paper optimization flags (EXPERIMENTS.md §Perf):
    #   moe_grouped   — group-local MoE routing (no global sort collectives)
    #   attn_chunked  — online-softmax attention at train/prefill lengths
    #   chunked_ce    — CE loss over vocab chunks (no (B,S,V) logits buffer)
    #   scan_unroll   — unroll recurrent scans (mamba/xlstm) to cut carry traffic
    opts: tuple = ()

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        red = dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if self.hybrid is None else self.hybrid.period),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads > 1 else 1,
            head_dim=16,
            d_ff=128,
            vocab=512,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )
        if self.moe is not None:
            red = dataclasses.replace(red, moe=dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert_ff=32,
                d_shared_ff=32 if self.moe.num_shared else 0,
                first_dense_d_ff=64 if self.moe.first_dense_layers else 0))
        if self.mamba is not None:
            red = dataclasses.replace(red, mamba=dataclasses.replace(
                self.mamba, d_state=4))
        if self.encoder is not None:
            red = dataclasses.replace(red, encoder=dataclasses.replace(
                self.encoder, n_layers=2, n_frames=16))
        if self.n_patches:
            red = dataclasses.replace(red, n_patches=8)
        return red


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One benchmark cell: (kind, seq_len, global_batch)."""
    name: str
    kind: str                        # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch × shape) runs; reason recorded in EXPERIMENTS.md."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 512k-token KV decode is not the "
                       "sub-quadratic regime this shape targets (DESIGN.md §4)")
    return True, ""
