"""PaliGemma-3B backbone [arXiv:2407.07726]: SigLIP frontend (stubbed as
precomputed patch embeddings) + Gemma-2B-class decoder. MQA (kv=1),
head_dim 256, GeGLU, tied embeddings, prefix-LM attention over patches."""
from repro.lm.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216,
    mlp_act="geglu", pos="rope", tie_embeddings=True,
    n_patches=256,
)
