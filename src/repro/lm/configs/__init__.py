"""Architecture registry: --arch <id> resolves here."""
from repro.lm.configs.base import ModelConfig, ShapeSpec, SHAPES, cell_applicable

from repro.lm.configs.paligemma_3b import CONFIG as _paligemma
from repro.lm.configs.whisper_medium import CONFIG as _whisper
from repro.lm.configs.granite_moe_1b import CONFIG as _granite
from repro.lm.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.lm.configs.command_r_35b import CONFIG as _command_r
from repro.lm.configs.minitron_4b import CONFIG as _minitron
from repro.lm.configs.qwen3_32b import CONFIG as _qwen3
from repro.lm.configs.phi3_medium_14b import CONFIG as _phi3
from repro.lm.configs.xlstm_125m import CONFIG as _xlstm
from repro.lm.configs.jamba_52b import CONFIG as _jamba

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        _paligemma, _whisper, _granite, _deepseek, _command_r,
        _minitron, _qwen3, _phi3, _xlstm, _jamba,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "get_config", "ModelConfig", "ShapeSpec", "SHAPES",
           "cell_applicable"]
