"""Granite-3.0-1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base]:
24L, 32 experts top-8, per-expert d_ff 512, GQA kv=8, tied embeddings."""
from repro.lm.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    mlp_act="swiglu", pos="rope", tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert_ff=512),
)
