"""xLSTM-125M [arXiv:2405.04517]: 12 blocks alternating mLSTM (matrix
memory, chunked linear attention) and sLSTM (scalar recurrence); d_ff=0
(no FFN blocks), 4 heads, vocab 50304. Recurrent state => O(1) decode =>
eligible for long_500k."""
from repro.lm.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab=50304,
    pos="none", xlstm_pattern="ms",
    subquadratic=True,
)
