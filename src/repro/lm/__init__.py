"""Quarantined LM scaffolding (seed-era models / training / serving glue).

The graph engine (`core`, `exchange`, `kernels`, `query`, `serve`
admission/scheduling) must not import anything from this package at
module-import time: these trees pull in the full transformer stack
(models, optimizer, train step, launch specs) which the paper
reproduction does not exercise.  Import `repro.lm.*` explicitly from
LM entry points (examples/train_lm.py, examples/serve_lm.py, the LM
test files) only.
"""
