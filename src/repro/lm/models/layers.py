"""Core transformer layers: RMSNorm, RoPE, GQA attention (+KV cache,
online-softmax chunking for long sequences), MLP variants, embeddings.

Parameter convention: init fns return a pytree whose leaves are
``Leaf(value, axes)`` — a weight plus its logical sharding axes.
``split_tree`` separates (params, axes) once per model; apply fns consume
plain arrays.
"""
from __future__ import annotations

import dataclasses
import functools
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.specs import ShardCtx, constrain


@dataclasses.dataclass
class Leaf:
    value: jax.Array
    axes: tuple


def split_tree(tree):
    is_leaf = lambda x: isinstance(x, Leaf)
    params = jax.tree.map(lambda l: l.value, tree, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=is_leaf)
    return params, axes


def dense_init(key, shape, axes, dtype, fan_in: int | None = None, scale=1.0):
    fan = fan_in if fan_in is not None else shape[0]
    w = jax.random.normal(key, shape, jnp.float32) * (scale / np.sqrt(max(fan, 1)))
    return Leaf(w.astype(dtype), axes)


def zeros_init(shape, axes, dtype):
    return Leaf(jnp.zeros(shape, dtype), axes)


def ones_init(shape, axes, dtype):
    return Leaf(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# norms / positions
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    nrm = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return ((xf / nrm) * scale.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq, d, dtype):
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

ATTN_CHUNK_THRESHOLD = 8192
KV_CHUNK = 1024


def init_attention(key, cfg, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": dense_init(ks[1], (d, KV, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": dense_init(ks[2], (d, KV, hd), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": dense_init(ks[3], (H, hd, d), ("heads", "head_dim", "embed"),
                         dtype, fan_in=H * hd),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), ("head_dim",), dtype)
        p["k_norm"] = ones_init((hd,), ("head_dim",), dtype)
    if cfg.attn_bias:
        p["bq"] = zeros_init((H, hd), ("heads", "head_dim"), dtype)
        p["bk"] = zeros_init((KV, hd), ("kv_heads", "head_dim"), dtype)
        p["bv"] = zeros_init((KV, hd), ("kv_heads", "head_dim"), dtype)
        p["bo"] = zeros_init((d,), ("embed",), dtype)
    return p


def _plain_attention(q, k, v, mask_fn, q_pos, k_pos, scale):
    """q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd). Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    kr = jnp.repeat(k, rep, axis=2)
    vr = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kr,
                        preferred_element_type=jnp.float32) * scale
    mask = mask_fn(q_pos[:, None], k_pos[None, :])  # (Sq, Sk)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, vr)


def _chunked_attention(q, k, v, mask_fn, q_pos, k_pos, scale):
    """Online-softmax over KV chunks: O(Sq·C) live memory (flash pattern)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    nc = -(-Sk // KV_CHUNK)
    pad = nc * KV_CHUNK - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
    kc = k.reshape(B, nc, KV_CHUNK, KV, hd)
    vc = v.reshape(B, nc, KV_CHUNK, KV, hd)
    pc = k_pos.reshape(nc, KV_CHUNK)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        kb = jnp.repeat(kb, rep, axis=2)
        vb = jnp.repeat(vb, rep, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                            preferred_element_type=jnp.float32) * scale
        mask = mask_fn(q_pos[:, None], pb[None, :])
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
    return jnp.moveaxis(out, 1, 2)  # (B,Sq,H,hd)


def apply_attention(p, cfg, x, positions, mask_fn, ctx: ShardCtx | None,
                    kv_override=None, cache=None, cache_index=None):
    """x: (B,S,d). mask_fn(q_pos, k_pos)->bool. Returns (out, new_cache).

    kv_override: (xkv, kv_positions) for cross-attention.
    cache: dict(k=(B,Smax,KV,hd), v=..., len=()) for incremental decode.
    """
    B, S, d = x.shape
    scale = cfg.hd ** -0.5
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    wk, wv, wo = p["wk"], p["wv"], p["wo"]
    xkv, kv_pos = (x, positions) if kv_override is None else kv_override
    k = jnp.einsum("bsd,dhk->bshk", xkv, wk)
    v = jnp.einsum("bsd,dhk->bshk", xkv, wv)
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    q = constrain(q, ("act_batch", None, "act_heads", None), ctx)
    k = constrain(k, ("act_batch", None, "act_kv_heads", None), ctx)

    new_cache = None
    if cache is not None:
        # write this step's K/V at cache_index, attend over the full cache
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv
        Smax = ck.shape[1]
        k_pos_full = jnp.arange(Smax)
        mask_base = mask_fn
        # validity from kp itself (works under per-chunk position slices)
        mask_fn = lambda qp, kp: mask_base(qp, kp) & (kp < cache_index + S)
        kv_pos = k_pos_full

    Sk = k.shape[1]
    # §Perf: 'attn_chunked' switches to online-softmax at train lengths too —
    # the (B,H,Sq,Sk) f32 score tensor never hits HBM (flash pattern)
    threshold = 1024 if "attn_chunked" in cfg.opts else ATTN_CHUNK_THRESHOLD
    attn = (_chunked_attention if max(S, Sk) > threshold
            else _plain_attention)
    out = attn(q, k.astype(q.dtype), v.astype(q.dtype), mask_fn,
               positions, kv_pos, scale)
    out = jnp.einsum("bshk,hkd->bsd", out, wo)
    if cfg.attn_bias:
        out = out + p["bo"]
    return out, new_cache


def causal_mask(qp, kp):
    return kp <= qp


def full_mask(qp, kp):
    return jnp.full(jnp.broadcast_shapes(qp.shape, kp.shape), True)


def prefix_lm_mask(prefix_len):
    def fn(qp, kp):
        return (kp <= qp) | (kp < prefix_len)
    return fn


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, dtype, d_ff=None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], (d, f), ("embed", "mlp"), dtype),
            "wg": dense_init(ks[1], (d, f), ("embed", "mlp"), dtype),
            "wo": dense_init(ks[2], (f, d), ("mlp", "embed"), dtype, fan_in=f),
        }
    return {  # relu2 / gelu: non-gated
        "wi": dense_init(ks[0], (d, f), ("embed", "mlp"), dtype),
        "wo": dense_init(ks[2], (f, d), ("mlp", "embed"), dtype, fan_in=f),
    }


def apply_mlp(p, cfg, x, ctx: ShardCtx | None):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    elif cfg.mlp_act == "geglu":
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("act_batch", None, "act_mlp"), ctx)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def init_embedding(key, cfg, dtype):
    p = {"table": dense_init(key, (cfg.vocab, cfg.d_model),
                             ("vocab", "embed"), dtype, fan_in=1)}
    return p


def embed(p, tokens, cfg):
    x = jnp.take(p["table"], tokens, axis=0)
    if cfg.family in ("vlm",):  # gemma scales embeddings
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def init_unembed(key, cfg, dtype):
    if cfg.tie_embeddings:
        return {}
    return {"wout": dense_init(key, (cfg.d_model, cfg.vocab),
                               ("embed", "vocab"), dtype)}


def unembed(p, emb_p, x, cfg, ctx):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, emb_p["table"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["wout"])
    logits = constrain(logits, ("act_batch", None, "act_vocab"), ctx)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits
