from repro.lm.models.model import Model

__all__ = ["Model"]
