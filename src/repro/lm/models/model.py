"""Model assembly: every assigned architecture as a sequence of scanned
homogeneous *stages*.

Stage kinds:
  attn_dense   — pre-norm GQA attention + dense MLP (all dense archs)
  attn_moe     — attention + MoE FFN (granite, deepseek)
  jamba_period — Jamba period of `hybrid.period` sublayers: Mamba
                 everywhere except attention at `hybrid.attn_index`;
                 MoE FFN every other sublayer
  xlstm_pair   — mLSTM block + sLSTM block (no FFN; d_ff = 0)
  enc_layer    — bidirectional encoder layer (whisper)
  dec_layer    — causal self-attn + cross-attn + MLP (whisper decoder)

Within a stage, per-layer params are stacked on a leading axis and the
stack is folded with ``lax.scan`` (keeps HLO size O(1) in depth); each
scan body is optionally wrapped in ``jax.checkpoint`` (remat).

Modes: ``loss`` (train), ``prefill`` (build caches, return logits),
``decode_step`` (one token, O(1) state updates).
"""
from __future__ import annotations

import dataclasses
import functools
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs.base import ModelConfig
from repro.lm.models import layers as L
from repro.lm.models import moe as M
from repro.lm.models import ssm as S
from repro.sharding.specs import ShardCtx, constrain


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


@dataclasses.dataclass(frozen=True)
class Stage:
    kind: str
    count: int
    d_ff: int = 0          # dense-FFN override (deepseek first layer)
    use_moe: bool = False


def build_stages(cfg: ModelConfig) -> list[Stage]:
    if cfg.family in ("dense", "vlm"):
        return [Stage("attn_dense", cfg.n_layers)]
    if cfg.family == "moe":
        st = []
        fd = cfg.moe.first_dense_layers
        if fd:
            st.append(Stage("attn_dense", fd, d_ff=cfg.moe.first_dense_d_ff))
        st.append(Stage("attn_moe", cfg.n_layers - fd, use_moe=True))
        return st
    if cfg.family == "hybrid":
        per = cfg.hybrid.period
        assert cfg.n_layers % per == 0
        return [Stage("jamba_period", cfg.n_layers // per, use_moe=True)]
    if cfg.family == "ssm":
        assert cfg.n_layers % 2 == 0
        return [Stage("xlstm_pair", cfg.n_layers // 2)]
    if cfg.family == "enc_dec":
        return [Stage("enc_layer", cfg.encoder.n_layers),
                Stage("dec_layer", cfg.n_layers)]
    raise ValueError(cfg.family)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stages = build_stages(cfg)
        self.pdt = _dt(cfg.param_dtype)
        self.adt = _dt(cfg.dtype)

    # ------------------------------------------------------------------ init
    def _init_layer(self, key, stage: Stage):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        norm = lambda: L.ones_init((cfg.d_model,), ("embed",), self.pdt)
        if stage.kind in ("attn_dense", "attn_moe", "enc_layer"):
            p = {"ln1": norm(), "attn": L.init_attention(ks[0], cfg, self.pdt),
                 "ln2": norm()}
            if stage.use_moe:
                p["moe"] = M.init_moe(ks[1], cfg, self.pdt)
            else:
                p["mlp"] = L.init_mlp(ks[1], cfg, self.pdt,
                                      d_ff=stage.d_ff or None)
            return p
        if stage.kind == "dec_layer":
            return {
                "ln1": norm(), "self_attn": L.init_attention(ks[0], cfg, self.pdt),
                "ln2": norm(), "cross_attn": L.init_attention(ks[1], cfg, self.pdt),
                "ln3": norm(), "mlp": L.init_mlp(ks[2], cfg, self.pdt),
            }
        if stage.kind == "jamba_period":
            subs = {}
            hy = cfg.hybrid
            for i in range(hy.period):
                kk = jax.random.split(ks[3 + i % 4], 4)
                sub = {"ln1": norm()}
                if i == hy.attn_index:
                    sub["attn"] = L.init_attention(kk[0], cfg, self.pdt)
                else:
                    sub["mamba"] = S.init_mamba(kk[0], cfg, self.pdt)
                sub["ln2"] = norm()
                if i % 2 == 1 and cfg.moe is not None:
                    sub["moe"] = M.init_moe(kk[1], cfg, self.pdt)
                else:
                    sub["mlp"] = L.init_mlp(kk[1], cfg, self.pdt)
                subs[f"sub{i}"] = sub
            return subs
        if stage.kind == "xlstm_pair":
            return {
                "ln_m": norm(), "mlstm": S.init_mlstm(ks[0], cfg, self.pdt),
                "ln_s": norm(), "slstm": S.init_slstm(ks[1], cfg, self.pdt),
            }
        raise ValueError(stage.kind)

    def init(self, key):
        """Returns (params, logical_axes) pytrees."""
        cfg = self.cfg
        keys = jax.random.split(key, len(self.stages) + 3)
        tree = {"embed": L.init_embedding(keys[0], cfg, self.pdt),
                "unembed": L.init_unembed(keys[1], cfg, self.pdt),
                "ln_f": L.ones_init((cfg.d_model,), ("embed",), self.pdt)}
        is_leaf = lambda x: isinstance(x, L.Leaf)
        for si, stage in enumerate(self.stages):
            lkeys = jax.random.split(keys[2 + si], stage.count)
            per = [self._init_layer(lkeys[i], stage) for i in range(stage.count)]
            stacked = jax.tree.map(
                lambda *ls: L.Leaf(jnp.stack([l.value for l in ls]),
                                   ("layers",) + ls[0].axes),
                *per, is_leaf=is_leaf)
            tree[f"stage{si}"] = stacked
        return L.split_tree(tree)

    # -------------------------------------------------------------- sublayers
    def _attn_block(self, p, x, positions, mask_fn, ctx, cache, cache_index,
                    names=("ln1", "attn")):
        cfg = self.cfg
        h = L.rms_norm(x, p[names[0]], cfg.norm_eps)
        out, new_cache = L.apply_attention(
            p[names[1]], cfg, h, positions, mask_fn, ctx,
            cache=cache, cache_index=cache_index)
        return x + out, new_cache

    def _ffn_block(self, p, x, ctx):
        cfg = self.cfg
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            out, aux = M.apply_moe(p["moe"], cfg, h, ctx)
        else:
            out, aux = L.apply_mlp(p["mlp"], cfg, h, ctx), {}
        return x + out, aux

    def _apply_layer(self, stage: Stage, p, x, positions, mask_fn, ctx,
                     cache, cache_index, mode, enc_out=None, enc_pos=None):
        """One scanned layer. Returns (x, new_cache, aux)."""
        cfg = self.cfg
        aux = {}
        if stage.kind in ("attn_dense", "attn_moe", "enc_layer"):
            mfn = L.full_mask if stage.kind == "enc_layer" else mask_fn
            x, new_cache = self._attn_block(
                p, x, positions, mfn, ctx, cache, cache_index)
            x, aux = self._ffn_block(p, x, ctx)
            return x, new_cache, aux
        if stage.kind == "dec_layer":
            x, new_self = self._attn_block(
                p, x, positions, mask_fn, ctx,
                cache.get("self") if cache else None, cache_index,
                names=("ln1", "self_attn"))
            h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
            co, _ = L.apply_attention(
                p["cross_attn"], cfg, h, positions, L.full_mask, ctx,
                kv_override=(enc_out, enc_pos))
            x = x + co
            h = L.rms_norm(x, p["ln3"], cfg.norm_eps)
            x = x + L.apply_mlp(p["mlp"], cfg, h, ctx)
            return x, ({"self": new_self} if new_self else None), aux
        if stage.kind == "jamba_period":
            hy = cfg.hybrid
            new_cache = {}
            for i in range(hy.period):
                sp = p[f"sub{i}"]
                sub_cache = cache.get(f"sub{i}") if cache else None
                if i == hy.attn_index:
                    x, nc = self._attn_block(
                        sp, x, positions, mask_fn, ctx, sub_cache, cache_index)
                else:
                    h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
                    if mode == "decode":
                        out, nc = S.mamba_step(sp["mamba"], cfg, h, sub_cache, ctx)
                    else:
                        out, nc = S.apply_mamba(sp["mamba"], cfg, h, ctx)
                    x = x + out
                x, a = self._ffn_block(sp, x, ctx)
                for k, v in a.items():
                    aux[k] = aux.get(k, 0.0) + v
                if nc is not None:
                    new_cache[f"sub{i}"] = nc
            return x, (new_cache or None), aux
        if stage.kind == "xlstm_pair":
            h = L.rms_norm(x, p["ln_m"], cfg.norm_eps)
            if mode == "decode":
                out, ncm = S.mlstm_step(p["mlstm"], cfg, h,
                                        cache["m"] if cache else None, ctx)
            else:
                out, ncm = S.apply_mlstm(p["mlstm"], cfg, h, ctx)
            x = x + out
            h = L.rms_norm(x, p["ln_s"], cfg.norm_eps)
            if mode == "decode":
                out, ncs = S.slstm_step(p["slstm"], cfg, h,
                                        cache["s"] if cache else None, ctx)
            else:
                out, ncs = S.apply_slstm(p["slstm"], cfg, h, ctx)
            x = x + out
            return x, {"m": ncm, "s": ncs}, aux
        raise ValueError(stage.kind)

    # ---------------------------------------------------------------- stages
    def _run_stage(self, si, stage, params, x, positions, mask_fn, ctx,
                   caches, cache_index, mode, enc_out=None, enc_pos=None):
        """Scan the stacked layers of one stage."""
        p_st = params[f"stage{si}"]
        cache_st = caches.get(f"stage{si}") if caches else None
        aux_zero = self._aux_zero(stage)

        def body(x, layer_in):
            p_layer, cache_layer = layer_in
            x = constrain(x, ("act_batch", "act_seq", None), ctx)
            x, new_cache, aux = self._apply_layer(
                stage, p_layer, x, positions, mask_fn, ctx, cache_layer,
                cache_index, mode, enc_out, enc_pos)
            aux = {**aux_zero, **{k: jnp.asarray(v, jnp.float32)
                                  for k, v in aux.items()}}
            return x, (new_cache, aux)

        if self.cfg.remat and mode == "train":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (new_caches, auxs) = jax.lax.scan(body, x, (p_st, cache_st))
        aux = {k: v.sum() for k, v in auxs.items()}
        return x, new_caches, aux

    def _aux_zero(self, stage):
        if stage.use_moe and self.cfg.moe is not None:
            return {"moe_load_balance": jnp.zeros((), jnp.float32),
                    "moe_router_z": jnp.zeros((), jnp.float32),
                    "moe_drop_fraction": jnp.zeros((), jnp.float32)}
        return {}

    # ---------------------------------------------------------------- fronts
    def _embed_tokens(self, params, batch, ctx, pos_offset=0):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"], cfg).astype(self.adt)
        if cfg.pos == "sinusoidal":  # whisper decoder-style table positions
            S = x.shape[1]
            table = L.sinusoidal_positions(pos_offset + S, cfg.d_model, self.adt)
            x = x + table[None, pos_offset:pos_offset + S]
        prefix_len = 0
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(self.adt)
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
        x = constrain(x, ("act_batch", "act_seq", None), ctx)
        return x, prefix_len

    def _encoder(self, params, batch, ctx):
        """Whisper encoder over stub frame embeddings."""
        cfg = self.cfg
        frames = batch["frames"].astype(self.adt)
        F = frames.shape[1]
        pos_table = L.sinusoidal_positions(F, cfg.d_model, self.adt)
        x = frames + pos_table[None]
        positions = jnp.arange(F)
        x, _, _ = self._run_stage(0, self.stages[0], params, x, positions,
                                  L.full_mask, ctx, None, None, "train")
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        return x, positions

    # ----------------------------------------------------------------- modes
    def _backbone(self, params, x, positions, mask_fn, ctx, caches,
                  cache_index, mode, enc_out=None, enc_pos=None):
        new_caches = {}
        aux = {}
        for si, stage in enumerate(self.stages):
            if stage.kind == "enc_layer":
                continue  # encoder handled separately
            x, nc, a = self._run_stage(
                si, stage, params, x, positions, mask_fn, ctx, caches,
                cache_index, mode, enc_out, enc_pos)
            if nc is not None:
                new_caches[f"stage{si}"] = nc
            for k, v in a.items():
                aux[k] = aux.get(k, 0.0) + v
        return x, new_caches, aux

    def loss(self, params, batch, ctx: ShardCtx | None = None):
        """Next-token CE (+ MoE aux). batch: tokens (B,S) [, labels (B,S),
        patch_embeds, frames]."""
        cfg = self.cfg
        labels = batch.get("labels", batch["tokens"])
        enc_out = enc_pos = None
        if cfg.family == "enc_dec":
            enc_out, enc_pos = self._encoder(params, batch, ctx)
        x, prefix_len = self._embed_tokens(params, batch, ctx)
        Stot = x.shape[1]
        positions = jnp.arange(Stot)
        mask_fn = (L.prefix_lm_mask(prefix_len) if prefix_len
                   else L.causal_mask)
        x, _, aux = self._backbone(params, x, positions, mask_fn, ctx,
                                   None, None, "train", enc_out, enc_pos)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if prefix_len:
            x = x[:, prefix_len:]
        if "chunked_ce" in cfg.opts:
            ce = self._chunked_ce(params, x[:, :-1], labels[:, 1:], ctx)
        else:
            logits = L.unembed(params["unembed"], params["embed"], x, cfg, ctx)
            lg = logits[:, :-1].astype(jnp.float32)
            tg = labels[:, 1:]
            lse = jax.nn.logsumexp(lg, axis=-1)
            ll = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
            ce = (lse - ll).mean()
        total = ce
        metrics = {"ce": ce}
        for k, v in aux.items():
            metrics[k] = v
            if k in ("moe_load_balance", "moe_router_z"):
                total = total + v
        metrics["loss"] = total
        return total, metrics

    def _chunked_ce(self, params, x, labels, ctx, chunk: int = 256):
        """§Perf: CE over sequence chunks under a rematerialized scan — the
        (B, S, V) logits tensor never materializes (peak logits buffer is
        (B, chunk, V)). Exact same loss value as the dense path."""
        cfg = self.cfg
        B, S, d = x.shape
        nc = S // chunk if S % chunk == 0 else 1
        ck = S // nc
        xc = jnp.moveaxis(x.reshape(B, nc, ck, d), 1, 0)
        tc = jnp.moveaxis(labels.reshape(B, nc, ck), 1, 0)

        def body(acc, inp):
            xb, tb = inp
            logits = L.unembed(params["unembed"], params["embed"], xb,
                               cfg, ctx).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
            return acc + (lse - ll).sum(), None

        body = jax.checkpoint(body)
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
        return total / (B * S)

    # ---- serving ----------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int,
                   cache_dtype=None, enc_frames: int | None = None):
        """Zeroed cache pytree (use under jax.eval_shape for dry-runs)."""
        cfg = self.cfg
        cdt = cache_dtype or self.adt
        KV, hd = cfg.n_kv_heads, cfg.hd

        def attn_cache():
            return {"k": jnp.zeros((batch_size, max_len, KV, hd), cdt),
                    "v": jnp.zeros((batch_size, max_len, KV, hd), cdt)}

        caches = {}
        for si, stage in enumerate(self.stages):
            if stage.kind in ("attn_dense", "attn_moe"):
                caches[f"stage{si}"] = jax.tree.map(
                    lambda x: jnp.zeros((stage.count,) + x.shape, x.dtype),
                    attn_cache())
            elif stage.kind == "dec_layer":
                caches[f"stage{si}"] = jax.tree.map(
                    lambda x: jnp.zeros((stage.count,) + x.shape, x.dtype),
                    {"self": attn_cache()})
            elif stage.kind == "jamba_period":
                hy = cfg.hybrid
                per = {}
                di = cfg.mamba.expand * cfg.d_model
                for i in range(hy.period):
                    if i == hy.attn_index:
                        per[f"sub{i}"] = attn_cache()
                    else:
                        per[f"sub{i}"] = {
                            "conv": jnp.zeros(
                                (batch_size, cfg.mamba.d_conv - 1, di), cdt),
                            "ssm": jnp.zeros(
                                (batch_size, di, cfg.mamba.d_state),
                                jnp.float32)}
                caches[f"stage{si}"] = jax.tree.map(
                    lambda x: jnp.zeros((stage.count,) + x.shape, x.dtype), per)
            elif stage.kind == "xlstm_pair":
                H = cfg.n_heads
                per = {
                    "m": {"C": jnp.zeros((batch_size, H, hd, hd), jnp.float32),
                          "n": jnp.zeros((batch_size, H, hd), jnp.float32),
                          "m": jnp.zeros((batch_size, H), jnp.float32)},
                    "s": {"c": jnp.zeros((batch_size, H, hd), jnp.float32),
                          "n": jnp.zeros((batch_size, H, hd), jnp.float32),
                          "m": jnp.full((batch_size, H), -30.0, jnp.float32)},
                }
                caches[f"stage{si}"] = jax.tree.map(
                    lambda x: jnp.zeros((stage.count,) + x.shape, x.dtype), per)
        return caches

    def prefill(self, params, batch, caches, ctx: ShardCtx | None = None):
        """Fill caches from a prompt; returns (last-token logits, caches).
        For enc_dec, also computes encoder output (stored under 'enc')."""
        cfg = self.cfg
        enc_out = enc_pos = None
        if cfg.family == "enc_dec":
            enc_out, enc_pos = self._encoder(params, batch, ctx)
        x, prefix_len = self._embed_tokens(params, batch, ctx)
        positions = jnp.arange(x.shape[1])
        mask_fn = (L.prefix_lm_mask(prefix_len) if prefix_len
                   else L.causal_mask)
        x, new_caches, _ = self._backbone(
            params, x, positions, mask_fn, ctx, caches, 0, "prefill",
            enc_out, enc_pos)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["unembed"], params["embed"],
                           x[:, -1:], cfg, ctx)
        if cfg.family == "enc_dec":
            new_caches["enc"] = {"out": enc_out, "pos": enc_pos}
        return logits, new_caches

    def decode_step(self, params, tokens_t, caches, index,
                    ctx: ShardCtx | None = None):
        """tokens_t: (B,1) next-token ids; index: scalar current length."""
        cfg = self.cfg
        x = L.embed(params["embed"], tokens_t, cfg).astype(self.adt)
        positions = jnp.asarray(index)[None]
        enc_out = enc_pos = None
        if cfg.family == "enc_dec":
            enc_out = caches["enc"]["out"]
            enc_pos = caches["enc"]["pos"]
        if cfg.pos == "sinusoidal":
            smax = jax.tree.leaves(
                {k: v for k, v in caches.items() if k != "enc"})[0].shape[2]
            table = L.sinusoidal_positions(smax, cfg.d_model, self.adt)
            x = x + jax.lax.dynamic_slice_in_dim(table, index, 1)[None]
        x, new_caches, _ = self._backbone(
            params, x, positions, L.causal_mask, ctx, caches, index,
            "decode", enc_out, enc_pos)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = L.unembed(params["unembed"], params["embed"], x, cfg, ctx)
        if cfg.family == "enc_dec":
            new_caches["enc"] = caches["enc"]
        return logits, new_caches
