"""Top-k token-choice MoE with static capacity, shared experts, and EP.

Routing is sort/scatter based — no (T, E, C) dispatch tensor:

1. per-group top-k assignment (groups = data-parallel rows, so sorting
   stays shard-local under GSPMD),
2. rank-within-expert via sorted cumulative counts,
3. scatter into an (E, C, d) buffer with ``mode="drop"`` (capacity
   overflow drops, like classic capacity-factor routing),
4. grouped einsum over experts (experts sharded over the model axis ⇒
   the token->expert reshard lowers to an all-to-all = EP),
5. gather back + combine with router weights.

**Rhizome note (DESIGN.md §4):** token→expert routing is a skewed
bipartite graph; the (E, C) buffer is the expert's "replica slot" row and
the capacity clip plays the cutoff_chunk role — the same
split-hot-destinations idea the paper applies to hub vertices.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.lm.models import layers as L
from repro.sharding.specs import ShardCtx, constrain


def init_moe(key, cfg, dtype):
    m = cfg.moe
    d = cfg.d_model
    f = m.d_expert_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, m.num_experts), ("embed", None),
                               dtype, scale=0.1),
        "w_gate": L.dense_init(ks[1], (m.num_experts, d, f),
                               ("experts", "embed", "expert_mlp"), dtype,
                               fan_in=d),
        "w_up": L.dense_init(ks[2], (m.num_experts, d, f),
                             ("experts", "embed", "expert_mlp"), dtype,
                             fan_in=d),
        "w_down": L.dense_init(ks[3], (m.num_experts, f, d),
                               ("experts", "expert_mlp", "embed"), dtype,
                               fan_in=f),
    }
    if m.num_shared:
        shared_ff = m.d_shared_ff or m.d_expert_ff
        p["shared"] = L.init_mlp(ks[4], cfg, dtype,
                                 d_ff=shared_ff * m.num_shared)
    return p


def apply_moe(p, cfg, x, ctx: ShardCtx | None):
    """x: (B, S, d) -> (out, aux_losses dict)."""
    if "moe_shardmap" in cfg.opts and ctx is not None and ctx.mesh is not None:
        return apply_moe_shardmap(p, cfg, x, ctx)
    if ("moe_grouped" in cfg.opts or "moe_shardmap" in cfg.opts):
        return apply_moe_grouped(p, cfg, x, ctx)
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    T = B * S
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)            # renormalize top-k

    # ---- rank within expert (sorted cumulative counts) --------------------
    flat_e = eidx.reshape(-1)                              # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[se]

    C = max(int(T * K / E * m.capacity_factor), 1)
    keep = rank < C
    rank_c = jnp.where(keep, rank, 0)
    e_idx = jnp.where(keep, se, E)                         # E => dropped

    # ---- dispatch: (E, C, d) ----------------------------------------------
    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[e_idx, rank_c].set(
        jnp.where(keep[:, None], xt[st], 0.0), mode="drop")
    buf = constrain(buf, ("act_experts", None, None), ctx)

    # ---- expert compute (grouped einsums; experts sharded 'tp') -----------
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    act = jax.nn.silu(g) * h if cfg.mlp_act in ("swiglu", "geglu") else \
        jnp.square(jax.nn.relu(h))
    out_buf = jnp.einsum("ecf,efd->ecd", act, p["w_down"])
    out_buf = constrain(out_buf, ("act_experts", None, None), ctx)

    # ---- combine -----------------------------------------------------------
    gathered = out_buf[e_idx, rank_c]                      # (T*K, d), 0 if drop
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    out = jnp.zeros((T, d), xt.dtype).at[st].add(gathered * sg[:, None].astype(xt.dtype))

    if m.num_shared:
        out = out + L.apply_mlp(p["shared"], cfg, x, ctx).reshape(T, d)

    # ---- aux losses (Switch-style load balance + router z-loss) -----------
    me = probs.mean(axis=0)                                # (E,)
    ce = jnp.bincount(flat_e, length=E) / (T * K)
    aux = {
        "moe_load_balance": E * jnp.sum(me * ce) * m.router_aux_weight,
        "moe_router_z": (jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_weight),
        "moe_drop_fraction": 1.0 - keep.mean(),
    }
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# §Perf optimization: group-local routing
# ---------------------------------------------------------------------------

def _route_group(xt, logits, E, K, C, mlp_act):
    """Route one token group: returns (dispatch buffer (E,C,d), combine
    metadata). All ops are local to the group — under a (G[dp], ...)
    sharding, GSPMD keeps sort/bincount/scatter shard-local."""
    T = xt.shape[0]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    flat_e = eidx.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < C
    rank_c = jnp.where(keep, rank, 0)
    e_idx = jnp.where(keep, se, E)
    buf = jnp.zeros((E, C, xt.shape[1]), xt.dtype)
    buf = buf.at[e_idx, rank_c].set(
        jnp.where(keep[:, None], xt[st], 0.0), mode="drop")
    return buf, (e_idx, rank_c, st, sg, keep, probs, flat_e)


def apply_moe_shardmap(p, cfg, x, ctx: ShardCtx):
    """§Perf iteration 2 (MoE cells): GSPMD lowers the combine gather (and
    the dispatch scatter's backward) into all-reduces of (Tg·K, d) f32
    buffers — 6×K more bytes than necessary. Hand-schedule EP with
    shard_map: each tp shard dispatches/computes ONLY its local experts,
    produces a partial (Tg, d) token-sum, and one bf16 psum over tp
    finishes the combine. Expert weights stay FSDP'd over dp (manual
    all-gather inside; AD gives the reduce-scatter wgrad)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    dp_axes, tp_axes = ctx.dp, ctx.tp
    G = ctx.axis_size("dp")
    tp = ctx.axis_size("tp")
    T = B * S
    Tg = T // G
    Cg = max(int(Tg * K / E * m.capacity_factor), 1)
    assert E % tp == 0, (E, tp)
    E_loc = E // tp

    xg = x.reshape(G, Tg, d)
    # router + aux outside (tiny, replicated over tp is fine)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eidx = jax.lax.top_k(probs, K)                # (G,Tg,K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def ffn(xg_l, eidx_l, gate_l, wg_l, wu_l, wd_l):
        # shapes: xg_l (1,Tg,d) dp-local; eidx/gate (1,Tg,K);
        # w*_l (E_loc, d/|dp|, f) — gather FSDP shards of local experts
        xg_l, eidx_l, gate_l = xg_l[0], eidx_l[0], gate_l[0]
        wg = lax.all_gather(wg_l, dp_axes, axis=1, tiled=True)
        wu = lax.all_gather(wu_l, dp_axes, axis=1, tiled=True)
        wd = lax.all_gather(wd_l, dp_axes, axis=2, tiled=True)
        my = lax.axis_index(tp_axes)
        e0 = my * E_loc
        flat_e = eidx_l.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tg), K)
        # keep the whole dispatch/combine chain in activation dtype: a f32
        # gate here promotes the backward gather/scatter chain to f32 (2x
        # HBM traffic on (Tg*K, d) buffers — §Perf iteration 4)
        flat_g = gate_l.reshape(-1).astype(xg_l.dtype)
        order = jnp.argsort(flat_e)
        se, st, sg = flat_e[order], flat_t[order], flat_g[order]
        counts = jnp.bincount(se, length=E)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(Tg * K) - starts[se]
        local = (se >= e0) & (se < e0 + E_loc) & (rank < Cg)
        e_rel = jnp.where(local, se - e0, E_loc)
        rank_c = jnp.where(local, rank, 0)
        buf = jnp.zeros((E_loc, Cg, d), xg_l.dtype)
        buf = buf.at[e_rel, rank_c].set(
            jnp.where(local[:, None], xg_l[st], 0.0), mode="drop")
        h = jnp.einsum("ecd,edf->ecf", buf, wu)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        act = (jax.nn.silu(g) * h if cfg.mlp_act in ("swiglu", "geglu")
               else jnp.square(jax.nn.relu(h)))
        ob = jnp.einsum("ecf,efd->ecd", act, wd)
        gathered = ob[e_rel, rank_c]
        gathered = jnp.where(local[:, None], gathered, 0.0)
        part = jnp.zeros((Tg, d), ob.dtype).at[st].add(
            gathered * sg[:, None].astype(ob.dtype))
        out = lax.psum(part, tp_axes)               # one bf16 (Tg,d) reduce
        return out[None]

    fn = shard_map(
        ffn, mesh=ctx.mesh,
        in_specs=(P(dp_axes, None, None), P(dp_axes, None, None),
                  P(dp_axes, None, None),
                  P(tp_axes, dp_axes, None), P(tp_axes, dp_axes, None),
                  P(tp_axes, None, dp_axes)),
        out_specs=P(dp_axes, None, None),
        check_rep=False,
    )
    out = fn(xg, eidx, gate_vals, p["w_gate"], p["w_up"], p["w_down"])
    out = out.reshape(B, S, d)
    if m.num_shared:
        out = out + L.apply_mlp(p["shared"], cfg, x, ctx)

    me = probs.mean(axis=(0, 1))
    ce = jax.vmap(lambda fe: jnp.bincount(fe.reshape(-1), length=E))(
        eidx).sum(0) / (T * K)
    aux = {
        "moe_load_balance": E * jnp.sum(me * ce) * m.router_aux_weight,
        "moe_router_z": (jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_weight),
        "moe_drop_fraction": jnp.zeros((), jnp.float32),  # tracked in tests
    }
    return out, aux


def apply_moe_grouped(p, cfg, x, ctx: ShardCtx | None):
    """Hypothesis (§Perf iteration 1, MoE cells): global-token routing puts
    argsort/bincount/scatter across the DP-sharded token dim, which GSPMD
    lowers to full-activation all-gathers per MoE layer. Routing *within
    per-DP-shard groups* keeps those ops local; the only cross-shard
    movement left is the dispatched (G, E, C_g, d) buffer reshard
    (token->expert all-to-all = textbook EP)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    G = ctx.axis_size("dp") if ctx is not None and ctx.mesh is not None else 1
    T = B * S
    assert T % G == 0, (T, G)
    Tg = T // G
    Cg = max(int(Tg * K / E * m.capacity_factor), 1)

    xg = x.reshape(G, Tg, d)
    xg = constrain(xg, ("act_batch", None, None), ctx)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)

    buf, meta = jax.vmap(
        lambda xt, lg: _route_group(xt, lg, E, K, Cg, cfg.mlp_act))(xg, logits)
    e_idx, rank_c, st, sg, keep, probs, flat_e = meta
    # (G, E, Cg, d): G over dp, E over tp => GSPMD emits the EP all-to-all
    buf = constrain(buf, ("act_batch", "act_experts", None, None), ctx)

    h = jnp.einsum("gecd,edf->gecf", buf, p["w_up"])
    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])
    act = jax.nn.silu(g) * h if cfg.mlp_act in ("swiglu", "geglu") else \
        jnp.square(jax.nn.relu(h))
    out_buf = jnp.einsum("gecf,efd->gecd", act, p["w_down"])
    out_buf = constrain(out_buf, ("act_batch", "act_experts", None, None), ctx)

    def combine(out_b, e_i, r_c, s_t, s_g, kp):
        gathered = out_b[e_i, r_c]
        gathered = jnp.where(kp[:, None], gathered, 0.0)
        return jnp.zeros((Tg, d), out_b.dtype).at[s_t].add(
            gathered * s_g[:, None].astype(out_b.dtype))

    out = jax.vmap(combine)(out_buf, e_idx, rank_c, st, sg, keep)
    out = constrain(out, ("act_batch", None, None), ctx).reshape(B, S, d)

    if m.num_shared:
        out = out + L.apply_mlp(p["shared"], cfg, x, ctx)

    me = probs.mean(axis=(0, 1))
    ce = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_e).sum(0) / (T * K)
    aux = {
        "moe_load_balance": E * jnp.sum(me * ce) * m.router_aux_weight,
        "moe_router_z": (jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2) * m.router_z_weight),
        "moe_drop_fraction": 1.0 - keep.mean(),
    }
    return out, aux
