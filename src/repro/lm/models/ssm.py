"""State-space / recurrent blocks: Mamba (selective SSM) and xLSTM
(mLSTM chunked linear attention + sLSTM scalar recurrence).

All blocks expose two forms:
* sequence form  — ``apply_*(p, cfg, x)`` over (B, S, d) for train/prefill;
* step form      — ``*_step(p, cfg, x_t, state)`` for O(1) decode, which
  is what makes the ssm/hybrid archs eligible for the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.models import layers as L
from repro.sharding.specs import constrain

MLSTM_CHUNK = 256


def _unroll(cfg, length):
    """§Perf 'scan_unroll': unroll recurrent scans so the carry is written
    back to HBM once per U steps instead of every step."""
    if "scan_unroll" in cfg.opts:
        for u in (32, 16, 8, 4):
            if length % u == 0:
                return u
    return 1


# ---------------------------------------------------------------------------
# Mamba (selective SSM, mamba-1 style as used by Jamba)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg, dtype):
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.expand * d
    ks = jax.random.split(key, 7)
    dt_rank = max(d // 16, 1)
    a = jnp.tile(jnp.arange(1, mc.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": L.dense_init(ks[0], (d, 2 * di), ("embed", "mamba_inner"), dtype),
        "conv_w": L.dense_init(ks[1], (mc.d_conv, di), (None, "mamba_inner"),
                               dtype, fan_in=mc.d_conv),
        "conv_b": L.zeros_init((di,), ("mamba_inner",), dtype),
        "x_proj": L.dense_init(ks[2], (di, dt_rank + 2 * mc.d_state),
                               ("mamba_inner", None), dtype, fan_in=di),
        "dt_proj": L.dense_init(ks[3], (dt_rank, di), (None, "mamba_inner"),
                                dtype, fan_in=dt_rank),
        "dt_bias": L.zeros_init((di,), ("mamba_inner",), dtype),
        "A_log": L.Leaf(jnp.log(a).astype(jnp.float32), ("mamba_inner", None)),
        "D": L.ones_init((di,), ("mamba_inner",), jnp.float32),
        "out_proj": L.dense_init(ks[4], (di, d), ("mamba_inner", "embed"),
                                 dtype, fan_in=di),
    }


def _mamba_scan_inputs(p, cfg, xz):
    """Shared front: conv + projections. xz: (B,S,2*di) -> (u,dt,Bm,Cm,z)."""
    mc = cfg.mamba
    di = p["conv_b"].shape[0]
    dt_rank = p["dt_proj"].shape[0]
    u, z = jnp.split(xz, 2, axis=-1)                # (B,S,di) each
    # causal depthwise conv along S
    pad = mc.d_conv - 1
    up = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
    u = sum(up[:, i : i + u.shape[1]] * p["conv_w"][i]
            for i in range(mc.d_conv)) + p["conv_b"]
    u = jax.nn.silu(u)
    proj = jnp.einsum("bsi,ij->bsj", u, p["x_proj"])
    dt_in, Bm, Cm = jnp.split(
        proj, [dt_rank, dt_rank + mc.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, p["dt_proj"]) + p["dt_bias"])
    return u, dt, Bm, Cm, z


def apply_mamba(p, cfg, x, ctx=None):
    """Sequence form. x: (B,S,d). Returns (y, final_state) so prefill can
    hand the recurrent state to the decode loop."""
    mc = cfg.mamba
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xz = constrain(xz, ("act_batch", None, "act_mamba_inner"), ctx)
    u_raw = jnp.split(xz, 2, axis=-1)[0]
    u, dt, Bm, Cm, z = _mamba_scan_inputs(p, cfg, xz)
    A = -jnp.exp(p["A_log"])                        # (di, N)

    def step(h, inp):
        u_t, dt_t, B_t, C_t = inp                   # (B,di),(B,di),(B,N),(B,N)
        dA = jnp.exp(dt_t[..., None] * A)           # (B,di,N)
        dBu = dt_t[..., None] * B_t[:, None, :] * u_t[..., None]
        h = h * dA + dBu
        # keep the carry (and hence the grad stash) sharded over d_inner —
        # otherwise GSPMD replicates the whole recurrence per tp shard
        h = constrain(h, ("act_batch", "act_mamba_inner", None), ctx)
        y = jnp.einsum("bin,bn->bi", h, C_t)
        return h, y

    B, S, di = u.shape
    h0 = jnp.zeros((B, di, mc.d_state), jnp.float32)
    xs = (jnp.moveaxis(u, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cm, 1, 0).astype(jnp.float32))
    xs = (constrain(xs[0], (None, "act_batch", "act_mamba_inner"), ctx),
          constrain(xs[1], (None, "act_batch", "act_mamba_inner"), ctx),
          xs[2], xs[3])
    h_last, ys = jax.lax.scan(step, h0, xs, unroll=_unroll(cfg, S))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype) + u * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    # final conv state: last (d_conv-1) pre-conv inputs
    pad = max(mc.d_conv - 1 - S, 0)
    tail = u_raw[:, S - (mc.d_conv - 1 - pad):]
    if pad:
        tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
    state = {"conv": tail.astype(u_raw.dtype), "ssm": h_last}
    return out, state


def mamba_init_state(p, cfg, batch, dtype):
    mc = cfg.mamba
    di = p["conv_b"].shape[0]
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, mc.d_state), jnp.float32),
    }


def mamba_step(p, cfg, x_t, state, ctx=None):
    """x_t: (B,1,d). O(1) decode update."""
    mc = cfg.mamba
    xz = jnp.einsum("bsd,de->bse", x_t, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)                # (B,1,di)
    conv_buf = jnp.concatenate([state["conv"], u], axis=1)  # (B,d_conv,di)
    u1 = (jnp.einsum("bci,ci->bi", conv_buf, p["conv_w"]) + p["conv_b"])[:, None]
    u1 = jax.nn.silu(u1)
    dt_rank = p["dt_proj"].shape[0]
    proj = jnp.einsum("bsi,ij->bsj", u1, p["x_proj"])
    dt_in, Bm, Cm = jnp.split(proj, [dt_rank, dt_rank + mc.d_state], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_in, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A)
    dBu = (dt[:, 0, :, None] * Bm[:, 0, None, :] * u1[:, 0, :, None]).astype(jnp.float32)
    h = state["ssm"] * dA + dBu
    y = jnp.einsum("bin,bn->bi", h, Cm[:, 0].astype(jnp.float32))[:, None]
    y = y.astype(x_t.dtype) + u1 * p["D"].astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"conv": conv_buf[:, 1:], "ssm": h}


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM, chunkwise-parallel linear attention w/ gating)
# ---------------------------------------------------------------------------

def init_mlstm(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "wq": L.dense_init(ks[0], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": L.dense_init(ks[1], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wv": L.dense_init(ks[2], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wi": L.dense_init(ks[3], (d, H), ("embed", "heads"), dtype, scale=0.1),
        "wf": L.dense_init(ks[4], (d, H), ("embed", "heads"), dtype, scale=0.1),
        "f_bias": L.Leaf(jnp.full((H,), 3.0, jnp.float32), ("heads",)),
        "wo": L.dense_init(ks[5], (H, hd, d), ("heads", "head_dim", "embed"),
                           dtype, fan_in=H * hd),
        "norm": L.ones_init((H, hd), ("heads", "head_dim"), dtype),
    }


def _mlstm_gates(p, x):
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x, p["wf"]).astype(jnp.float32)
        + p["f_bias"])
    logi = jnp.einsum("bsd,dh->bsh", x, p["wi"]).astype(jnp.float32)
    return logi, logf


def apply_mlstm(p, cfg, x, ctx=None):
    """Chunkwise-parallel mLSTM. x: (B,S,d)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) * hd ** -0.5
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"]) * hd ** -0.5
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    logi, logf = _mlstm_gates(p, x)

    Lc = min(MLSTM_CHUNK, S)
    nc = -(-S // Lc)
    pad = nc * Lc - S
    if pad:
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)), constant_values=-30.0)
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))

    def chunk(t):
        return jnp.moveaxis(
            t.reshape(B, nc, Lc, *t.shape[2:]), 1, 0)  # (nc,B,Lc,...)

    qc, kc, vc, lic, lfc = map(chunk, (q, k, v, logi, logf))

    def step(carry, inp):
        Cst, nst, mst = carry          # (B,H,hd,hd),(B,H,hd),(B,H)
        qb, kb, vb, li, lf = inp
        # cumulative log-forget within the chunk
        F = jnp.cumsum(lf, axis=1)                     # (B,Lc,H)
        # intra-chunk decay matrix D[t,s] = exp(F_t - F_s + i_s) for s<=t
        logD = (F[:, :, None, :] - F[:, None, :, :]
                + li[:, None, :, :])                   # (B,Lq,Ls,H)
        tri = jnp.tril(jnp.ones((Lc, Lc), bool))
        logD = jnp.where(tri[None, :, :, None], logD, -jnp.inf)
        # inter-chunk: state decayed by exp(F_t), query it
        m_intra = logD.max(axis=2)                     # (B,Lq,H)
        m_inter = mst[:, None, :] + F                  # (B,Lq,H)
        m_all = jnp.maximum(m_intra, m_inter)
        Dn = jnp.exp(logD - m_all[:, :, None, :])
        scores = jnp.einsum("bqhk,bshk->bqsh", qb, kb) * Dn
        h_intra = jnp.einsum("bqsh,bshk->bqhk", scores, vb)
        w_inter = jnp.exp(m_inter - m_all)             # (B,Lq,H)
        h_inter = jnp.einsum("bqhk,bhkx->bqhx", qb * w_inter[..., None], Cst)
        norm_intra = scores.sum(axis=2)                # (B,Lq,H)
        norm_inter = jnp.einsum("bqhk,bhk->bqh", qb * w_inter[..., None], nst)
        h = h_intra + h_inter
        denom = jnp.maximum(jnp.abs(norm_intra + norm_inter),
                            jnp.exp(-m_all))[..., None]
        out = h / denom
        # ---- state update to end of chunk ----
        Fend = F[:, -1, :]                             # (B,H)
        m_new = jnp.maximum(mst + Fend, (Fend[:, None, :] - F + li).max(axis=1))
        decay_state = jnp.exp(mst + Fend - m_new)      # (B,H)
        wk_ = jnp.exp(Fend[:, None, :] - F + li - m_new[:, None, :])  # (B,Ls,H)
        C_new = (Cst * decay_state[..., None, None]
                 + jnp.einsum("bsh,bshk,bshx->bhkx", wk_, kb, vb))
        n_new = (nst * decay_state[..., None]
                 + jnp.einsum("bsh,bshk->bhk", wk_, kb))
        return (C_new, n_new, m_new), out

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    (Cf, nf, mf), outs = jax.lax.scan(
        step, (C0, n0, m0),
        (qc.astype(jnp.float32), kc.astype(jnp.float32),
         vc.astype(jnp.float32), lic, lfc))  # chunked already: nc is small
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nc * Lc, H, hd)[:, :S]
    out = L.rms_norm(out, p["norm"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"C": Cf, "n": nf, "m": mf}


def mlstm_init_state(p, cfg, batch, dtype):
    H, hd = cfg.n_heads, cfg.hd
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.zeros((batch, H), jnp.float32),
    }


def mlstm_step(p, cfg, x_t, state, ctx=None):
    """x_t: (B,1,d); O(1) recurrent update."""
    B = x_t.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bd,dhk->bhk", x_t[:, 0], p["wq"]) * hd ** -0.5
    k = jnp.einsum("bd,dhk->bhk", x_t[:, 0], p["wk"]) * hd ** -0.5
    v = jnp.einsum("bd,dhk->bhk", x_t[:, 0], p["wv"])
    logi, logf = _mlstm_gates(p, x_t)
    logi, logf = logi[:, 0], logf[:, 0]              # (B,H)
    m_new = jnp.maximum(state["m"] + logf, logi)
    fdec = jnp.exp(state["m"] + logf - m_new)
    iw = jnp.exp(logi - m_new)
    C = state["C"] * fdec[..., None, None] + jnp.einsum(
        "bhk,bhx->bhkx", (k * iw[..., None]).astype(jnp.float32),
        v.astype(jnp.float32))
    n = state["n"] * fdec[..., None] + (k * iw[..., None]).astype(jnp.float32)
    h = jnp.einsum("bhk,bhkx->bhx", q.astype(jnp.float32), C)
    denom = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n)),
        jnp.exp(-m_new))[..., None]
    out = (h / denom)[:, None]                       # (B,1,H,hd)
    out = L.rms_norm(out, p["norm"], cfg.norm_eps).astype(x_t.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with exponential gating)
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, dtype):
    d, H = cfg.d_model, cfg.n_heads
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "wz": L.dense_init(ks[0], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "wi": L.dense_init(ks[1], (d, H), ("embed", "heads"), dtype, scale=0.1),
        "wf": L.dense_init(ks[2], (d, H), ("embed", "heads"), dtype, scale=0.1),
        "wo_gate": L.dense_init(ks[3], (d, H, hd), ("embed", "heads", "head_dim"), dtype),
        "f_bias": L.Leaf(jnp.full((H,), 3.0, jnp.float32), ("heads",)),
        "wo": L.dense_init(ks[4], (H, hd, d), ("heads", "head_dim", "embed"),
                           dtype, fan_in=H * hd),
    }


def _slstm_step_math(p, z_t, o_t, logi, logf, state):
    c, n, m = state                                  # (B,H,hd),(B,H,hd),(B,H)
    m_new = jnp.maximum(logf + m, logi)
    fw = jnp.exp(logf + m - m_new)[..., None]
    iw = jnp.exp(logi - m_new)[..., None]
    c_new = fw * c + iw * jnp.tanh(z_t)
    n_new = fw * n + iw
    h = jax.nn.sigmoid(o_t) * c_new / jnp.maximum(n_new, 1e-6)
    return h, (c_new, n_new, m_new)


def apply_slstm(p, cfg, x, ctx=None):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    z = jnp.einsum("bsd,dhk->bshk", x, p["wz"]).astype(jnp.float32)
    o = jnp.einsum("bsd,dhk->bshk", x, p["wo_gate"]).astype(jnp.float32)
    logi = jnp.einsum("bsd,dh->bsh", x, p["wi"]).astype(jnp.float32)
    logf = (jax.nn.log_sigmoid(
        jnp.einsum("bsd,dh->bsh", x, p["wf"]).astype(jnp.float32))
        + p["f_bias"])

    def step(carry, inp):
        z_t, o_t, li, lf = inp
        h, carry = _slstm_step_math(p, z_t, o_t, li, lf, carry)
        return carry, h

    c0 = jnp.zeros((B, H, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -30.0, jnp.float32)
    (cf, nf, mf), hs = jax.lax.scan(
        step, (c0, n0, m0),
        (jnp.moveaxis(z, 1, 0), jnp.moveaxis(o, 1, 0),
         jnp.moveaxis(logi, 1, 0), jnp.moveaxis(logf, 1, 0)),
        unroll=_unroll(cfg, S))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)       # (B,S,H,hd)
    out = jnp.einsum("bshk,hkd->bsd", h, p["wo"])
    return out, {"c": cf, "n": nf, "m": mf}


def slstm_init_state(p, cfg, batch, dtype):
    H, hd = cfg.n_heads, cfg.hd
    return {
        "c": jnp.zeros((batch, H, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), -30.0, jnp.float32),
    }


def slstm_step(p, cfg, x_t, state, ctx=None):
    z = jnp.einsum("bd,dhk->bhk", x_t[:, 0], p["wz"]).astype(jnp.float32)
    o = jnp.einsum("bd,dhk->bhk", x_t[:, 0], p["wo_gate"]).astype(jnp.float32)
    logi = jnp.einsum("bd,dh->bh", x_t[:, 0], p["wi"]).astype(jnp.float32)
    logf = (jax.nn.log_sigmoid(
        jnp.einsum("bd,dh->bh", x_t[:, 0], p["wf"]).astype(jnp.float32))
        + p["f_bias"])
    h, (c, n, m) = _slstm_step_math(
        p, z, o, logi, logf, (state["c"], state["n"], state["m"]))
    out = jnp.einsum("bhk,hkd->bd", h.astype(x_t.dtype), p["wo"])[:, None]
    return out, {"c": c, "n": n, "m": m}
