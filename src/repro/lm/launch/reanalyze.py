"""Recompute roofline terms from cached .hlo.gz files (no recompilation).

  PYTHONPATH=src python -m repro.lm.launch.reanalyze
"""
import glob
import gzip
import json
import os

from repro.lm.launch import hlo_analysis
from repro.lm.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS, RESULTS_DIR


def main():
    for jpath in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.gz")
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            continue
        with gzip.open(hpath, "rt") as zf:
            hlo = zf.read()
        ana = hlo_analysis.analyze(hlo)
        rec["per_device"] = {
            "flops": ana.flops, "bytes_accessed": ana.bytes_accessed,
            "collective_bytes": dict(ana.collective_bytes),
            "collective_total": ana.collective_total,
            "has_dynamic_loops": ana.has_dynamic_loops,
            "num_whiles": ana.num_whiles,
        }
        rec["roofline"] = {
            "compute_s": ana.flops / PEAK_FLOPS,
            "memory_s": ana.bytes_accessed / HBM_BW,
            "collective_s": ana.collective_total / ICI_BW,
        }
        t = rec["roofline"]
        dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
        rec["roofline"]["dominant"] = dom
        rec["roofline"]["bound_s"] = t[dom]
        if rec.get("model_flops"):
            g = ana.flops * rec["num_devices"]
            rec["useful_compute_ratio"] = rec["model_flops"] / g if g else None
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print(os.path.basename(jpath), "reanalyzed")


if __name__ == "__main__":
    main()
