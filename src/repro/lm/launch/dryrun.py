import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
for the 16×16 single-pod mesh AND the 2×16×16 multi-pod mesh, printing
``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes), and
parsing collective payload bytes from the compiled HLO — the §Roofline
inputs.

Results are cached as JSON under ``results/dryrun/`` (one file per cell)
so reruns and the benchmark harness are incremental.

Usage:
  PYTHONPATH=src python -m repro.lm.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.lm.launch.dryrun --all [--multi-pod] [--graph]
"""
import argparse
import gzip
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs import ARCHS, SHAPES, cell_applicable, get_config
from repro.lm.launch.mesh import make_ctx, make_production_mesh
from repro.lm.launch import specs as SP
from repro.lm.models.model import Model
from repro.sharding.specs import ShardCtx, sharding_for
from repro.lm.train.optimizer import AdamW, cosine_schedule
from repro.lm.train.train_step import TrainState, make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# --- hardware constants (TPU v5e-class, per brief) -------------------------
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op, by kind."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or (m.group(3) == "-done"):
            continue  # count -start (or plain), skip -done duplicates
        result_part, kind = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(result_part):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DT_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    return out


def _lower_cell(arch: str, shape_name: str, multi_pod: bool,
                rules: str = "default", opts: tuple = ()):
    import dataclasses
    cfg = get_config(arch)
    if opts:
        cfg = dataclasses.replace(cfg, opts=tuple(opts))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh, rules=rules)
    model = Model(cfg)
    batch = SP.input_specs(cfg, shape, ctx)

    if shape.kind == "train":
        params, axes = SP.abstract_params(model, ctx)
        opt = AdamW(lr=cosine_schedule(3e-4, 100, 10000))
        opt_shapes = SP.abstract_opt_state(opt, params, axes, ctx)
        step = make_train_step(model, opt, ctx)
        state = TrainState(params, opt_shapes, None)
        return jax.jit(step).lower(state, batch), mesh

    params, axes = SP.abstract_params(model, ctx)
    if shape.kind == "prefill":
        caches, _ = SP.abstract_caches(
            model, shape.global_batch,
            shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0),
            ctx)

        def prefill(p, b, c):
            return model.prefill(p, b, c, ctx)

        return jax.jit(prefill).lower(params, batch, caches), mesh

    # decode: one new token against a KV cache of seq_len
    max_len = shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)
    caches, _ = SP.abstract_caches(model, shape.global_batch, max_len, ctx)
    if cfg.family == "enc_dec":
        adt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        caches["enc"] = {
            "out": jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder.n_frames, cfg.d_model), adt,
                sharding=sharding_for(("act_batch", None, None), ctx,
                                      (shape.global_batch,
                                       cfg.encoder.n_frames, cfg.d_model))),
            "pos": jax.ShapeDtypeStruct((cfg.encoder.n_frames,), jnp.int32),
        }

    def decode(p, t, c, i):
        return model.decode_step(p, t, c, i, ctx)

    idx = jax.ShapeDtypeStruct((), jnp.int32)
    return jax.jit(decode).lower(params, batch["tokens"], caches, idx), mesh


def lower_graph_cell(multi_pod: bool, n_log2: int = 22, edge_factor: int = 16,
                     rpvo_max: int = 16, mode: str = "rhizome",
                     compact: bool = False):
    """The paper's own technique as a dry-run cell: BFS on an RMAT-<n_log2>
    scale partition, shard_map'd over the full mesh. Shapes are derived
    analytically (no 128M-edge host build)."""
    from repro.core import actions
    from repro.core.engine import DeviceArrays, EngineConfig, make_sharded_fn

    mesh = make_production_mesh(multi_pod=multi_pod)
    S = int(np.prod(list(mesh.shape.values())))
    axis_names = tuple(mesh.axis_names)
    n = 1 << n_log2
    E = edge_factor * n
    # analytic padded dims (balanced allocator ⇒ near-ideal)
    if mode == "rhizome":
        R_total = int(n * 1.02) + rpvo_max  # ~2% hub replicas (R22-like)
        E_max = int(np.ceil(E / S) * 1.05)
    elif mode == "rpvo":
        R_total = n
        E_max = int(np.ceil(E / S) * 1.05)
    else:  # 'simple': hub out-degree ~ n^0.55 concentrates on one shard
        R_total = n
        E_max = int(np.ceil(E / S) * 8)    # measured skew factor for R22
    R_max = int(np.ceil(R_total / S))
    K = rpvo_max if mode == "rhizome" else 1

    ecfg = EngineConfig(exchange="compact" if compact else "dense")
    fn, sharding = make_sharded_fn(
        actions.BFS, S, R_max, mesh, axis_names, ecfg)

    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)

    # compact-exchange plan shapes: distinct dsts per (src,tgt) bounded by
    # E_max/S with 2x pad for skew; rhizome table ~2% of slots
    P_t = max(int(np.ceil(E_max / S * 2)), 8)
    R_rz = max(int(np.ceil(R_max * 0.02)), 8) if mode == "rhizome" else 1
    arrays = DeviceArrays(
        edge_src_root_flat=sds((S, E_max), jnp.int32),
        edge_dst_flat=sds((S, E_max), jnp.int32),
        edge_w=sds((S, E_max), jnp.float32),
        edge_mask=sds((S, E_max), jnp.bool_),
        sibling_flat=sds((S, R_max, K), jnp.int32),
        sibling_mask=sds((S, R_max, K), jnp.bool_),
        slot_valid=sds((S, R_max), jnp.bool_),
        edge_dst_compact=sds((S, E_max), jnp.int32),
        inbox_slot_map=sds((S, S, P_t), jnp.int32),
        rz_local=sds((S, R_rz), jnp.int32),
        rz_sibling_idx=sds((S, R_rz, K), jnp.int32),
        rz_sibling_mask=sds((S, R_rz, K), jnp.bool_),
    )
    val = sds((S, R_max), jnp.float32)
    return fn.lower(arrays, val), mesh


def lower_pipeline_cell(n_micro: int = 8, mb: int = 32, d: int = 4096,
                        layers_per_stage: int = 4):
    """Pipeline-parallel proof cell: a 2-stage GPipe schedule over the
    'pod' axis of the production 2x16x16 mesh, transformer-MLP stages."""
    import jax.numpy as jnp
    from repro.sharding.pipeline import pipeline_apply
    from jax.sharding import PartitionSpec as P, NamedSharding

    mesh = make_production_mesh(multi_pod=True)

    def stage_fn(wp, x):  # wp: (layers_per_stage, d, 4d) + (..., 4d, d)
        w1, w2 = wp
        for i in range(layers_per_stage):
            h = jax.nn.gelu(x @ w1[i])
            x = x + h @ w2[i]
        return x

    fn = pipeline_apply(stage_fn, n_stages=2, n_micro=n_micro, mesh=mesh)
    sh = NamedSharding(mesh, P("pod"))
    w1 = jax.ShapeDtypeStruct((2, layers_per_stage, d, 4 * d), jnp.bfloat16,
                              sharding=sh)
    w2 = jax.ShapeDtypeStruct((2, layers_per_stage, 4 * d, d), jnp.bfloat16,
                              sharding=sh)
    x = jax.ShapeDtypeStruct((n_micro, mb, d), jnp.bfloat16,
                             sharding=NamedSharding(mesh, P()))
    return jax.jit(fn).lower((w1, w2), x), mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules: str = "default", force: bool = False,
             graph_mode: str | None = None, opts: tuple = ()) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}__{rules}"
    if opts:
        tag += "__" + "-".join(opts)
    path = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    rec: dict = {"arch": arch, "shape": shape_name,
                 "multi_pod": multi_pod, "rules": rules,
                 "opts": list(opts)}
    if graph_mode is None:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ok, reason = cell_applicable(cfg, shape)
        if not ok:
            rec["skipped"] = reason
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            return rec

    t0 = time.time()
    try:
        if graph_mode is not None:
            lowered, mesh = lower_graph_cell(
                multi_pod, mode=graph_mode, compact="compact" in opts)
        else:
            lowered, mesh = _lower_cell(arch, shape_name, multi_pod, rules,
                                        opts)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(mem, "generated_code_size_in_bytes", None),
        }
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        rec["xla_cost_raw"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}
        hlo = compiled.as_text()
        with gzip.open(path.replace(".json", ".hlo.gz"), "wt") as zf:
            zf.write(hlo)   # re-analyzable without recompiling
        # trip-count-aware per-device analysis (XLA's cost_analysis counts
        # while bodies once — useless under scan-over-layers)
        from repro.lm.launch import hlo_analysis
        ana = hlo_analysis.analyze(hlo)
        rec["num_devices"] = int(np.prod(list(mesh.shape.values())))
        chips = rec["num_devices"]
        rec["per_device"] = {
            "flops": ana.flops,
            "bytes_accessed": ana.bytes_accessed,
            "collective_bytes": dict(ana.collective_bytes),
            "collective_total": ana.collective_total,
            "has_dynamic_loops": ana.has_dynamic_loops,
            "num_whiles": ana.num_whiles,
        }
        rec["collectives"] = parse_collective_bytes(hlo)  # un-scaled reference
        # roofline terms: per-device program vs per-chip peaks
        rec["roofline"] = {
            "compute_s": ana.flops / PEAK_FLOPS,
            "memory_s": ana.bytes_accessed / HBM_BW,
            "collective_s": ana.collective_total / ICI_BW,
        }
        terms = rec["roofline"]
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: terms[k])
        rec["roofline"]["dominant"] = dom
        rec["roofline"]["bound_s"] = terms[dom]
        if graph_mode is None:
            mf = SP.model_flops(get_config(arch), SHAPES[shape_name])
            rec["model_flops"] = mf
            global_flops = ana.flops * chips
            rec["useful_compute_ratio"] = (
                (mf / global_flops) if global_flops else None)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--graph", action="store_true",
                    help="dry-run the graph engine cells")
    ap.add_argument("--pipeline", action="store_true",
                    help="dry-run the 2-stage GPipe cell on the 2x16x16 mesh")
    ap.add_argument("--graph-mode", default="rhizome",
                    choices=["rhizome", "rpvo", "simple"])
    ap.add_argument("--rules", default="default")
    ap.add_argument("--opts", default="",
                    help="comma list: moe_grouped,attn_chunked,chunked_ce,scan_unroll")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    pods = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.pipeline:
        import json as _json
        os.makedirs(RESULTS_DIR, exist_ok=True)
        t0 = time.time()
        lowered, mesh = lower_pipeline_cell()
        compiled = lowered.compile()
        rec = {"arch": "pipeline-gpipe2", "shape": "micro8x32x4096",
               "multi_pod": True, "ok": True,
               "compile_s": round(time.time() - t0, 1),
               "collectives": parse_collective_bytes(compiled.as_text())}
        with open(os.path.join(RESULTS_DIR, "pipeline-gpipe2.json"), "w") as f:
            _json.dump(rec, f, indent=1)
        print("pipeline-gpipe2 2x16x16 OK",
              {k: f"{v:.2e}" for k, v in rec["collectives"].items()})
        return
    if args.graph:
        for mp in pods:
            cells.append((f"graph-bfs-{args.graph_mode}", "rmat22", mp,
                          args.graph_mode))
    elif args.all:
        for arch in sorted(ARCHS):
            for shape in SHAPES:
                for mp in pods:
                    cells.append((arch, shape, mp, None))
    else:
        assert args.arch and args.shape
        for mp in pods:
            cells.append((args.arch, args.shape, mp, None))

    opts = tuple(o for o in args.opts.split(",") if o)
    for arch, shape, mp, gm in cells:
        rec = run_cell(arch, shape, mp, rules=args.rules, force=args.force,
                       graph_mode=gm, opts=opts)
        status = ("SKIP " + rec.get("skipped", "")) if "skipped" in rec else \
            ("OK" if rec.get("ok") else "FAIL " + rec.get("error", ""))
        r = rec.get("roofline", {})
        print(f"{arch:24s} {shape:12s} {'pod2' if mp else 'pod1'} "
              f"{status[:90]}"
              + (f"  comp={r.get('compute_s', 0):.3e}s "
                 f"mem={r.get('memory_s', 0):.3e}s "
                 f"coll={r.get('collective_s', 0):.3e}s "
                 f"dom={r.get('dominant', '')}" if r else ""))


if __name__ == "__main__":
    main()
