"""Training driver.

  PYTHONPATH=src python -m repro.lm.launch.train --arch minitron-4b --reduced \
      --steps 50 --global-batch 8 --seq-len 64

Full configs target the production mesh (--mesh data,model sizes must
match available devices); --reduced runs the smoke-scale variant on
whatever devices exist (CPU included). Checkpoints/restarts, async
saves, straggler monitoring and gradient compression are all flags.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.lm.launch.mesh import make_ctx
from repro.lm.models.model import Model
from repro.lm.train.optimizer import AdamW, cosine_schedule
from repro.lm.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="e.g. '4,2' => (data=4, model=2) over local devices")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)

    ctx = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split(","))
        dev = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
        mesh = jax.sharding.Mesh(dev, ("data", "model"))
        ctx = make_ctx(mesh)

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq_len,
                         global_batch=args.global_batch, seed=0)

    extra = None
    if cfg.family == "vlm":
        def extra(step):
            k = jax.random.PRNGKey(step)
            return {"patch_embeds": jax.random.normal(
                k, (args.global_batch, cfg.n_patches, cfg.d_model),
                jnp.float32)}
    elif cfg.family == "enc_dec":
        def extra(step):
            k = jax.random.PRNGKey(step)
            return {"frames": jax.random.normal(
                k, (args.global_batch, cfg.encoder.n_frames, cfg.d_model),
                jnp.float32)}

    opt = AdamW(lr=cosine_schedule(args.lr, warmup=10, total=args.steps))
    tcfg = TrainerConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir,
                         compress_grads=args.compress_grads, log_every=10)
    trainer = Trainer(model, opt, pipe, tcfg, ctx, extra_batch=extra)
    trainer.run()
    for row in trainer.history:
        print(",".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                       for k, v in row.items()))


if __name__ == "__main__":
    main()
