"""Abstract inputs (ShapeDtypeStructs) + shardings for every dry-run cell.

``input_specs(cfg, shape)`` returns weak-type-correct stand-ins for every
model input — no device allocation. ``abstract_train_state`` /
``abstract_serve_state`` do the same for params/opt/caches via eval_shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs.base import ModelConfig, ShapeSpec
from repro.lm.models.model import Model
from repro.sharding.specs import ShardCtx, sharding_for, spec_for
from repro.lm.train.optimizer import AdamW
from repro.lm.train.train_step import batch_axes, cache_axes_tree


def _sds(shape, dtype, axes=None, ctx: ShardCtx | None = None):
    sh = None
    if ctx is not None and ctx.mesh is not None and axes is not None:
        sh = sharding_for(axes, ctx, shape)
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                ctx: ShardCtx | None = None) -> dict:
    """Batch stand-ins for one cell. train/prefill: full (B, S) tokens;
    decode: (B, 1) next tokens."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    adt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    batch = {"tokens": _sds((B, S), jnp.int32, ("act_batch", None), ctx)}
    if shape.kind == "train":
        batch["labels"] = _sds((B, S), jnp.int32, ("act_batch", None), ctx)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patch_embeds"] = _sds(
            (B, cfg.n_patches, cfg.d_model), adt, ("act_batch", None, None), ctx)
    if cfg.family == "enc_dec" and shape.kind != "decode":
        batch["frames"] = _sds(
            (B, cfg.encoder.n_frames, cfg.d_model), adt,
            ("act_batch", None, None), ctx)
    return batch


def abstract_params(model: Model, ctx: ShardCtx | None = None):
    """(param ShapeDtypeStructs with shardings, logical axes tree)."""
    box = {}

    def f(key):
        params, axes = model.init(key)
        box["axes"] = axes
        return params

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    axes = box["axes"]
    if ctx is not None and ctx.mesh is not None:
        shapes = jax.tree.map(
            lambda s, a: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=sharding_for(a, ctx, s.shape)),
            shapes, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return shapes, axes


def abstract_opt_state(opt: AdamW, params_shapes, axes, ctx):
    shapes = jax.eval_shape(opt.init, params_shapes)
    if ctx is not None and ctx.mesh is not None:
        def shard_moments(tree):
            return jax.tree.map(
                lambda s, a: jax.ShapeDtypeStruct(
                    s.shape, s.dtype, sharding=sharding_for(a, ctx, s.shape)),
                tree, axes,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        shapes = shapes._replace(mu=shard_moments(shapes.mu),
                                 nu=shard_moments(shapes.nu))
    return shapes


def abstract_caches(model: Model, batch_size: int, max_len: int, ctx,
                    cache_dtype=None):
    shapes = jax.eval_shape(
        functools.partial(model.init_cache, batch_size, max_len,
                          cache_dtype=cache_dtype))
    axes = cache_axes_tree(shapes)
    if ctx is not None and ctx.mesh is not None:
        shapes = jax.tree.map(
            lambda s, a: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=sharding_for(a, ctx, s.shape)),
            shapes, axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return shapes, axes


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = B·1."""
    n_active = active_param_count(cfg)
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * n_active * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * n_active * D
    D = shape.global_batch * 1
    return 2.0 * n_active * D


def param_count(cfg: ModelConfig) -> float:
    model = Model(cfg)
    shapes, _ = abstract_params(model)
    return float(sum(np.prod(s.shape) for s in jax.tree.leaves(shapes)))


def active_param_count(cfg: ModelConfig) -> float:
    """Params touched per token (MoE: top_k of routed + shared + backbone)."""
    total = param_count(cfg)
    if cfg.moe is None:
        return total
    m = cfg.moe
    # routed expert params per MoE layer
    per_expert = 3 * cfg.d_model * m.d_expert_ff
    n_moe_layers = _num_moe_layers(cfg)
    routed_total = n_moe_layers * m.num_experts * per_expert
    routed_active = n_moe_layers * m.top_k * per_expert
    return total - routed_total + routed_active


def _num_moe_layers(cfg: ModelConfig) -> int:
    if cfg.family == "moe":
        return cfg.n_layers - cfg.moe.first_dense_layers
    if cfg.family == "hybrid":
        return cfg.n_layers // 2   # MoE every other layer
    return 0
