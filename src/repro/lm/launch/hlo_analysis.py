"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — under
scan-over-layers that undercounts FLOPs/bytes/collective payload by the
layer count (and by seq_len for recurrent scans). This module parses the
compiled HLO text, recovers each while's static trip count from its
condition (`compare(iv, constant), direction=LT`), and accumulates:

* dot FLOPs (2 · prod(result dims) · prod(contracting dims)),
* HBM traffic proxy: Σ over top-level ops of (result + operand bytes) —
  post-fusion, inter-op buffers are materialized, so this tracks real
  traffic (fusion-internal ops excluded by construction),
* collective payload bytes by kind,

each scaled by the product of enclosing-loop trip counts. Whiles whose
trip count is data-dependent (the graph engine's fixpoint) multiply by 1
and set ``has_dynamic_loops`` — their numbers are per-iteration.

All numbers are for the PER-DEVICE (SPMD-partitioned) program.
"""
from __future__ import annotations

import dataclasses
import re

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
             "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
             "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|"
    r"f8e5m2|c64|c128)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\bcall\(.*?\),\s*to_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(
    r"conditional\(.*?(?:true_computation=%?([\w\.\-]+),\s*"
    r"false_computation=%?([\w\.\-]+)|branch_computations={([^}]*)})")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims={([0-9,]*)}")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _first_shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        total += _shape_elems(dims) * _DT_BYTES[dt]
    return total


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    has_dynamic_loops: bool = False
    num_whiles: int = 0

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective_bytes.values()))


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    depth = 0
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                depth = stripped.count("{") - stripped.count("}")
                if depth <= 0:
                    cur = None
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list[str]) -> int | None:
    """Recover `iv < constant` trip counts. Returns None if data-dependent."""
    consts: dict[str, int] = {}
    cmp_const: int | None = None
    direction = None
    for line in cond_lines:
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rhs = mo.groups()
        mc = _CONST_RE.search(rhs)
        if rhs.lstrip().startswith(("s32[]", "s64[]", "u32[]", "u64[]")) and \
                "constant(" in rhs and mc:
            consts[name] = int(mc.group(1))
        if " compare(" in rhs or rhs.startswith("pred[] compare("):
            md = re.search(r"direction=(\w+)", rhs)
            direction = md.group(1) if md else None
            # operand names
            ops = re.findall(r"%([\w\.\-]+)", rhs.split("compare(", 1)[1])
            for op in ops:
                if op in consts:
                    cmp_const = consts[op]
    if cmp_const is not None and direction in ("LT", "GT", "LE", "GE", "NE"):
        return max(cmp_const, 1)
    return None


def _build_symtab(lines: list[str]) -> dict[str, tuple]:
    """op name -> (dims tuple of first result shape, bytes of result)."""
    tab: dict[str, tuple] = {}
    for line in lines:
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rhs = mo.groups()
        m0 = _SHAPE_RE.search(rhs.split("(", 1)[0]) or _SHAPE_RE.search(rhs)
        if m0:
            dims = tuple(int(d) for d in m0.group(2).split(",") if d)
            tab[name] = (dims, _first_shape_bytes(rhs.split(" ", 1)[0])
                         or _shape_elems(m0.group(2)) * _DT_BYTES[m0.group(1)])
    return tab



_FUSION_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_PARAM_IDX = re.compile(r"parameter\((\d+)\)")


def _fusion_bytes(operand_names, symtab, body_lines):
    """HBM traffic of a fusion: slice-aware reads + root-aware writes.

    A fusion param consumed (transitively through bitcast/reshape/copy/
    convert/transpose) only by dynamic-slice/gather reads just the slices;
    a param that is only the aliased destination of a dynamic-update-slice
    is not re-read; a DUS root (possibly behind a bitcast, or inside a
    tuple root) writes just the update. Keeps scan-carried stacked buffers
    (params stacks, activation stashes) from being charged at full size
    every loop iteration.
    """
    itab = _build_symtab(body_lines)
    producers: dict[str, tuple] = {}
    param_idx: dict[str, int] = {}
    root_name = None
    for line in body_lines:
        mo = _OP_RE.match(line)
        if not mo:
            continue
        name, rhs = mo.groups()
        opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        opn = opm.group(1) if opm else ""
        args = rhs.split(opn + "(", 1) if opn else [rhs]
        ops = (_OPERAND_RE.findall(args[1].split(")", 1)[0])
               if len(args) > 1 else [])
        producers[name] = (opn, ops, rhs)
        mp = _PARAM_IDX.search(rhs)
        if opn == "parameter" and mp:
            param_idx[name] = int(mp.group(1))
        if line.lstrip().startswith("ROOT"):
            root_name = name

    _TRANSPARENT = ("bitcast", "reshape", "copy", "transpose", "convert",
                    "broadcast")
    consumers: dict[str, list] = {}
    for name, (opn, ops, _) in producers.items():
        for o in ops:
            consumers.setdefault(o, []).append(name)

    def effective_consumers(pname):
        out = []
        stack = [pname]
        seen = set()
        while stack:
            cur = stack.pop()
            for c in consumers.get(cur, []):
                if c in seen:
                    continue
                seen.add(c)
                opn = producers[c][0]
                if opn in _TRANSPARENT:
                    stack.append(c)
                else:
                    out.append((opn, c, cur))
        return out

    read = 0.0
    for i, on in enumerate(operand_names):
        full = symtab.get(on, ((), 0.0))[1]
        pname = next((n for n, idx in param_idx.items() if idx == i), None)
        if pname is None:
            read += full
            continue
        eff = effective_consumers(pname)
        if eff and all(op in ("dynamic-slice", "gather") for op, _, _ in eff):
            sbytes = sum(itab.get(n, ((), 0.0))[1] for _, n, _ in eff)
            read += min(sbytes or full, full)
        elif eff and all(
                op == "dynamic-update-slice" and
                producers[n][1] and producers[n][1][0] == src
                for op, n, src in eff):
            pass  # pure aliased DUS destination: no read
        else:
            read += full

    def resolve(name, depth=0):
        if depth > 20 or name not in producers:
            return name
        opn, ops, _ = producers[name]
        if opn in ("bitcast", "reshape", "copy", "transpose", "convert") and ops:
            return resolve(ops[0], depth + 1)
        return name

    def write_bytes_of(name):
        rn = resolve(name)
        opn, ops, rhs = producers.get(rn, ("", [], ""))
        if opn == "dynamic-update-slice" and len(ops) > 1:
            return itab.get(ops[1], ((), 0.0))[1]
        if opn == "tuple":
            return sum(write_bytes_of(o) for o in ops)
        return itab.get(rn, ((), 0.0))[1]

    write = write_bytes_of(root_name) if root_name else 0.0
    return read + write


_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _line_costs(line: str, symtab: dict, comps: dict | None = None):
    """(flops, bytes, collective_kind_or_None, coll_bytes) for one op line."""
    mo = _OP_RE.match(line)
    if not mo:
        return 0.0, 0.0, None, 0.0
    rhs = mo.group(2)
    opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
    opname = opm.group(1) if opm else ""
    if opname in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id",
                  "while", "call", "conditional"):
        return 0.0, 0.0, None, 0.0

    # result bytes: all shapes before the op name (covers tuple results)
    pre = rhs.split(opname + "(", 1)[0] if opname else rhs
    result_bytes = _first_shape_bytes(pre)
    # operand bytes via symbol table
    args = rhs.split(opname + "(", 1)
    operand_bytes = 0.0
    operand_names = []
    if len(args) > 1:
        argstr = args[1].split("), ", 1)[0].split(")", 1)[0]
        operand_names = _OPERAND_RE.findall(argstr)
        for on in operand_names:
            if on in symtab:
                operand_bytes += symtab[on][1]
    nbytes = result_bytes + operand_bytes
    if opname == "fusion" and comps is not None:
        mc = _FUSION_CALLS.search(rhs)
        body = comps.get(mc.group(1)) if mc else None
        if body:
            nbytes = _fusion_bytes(operand_names, symtab, body)
    elif opname == "dynamic-update-slice":
        # aliased in-place: traffic = 2 x update slice
        upd = operand_names[1] if len(operand_names) > 1 else None
        if upd and upd in symtab:
            nbytes = 2.0 * symtab[upd][1]
    elif opname in ("dynamic-slice", "gather"):
        nbytes = 2.0 * result_bytes

    flops = 0.0
    if opname == "dot":
        m0 = _SHAPE_RE.search(pre)
        result_elems = _shape_elems(m0.group(2)) if m0 else 0
        mc = _DOT_CONTRACT.search(rhs)
        contract = 1
        if mc and operand_names and operand_names[0] in symtab:
            lhs_dims = symtab[operand_names[0]][0]
            for ci in mc.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    contract *= lhs_dims[int(ci)]
        flops = 2.0 * result_elems * contract
    elif opname == "convolution":
        m0 = _SHAPE_RE.search(pre)
        flops = 2.0 * (_shape_elems(m0.group(2)) if m0 else 0)

    coll_kind = None
    coll_bytes = 0.0
    for kind in _COLLECTIVES:
        if re.search(rf"\b{kind}(-start)?\(", rhs):
            if re.search(rf"\b{kind}-done\(", rhs):
                break  # counted at -start
            coll_kind = kind
            coll_bytes = result_bytes
            break
    return flops, nbytes, coll_kind, coll_bytes


def analyze(hlo: str) -> Analysis:
    comps = _split_computations(hlo)
    symtabs = {name: _build_symtab(lines) for name, lines in comps.items()}

    res = Analysis()
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry = m.group(1) if m else list(comps)[-1]

    def walk(comp: str, mult: float):
        lines = comps.get(comp)
        if lines is None:
            return
        tab = symtabs[comp]
        for line in lines:
            f, b, ck, cb = _line_costs(line, tab, comps)
            res.flops += f * mult
            res.bytes_accessed += b * mult
            if ck:
                res.collective_bytes[ck] = (
                    res.collective_bytes.get(ck, 0.0) + cb * mult)
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.groups()
                res.num_whiles += 1
                mt = _TRIP_RE.search(line)  # XLA backend_config, if present
                tc = int(mt.group(1)) if mt else _trip_count(
                    comps.get(cond, []))
                if tc is None:
                    res.has_dynamic_loops = True
                    tc = 1
                walk(body, mult * tc)
                walk(cond, mult * tc)
                continue
            if "fusion(" not in line:
                mc = _CALL_RE.search(line)
                if mc:
                    walk(mc.group(1), mult)
            md = _COND_RE.search(line)
            if md:
                branches = [g for g in md.groups()[:2] if g]
                if md.group(3):
                    branches += re.findall(r"%?([\w\.\-]+)", md.group(3))
                for br in branches:
                    walk(br, mult)  # upper bound: all branches

    walk(entry, 1.0)
    return res
