"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.lm.launch.serve --arch xlstm-125m --reduced \
      --batch 4 --prompt-len 16 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.lm.configs import get_config
from repro.lm.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params, _ = model.init(key)

    B, S = args.batch, args.prompt_len
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks}
    prefix = 0
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32)
        prefix = cfg.n_patches
    if cfg.family == "enc_dec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)

    max_len = prefix + S + args.gen
    caches = model.init_cache(B, max_len)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, caches = prefill(params, batch, caches)
    logits[0].block_until_ready()
    t_prefill = time.time() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.time()
    for i in range(args.gen):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, caches = decode(
            params, tok, caches, jnp.asarray(prefix + S + i, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} batch={B} prompt={S} gen={args.gen}")
    print(f"prefill_s={t_prefill:.3f} decode_s={t_decode:.3f} "
          f"tok_per_s={B * args.gen / max(t_decode, 1e-9):.1f}")
    for b in range(min(B, 2)):
        print(f"sample[{b}] generated ids: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
