"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state): 16×16 = 256 chips per pod, 2 pods = 512 chips
multi-pod. The dry-run forces 512 host devices via XLA_FLAGS before any
jax import; everything else sees the real device count.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.sharding.specs import ShardCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "(launch/dryrun.py does this) or on a real pod slice")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_ctx(mesh, rules: str = "default") -> ShardCtx:
    """Bind the ruleset's dp/tp roles to this mesh's axes."""
    names = mesh.axis_names
    dp = tuple(a for a in ("pod", "data") if a in names)
    tp = ("model",) if "model" in names else ()
    return ShardCtx(mesh=mesh, rules=rules, dp=dp, tp=tp)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
