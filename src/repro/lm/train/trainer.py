"""Training loop with checkpoint/restart, async saves, and elastic hooks.

The loop is deliberately boring: everything interesting lives in the
substrates it composes (train_step, CheckpointManager, TokenPipeline,
StragglerMonitor). ``run`` resumes from the latest valid checkpoint
automatically; a simulated failure raised by ``failure_hook`` exercises
the restore path in tests.
"""
from __future__ import annotations

import dataclasses
import time
import typing

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.lm.models.model import Model
from repro.runtime.elastic import StragglerMonitor
from repro.sharding.specs import ShardCtx
from repro.lm.train.optimizer import AdamW
from repro.lm.train.train_step import TrainState, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    async_ckpt: bool = True
    log_every: int = 10
    compress_grads: bool = False


class Trainer:
    def __init__(self, model: Model, opt: AdamW, pipeline: TokenPipeline,
                 tcfg: TrainerConfig, ctx: ShardCtx | None = None,
                 extra_batch: typing.Callable | None = None):
        self.model = model
        self.opt = opt
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.ctx = ctx
        self.extra_batch = extra_batch  # vlm/enc_dec stub inputs per step
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self.straggler = StragglerMonitor()
        self.step_fn = jax.jit(make_train_step(
            model, opt, ctx, compress_grads=tcfg.compress_grads))
        self.history: list[dict] = []

    def init_state(self, seed: int = 0) -> TrainState:
        params, _ = self.model.init(jax.random.PRNGKey(seed))
        residuals = None
        if self.tcfg.compress_grads:
            from repro.sharding import compression
            residuals = compression.init_residuals(params)
        return TrainState(params, self.opt.init(params), residuals)

    def run(self, state: TrainState | None = None,
            failure_hook: typing.Callable | None = None):
        if state is None:
            state = self.init_state()
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            start = latest
            state = self.ckpt.restore(latest, state)
        for step in range(start, self.tcfg.steps):
            if failure_hook is not None:
                failure_hook(step)  # may raise SimulatedFailure
            batch = self.pipeline.batch_at(step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if self.extra_batch is not None:
                batch.update(self.extra_batch(step))
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            self.straggler.record(self.pipeline.host_id, time.time() - t0)
            if (step + 1) % self.tcfg.log_every == 0 or step == start:
                self.history.append({"step": step + 1, **metrics})
            if (step + 1) % self.tcfg.ckpt_every == 0:
                self.ckpt.save(step + 1, state,
                               blocking=not self.tcfg.async_ckpt)
        self.ckpt.wait()
        return state


class SimulatedFailure(RuntimeError):
    pass
