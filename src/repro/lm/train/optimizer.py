"""AdamW with f32 moments, global-norm clipping, and ZeRO-style sharding.

Moments inherit each parameter's sharding (already FSDP+TP sharded by the
default ruleset ⇒ optimizer state is fully distributed — ZeRO-1/3 hybrid).
Pure functional: ``init``/``update`` pytrees, jit/pjit friendly.
"""
from __future__ import annotations

import dataclasses
import typing

import jax
import jax.numpy as jnp


class OptState(typing.NamedTuple):
    step: jax.Array
    mu: typing.Any          # first moment (f32)
    nu: typing.Any          # second moment (f32)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: typing.Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> OptState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                        nu=jax.tree.map(jnp.copy, zeros))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step, new_m, new_v), {
            "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
