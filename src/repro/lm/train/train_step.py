"""Sharded train / serve step factories.

``make_train_step`` builds the pjit-able step: value_and_grad over the
model loss, optional int8 gradient compression (error feedback carried in
the step state), AdamW update. Sharding comes from the params' logical
axes + ruleset; batch is DP-sharded; activations SP via the model's
internal constraints. GSPMD inserts the FSDP all-gathers/reduce-scatters.

``make_serve_steps`` builds the prefill and decode steps with a sharded
KV cache (sequence dim over 'tp' by default — exact for any kv-head
count, incl. MQA).
"""
from __future__ import annotations

import functools
import typing

import jax
import jax.numpy as jnp

from repro.lm.models.model import Model
from repro.sharding import compression
from repro.sharding.specs import ShardCtx, constrain, spec_for
from repro.lm.train.optimizer import AdamW


class TrainState(typing.NamedTuple):
    params: typing.Any
    opt: typing.Any
    residuals: typing.Any    # grad-compression error feedback (or None)


def make_train_step(model: Model, opt: AdamW, ctx: ShardCtx | None = None,
                    compress_grads: bool = False, accum_steps: int = 1):
    """Returns step(state: TrainState, batch) -> (state, metrics).

    ``accum_steps > 1`` splits the batch into microbatches and accumulates
    gradients under a rematerialized scan — peak activation memory scales
    with the microbatch, the update is numerically the full-batch mean."""

    def _grads(params, batch):
        def loss_fn(p):
            return model.loss(p, batch, ctx)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(state: TrainState, batch):
        if accum_steps > 1:
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = _grads(state.params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            gsum, (losses, ms) = jax.lax.scan(
                jax.checkpoint(body), zeros, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        else:
            (loss, metrics), grads = _grads(state.params, batch)
        residuals = state.residuals
        if compress_grads:
            grads, residuals = compression.compress_decompress(
                grads, residuals)
        new_params, new_opt, opt_metrics = opt.update(
            grads, state.opt, state.params)
        metrics = {**metrics, **opt_metrics}
        return TrainState(new_params, new_opt, residuals), metrics

    return step


def batch_axes(cfg) -> dict:
    """Logical axes for each batch field (DP batch, replicated seq)."""
    ax = {"tokens": ("act_batch", None), "labels": ("act_batch", None)}
    if cfg.family == "vlm":
        ax["patch_embeds"] = ("act_batch", None, None)
    if cfg.family == "enc_dec":
        ax["frames"] = ("act_batch", None, None)
    return ax


def cache_axes_tree(caches):
    """Logical axes for a cache pytree: KV tensors get their sequence dim
    sharded 'tp' (act_kv_seq), recurrent states shard the inner dim."""
    def axes_for(path, leaf):
        names = [getattr(p, "key", str(p)) for p in path]
        nd = leaf.ndim
        if names[-1] in ("k", "v"):
            # (layers, B, S, KV, hd)
            return ("layers", "act_batch", "act_kv_seq", "act_kv_heads", None)[:nd]
        if names[-1] in ("conv",):
            return ("layers", "act_batch", None, "act_mamba_inner")[:nd]
        if names[-1] in ("ssm",):
            return ("layers", "act_batch", "act_mamba_inner", None)[:nd]
        if names[-1] in ("C",):
            return ("layers", "act_batch", "act_heads", None, None)[:nd]
        if names[-1] in ("n", "c"):
            return ("layers", "act_batch", "act_heads", None)[:nd]
        if names[-1] in ("m",):
            return ("layers", "act_batch", "act_heads")[:nd]
        if names[-1] == "out":   # encoder output (B, F, d)
            return ("act_batch", None, None)[:nd]
        if names[-1] == "pos":
            return (None,)[:nd]
        return tuple([None] * nd)

    return jax.tree_util.tree_map_with_path(axes_for, caches)


def make_serve_steps(model: Model, ctx: ShardCtx | None = None):
    """Returns (prefill_fn, decode_fn)."""

    def prefill(params, batch, caches):
        return model.prefill(params, batch, caches, ctx)

    def decode(params, tokens_t, caches, index):
        logits, caches = model.decode_step(params, tokens_t, caches, index, ctx)
        return logits, caches

    return prefill, decode
