from repro.lm.train.optimizer import AdamW, OptState, cosine_schedule
from repro.lm.train.train_step import make_train_step

__all__ = ["AdamW", "OptState", "cosine_schedule", "make_train_step"]
