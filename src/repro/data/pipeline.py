"""Deterministic, resumable, host-sharded token pipeline.

``batch_at(step)`` is a pure function of (seed, step, host shard) — resume
after preemption is exact with no iterator state to persist, and every
host reads only its own slice of the global batch (data parallelism at
ingest). Sources: seeded synthetic Zipf tokens (default) or a memory-
mapped binary token file.

Straggler hook: ``fetch_with_deadline`` wraps ``batch_at`` with a timeout;
on expiry it substitutes the deterministic fallback batch and reports the
event to the elastic runtime instead of stalling the global step
(bounded-staleness ingest — see runtime/elastic.py).
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 0
    token_file: str | None = None
    zipf_a: float = 1.2

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.host_batch = self.global_batch // self.num_hosts
        self._mm = None
        if self.token_file:
            self._mm = np.memmap(self.token_file, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> dict:
        """Pure: (seed, step, host_id) -> {'tokens','labels'} int32 arrays."""
        if self._mm is not None:
            return self._file_batch(step)
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))
        z = rng.zipf(self.zipf_a, size=(self.host_batch, self.seq_len + 1))
        toks = (z % (self.vocab - 1) + 1).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _file_batch(self, step: int) -> dict:
        need = self.host_batch * (self.seq_len + 1)
        total = self._mm.size - need - 1
        offset = ((step * self.num_hosts + self.host_id)
                  * need) % max(total, 1)
        flat = np.asarray(self._mm[offset: offset + need], dtype=np.int32)
        toks = flat.reshape(self.host_batch, self.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    # ---------------------------------------------------------- straggler --
    def fetch_with_deadline(self, step: int, deadline_s: float = 5.0,
                            on_timeout=None) -> dict:
        """Fetch batch; on deadline expiry return the synthetic fallback and
        invoke ``on_timeout(step)`` (reported to the elastic runtime)."""
        result: dict = {}
        err: list = []

        def work():
            try:
                result.update(self.batch_at(step))
            except Exception as e:  # noqa: BLE001
                err.append(e)

        t = threading.Thread(target=work, daemon=True)
        t.start()
        t.join(deadline_s)
        if t.is_alive() or err:
            if on_timeout is not None:
                on_timeout(step)
            fallback = TokenPipeline(
                self.vocab, self.seq_len, self.global_batch,
                self.num_hosts, self.host_id, seed=self.seed + 993)
            return fallback.batch_at(step)
        return result
