"""MoE: routing invariants + grouped-vs-global equivalence (§Perf opt)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.lm.configs import get_config
from repro.lm.models import moe as M
from repro.lm.models.model import Model


def _setup(seed=0):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    model = Model(cfg)
    key = jax.random.PRNGKey(seed)
    p = M.init_moe(key, cfg, jnp.float32)
    from repro.lm.models.layers import split_tree
    params, _ = split_tree(p)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model))
    return cfg, params, x


def test_grouped_equals_global_at_g1():
    """With one group (no mesh), grouped routing must match global routing
    exactly — same capacity, same drops, same combine."""
    cfg, params, x = _setup()
    out_g, aux_g = M.apply_moe(params, cfg, x, None)
    cfg2 = dataclasses.replace(cfg, opts=("moe_grouped",))
    out_l, aux_l = M.apply_moe(params, cfg2, x, None)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_l),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(aux_g["moe_load_balance"]),
                               float(aux_l["moe_load_balance"]), rtol=1e-5)


def test_moe_output_is_mix_of_experts():
    """Permutation test: permuting tokens permutes outputs (routing is
    per-token)."""
    cfg, params, x = _setup(3)
    out, _ = M.apply_moe(params, cfg, x.reshape(1, 32, -1), None)
    perm = np.random.default_rng(0).permutation(32)
    out_p, _ = M.apply_moe(params, cfg, x.reshape(1, 32, -1)[:, perm], None)
    np.testing.assert_allclose(np.asarray(out[0, perm]), np.asarray(out_p[0]),
                               rtol=2e-5, atol=1e-5)


def test_capacity_drop_fraction_reported():
    cfg, params, x = _setup(5)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    out, aux = M.apply_moe(params, cfg, x, None)
    assert float(aux["moe_drop_fraction"]) > 0.0
    assert np.isfinite(np.asarray(out)).all()


def test_shared_experts_always_on():
    """deepseek-style shared experts contribute even when routed experts
    drop every token (capacity ~ 0)."""
    cfg = get_config("deepseek-moe-16b").reduced()
    key = jax.random.PRNGKey(0)
    from repro.lm.models.layers import split_tree
    p = M.init_moe(key, cfg, jnp.float32)
    params, _ = split_tree(p)
    x = jax.random.normal(key, (1, 8, cfg.d_model))
    cfg_tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e-9))
    out, aux = M.apply_moe(params, cfg_tiny, x, None)
    assert float(jnp.abs(out).sum()) > 0.0  # shared path is alive
