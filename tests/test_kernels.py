"""Pallas kernel vs pure-jnp oracle: shape/dtype sweep + property tests.

interpret=True executes the kernel body on CPU; the same pallas_call
compiles for TPU via Mosaic.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import segment_combine_ref
from repro.kernels.rhizome_segment_reduce import (
    EBLK, SBLK, segment_combine_pallas,
)


def _case(e, nseg, kind, dtype, sorted_ids, seed):
    rng = np.random.default_rng(seed)
    data = rng.uniform(-10, 10, size=e).astype(dtype)
    ids = rng.integers(0, nseg, size=e).astype(np.int32)
    if sorted_ids:
        ids = np.sort(ids)
    return jnp.asarray(data), jnp.asarray(ids)


SHAPES = [
    (1, 1), (7, 3), (100, 17), (EBLK, SBLK), (EBLK + 1, SBLK + 1),
    (2 * EBLK + 13, 2 * SBLK + 5), (EBLK - 1, 1000), (3000, 5),
]


@pytest.mark.parametrize("kind", ["min", "sum"])
@pytest.mark.parametrize("e,nseg", SHAPES)
@pytest.mark.parametrize("sorted_ids", [True, False])
def test_kernel_matches_ref_f32(kind, e, nseg, sorted_ids):
    data, ids = _case(e, nseg, kind, np.float32, sorted_ids, seed=e * 7 + nseg)
    got = segment_combine_pallas(data, ids, nseg, kind, interpret=True)
    want = segment_combine_ref(data, ids, nseg, kind)
    rtol = 1e-6 if kind == "min" else 1e-5
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol,
                               atol=1e-6)


@pytest.mark.parametrize("kind", ["min", "sum"])
def test_kernel_bf16(kind):
    """bf16 inputs: kernel accumulates in f32 (preferred_element_type), so
    compare against the f32 oracle at bf16 resolution."""
    data, ids = _case(777, 300, kind, np.float32, True, seed=1)
    data_bf = data.astype(jnp.bfloat16)
    got = segment_combine_pallas(data_bf, ids, 300, kind, interpret=True)
    want = segment_combine_ref(data_bf.astype(jnp.float32), ids, 300, kind)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=8e-2, atol=0.5)


@pytest.mark.parametrize("kind", ["min", "sum"])
def test_empty_segments_hold_identity(kind):
    data = jnp.asarray([1.0, 2.0, 3.0], jnp.float32)
    ids = jnp.asarray([5, 5, 9], jnp.int32)
    got = np.asarray(segment_combine_pallas(data, ids, 12, kind, interpret=True))
    identity = np.inf if kind == "min" else 0.0
    for s in range(12):
        if s not in (5, 9):
            assert got[s] == identity


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(1, 700),
    nseg=st.integers(1, 400),
    kind=st.sampled_from(["min", "sum"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_property(e, nseg, kind, seed):
    data, ids = _case(e, nseg, kind, np.float32, True, seed)
    got = segment_combine_pallas(data, ids, nseg, kind, interpret=True)
    want = segment_combine_ref(data, ids, nseg, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_engine_with_pallas_kernel_matches():
    """End-to-end: the engine flag routes the inbox reduce through Pallas."""
    from repro.apps import bfs
    from repro.core import engine
    from repro.graph import generators, reference

    g = generators.erdos_renyi(150, avg_degree=4.0, seed=21)
    root = int(g.src[0])
    want = reference.bfs_levels(g, root)
    got, _, _ = bfs(g, root, num_shards=4, rpvo_max=2,
                    cfg=engine.EngineConfig(use_pallas=True))
    np.testing.assert_array_equal(got, want)
