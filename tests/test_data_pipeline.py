"""Data pipeline: determinism, resumability, host sharding, straggler path."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import TokenPipeline


def test_deterministic_and_resumable():
    p1 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=3)
    p2 = TokenPipeline(vocab=100, seq_len=16, global_batch=8, seed=3)
    for step in (0, 5, 917):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # labels are next-token shifted
    b = p1.batch_at(0)
    assert b["tokens"].shape == (8, 16)


def test_host_sharding_disjoint():
    hosts = [TokenPipeline(vocab=1000, seq_len=8, global_batch=16,
                           num_hosts=4, host_id=h, seed=1) for h in range(4)]
    batches = [p.batch_at(3)["tokens"] for p in hosts]
    assert all(b.shape == (4, 8) for b in batches)
    # different hosts see different data
    assert not np.array_equal(batches[0], batches[1])


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10**6), seed=st.integers(0, 2**30))
def test_tokens_in_vocab_property(step, seed):
    p = TokenPipeline(vocab=97, seq_len=12, global_batch=4, seed=seed)
    b = p.batch_at(step)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 97
    assert b["labels"].min() >= 0 and b["labels"].max() < 97


def test_file_backed(tmp_path):
    data = np.arange(10000, dtype=np.int32) % 50
    f = tmp_path / "tokens.bin"
    data.tofile(str(f))
    p = TokenPipeline(vocab=50, seq_len=8, global_batch=4,
                      token_file=str(f))
    b0 = p.batch_at(0)
    b1 = p.batch_at(1)
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(p.batch_at(0)["tokens"], b0["tokens"])


def test_straggler_deadline_fallback():
    p = TokenPipeline(vocab=100, seq_len=8, global_batch=4, seed=1)
    timeouts = []

    real_batch_at = p.batch_at
    def slow(step):
        import time
        time.sleep(2.0)
        return real_batch_at(step)
    p.batch_at = slow

    b = p.fetch_with_deadline(0, deadline_s=0.1,
                              on_timeout=lambda s: timeouts.append(s))
    assert timeouts == [0]
    assert b["tokens"].shape == (4, 8)  # fallback batch, not a stall
