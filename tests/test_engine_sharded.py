"""run_sharded (shard_map + real collectives) equals run_stacked + oracle.

Real multi-device collectives need >1 device, and XLA locks the device
count at first init — so the multi-device check runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_sharded_single_device_mesh():
    """shard_map path on the trivial 1-device mesh."""
    import jax
    from jax.sharding import Mesh
    from repro.apps import bfs
    from repro.graph import generators
    from repro.graph import reference

    g = generators.erdos_renyi(200, avg_degree=4.0, seed=0)
    root = int(g.src[0])
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    got, stats, _ = bfs(g, root, num_shards=1, mesh=mesh)
    want = reference.bfs_levels(g, root)
    np.testing.assert_array_equal(got, want)


CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.apps import bfs, sssp
    from repro.core import engine
    from repro.graph import generators, reference

    assert len(jax.devices()) == 8
    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

    g = generators.ba_skewed(300, m_per=4, seed=9).with_random_weights(seed=9)
    root = int(g.src[0])

    # BFS, with rhizomes, sharded over 8 real host devices
    got, stats, part = bfs(g, root, num_shards=8, rpvo_max=4, mesh=mesh)
    want = reference.bfs_levels(g, root)
    np.testing.assert_array_equal(got, want)
    assert int(stats.messages) > 0

    # SSSP with deferred collapse
    gotd, _, _ = sssp(g, root, num_shards=8, rpvo_max=4, mesh=mesh,
                      cfg=engine.EngineConfig(collapse="deferred"))
    np.testing.assert_allclose(gotd, reference.sssp_dijkstra(g, root),
                               rtol=1e-5, atol=1e-5)
    print("SHARDED_OK")
""")


def test_sharded_eight_devices_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # pin the child to CPU: with libtpu present, backend autodetect
    # stalls on (unreachable) TPU metadata; these meshes are CPU
    # host devices by construction (xla_force_host_platform_device_count)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD], env=env, capture_output=True, text=True,
        timeout=420,
    )
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "SHARDED_OK" in out.stdout


CHILD_COMPACT = CHILD.replace(
    "from repro.core import engine",
    "from repro.core import engine").replace(
    "bfs(g, root, num_shards=8, rpvo_max=4, mesh=mesh)",
    "bfs(g, root, num_shards=8, rpvo_max=4, mesh=mesh,\n"
    "                      cfg=engine.EngineConfig(exchange='compact'))").replace(
    "cfg=engine.EngineConfig(collapse=\"deferred\")",
    "cfg=engine.EngineConfig(collapse='deferred', exchange='compact')")


def test_sharded_compact_exchange_subprocess():
    """The §Perf compact targeted exchange computes identical fixpoints
    under real 8-device collectives."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # pin the child to CPU: with libtpu present, backend autodetect
    # stalls on (unreachable) TPU metadata; these meshes are CPU
    # host devices by construction (xla_force_host_platform_device_count)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD_COMPACT], env=env, capture_output=True,
        text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "SHARDED_OK" in out.stdout


CHILD_PALLAS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.apps import bfs, pagerank, sssp
    from repro.core import engine
    from repro.graph import generators, reference

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    g = generators.ba_skewed(260, m_per=4, seed=9).with_random_weights(seed=9)
    root = int(np.argmax(g.out_degrees()))
    want = reference.bfs_levels(g, root)

    for exch in ("dense", "compact"):
        cfg = engine.EngineConfig(exchange=exch, use_pallas=True)
        # fused kernel under real 8-device shard_map == stacked fused run
        sh, sh_stats, _ = bfs(g, root, num_shards=8, rpvo_max=4,
                              mesh=mesh, cfg=cfg)
        st, st_stats, _ = bfs(g, root, num_shards=8, rpvo_max=4, cfg=cfg)
        np.testing.assert_array_equal(sh, want)
        np.testing.assert_array_equal(sh, st)
        assert int(sh_stats.messages) == int(st_stats.messages)
        assert int(sh_stats.pruned_actions) == int(st_stats.pruned_actions)
        d_sh, _, _ = sssp(g, root, num_shards=8, rpvo_max=4,
                          mesh=mesh, cfg=cfg)
        d_st, _, _ = sssp(g, root, num_shards=8, rpvo_max=4, cfg=cfg)
        np.testing.assert_array_equal(d_sh, d_st)
        np.testing.assert_allclose(d_sh, reference.sssp_dijkstra(g, root),
                                   rtol=1e-5, atol=1e-5)
        # sharded PageRank (sum semiring; compact now supported) vs oracle
        pr_sh, _ = pagerank(g, iters=12, num_shards=8, rpvo_max=4,
                            mesh=mesh, cfg=cfg)
        pr_st, _ = pagerank(g, iters=12, num_shards=8, rpvo_max=4, cfg=cfg)
        np.testing.assert_allclose(pr_sh, reference.pagerank(g, iters=12),
                                   rtol=1e-4, atol=1e-7)
        np.testing.assert_allclose(pr_sh, pr_st, rtol=1e-5, atol=1e-9)
    print("SHARDED_PALLAS_OK")
""")


def test_sharded_fused_pallas_subprocess():
    """The fused relax+reduce kernel inside shard_map over 8 real host
    devices: BFS and PageRank, dense and compact, vs the stacked run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # pin the child to CPU: with libtpu present, backend autodetect
    # stalls on (unreachable) TPU metadata; these meshes are CPU
    # host devices by construction (xla_force_host_platform_device_count)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD_PALLAS], env=env, capture_output=True,
        text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "SHARDED_PALLAS_OK" in out.stdout
