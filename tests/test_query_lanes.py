"""Lane-batched multi-query execution (ISSUE 2 tentpole).

Covers the acceptance matrix: the laned fused kernel vs its jnp oracle
(mixed BFS/SSSP lanes via lane_unitw, sum lanes, OR-frontier chunk
bitmap), exactness — a K-query mixed batch is bit-identical to K
independent ``engine.run_stacked`` runs for both use_pallas paths,
stacked and sharded — per-lane round/message stats, converged-lane
inertness, and the lane-built apps (connected components, multi-source
BFS/SSSP, personalized PageRank) vs numpy references.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.apps import (
    batched_queries, bfs, cc, multi_source_bfs, personalized_pagerank, sssp,
)
from repro.core import actions, engine
from repro.core.partition import PartitionConfig, build_partition
from repro.graph import generators, reference
from repro.kernels.fused_relax_reduce import (
    EBLK, _chunk_tables_lanes, fused_relax_reduce_lanes_pallas,
)
from repro.kernels.ref import fused_relax_reduce_lanes_ref
from repro.query.lanes import (
    _lane_round_stacked, init_lane_values, run_stacked_lanes,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _lane_case(v, e, nseg, q, frontier_frac, seed):
    rng = np.random.default_rng(seed)
    gval = rng.uniform(0.0, 10.0, (v, q)).astype(np.float32)
    gchg = rng.random((v, q)) < frontier_frac
    unitw = (rng.random(q) < 0.5).astype(np.int32)
    src = rng.integers(0, v, e).astype(np.int32)
    w = rng.uniform(0.1, 2.0, e).astype(np.float32)
    mask = rng.random(e) < 0.9
    ids = np.sort(rng.integers(0, nseg, e).astype(np.int32))
    return tuple(jnp.asarray(x)
                 for x in (gval, gchg, unitw, src, w, mask, ids))


# --------------------------------------------------------------------------
# laned kernel vs laned oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("relax,kind", [("add_w", "min"), ("mul_w", "sum")])
@pytest.mark.parametrize("v,e,nseg,q", [
    (1, 1, 1, 1), (60, 90, 40, 3), (200, EBLK + 7, 300, 5),
])
def test_lanes_kernel_matches_ref(relax, kind, v, e, nseg, q):
    gval, gchg, unitw, src, w, mask, ids = _lane_case(
        v, e, nseg, q, 0.4, seed=e + q)
    got = fused_relax_reduce_lanes_pallas(
        gval, gchg, unitw, src, w, mask, ids, nseg, relax, kind,
        interpret=True)
    want = fused_relax_reduce_lanes_ref(
        gval, gchg, unitw, src, w, mask, ids, nseg, relax, kind)
    if kind == "min":
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    else:
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_lanes_kernel_converged_lane_is_inert():
    """A lane with an all-False frontier column contributes identity
    everywhere while live lanes still reduce — the per-lane convergence
    mask the server relies on."""
    gval, gchg, unitw, src, w, mask, ids = _lane_case(
        120, 2 * EBLK + 3, 150, 4, 0.5, seed=9)
    gchg = gchg.at[:, 2].set(False)           # lane 2 converged
    got, counts = fused_relax_reduce_lanes_pallas(
        gval, gchg, unitw, src, w, mask, ids, 150, "add_w", "min",
        interpret=True, with_count=True)
    got = np.asarray(got)
    assert np.all(got[:, 2] == np.inf)
    assert int(counts[2]) == 0
    live = [q for q in range(4) if q != 2]
    assert np.isfinite(got[:, live]).any()
    want = fused_relax_reduce_lanes_ref(
        gval, gchg, unitw, src, w, mask, ids, 150, "add_w", "min")
    np.testing.assert_array_equal(got, np.asarray(want))


def test_lanes_chunk_bitmap_is_or_across_lanes():
    """The frontier chunk-skip bit is the OR across active lanes: a chunk
    is dead only when no lane has a changed source in it."""
    v, q = 64, 3
    e_pad = 2 * EBLK
    rng = np.random.default_rng(3)
    src_p = jnp.asarray(rng.integers(0, v, e_pad).astype(np.int32))
    ids_p = jnp.asarray(np.sort(rng.integers(0, 50, e_pad)).astype(np.int32))
    mask_i = jnp.ones(e_pad, jnp.int32)
    # lane 0 active only in chunk 0's sources, lane 1 only in chunk 1's
    gchg = np.zeros((v, q), np.int32)
    gchg[np.asarray(src_p)[:EBLK], 0] = 1
    gchg[np.asarray(src_p)[EBLK:], 1] = 1
    _, _, chunk_act, counts, _ = _chunk_tables_lanes(
        ids_p, src_p, mask_i, jnp.asarray(gchg))
    assert np.asarray(chunk_act).tolist() == [1, 1]   # OR keeps both live
    assert int(counts[2]) == 0                        # lane 2 fully dead
    dead = jnp.zeros((v, q), jnp.int32)
    _, _, act_dead, _, _ = _chunk_tables_lanes(ids_p, src_p, mask_i, dead)
    assert np.asarray(act_dead).tolist() == [0, 0]


def test_lanes_kernel_rejects_non_absorbing_pairing():
    gval, gchg, unitw, src, w, mask, ids = _lane_case(
        30, 50, 20, 2, 0.5, seed=1)
    with pytest.raises(ValueError, match="non-absorbing"):
        fused_relax_reduce_lanes_pallas(
            gval, gchg, unitw, src, w, mask, ids, 20, "add_w", "sum",
            interpret=True)


# --------------------------------------------------------------------------
# exactness: K-lane mixed batch == K independent run_stacked runs
# --------------------------------------------------------------------------

def _mixed_workload(seed=4):
    g = generators.rmat(8, edge_factor=4, seed=seed).with_random_weights(
        seed=seed)
    deg = np.argsort(-g.out_degrees())
    roots = [int(deg[i]) for i in (0, 1, 2, 7)]
    queries = [("bfs", roots[0]), ("sssp", roots[1]),
               ("bfs", roots[2]), ("sssp", roots[3])]
    return g, queries


@pytest.mark.parametrize("use_pallas", [False, True])
def test_lane_batch_bit_identical_to_solo_stacked(use_pallas):
    g, queries = _mixed_workload()
    cfg = engine.EngineConfig(use_pallas=use_pallas)
    res, stats, part = batched_queries(g, queries, num_shards=4, rpvo_max=2,
                                       cfg=cfg)
    for q, ((kind, root), got) in enumerate(zip(queries, res)):
        solo_fn = bfs if kind == "bfs" else sssp
        solo, solo_stats, _ = solo_fn(g, root, part=part, cfg=cfg)
        np.testing.assert_array_equal(got, solo)    # bit-identical (min)
        # per-lane stats == the solo run's Fig-6 counters
        assert int(stats.rounds[q]) == int(solo_stats.iterations)
        assert int(stats.messages[q]) == int(solo_stats.messages)
        ref = (reference.bfs_levels(g, root) if kind == "bfs"
               else reference.sssp_dijkstra(g, root))
        if kind == "bfs":
            np.testing.assert_array_equal(got, ref)
        else:
            finite = np.isfinite(ref)
            np.testing.assert_allclose(got[finite], ref[finite], rtol=1e-5)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_lane_batch_sharded_matches_stacked(use_pallas):
    """Laned shard_map on the trivial 1-device mesh == the stacked laned
    run (the real 8-device check runs in the subprocess test below)."""
    from jax.sharding import Mesh
    g, queries = _mixed_workload(seed=6)
    cfg = engine.EngineConfig(use_pallas=use_pallas)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    res_sh, st_sh, part = batched_queries(g, queries, num_shards=1,
                                          rpvo_max=2, mesh=mesh, cfg=cfg)
    res_st, st_st, _ = batched_queries(g, queries, part=part, cfg=cfg)
    for a, b in zip(res_sh, res_st):
        np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(np.asarray(st_sh.rounds),
                                  np.asarray(st_st.rounds))
    np.testing.assert_array_equal(np.asarray(st_sh.messages),
                                  np.asarray(st_st.messages))


CHILD_LANES = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.apps import batched_queries
    from repro.core import engine
    from repro.graph import generators

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
    g = generators.rmat(8, edge_factor=4, seed=6).with_random_weights(seed=6)
    deg = np.argsort(-g.out_degrees())
    queries = [("bfs", int(deg[0])), ("sssp", int(deg[1])),
               ("bfs", int(deg[2])), ("sssp", int(deg[7]))]
    for use_pallas in (False, True):
        cfg = engine.EngineConfig(use_pallas=use_pallas)
        sh, st_sh, part = batched_queries(g, queries, num_shards=8,
                                          rpvo_max=4, mesh=mesh, cfg=cfg)
        st, st_st, _ = batched_queries(g, queries, part=part, cfg=cfg)
        for a, b in zip(sh, st):
            np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(np.asarray(st_sh.rounds),
                                      np.asarray(st_st.rounds))
        np.testing.assert_array_equal(np.asarray(st_sh.messages),
                                      np.asarray(st_st.messages))
    print("LANES_SHARDED_OK")
""")


def test_lane_batch_eight_devices_subprocess():
    """Laned fixpoint under real 8-device shard_map collectives equals
    the stacked laned run, jnp and fused."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # pin the child to CPU: with libtpu present, backend autodetect stalls
    # on (unreachable) TPU metadata; these are CPU host devices
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", CHILD_LANES], env=env, capture_output=True,
        text=True, timeout=420)
    assert out.returncode == 0, f"stdout={out.stdout}\nstderr={out.stderr}"
    assert "LANES_SHARDED_OK" in out.stdout


def test_converged_lane_stays_frozen_across_extra_rounds():
    """Drive the laned round past one lane's convergence: the converged
    column must stay bit-stable while the other lane keeps relaxing."""
    g = generators.ring(64).with_random_weights(seed=0)
    part = build_partition(g, PartitionConfig(num_shards=4, rpvo_max=1))
    # lane 0: seeded one hop from the wrap point -> converges in ~2 rounds?
    # on a directed ring every BFS takes n-1 rounds; instead make lane 0
    # converge instantly by seeding EVERY vertex at 0 (no improvement
    # possible), lane 1 a genuine BFS from vertex 0
    init, unitw = init_lane_values(
        part, [("bfs", {v: 0.0 for v in range(64)}), ("bfs", 0)])
    arrays = engine.DeviceArrays.from_partition(part)
    val = jnp.asarray(init)
    chg = actions.SSSP.improved(val, jnp.full_like(val, jnp.inf)) \
        & arrays.slot_valid[..., None]
    cfg = engine.EngineConfig(use_pallas=True)
    frozen = None
    for rnd in range(6):
        val, chg, _ = _lane_round_stacked(
            actions.SSSP, arrays, cfg, part.S, part.R_max,
            jnp.asarray(unitw), val, chg)
        lane0_live = bool(np.asarray(chg)[..., 0].any())
        if rnd == 0:
            assert not lane0_live        # all-zero seed converges round 1
            frozen = np.asarray(val)[..., 0].copy()
        else:
            np.testing.assert_array_equal(np.asarray(val)[..., 0], frozen)
            assert bool(np.asarray(chg)[..., 1].any())   # ring BFS still live
    assert frozen is not None


def test_lane_runner_rejects_unsupported_configs():
    g = generators.ring(16)
    part = build_partition(g, PartitionConfig(num_shards=2))
    init = np.full((part.S, part.R_max, 1), np.inf, np.float32)
    with pytest.raises(ValueError, match="eager"):
        run_stacked_lanes(part, init,
                          cfg=engine.EngineConfig(collapse="deferred"))
    with pytest.raises(ValueError, match="fused-only"):
        run_stacked_lanes(part, init,
                          cfg=engine.EngineConfig(use_pallas=True,
                                                  pallas_mode="reduce"))
    with pytest.raises(ValueError, match="min-semiring"):
        run_stacked_lanes(part, init, sem=actions.PAGERANK)
    # the BFS semiring's own relax is 'add_one', which the laned round
    # (hardcoded 'add_w' + lane_unitw) would silently mis-execute on a
    # weighted graph — it must be rejected, BFS lanes use lane_unitw=1
    with pytest.raises(ValueError, match="lane_unitw"):
        run_stacked_lanes(part, init, sem=actions.BFS)
    with pytest.raises(ValueError, match=r"\(S, R_max, Q\)"):
        run_stacked_lanes(part, init[..., 0])


# --------------------------------------------------------------------------
# lane-built apps vs numpy references
# --------------------------------------------------------------------------

@pytest.mark.parametrize("use_pallas", [False, True])
def test_connected_components_matches_reference(use_pallas):
    g = generators.erdos_renyi(220, avg_degree=2.0, seed=11)
    labels, stats, _ = cc(g, num_shards=4, rpvo_max=2,
                          cfg=engine.EngineConfig(use_pallas=use_pallas))
    np.testing.assert_array_equal(labels, reference.connected_components(g))
    assert int(stats.rounds[0]) > 1


def test_connected_components_disconnected_graph():
    """Two disjoint rings -> two labels (the min vertex id of each)."""
    from repro.graph.graph import COOGraph
    r = 20
    src = np.concatenate([np.arange(r), np.arange(r) + r])
    dst = np.concatenate([(np.arange(r) + 1) % r,
                          (np.arange(r) + 1) % r + r]).astype(np.int32)
    g = COOGraph(2 * r, src.astype(np.int32), dst, None)
    labels, _, _ = cc(g, num_shards=4)
    assert set(labels[:r]) == {0} and set(labels[r:]) == {r}


def test_multi_source_bfs_is_min_over_solo_runs():
    g = generators.rmat(8, edge_factor=4, seed=13)
    deg = np.argsort(-g.out_degrees())
    roots = [int(deg[0]), int(deg[3]), int(deg[9])]
    got, _, _ = multi_source_bfs(g, roots, num_shards=4)
    solo = np.stack([reference.bfs_levels(g, r) for r in roots])
    np.testing.assert_array_equal(got, solo.min(axis=0))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_personalized_pagerank_lanes_match_reference(use_pallas):
    g = generators.rmat(7, edge_factor=5, seed=3)
    deg = np.argsort(-g.out_degrees())
    seeds = [int(deg[0]), int(deg[2])]
    dampings = [0.85, 0.6]
    scores, stats, _ = personalized_pagerank(
        g, seeds, dampings, num_shards=4, rpvo_max=2, tol=1e-9,
        cfg=engine.EngineConfig(use_pallas=use_pallas))
    for q, (s, d) in enumerate(zip(seeds, dampings)):
        want = reference.personalized_pagerank(g, s, d, tol=1e-12)
        np.testing.assert_allclose(scores[:, q], want, rtol=1e-4, atol=1e-7)
    # the lower-damping lane contracts faster -> strictly fewer rounds
    assert int(stats.rounds[1]) <= int(stats.rounds[0])
